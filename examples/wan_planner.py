"""The 'Globus service' planning loop: auto chunk-size + mover allocation.

Uses the calibrated simulator as the cost model to (1) pick the chunk size
for a 500 GB transfer (paper §6 asks for exactly this automation) and
(2) split 64 movers across competing transfers by marginal benefit.

Run: PYTHONPATH=src python examples/wan_planner.py
"""
from repro.core.chunker import MiB, plan_auto
from repro.core.scheduler import TransferRequest, allocate
from repro.core.simulator import ALCF, NERSC, predict_transfer_time

GB = 10 ** 9

# 1. automated chunk-size selection for 1x500GB ALCF -> NERSC
cost = lambda chunk: predict_transfer_time(  # noqa: E731
    ALCF, NERSC, 500 * GB, chunk_bytes=chunk, integrity=True)
plan = plan_auto(500 * GB, movers=64, cost_model=cost)
print(f"auto plan: chunk={plan.chunk_bytes/MiB:.0f} MiB, {plan.n_chunks} chunks "
      f"(predicted {cost(plan.chunk_bytes):.0f}s vs "
      f"{predict_transfer_time(ALCF, NERSC, 500*GB, chunk_bytes=None):.0f}s un-chunked)")

# 2. mover allocation across a mixed workload
reqs = [
    TransferRequest("cosmology-restart", ALCF, NERSC, (500 * GB,)),
    TransferRequest("climate-ensemble", ALCF, NERSC, tuple([2 * GB] * 100)),
    TransferRequest("checkpoint-sync", ALCF, NERSC, tuple([10 * GB] * 4)),
]
for a in allocate(reqs, total_movers=64, policy="marginal"):
    print(f"  {a.request.name:20s} movers={a.movers:3d} "
          f"predicted={a.predicted_seconds:7.0f}s  {a.predicted_gbps:6.1f} Gb/s")
