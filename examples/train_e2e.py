"""End-to-end training driver: ~100M-param model, checkpointed, restartable.

Default invocation trains a reduced ~2M model for 60 steps (a couple of
minutes on CPU) so the example is actually runnable here; pass --full for a
~100M-parameter gemma-style model and a few hundred steps — the same code
path the dry-run lowers at 256/512 devices.

Run: PYTHONPATH=src python examples/train_e2e.py [--full]
"""
import sys
import tempfile

from repro.launch.train import main

full = "--full" in sys.argv
with tempfile.TemporaryDirectory() as ckpt:
    args = [
        "--arch", "gemma-2b", "--mesh", "1x1",
        "--ckpt-dir", ckpt, "--lr", "3e-3",
    ]
    if full:
        # ~100M params: use the real gemma-2b config shrunk to 6 layers/512 d
        args += ["--steps", "300", "--seq-len", "256", "--global-batch", "8",
                 "--ckpt-every", "50", "--log-every", "10"]
    else:
        args += ["--smoke", "--steps", "60", "--seq-len", "64",
                 "--global-batch", "8", "--ckpt-every", "20", "--log-every", "10"]
    out = main(args)
    print(f"\nloss {out['losses'][0]:.3f} -> {out['final_loss']:.3f} "
          f"over {len(out['losses'])} steps")
    assert out["final_loss"] < out["losses"][0]
