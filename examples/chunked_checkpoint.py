"""Chunked, integrity-checked checkpointing with corruption detection.

Run: PYTHONPATH=src python examples/chunked_checkpoint.py
"""
import os
import tempfile

import jax.numpy as jnp

from repro.ckpt import CheckpointManager, CorruptionError

tree = {
    "wte": jnp.ones((32000, 256), jnp.bfloat16),
    "blocks": {"w1": jnp.full((8, 256, 1024), 0.5, jnp.bfloat16),
               "w2": jnp.full((8, 1024, 256), 0.25, jnp.bfloat16)},
}

with tempfile.TemporaryDirectory() as root:
    mgr = CheckpointManager(root, keep=2)
    rep = mgr.save(100, tree)
    print(f"saved step 100: {rep.total_bytes/1e6:.1f} MB, {rep.n_leaves} leaves, "
          f"{rep.seconds:.2f}s")

    got, step = mgr.restore()
    print(f"restored step {step}: leaves {sorted(got)} — all chunk digests verified")

    # silent corruption: flip one byte in one leaf
    victim = os.path.join(root, "step_00000100", "wte.bin")
    with open(victim, "r+b") as fh:
        fh.seek(12345)
        b = fh.read(1)
        fh.seek(12345)
        fh.write(bytes([b[0] ^ 0x80]))
    try:
        mgr.restore()
    except CorruptionError as e:
        print(f"corruption detected -> leaf {e.leaf!r}, chunks {e.bad_chunks} "
              "(repair = re-fetch those byte ranges only)")
