"""Quickstart: client-driven chunking in 40 lines.

Moves a 'large file' (an in-memory payload) with 8 data movers, per-chunk
integrity fingerprints computed in the same pass, a journal for partial
restart, and an end-to-end digest verification — the paper's §3 pipeline.

Run: PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import (
    BufferDest, BufferSource, ChunkedTransfer, fingerprint_bytes, plan_chunks,
)

MiB = 1024 * 1024

# 1. the "file": 256 MiB of bytes
rng = np.random.default_rng(0)
payload = rng.integers(0, 256, 256 * MiB, dtype=np.uint8).tobytes()
expected = fingerprint_bytes(payload)
print(f"payload: {len(payload)/MiB:.0f} MiB, digest {expected.hexdigest()[:16]}…")

# 2. the client-driven plan (the Globus service's role): 8 movers, pipelined
plan = plan_chunks(len(payload), movers=8, pipeline_depth=4,
                   min_chunk=1 * MiB, max_chunk=32 * MiB)
print(f"plan: {plan.n_chunks} chunks x ~{plan.chunk_bytes/MiB:.0f} MiB "
      f"over {plan.movers} movers")

# 3. run the transfer: movers pull chunks (work stealing), fingerprint
#    per chunk, verify on write-back
dst = BufferDest(len(payload))
report = ChunkedTransfer(BufferSource(payload), dst, plan, integrity=True).run()

# 4. per-chunk digests merge into the file digest (ERET/ESTO checksums, §3.2)
assert report.file_digest == expected
assert bytes(dst.buf) == payload
print(f"moved {report.total_bytes/MiB:.0f} MiB in {report.seconds:.2f}s "
      f"({report.gbps:.2f} Gb/s) — end-to-end digest verified")
