"""Batched serving example: greedy decode with KV caches (gemma2 smoke).

Run: PYTHONPATH=src python examples/serve_batch.py
"""
from repro.launch.serve import main

seqs = main(["--arch", "gemma2-2b", "--smoke", "--batch", "4",
             "--prompt-len", "8", "--gen", "24"])
print("shapes:", seqs.shape)
