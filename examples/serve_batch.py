"""Submit a mixed batch of transfers through the TransferService.

Creates a handful of small files and one large file for each of two tenants,
submits them in one request per tenant (the Batcher coalesces the small ones
and routes the large one to its own chunked task), streams lifecycle events,
and prints the per-task report — including the per-item integrity digests
the movers computed in-line with the data movement.

Run: PYTHONPATH=src python examples/serve_batch.py
"""
import os
import tempfile

import numpy as np

from repro.core.chunker import MiB
from repro.service import BatchConfig, ServiceConfig, TransferService

root = tempfile.mkdtemp(prefix="transferd-")
datadir = os.path.join(root, "data")
os.makedirs(datadir)
rng = np.random.default_rng(0)

svc = TransferService(
    os.path.join(root, "state"),
    ServiceConfig(
        mover_budget=8,
        max_concurrent_tasks=4,
        policy="marginal",
        chunk_bytes=512 * 1024,
        batch=BatchConfig(direct_bytes=4 * MiB, batch_files=8),
    ),
)
svc.subscribe(lambda e: e.kind in ("ACTIVATED", "SUCCEEDED", "FAILED")
              and print(f"  [event] {e.kind:9s} {e.task_id} ({e.tenant})"))

task_ids = []
for tenant in ("alice", "bob"):
    items = []
    for i in range(10):                                   # small files -> batched
        p = os.path.join(datadir, f"{tenant}-{i}.bin")
        with open(p, "wb") as fh:
            fh.write(rng.integers(0, 256, 256 * 1024 + i, dtype=np.uint8).tobytes())
        items.append((p, p + ".out"))
    big = os.path.join(datadir, f"{tenant}-big.bin")      # large file -> own task
    with open(big, "wb") as fh:
        fh.write(rng.integers(0, 256, 8 * MiB, dtype=np.uint8).tobytes())
    items.append((big, big + ".out"))
    ids = svc.submit(items, tenant=tenant, label="mixed-batch")
    print(f"{tenant}: 11 files submitted as {len(ids)} tasks: {ids}")
    task_ids += ids

print("\nper-task report:")
for st in svc.wait_all(task_ids, timeout=120):
    print(f"  {st.task_id:22s} {st.state:9s} tenant={st.tenant:5s} "
          f"files={st.n_files:2d} bytes={st.bytes_done:>9d} "
          f"chunks={st.chunks_done}/{st.chunks_total} latency={st.latency_s:.2f}s")
    for rep in st.item_reports[:2]:
        print(f"      {os.path.basename(rep.dst):20s} digest={rep.digest_hex[:24]}…")

svc.close()
print("\nall tasks complete; service state in", root)
