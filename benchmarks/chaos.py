"""Chaos conformance benchmark: N-seed fault campaigns over the whole stack.

Runs the ``repro.faults`` scenario matrix (silent corruption at scaled
Globus-log rates, mover deaths mid-chunk, endpoint outage windows, stalls,
torn journal tails — alone and composed) against

  * the REAL threaded chunked-transfer engine (``core.transfer``), including
    a crash + torn-journal + restart leg per campaign,
  * the REAL multi-tenant service (``repro.service``) on the compound
    campaign, including a kill() + restart leg, and
  * the VIRTUAL-time testbed (``service.testbed``) across the full matrix,

and reports, per scenario aggregated over seeds:

  * ``escapes``             — integrity escapes: final destination bytes that
    differ from the source after recovery. MUST be 0.
  * ``re_moved_journaled``  — journaled (fsync'd, verified) chunks that a
    restarted engine/service moved again. MUST be 0.
  * ``corrupt_writes`` / ``healed`` — every corrupt chunk landing must be
    caught by the read-back digest and healed by a source re-fetch
    (healed == corrupt_writes, enforced).
  * ``goodput_retention``   — faulted vs fault-free throughput.
  * ``retry_amplification`` — chunk move attempts / chunks needed.

Prints ``name,value,unit`` CSV like the other benchmarks, writes
``BENCH_chaos.json`` (metrics + seeds + git rev) for trajectory tracking,
and exits non-zero on any conformance violation, so CI can gate on it.

Run: PYTHONPATH=src python -m benchmarks.chaos [--seeds N] [--quick]
"""
from __future__ import annotations

import argparse
import os
import sys
import tempfile
import threading
import time

import numpy as np

from benchmarks._results import emit
from repro.core import (
    BufferSource,
    ChunkJournal,
    ChunkedTransfer,
    FileDest,
    plan_chunks,
)
from repro.faults import FULL_MATRIX, FaultCampaign, parse_scenario, tear_journal_tail
from repro.service import BatchConfig, ServiceConfig, TransferService, run_load
from repro.service.testbed import Submission


# ---------------------------------------------------------------------------
# real-engine campaigns
# ---------------------------------------------------------------------------
class _HostCrash(Exception):
    """The crash bomb: simulates the host dying mid-transfer (leg 2 setup)."""


def _payload(seed: int, nbytes: int) -> bytes:
    return np.random.default_rng(seed).integers(0, 256, nbytes, dtype=np.uint8).tobytes()


def _engine_run(payload, plan, campaign, jpath, *, injector=None, max_retries=3,
                dedup_index=None):
    dst = FileDest(jpath + ".out", len(payload))
    journal = ChunkJournal(jpath)
    try:
        eng = ChunkedTransfer(
            campaign.wrap_source(BufferSource(payload)),
            campaign.wrap_dest(dst),
            plan,
            journal=journal,
            max_retries=max_retries,
            fault_injector=injector,
            dedup_index=dedup_index,
            dedup_target=(jpath + ".out") if dedup_index is not None else "",
        )
        report = eng.run()
    finally:
        journal.close()
    with open(jpath + ".out", "rb") as fh:
        final = fh.read()
    return report, final


def engine_campaign(expr: str, seed: int, *, nbytes: int, chunk: int, movers: int,
                    clean_seconds: float, tmpdir: str) -> dict:
    scenario = parse_scenario(expr).scaled_to(nbytes, target_events=4.0)
    payload = _payload(seed, nbytes)
    plan = plan_chunks(nbytes, movers, chunk_bytes=chunk, min_chunk=1, max_chunk=1 << 50)
    out = dict(escapes=0, re_moved_journaled=0, corrupt_writes=0, healed=0,
               mover_deaths=0, outage_rejections=0, stale_demotions=0,
               amplification=1.0, retention=1.0)

    # ---- leg A: full faulted transfer (no crash): escapes + healed + timing
    camp = FaultCampaign(scenario, total_bytes=nbytes, seed=seed, movers=movers)
    attempts = [0]
    lock = threading.Lock()

    def count(_chunk, _attempt):
        with lock:
            attempts[0] += 1

    # stale-index leg setup: a clean pre-pass populates a chunk index, then
    # seeded victim entries get their backing bytes corrupted — leg A runs
    # its dedup negotiation against an index that lies about what it holds
    dedup_index = None
    if scenario.stale_index:
        from repro.cas import ChunkIndex
        from repro.faults import corrupt_index_backing

        donor = os.path.join(tmpdir, f"donor-{expr.replace('+', '_')}-{seed}")
        dedup_index = ChunkIndex(donor + ".idx")
        camp_pre = FaultCampaign(parse_scenario("clean"),
                                 total_bytes=nbytes, seed=seed)
        _engine_run(payload, plan, camp_pre, donor + ".journal",
                    dedup_index=dedup_index)
        corrupt_index_backing(dedup_index, count=scenario.stale_index,
                              seed=seed, stats=camp.stats)

    ja = os.path.join(tmpdir, f"A-{expr.replace('+', '_')}-{seed}.journal")
    t0 = time.perf_counter()
    report, final = _engine_run(payload, plan, camp, ja, injector=count,
                                dedup_index=dedup_index)
    secs = time.perf_counter() - t0
    if dedup_index is not None:
        out["stale_demotions"] += report.dedup_demoted
        dedup_index.close()
    out["escapes"] += int(final != payload)
    out["corrupt_writes"] += camp.stats.corrupt_writes
    out["healed"] += report.refetches
    out["mover_deaths"] += report.mover_deaths
    out["outage_rejections"] += camp.stats.outage_rejections
    out["amplification"] = attempts[0] / max(1, plan.n_chunks)
    out["retention"] = min(1.0, clean_seconds / secs) if secs > 0 else 1.0

    # ---- leg B: crash mid-transfer (+ torn tail), restart, count re-moves
    jb = os.path.join(tmpdir, f"B-{expr.replace('+', '_')}-{seed}.journal")
    camp1 = FaultCampaign(scenario, total_bytes=nbytes, seed=seed + 101, movers=movers)
    bomb_after = max(1, plan.n_chunks // 2)
    calls = [0]

    def bomb(_chunk, _attempt):
        with lock:
            calls[0] += 1
            if calls[0] > bomb_after:
                raise _HostCrash("host died mid-transfer")

    try:
        _engine_run(payload, plan, camp1, jb, injector=bomb, max_retries=0)
    except (_HostCrash, RuntimeError):
        pass                     # the crash (or a fault it raced) is the point
    if scenario.torn_journal and os.path.exists(jb):
        tear_journal_tail(jb, seed=seed)
    probe = ChunkJournal(jb)     # replay stops at the torn record, repairs tail
    journaled = set(probe.records)
    probe.close()

    camp2 = FaultCampaign(scenario.replace(torn_journal=False),
                          total_bytes=nbytes, seed=seed + 202, movers=movers)
    moved2: list[int] = []

    def record(chunk, _attempt):
        with lock:
            moved2.append(chunk.index)

    report2, final2 = _engine_run(payload, plan, camp2, jb, injector=record)
    out["escapes"] += int(final2 != payload)
    out["re_moved_journaled"] += len(set(moved2) & journaled)
    out["corrupt_writes"] += camp2.stats.corrupt_writes
    out["healed"] += report2.refetches
    return out


# ---------------------------------------------------------------------------
# real-service campaign (compound scenario + kill/restart leg)
# ---------------------------------------------------------------------------
def service_campaign(expr: str, seed: int, *, nbytes: int, tmpdir: str) -> dict:
    scenario = parse_scenario(expr)
    root = os.path.join(tmpdir, f"svc-{expr.replace('+', '_')}-{seed}")
    os.makedirs(root, exist_ok=True)
    rng = np.random.default_rng(seed)
    items = []
    for i in range(2):
        p = os.path.join(root, f"src{i}.bin")
        with open(p, "wb") as fh:
            fh.write(rng.integers(0, 256, nbytes, dtype=np.uint8).tobytes())
        items.append((p, p + ".out"))
    total = 2 * nbytes
    scenario = scenario.scaled_to(total, target_events=4.0)
    cfg = ServiceConfig(
        mover_budget=4, max_concurrent_tasks=2, chunk_bytes=32 * 1024,
        tick_s=0.002, retry_backoff_s=0.001,
        batch=BatchConfig(direct_bytes=1 << 30, batch_files=64),
    )
    out = dict(escapes=0, re_moved_journaled=0, corrupt_writes=0, healed=0,
               mover_deaths=0)

    # ---- leg A: faulted submit -> SUCCEEDED
    sizes = [os.path.getsize(p) for p, _ in items]
    camp = FaultCampaign(scenario, total_bytes=total, seed=seed,
                         movers=cfg.mover_budget, item_bytes=sizes)
    svc = TransferService(os.path.join(root, "svcA"), cfg,
                          source_wrapper=camp.service_source_wrapper,
                          dest_wrapper=camp.service_dest_wrapper)
    try:
        [tid] = svc.submit(items, batch=False)
        st = svc.wait(tid, timeout=120)
        ok = st.state == "SUCCEEDED"
        for src, dst in items:
            with open(src, "rb") as a, open(dst, "rb") as b:
                ok = ok and a.read() == b.read()
        out["escapes"] += int(not ok)
        out["corrupt_writes"] += camp.stats.corrupt_writes
        out["healed"] += st.refetches
        out["mover_deaths"] += st.mover_deaths
    finally:
        svc.close()

    # ---- leg B: kill mid-flight (+ torn journal), restart, count re-moves
    for _src, dst in items:
        if os.path.exists(dst):
            os.remove(dst)
    rootB = os.path.join(root, "svcB")
    pace = lambda *_a: time.sleep(0.003)  # noqa: E731
    svc1 = TransferService(rootB, cfg, fault_injector=pace)
    [tid] = svc1.submit(items, batch=False)
    deadline = time.monotonic() + 60
    while svc1.status(tid).chunks_done < 4 and time.monotonic() < deadline:
        time.sleep(0.002)
    svc1.kill()
    jpath = svc1.store.journal_path(tid)
    if scenario.torn_journal and os.path.exists(jpath):
        tear_journal_tail(jpath, seed=seed)
    probe = ChunkJournal(jpath)
    journaled = set(probe.records)
    probe.close()

    camp2 = FaultCampaign(scenario.replace(torn_journal=False),
                          total_bytes=total, seed=seed + 77,
                          movers=cfg.mover_budget, item_bytes=sizes)
    moved2: list[tuple] = []
    lock = threading.Lock()

    def record(task_id, item_idx, chunk, _attempt):
        with lock:
            moved2.append((task_id, item_idx, chunk.offset))

    svc2 = TransferService(rootB, cfg, fault_injector=record,
                           source_wrapper=camp2.service_source_wrapper,
                           dest_wrapper=camp2.service_dest_wrapper)
    try:
        st = svc2.wait(tid, timeout=120)
        ok = st.state == "SUCCEEDED"
        for src, dst in items:
            with open(src, "rb") as a, open(dst, "rb") as b:
                ok = ok and a.read() == b.read()
        out["escapes"] += int(not ok)
        # global chunk ids: offsets within item i start at chunk_base[i]
        t = svc2._tasks[tid]
        gidx = {(i, c.offset): t.chunk_base[i] + c.index
                for i, plan in enumerate(t.plans) for c in plan.chunks}
        moved_g = {gidx[(i, off)] for (_tid, i, off) in moved2}
        out["re_moved_journaled"] += len(moved_g & journaled)
        out["corrupt_writes"] += camp2.stats.corrupt_writes
        out["healed"] += st.refetches
        out["mover_deaths"] += st.mover_deaths
    finally:
        svc2.close()
    return out


# ---------------------------------------------------------------------------
# virtual-time testbed campaigns
# ---------------------------------------------------------------------------
def testbed_workload(quick: bool):
    GB = 10**9
    n = 8 if quick else 16
    subs = [Submission(0.0, f"t{k % 3}", (20 * GB,)) for k in range(n)]
    subs.append(Submission(0.0, "t3", tuple([2 * GB] * 8)))
    return subs


def testbed_campaign(expr: str, seed: int, *, work, clean_makespan: float) -> dict:
    scenario = parse_scenario(expr)
    total = sum(sum(s.file_bytes) for s in work)
    scenario = scenario.scaled_to(total, target_events=8.0)
    try:
        rep = run_load(
            work, policy="marginal", mover_budget=32, max_concurrent=8,
            chunk_bytes=500 * 10**6,
            batch=BatchConfig(direct_bytes=10**9, batch_files=16),
            scenario=scenario, seed=seed,
        )
    except RuntimeError:
        # run_load raises (deadlock / convergence guard) rather than
        # returning unfinished tasks — report it as the conformance failure
        # it is instead of crashing the sweep
        return dict(unfinished=1, amplification=1.0, retention=0.0, corruptions=0)
    return dict(
        unfinished=0,
        amplification=rep.retry_amplification,
        retention=min(1.0, clean_makespan / rep.makespan_s) if rep.makespan_s else 1.0,
        corruptions=rep.faults.corruptions,
    )


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------
def _merge(agg: dict, one: dict) -> None:
    for k, v in one.items():
        agg[k] = agg.get(k, 0) + v


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--seeds", type=int, default=5)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--force", action="store_true",
                    help="overwrite a BENCH result from a different git rev")
    args = ap.parse_args(argv)
    t_start = time.perf_counter()

    nbytes = (1 * 1024 * 1024 + 4093) if args.quick else (3 * 1024 * 1024 + 4093)
    chunk, movers = 96 * 1024, 8
    svc_bytes = 96 * 1024 if args.quick else 256 * 1024
    rows: list[tuple[str, float, str]] = []
    violations: list[str] = []

    with tempfile.TemporaryDirectory(prefix="chaos-") as tmpdir:
        # clean engine reference timing
        plan = plan_chunks(nbytes, movers, chunk_bytes=chunk, min_chunk=1, max_chunk=1 << 50)
        payload = _payload(0, nbytes)
        camp0 = FaultCampaign(parse_scenario("clean"), total_bytes=nbytes, seed=0)
        t0 = time.perf_counter()
        _engine_run(payload, plan, camp0, os.path.join(tmpdir, "clean.journal"))
        clean_secs = time.perf_counter() - t0

        # ---- real engine: full matrix x seeds
        for expr in FULL_MATRIX:
            agg: dict = {}
            amps, rets = [], []
            for seed in range(args.seeds):
                one = engine_campaign(
                    expr, seed, nbytes=nbytes, chunk=chunk, movers=movers,
                    clean_seconds=clean_secs, tmpdir=tmpdir,
                )
                amps.append(one.pop("amplification"))
                rets.append(one.pop("retention"))
                _merge(agg, one)
            pre = f"chaos/engine/{expr}"
            rows.append((f"{pre}/escapes", agg["escapes"], "chunks"))
            rows.append((f"{pre}/re_moved_journaled", agg["re_moved_journaled"], "chunks"))
            rows.append((f"{pre}/corrupt_writes", agg["corrupt_writes"], "events"))
            rows.append((f"{pre}/healed_by_refetch", agg["healed"], "events"))
            rows.append((f"{pre}/mover_deaths", agg["mover_deaths"], "movers"))
            rows.append((f"{pre}/stale_demotions", agg.get("stale_demotions", 0), "chunks"))
            rows.append((f"{pre}/retry_amplification", round(sum(amps) / len(amps), 3), "x"))
            rows.append((f"{pre}/goodput_retention", round(sum(rets) / len(rets), 3), "frac"))
            if agg["escapes"]:
                violations.append(f"engine/{expr}: {agg['escapes']} integrity escapes")
            if parse_scenario(expr).stale_index and not agg.get("stale_demotions"):
                violations.append(
                    f"engine/{expr}: stale index entries were never demoted to "
                    f"wire moves (the lying index went unprobed)")
            if agg["re_moved_journaled"]:
                violations.append(
                    f"engine/{expr}: {agg['re_moved_journaled']} journaled chunks re-moved")
            if agg["healed"] != agg["corrupt_writes"]:
                violations.append(
                    f"engine/{expr}: {agg['corrupt_writes']} corrupt writes but "
                    f"{agg['healed']} healed by re-fetch")

        # ---- real service: compound + torn campaigns x seeds
        for expr in ("corrupt_1_per_TiB+kill_2_movers+outage_at_50pct",
                     "corrupt_1_per_TiB+torn_journal_tail"):
            agg = {}
            for seed in range(args.seeds):
                _merge(agg, service_campaign(expr, seed, nbytes=svc_bytes, tmpdir=tmpdir))
            pre = f"chaos/service/{expr}"
            rows.append((f"{pre}/escapes", agg["escapes"], "tasks"))
            rows.append((f"{pre}/re_moved_journaled", agg["re_moved_journaled"], "chunks"))
            rows.append((f"{pre}/corrupt_writes", agg["corrupt_writes"], "events"))
            rows.append((f"{pre}/healed_by_refetch", agg["healed"], "events"))
            rows.append((f"{pre}/mover_deaths", agg["mover_deaths"], "movers"))
            if agg["escapes"]:
                violations.append(f"service/{expr}: {agg['escapes']} integrity escapes")
            if agg["re_moved_journaled"]:
                violations.append(
                    f"service/{expr}: {agg['re_moved_journaled']} journaled chunks re-moved")
            if agg["healed"] != agg["corrupt_writes"]:
                violations.append(
                    f"service/{expr}: {agg['corrupt_writes']} corrupt writes but "
                    f"{agg['healed']} healed by re-fetch")

        # ---- virtual testbed: full matrix x seeds
        work = testbed_workload(args.quick)
        clean = run_load(
            work, policy="marginal", mover_budget=32, max_concurrent=8,
            chunk_bytes=500 * 10**6, batch=BatchConfig(direct_bytes=10**9, batch_files=16),
        )
        for expr in FULL_MATRIX:
            amps, rets, unfin, corr = [], [], 0, 0
            for seed in range(args.seeds):
                one = testbed_campaign(expr, seed, work=work, clean_makespan=clean.makespan_s)
                amps.append(one["amplification"])
                rets.append(one["retention"])
                unfin += one["unfinished"]
                corr += one["corruptions"]
            pre = f"chaos/testbed/{expr}"
            rows.append((f"{pre}/failed_campaigns", unfin, "runs"))
            rows.append((f"{pre}/corruptions", corr, "events"))
            rows.append((f"{pre}/retry_amplification", round(sum(amps) / len(amps), 4), "x"))
            rows.append((f"{pre}/goodput_retention", round(sum(rets) / len(rets), 3), "frac"))
            if unfin:
                violations.append(f"testbed/{expr}: {unfin} campaigns failed to converge")

    total_escapes = sum(v for n, v, _u in rows if n.endswith("/escapes"))
    total_re_moved = sum(v for n, v, _u in rows if n.endswith("/re_moved_journaled"))
    rows.append(("chaos/total_escapes", total_escapes, "chunks"))
    rows.append(("chaos/total_re_moved_journaled", total_re_moved, "chunks"))
    rows.append(("chaos/seeds", args.seeds, "seeds"))

    print("name,value,unit")
    for name, val, unit in rows:
        print(f"{name},{val},{unit}")
    path = emit("chaos", rows,
                args={"quick": args.quick, "seeds": list(range(args.seeds))},
                elapsed_s=round(time.perf_counter() - t_start, 3),
                force=args.force)
    print(f"# wrote {path}")
    if violations:
        print("\nCONFORMANCE VIOLATIONS:", file=sys.stderr)
        for v in violations:
            print(f"  - {v}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
