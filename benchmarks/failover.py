"""Resilience-plane benchmark: failover availability, scrub repair, breakers.

Three gated legs:

  * **Rolling-outage storm** — N relay tasks cross a diamond fabric while a
    seeded storm kills intermediate DTNs mid-flight (every task loses the
    DTN its planned route crosses; some lose a second one). The no-failover
    baseline must FAIL under this storm (the route is pinned, the outage
    budget exhausts); the failover plane must deliver ``availability``
    >= 95% by re-planning around the dead node with custody handoff:
    chunks already journaled at the last healthy DTN become the new source.
    Gates: availability >= 0.95, baseline fails, 0 integrity escapes,
    0 re-moved journaled chunks (the custody-handoff invariant).

  * **Scrub repair** — a service lands the same payload at two replicas
    (CAS-indexed), then seeded bit-rot flips bytes inside landed, verified
    regions of one replica (``corrupt_landed_regions``). The scrub daemon
    must detect 100% of the flips against the journal digests and repair
    every one from the surviving replica. Gates: rot_detected == injected,
    repaired == injected, 0 quarantines, final bytes == origin bytes.

  * **Breaker determinism** — two HealthTrackers with the same seed, driven
    by the same scripted outcome stream, must produce byte-identical
    transition logs and rejection schedules (the circuit breaker is
    op-count based and seeded — wall clocks never enter the state machine).

Prints ``name,value,unit`` CSV, writes ``BENCH_failover.json``, exits
non-zero on any gate violation so CI can gate on it.

Run: PYTHONPATH=src python -m benchmarks.failover [--quick] [--seeds N]
"""
from __future__ import annotations

import argparse
import os
import random
import sys
import tempfile
import threading
import time

import numpy as np

from benchmarks._results import emit
from repro.core import FileDest
from repro.core.transfer import BufferSource, EndpointOutage
from repro.fabric.relay import RelayTransfer
from repro.fabric.topology import Endpoint, RoutePlanner, Topology
from repro.faults import corrupt_landed_regions
from repro.faults.injectors import _seed_int
from repro.resil import BreakerConfig, HealthTracker
from repro.service import ServiceConfig, TransferService


# ---------------------------------------------------------------------------
# leg 1: rolling-outage storm over relay routes
# ---------------------------------------------------------------------------
INTERMEDIATES = ("dtnA", "dtnB", "dtnC")


def _storm_topology() -> Topology:
    """Diamond fabric: origin -> {A,B,C} -> final, A fastest (the planned
    route), B and C the survivors failover must discover."""
    topo = Topology()
    topo.add_endpoint(Endpoint("origin"))
    topo.add_endpoint(Endpoint("final"))
    topo.add_endpoint(Endpoint("dtnA"))
    topo.add_endpoint(Endpoint("dtnB"))
    topo.add_endpoint(Endpoint("dtnC"))
    topo.add_link("origin", "dtnA", gbps=100, rtt_ms=5)
    topo.add_link("dtnA", "final", gbps=100, rtt_ms=5)
    topo.add_link("origin", "dtnB", gbps=80, rtt_ms=10)
    topo.add_link("dtnB", "final", gbps=80, rtt_ms=10)
    topo.add_link("origin", "dtnC", gbps=60, rtt_ms=20)
    topo.add_link("dtnC", "final", gbps=60, rtt_ms=20)
    return topo


class _StormDest:
    """ByteDest wrapper: after ``live_writes`` successful writes, the node
    is dead — every further write is rejected (a hard endpoint death, not a
    finite window: only re-routing recovers)."""

    def __init__(self, inner, node: str, live_writes: int):
        self._inner = inner
        self._node = node
        self._left = live_writes
        self._lock = threading.Lock()

    def write(self, offset: int, data: bytes) -> None:
        with self._lock:
            if self._left <= 0:
                raise EndpointOutage(f"{self._node} is down (storm victim)")
            self._left -= 1
        self._inner.write(offset, data)

    def read_back(self, offset: int, length: int) -> bytes:
        return self._inner.read_back(offset, length)


def _storm_task(seed: int, *, nbytes: int, chunk: int, failover: bool,
                tmpdir: str) -> dict:
    """One relay task under the storm. Returns outcome counters."""
    topo = _storm_topology()
    planner = RoutePlanner(topo)
    route = planner.best_route("origin", "final", nbytes)
    primary = [n for n in route.nodes if n in INTERMEDIATES]
    rng = random.Random(_seed_int(seed, "storm"))
    victims: dict[str, int] = {}
    n_chunks = max(1, nbytes // chunk)
    # the DTN the planned route crosses dies mid-flight (after roughly half
    # the chunks landed there); some tasks lose a second, already-dead DTN —
    # the first re-plan walks into it and must fail over again
    victims[primary[0]] = max(1, n_chunks // 2)
    if rng.random() < 0.5:
        second = rng.choice([n for n in INTERMEDIATES if n not in victims])
        victims[second] = 0
    payload = np.random.default_rng(seed).integers(
        0, 256, nbytes, dtype=np.uint8).tobytes()
    workdir = os.path.join(tmpdir, f"storm-{'fo' if failover else 'base'}-{seed}")
    dst_path = os.path.join(workdir, "final.out")
    os.makedirs(workdir, exist_ok=True)

    def wrap_dest(u: str, v: str, dest):
        if v in victims:
            return _StormDest(dest, v, victims[v])
        return dest

    out = dict(succeeded=0, escapes=0, failovers=0, re_moved=0)
    try:
        xfer = RelayTransfer(
            route, BufferSource(payload), FileDest(dst_path, nbytes),
            workdir=workdir, chunk_bytes=chunk, movers=3,
            outage_retries=8, outage_backoff_s=0.001, retry_backoff_s=0.001,
            backoff_seed=seed,
            planner=planner, failover=failover, failover_outage_threshold=4,
            health=HealthTracker(seed=seed),
            link_dest_wrapper=wrap_dest,
            task=f"storm-{seed}",
        )
        report = xfer.run()
    except Exception:
        return out                       # the baseline is SUPPOSED to land here
    out["succeeded"] = 1
    out["failovers"] = report.failovers
    out["re_moved"] = report.re_moved_journaled
    with open(dst_path, "rb") as fh:
        out["escapes"] = int(fh.read() != payload)
    return out


# ---------------------------------------------------------------------------
# leg 2: landed bit-rot -> scrub detect + repair from the replica
# ---------------------------------------------------------------------------
def scrub_leg(seed: int, *, nbytes: int, chunk: int, flips: int,
              tmpdir: str) -> dict:
    root = os.path.join(tmpdir, f"scrub-{seed}")
    os.makedirs(root, exist_ok=True)
    payload = np.random.default_rng(seed + 1).integers(
        0, 256, nbytes, dtype=np.uint8).tobytes()
    src = os.path.join(root, "src.bin")
    with open(src, "wb") as fh:
        fh.write(payload)
    dst1 = os.path.join(root, "replica1", "f.bin")
    dst2 = os.path.join(root, "replica2", "f.bin")
    svc = TransferService(os.path.join(root, "svc"),
                          ServiceConfig(dedup="on", chunk_bytes=chunk))
    out = dict(injected=0, detected=0, repaired=0, quarantined=0, escapes=0)
    try:
        [t1] = svc.submit([(src, dst1)], batch=False)
        svc.wait(t1, timeout=120)
        [t2] = svc.submit([(src, dst2)], batch=False)
        svc.wait(t2, timeout=120)
        regions = [
            (dst1, int(c["offset"]), int(c["length"]))
            for c in svc.status(t1).item_reports[0].chunks
        ]
        victims = corrupt_landed_regions(regions, count=flips, seed=seed)
        out["injected"] = len(victims)
        report = svc.scrub()
        out["detected"] = report.rot_detected
        out["repaired"] = report.repaired
        out["quarantined"] = report.quarantined
        with open(dst1, "rb") as fh:
            out["escapes"] = int(fh.read() != payload)
    finally:
        svc.close()
    return out


# ---------------------------------------------------------------------------
# leg 3: breaker determinism across same-seed runs
# ---------------------------------------------------------------------------
def breaker_leg(seed: int, *, ops: int = 400) -> bool:
    """Drive two same-seed trackers with the same scripted outcome stream;
    their transition logs and rejection schedules must be identical."""
    cfg = BreakerConfig(fail_threshold=3, open_ops=8, probe_ops=2)
    script = random.Random(_seed_int(seed, "breaker-script"))
    outcomes = [script.random() > 0.45 for _ in range(ops)]
    snaps = []
    for _run in range(2):
        tracker = HealthTracker(seed=seed, config=cfg)
        rejected = []
        for i, ok in enumerate(outcomes):
            target = HealthTracker.link_target("u", "v")
            if tracker.allow(target):
                tracker.record(target, ok)
            else:
                rejected.append(i)
        snaps.append((tracker.snapshot(), tuple(rejected)))
    return snaps[0] == snaps[1]


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------
def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--seeds", type=int, default=None,
                    help="storm tasks (default: 20, quick: 8)")
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--force", action="store_true",
                    help="overwrite a BENCH result from a different git rev")
    args = ap.parse_args(argv)
    t_start = time.perf_counter()

    n_tasks = args.seeds if args.seeds is not None else (8 if args.quick else 20)
    nbytes = (256 * 1024 + 4093) if args.quick else (768 * 1024 + 4093)
    chunk = 32 * 1024
    scrub_bytes = 128 * 1024 if args.quick else 512 * 1024
    scrub_seeds = 2 if args.quick else 4
    flips = 4

    rows: list[tuple[str, float, str]] = []
    violations: list[str] = []

    with tempfile.TemporaryDirectory(prefix="failover-") as tmpdir:
        # ---- leg 1: the storm, baseline then failover
        base = dict(succeeded=0, escapes=0, failovers=0, re_moved=0)
        fo = dict(succeeded=0, escapes=0, failovers=0, re_moved=0)
        for seed in range(n_tasks):
            for k, v in _storm_task(seed, nbytes=nbytes, chunk=chunk,
                                    failover=False, tmpdir=tmpdir).items():
                base[k] += v
            for k, v in _storm_task(seed, nbytes=nbytes, chunk=chunk,
                                    failover=True, tmpdir=tmpdir).items():
                fo[k] += v
        availability = fo["succeeded"] / n_tasks
        baseline_rate = base["succeeded"] / n_tasks
        rows.append(("failover/storm_tasks", n_tasks, "tasks"))
        rows.append(("failover/availability", round(availability, 4), "frac"))
        rows.append(("failover/baseline_availability", round(baseline_rate, 4), "frac"))
        rows.append(("failover/failovers", fo["failovers"], "events"))
        rows.append(("failover/integrity_escapes", fo["escapes"], "tasks"))
        rows.append(("failover/re_moved_journaled", fo["re_moved"], "chunks"))
        if availability < 0.95:
            violations.append(
                f"storm availability {availability:.2%} < 95% with failover")
        if base["succeeded"] >= n_tasks:
            violations.append(
                "the no-failover baseline survived the storm — the storm is "
                "not forcing re-routes and the availability gate is theatre")
        if fo["escapes"]:
            violations.append(f"storm: {fo['escapes']} integrity escapes")
        if fo["re_moved"]:
            violations.append(
                f"storm: {fo['re_moved']} journaled chunks re-moved across "
                f"failovers (custody handoff broken)")
        if fo["succeeded"] and not fo["failovers"]:
            violations.append("storm tasks succeeded without a single "
                              "failover — victims were never on the route")

        # ---- leg 2: scrub detect + repair
        agg = dict(injected=0, detected=0, repaired=0, quarantined=0, escapes=0)
        for seed in range(scrub_seeds):
            for k, v in scrub_leg(seed, nbytes=scrub_bytes, chunk=chunk,
                                  flips=flips, tmpdir=tmpdir).items():
                agg[k] += v
        rows.append(("scrub/injected_flips", agg["injected"], "regions"))
        rows.append(("scrub/rot_detected", agg["detected"], "regions"))
        rows.append(("scrub/repaired", agg["repaired"], "regions"))
        rows.append(("scrub/quarantined", agg["quarantined"], "regions"))
        rows.append(("scrub/escapes_after_scrub", agg["escapes"], "replicas"))
        if agg["detected"] != agg["injected"]:
            violations.append(
                f"scrub detected {agg['detected']}/{agg['injected']} injected flips")
        if agg["repaired"] != agg["injected"]:
            violations.append(
                f"scrub repaired {agg['repaired']}/{agg['injected']} rotted regions")
        if agg["quarantined"]:
            violations.append(
                f"scrub quarantined {agg['quarantined']} regions despite a "
                f"healthy replica donor")
        if agg["escapes"]:
            violations.append(
                f"{agg['escapes']} replicas still corrupt after the scrub pass")

        # ---- leg 3: breaker determinism
        det = all(breaker_leg(seed) for seed in range(3))
        rows.append(("breaker/deterministic", int(det), "bool"))
        if not det:
            violations.append(
                "breaker transition logs diverged across same-seed runs")

    print("name,value,unit")
    for name, val, unit in rows:
        print(f"{name},{val},{unit}")
    path = emit("failover", rows,
                args={"quick": args.quick, "tasks": n_tasks},
                elapsed_s=round(time.perf_counter() - t_start, 3),
                force=args.force)
    print(f"# wrote {path}")
    if violations:
        print("\nGATE VIOLATIONS:", file=sys.stderr)
        for v in violations:
            print(f"  - {v}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
