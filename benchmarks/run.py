"""Benchmark harness: one section per paper figure + real overlap + roofline.

Prints ``name,value,unit`` CSV. Sections:
  fig5..fig10  — calibrated-simulator reproductions of the paper's §4 figures
  overlap/*    — real wall-clock chunked-transfer/checksum measurements (CPU)
  kernel/*     — digest kernel + host fingerprint rates
  roofline/*   — summary terms from the dry-run artifact (if present)

Run: PYTHONPATH=src python -m benchmarks.run [--quick]
"""
from __future__ import annotations

import sys


def main() -> None:
    quick = "--quick" in sys.argv
    from benchmarks import figures, overlap

    rows = []
    rows += figures.fig5_lustre_striping()
    rows += figures.fig6_chunk_size()
    rows += figures.fig7_integrity_throughput()
    rows += figures.fig8_checksum_times()
    if not quick:
        rows += figures.fig9_file_count()
    rows += figures.fig10_chunking_speedup()
    size = 64 if quick else 192
    rows += overlap.movers_scaling(size)
    rows += overlap.checksum_visibility(size)
    rows += overlap.chunk_size_sweep(64 if quick else 128)
    rows += overlap.kernel_rates()

    try:
        from benchmarks import roofline
        results = roofline.load()
        for r in roofline.table(results, "single"):
            if "skipped" in r:
                continue
            cell = f"{r['arch']}/{r['shape']}"
            rows.append((f"roofline/{cell}/dominant", r["dominant"], "term"))
            rows.append((f"roofline/{cell}/fraction", round(r["roofline_fraction"], 4), "frac"))
    except FileNotFoundError:
        pass

    print("name,value,unit")
    for name, val, unit in rows:
        print(f"{name},{val},{unit}")


if __name__ == "__main__":
    main()
