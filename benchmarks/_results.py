"""Machine-readable benchmark results — ``BENCH_<name>.json`` emission.

Every benchmark that prints its ``name,value,unit`` CSV also writes a JSON
document next to it so the performance trajectory of the repo is tracked
commit-over-commit: metrics, the seed(s) the run used, the git revision, and
the exact arguments. CI archives these files; diffing two of them answers
"did this PR move the needle" without re-parsing stdout.

Schema (stable; additions only):

    {
      "bench":     "<name>",
      "git_rev":   "<short rev or 'unknown'>",
      "timestamp": <unix seconds>,
      "seed":      <int | null>,
      "args":      {...},                      # run configuration
      "metrics":   {"<metric>": {"value": <num>, "unit": "<unit>"}}
    }
"""
from __future__ import annotations

import json
import os
import subprocess
import time
from typing import Any, Sequence

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def git_rev() -> str:
    """Short git revision of the repo this benchmark ran from."""
    try:
        out = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, cwd=_REPO_ROOT, timeout=10,
        )
        rev = out.stdout.strip()
        return rev if out.returncode == 0 and rev else "unknown"
    except Exception:  # noqa: BLE001 — no git in the environment
        return "unknown"


def emit(
    name: str,
    rows: Sequence[tuple[str, float, str]],
    *,
    seed: int | None = None,
    args: dict[str, Any] | None = None,
    out_dir: str | os.PathLike | None = None,
) -> str:
    """Write ``BENCH_<name>.json`` and return its path.

    ``rows`` is the same ``(metric, value, unit)`` list the benchmark prints
    as CSV, so both outputs can never disagree.
    """
    doc = {
        "bench": name,
        "git_rev": git_rev(),
        "timestamp": time.time(),
        "seed": seed,
        "args": dict(args or {}),
        "metrics": {n: {"value": v, "unit": u} for n, v, u in rows},
    }
    path = os.path.join(str(out_dir) if out_dir else os.getcwd(), f"BENCH_{name}.json")
    tmp = path + ".tmp"
    with open(tmp, "w", encoding="utf-8") as fh:
        json.dump(doc, fh, indent=2, sort_keys=True)
        fh.write("\n")
    os.replace(tmp, path)
    return path
