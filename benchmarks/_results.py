"""Machine-readable benchmark results — ``BENCH_<name>.json`` emission.

Every benchmark that prints its ``name,value,unit`` CSV also writes a JSON
document next to it so the performance trajectory of the repo is tracked
commit-over-commit: metrics, the seed(s) the run used, the git revision, the
exact arguments, and enough host provenance (CPU count, platform) to judge
whether two results are even comparable. CI archives these files; diffing
two of them answers "did this PR move the needle" without re-parsing stdout.

Overwrite protection: a ``BENCH_*.json`` written at one git revision is a
record of that revision's performance. ``emit`` refuses to silently replace
a result from a *different* revision — pass ``force=True`` (the benchmarks'
``--force`` flag) to overwrite deliberately. Same-revision re-runs always
overwrite (iterating locally must stay frictionless).

Schema (stable; additions only):

    {
      "schema_version": 2,
      "bench":     "<name>",
      "git_rev":   "<short rev or 'unknown'>",
      "timestamp": <unix seconds>,
      "elapsed_s": <benchmark wall time | null>,
      "host":      {"cpu_count": <int>, "platform": "...", "machine": "...",
                    "python": "..."},
      "seed":      <int | null>,
      "args":      {...},                      # run configuration
      "artifacts": ["<path>", ...],            # attached trace/attribution files
      "metrics":   {"<metric>": {"value": <num>, "unit": "<unit>"}}
    }
"""
from __future__ import annotations

import json
import os
import platform
import subprocess
from typing import Any, Sequence

from repro.obs.clock import wall_s

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SCHEMA_VERSION = 2


def git_rev() -> str:
    """Short git revision of the repo this benchmark ran from."""
    try:
        out = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, cwd=_REPO_ROOT, timeout=10,
        )
        rev = out.stdout.strip()
        return rev if out.returncode == 0 and rev else "unknown"
    except Exception:  # noqa: BLE001 — no git in the environment
        return "unknown"


def host_info() -> dict[str, Any]:
    """Comparability provenance: what machine produced this number."""
    return {
        "cpu_count": os.cpu_count() or 1,
        "platform": platform.platform(),
        "machine": platform.machine(),
        "python": platform.python_version(),
    }


class ResultOverwriteError(RuntimeError):
    """Refused to clobber a BENCH file from a different git revision."""


def _check_overwrite(path: str, rev: str, force: bool) -> None:
    if force or not os.path.exists(path):
        return
    try:
        with open(path, encoding="utf-8") as fh:
            prev_rev = json.load(fh).get("git_rev", "unknown")
    except Exception:  # noqa: BLE001 — corrupt/legacy file: replacing is fine
        return
    if prev_rev != "unknown" and rev != "unknown" and prev_rev != rev:
        raise ResultOverwriteError(
            f"{path} holds a result from git rev {prev_rev}, but this run is "
            f"rev {rev}. Overwriting would silently lose a recorded "
            f"performance point — re-run with --force to replace it."
        )


def emit(
    name: str,
    rows: Sequence[tuple[str, float, str]],
    *,
    seed: int | None = None,
    args: dict[str, Any] | None = None,
    out_dir: str | os.PathLike | None = None,
    elapsed_s: float | None = None,
    artifacts: Sequence[str] | None = None,
    force: bool = False,
) -> str:
    """Write ``BENCH_<name>.json`` and return its path.

    ``rows`` is the same ``(metric, value, unit)`` list the benchmark prints
    as CSV, so both outputs can never disagree. ``artifacts`` attaches paths
    of companion files (exported traces, attribution reports) so a perf
    number always arrives with its explanation.
    """
    rev = git_rev()
    doc = {
        "schema_version": SCHEMA_VERSION,
        "bench": name,
        "git_rev": rev,
        "timestamp": wall_s(),
        "elapsed_s": elapsed_s,
        "host": host_info(),
        "seed": seed,
        "args": dict(args or {}),
        "artifacts": list(artifacts or []),
        "metrics": {n: {"value": v, "unit": u} for n, v, u in rows},
    }
    path = os.path.join(str(out_dir) if out_dir else os.getcwd(), f"BENCH_{name}.json")
    _check_overwrite(path, rev, force)
    tmp = path + ".tmp"
    with open(tmp, "w", encoding="utf-8") as fh:
        json.dump(doc, fh, indent=2, sort_keys=True)
        fh.write("\n")
    os.replace(tmp, path)
    return path
