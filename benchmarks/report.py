"""Generate EXPERIMENTS.md from artifacts (dryrun.json, hillclimb.json).

Usage: PYTHONPATH=src:. python benchmarks/report.py > EXPERIMENTS.md
"""
from __future__ import annotations

import json
import os

from benchmarks import roofline
from repro.core.simulator import ALCF, NERSC, TransferSpec, simulate_transfer

GB = 1e9
MB = 1024 * 1024


def _sim(src, dst, files, chunk, integ, stripes=16):
    return simulate_transfer(src, dst, TransferSpec(tuple(files), chunk_bytes=chunk,
                                                    integrity=integ, stripe_count=stripes))


def section_claims() -> str:
    r = []
    base = _sim(ALCF, NERSC, [500 * GB], None, True)
    fast = _sim(ALCF, NERSC, [500 * GB], 200 * MB, True)
    s1 = _sim(NERSC, ALCF, [2500 * GB], 200 * MB, False, 1)
    s16 = _sim(NERSC, ALCF, [2500 * GB], 200 * MB, False, 16)
    noint = _sim(ALCF, NERSC, [500 * GB], None, False)
    cnoint = _sim(ALCF, NERSC, [500 * GB], 200 * MB, False)
    many = _sim(ALCF, NERSC, [1 * GB] * 500, None, True)
    r.append("## §Claims — paper validation on the calibrated testbed model\n")
    r.append("Model: `core/simulator.py` (max-min-fair DES over movers, WAN, OSTs,\n"
             "checksum units; calibration constants documented in the module).\n"
             "Checked automatically in `tests/test_simulator.py`; figure-by-figure\n"
             "sweeps in `benchmarks/figures.py` (CSV via `python -m benchmarks.run`).\n")
    rows = [
        ("un-chunked 1x500 GB A2N w/ integrity", f"{base.gbps:.2f} Gb/s", "1.98 Gb/s (Fig. 9)"),
        ("chunked speedup, single 500 GB file", f"{fast.gbps/base.gbps:.1f}x", "9.5x (§6)"),
        ("N2A chunked, stripe=1", f"{s1.gbps:.2f} Gb/s", "3.92 Gb/s (Fig. 5)"),
        ("N2A chunked, stripe=16", f"{s16.gbps:.2f} Gb/s", "31.76 Gb/s (Fig. 5)"),
        ("stripe 1->16 gain", f"{s16.gbps/s1.gbps:.1f}x", "8.1x (§6)"),
        ("visible checksum cost, un-chunked 1x500 GB",
         f"{base.seconds-noint.seconds:.0f} s", "773 s (Fig. 8)"),
        ("visible checksum cost, chunked",
         f"{fast.seconds-cnoint.seconds:.0f} s", "53.7 s (Fig. 8)"),
        ("1 -> 500 files speedup, un-chunked", f"{many.gbps/base.gbps:.0f}x", "23x (Fig. 9)"),
    ]
    r.append("| quantity | reproduced | paper |\n|---|---|---|")
    for a, b, c in rows:
        r.append(f"| {a} | {b} | {c} |")
    r.append(
        "\nKnown divergences (documented, not tuned away): (1) our mover model "
        "hides chunked checksum cost almost completely (~3 s visible vs the "
        "paper's 53.7 s) because it lets a mover's re-read/hash fully overlap "
        "its next receive; the paper's measured residual suggests extra "
        "dest-side contention we chose not to add a free parameter for. "
        "(2) multi-file chunk-size sensitivity (Fig. 6's 20x25GB rise) is "
        "muted: in our calibration those runs sit at the dest-I/O ceiling, "
        "which masks per-chunk latency effects; the falloff side (too few "
        "chunks for 64x4 sessions, paper §4.2) reproduces cleanly on the "
        "single-file task (19.1 -> 12.1 Gb/s from 200 MB to 25 GB chunks).")
    return "\n".join(r) + "\n"


def section_dryrun(results: dict) -> str:
    ok = [v for v in results.values() if "flops_per_device" in v]
    sk = [v for v in results.values() if "skipped" in v]
    er = [v for v in results.values() if "error" in v]
    fits = sum(1 for v in ok if v["peak_bytes"] <= 16e9)
    r = ["## §Dry-run — every (arch x shape x mesh) cell lowers and compiles\n"]
    r.append(f"* mesh single-pod **(data=16, model=16)** = 256 chips; multi-pod "
             f"**(pod=2, data=16, model=16)** = 512 chips (`launch/mesh.py`).")
    r.append(f"* **{len(ok)} cells compiled**, {len(sk)} documented skips "
             f"(long_500k on pure full-attention archs + whisper), {len(er)} errors.")
    r.append(f"* {fits}/{len(ok)} cells fit 16 GB/chip (v5e); over-budget cells are "
             f"decode layouts discussed in §Perf (grok decode) — train cells fit via "
             f"per-arch microbatching (`launch/steps.py::DEFAULT_MICROBATCHES`).")
    r.append("* per-cell records (FLOPs, bytes, per-collective bytes, memory "
             "analysis, compile times): `results/dryrun.json`.")
    r.append("* multi-pod pass proves the pod axis shards: batch "
             "P(('pod','data'), ...), cross-pod gradient all-reduce present in "
             "the HLO; chunked-pod variant exercised in §Perf cell 1.\n")
    from repro.launch.steps import DEFAULT_MICROBATCHES
    some = [v for v in ok if v["mesh"] == "single" and v["shape"] == "train_4k"]
    r.append("train_4k compile snapshot (single-pod):\n")
    r.append("| arch | lower s | compile s | peak GB | microbatches |")
    r.append("|---|---|---|---|---|")
    for v in sorted(some, key=lambda x: x["arch"]):
        mb = v["microbatches"] or DEFAULT_MICROBATCHES.get(v["arch"], 1)
        r.append(f"| {v['arch']} | {v['lower_s']} | {v['compile_s']} | "
                 f"{v['peak_bytes']/1e9:.1f} | {mb} |")
    return "\n".join(r) + "\n"


def section_roofline(results: dict) -> str:
    r = ["## §Roofline — three terms per cell (TPU v5e: 197 TF/s bf16, "
         "819 GB/s HBM, ~50 GB/s/link ICI)\n"]
    r.append(
        "Terms are *time lower bounds per step*: compute = HLO FLOPs/device / peak;\n"
        "memory = HLO bytes-accessed/device / HBM bw (sum over fused ops — an\n"
        "**upper bound** on true HBM traffic, typically 2-4x, so `dominant=memory`\n"
        "with a small margin over compute should be read as compute-or-memory);\n"
        "collective = ring-model interconnect bytes/device / link bw. FLOPs/bytes\n"
        "use unrolled reduced-layer probes (XLA counts while bodies once;\n"
        "`launch/dryrun.py::_reconstruct`). `6ND/HLO` = useful-FLOPs ratio\n"
        "(MoE: active params; catches remat/dispatch waste). `frac` = roofline\n"
        "fraction: useful work's time vs the dominant bound (decode cells use\n"
        "unavoidable params+cache HBM traffic as the 'useful' numerator).\n")
    for mesh in ("single", "multi"):
        rows = roofline.table(results, mesh)
        r.append(f"\n### {mesh}-pod ({256 if mesh=='single' else 512} chips)\n")
        r.append(roofline.render(rows))
        if mesh == "single":
            live = [x for x in rows if "skipped" not in x]
            by_dom = {}
            for x in live:
                by_dom.setdefault(x["dominant"], []).append(x)
            r.append("\nper-cell one-liners (what would move the dominant term):\n")
            notes = {
                "compute": "raise per-chip math utilization (larger per-device tiles, fewer remat passes)",
                "memory": "cut activation traffic: fused attention/xent already chunked; next lever is bf16 intermediates + smaller remat windows",
                "collective": "chunk + overlap the dominant collective; resize sharding so gathers amortize",
            }
            for dom, xs in sorted(by_dom.items()):
                cells = ", ".join(f"{x['arch']}/{x['shape']}" for x in xs)
                r.append(f"* **{dom}-bound** ({len(xs)}): {cells}. Lever: {notes[dom]}.")
    return "\n".join(r) + "\n"


def section_perf(hc: dict) -> str:
    r = ["## §Perf — hillclimb on the three selected cells\n"]
    r.append("Selection: (1) most paper-representative (cross-pod sync), "
             "(2) worst roofline fraction, (3) most collective-bound runnable "
             "serving cell. Each row is one hypothesis->change->measure cycle "
             "(`benchmarks/hillclimb.py`); baseline and optimized variants are "
             "recorded separately, paper-faithful first.\n")
    cells: dict[str, list] = {}
    for key, rec in hc.items():
        cell = "|".join(key.split("|")[:3])
        cells.setdefault(cell, []).append(rec)
    for cell, recs in cells.items():
        r.append(f"\n### {cell}\n")
        r.append("| variant | hypothesis | compute s | memory s | collective s | dominant | frac | verdict |")
        r.append("|---|---|---|---|---|---|---|---|")
        base = None
        for rec in recs:
            if "error" in rec:
                r.append(f"| {rec['variant']} | — | — | — | — | — | — | ERROR {rec['error'][:60]} |")
                continue
            a = rec["analysis"]
            if base is None:
                base = a
                verdict = "baseline"
            else:
                key_term = base["dominant"] + "_s"
                delta = (base[key_term] - a[key_term]) / base[key_term] if base[key_term] else 0
                verdict = f"{'confirmed' if delta > 0.05 else ('neutral' if abs(delta) <= 0.05 else 'refuted')} ({delta:+.0%} on baseline-dominant term)"
            r.append(f"| {rec['variant']} | {rec['hypothesis'][:80]} | "
                     f"{a['compute_s']*1e3:.0f}m | {a['memory_s']*1e3:.0f}m | "
                     f"{a['collective_s']*1e3:.0f}m | {a['dominant']} | "
                     f"{a['roofline_fraction']:.3f} | {verdict} |")
    r.append("""
### Findings (hypothesis -> measurement -> lesson)

**Cell 1 — gemma-2b/train_4k/multi (the paper's technique itself).**
Transposing client-driven chunking onto the cross-pod *gradient sync* is
REFUTED, with a clean mechanism: per-axis attribution (``by_group_size`` in
``results/hillclimb.json``) shows the baseline's pod-axis (DCN, group=2)
traffic is only ~0.5 GB/device/step — under ZeRO-3 the "large file" is
already sharded 256-way, so each device's DCN transfer is already
chunk-sized and XLA already pipelines per-tensor reductions. Wrapping the
step in a manual-pod region to drive our chunked rings costs ~12 GB/device
of extra ICI re-sharding (group=16/512 buckets: 2.7->15.6 and 0.6->9.0 GB),
swamping any overlap gain; bf16 wire "compression" is a no-op because
gradients already travel in bf16. **Lesson: the paper's mechanism pays
where one owner holds a bulk transfer — exactly the checkpoint path (movers
+ journal, measured in `benchmarks/overlap.py`) and the serving weight
gathers (cell 3) — not where a sharded optimizer has pre-chunked the data.**
The paper-faithful implementation is kept as a selectable mode
(``--sync-mode chunked``) and is numerically identical to the baseline
(tests/test_chunked_collectives.py::test_chunked_pod_step_matches_auto).

**Cell 2 — mamba2-370m/train_4k (worst roofline fraction).**
Three SSD-chunk-size/precision hypotheses REFUTED (memory term moved
+2%/+16%/+2%): an unrolled L=1 byte profile showed the dominant tensors are
f32[16,512,50280] chunked-xent logits — vocab 50280 % 16 != 0, so the whole
lm-head path was silently replicated. Padding vocab to 50432 (=16*3152)
CONFIRMED: compute term 215m -> 86m (-60%, replicated lm-head FLOPs now
shard) and memory -8%. Remaining memory term is genuine f32 elementwise SSD
traffic (decays/gates); a full bf16-safe SSD numerics pass is the next
lever (partial casts measured neutral — round-trip converts eat the win).
Stopped per rule after two consecutive <5% changes.

**Cell 3 — yi-34b/decode_32k (most collective-bound).**
CONFIRMED, large: serving with the training ZeRO-3 layout re-gathers ~4 GB
of weights per decoded token (collective term 395m). Weight-stationary
serving specs (shard on non-contracted dims: head_dim/ffn/vocab over MODEL;
``DenseLM.param_specs(serve=True)``) eliminate weight gathers: collective
395m -> 3m (-99%), memory 203m -> 128m (-37%), roofline fraction
0.016 -> 0.079 (5x). This *is* the paper's insight correctly transposed:
decode was moving the same "large file" (the weights) every step; the fix
makes the data stationary and moves the small thing (activations) instead.

**Paper-faithful vs beyond-paper, recorded separately:** the baseline table
(§Roofline) is the paper-faithful framework; `results/hillclimb.json` holds
each optimized variant. Net beyond-paper wins adopted as selectable flags:
weight-stationary serving (5x fraction on yi decode; default-off to keep
the baseline reproducible) and vocab padding (2.5x compute-term win on
mamba2).""")
    return "\n".join(r) + "\n"


def main() -> None:
    results = roofline.load()
    hc = {}
    hc_path = os.path.join(os.path.dirname(__file__), "..", "results", "hillclimb.json")
    if os.path.exists(hc_path):
        with open(hc_path) as fh:
            hc = json.load(fh)
    print("# EXPERIMENTS\n")
    print("Artifacts: `results/dryrun.json` (80 cells), `results/hillclimb.json`, "
          "`test_output.txt`, `bench_output.txt`. Regenerate this file with "
          "`PYTHONPATH=src:. python benchmarks/report.py > EXPERIMENTS.md`.\n")
    print(section_claims())
    print(section_dryrun(results))
    print(section_roofline(results))
    print(section_perf(hc))


if __name__ == "__main__":
    main()
