"""Aggregate every ``BENCH_*.json`` result into one summary report.

Each benchmark in this package writes a schema-v2 ``BENCH_<name>.json``
(``benchmarks/_results.py``) next to its CSV output: metrics, seeds, git
revision, arguments, and host provenance. This module renders them together
— one table per benchmark plus a cross-benchmark header — so "where does the
repo stand after this commit" is one command instead of ten files:

    PYTHONPATH=src:. python -m benchmarks.report [--dir .] [--json]

Comparability guards are surfaced, not hidden: results from different git
revisions or hosts are flagged in the header (they are still printed — a
stale number with a warning beats a missing one). ``--json`` emits the
merged document for machine consumers instead of the rendered tables.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys
import time


def load_results(directory: str) -> dict[str, dict]:
    """All parseable schema-v2 ``BENCH_*.json`` docs in ``directory``."""
    out: dict[str, dict] = {}
    for path in sorted(glob.glob(os.path.join(directory, "BENCH_*.json"))):
        try:
            with open(path, encoding="utf-8") as fh:
                doc = json.load(fh)
        except (OSError, ValueError) as exc:
            print(f"# skipping unreadable {path}: {exc}", file=sys.stderr)
            continue
        # schema v2 is the contract; legacy pre-versioned docs with the same
        # metrics shape are still rendered (flagged by their git rev/age)
        # rather than silently dropped
        if (doc.get("schema_version") not in (None, 2)
                or not isinstance(doc.get("metrics"), dict)
                or "bench" not in doc):
            print(f"# skipping {path}: not a schema-v2 BENCH document",
                  file=sys.stderr)
            continue
        name = doc.get("bench") or os.path.basename(path)[6:-5]
        out[name] = doc
    return out


def _fmt_value(v) -> str:
    if isinstance(v, float) and not v.is_integer():
        return f"{v:.4g}"
    return f"{int(v)}" if isinstance(v, (int, float)) else str(v)


def _age(ts) -> str:
    try:
        days = (time.time() - float(ts)) / 86400.0
    except (TypeError, ValueError):
        return "?"
    return f"{days:.1f}d" if days >= 0.1 else f"{days * 24:.1f}h"


def render(results: dict[str, dict]) -> str:
    if not results:
        return ("no BENCH_*.json results found — run the benchmarks first "
                "(python -m benchmarks.chaos / .dedup / .overlap / ...)")
    lines: list[str] = []
    revs = {d.get("git_rev", "unknown") for d in results.values()}
    hosts = {d.get("host", {}).get("platform", "?") for d in results.values()}
    lines.append(f"# benchmark report — {len(results)} suites, "
                 f"{sum(len(d['metrics']) for d in results.values())} metrics")
    if len(revs) > 1:
        lines.append(f"# WARNING: results span {len(revs)} git revisions "
                     f"({', '.join(sorted(revs))}) — not directly comparable")
    if len(hosts) > 1:
        lines.append(f"# WARNING: results span {len(hosts)} host platforms")

    lines.append("")
    lines.append(f"| suite | git rev | age | elapsed s | metrics | escapes |")
    lines.append("|---|---|---|---|---|---|")
    for name, doc in sorted(results.items()):
        esc = sum(
            m["value"] for k, m in doc["metrics"].items()
            if k.endswith(("escapes", "/escapes")) or k == "escapes"
        )
        lines.append(
            f"| {name} | {doc.get('git_rev', '?')} | "
            f"{_age(doc.get('timestamp'))} | "
            f"{doc.get('elapsed_s') if doc.get('elapsed_s') is not None else '?'} | "
            f"{len(doc['metrics'])} | {_fmt_value(esc)} |"
        )

    for name, doc in sorted(results.items()):
        lines.append("")
        lines.append(f"## {name}")
        args = doc.get("args") or {}
        if args:
            lines.append("args: " + ", ".join(
                f"{k}={v}" for k, v in sorted(args.items())))
        lines.append("")
        lines.append("| metric | value | unit |")
        lines.append("|---|---|---|")
        for metric, m in sorted(doc["metrics"].items()):
            lines.append(f"| {metric} | {_fmt_value(m['value'])} | "
                         f"{m.get('unit', '')} |")
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--dir", default=".",
                    help="directory holding BENCH_*.json files")
    ap.add_argument("--json", action="store_true",
                    help="emit the merged JSON document instead of tables")
    args = ap.parse_args(argv)
    results = load_results(os.path.abspath(args.dir))
    if args.json:
        json.dump(results, sys.stdout, indent=2, sort_keys=True)
        print()
    else:
        print(render(results))
    return 0 if results else 1


if __name__ == "__main__":
    sys.exit(main())
