import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""§Perf hillclimb driver: run named variants of the three selected cells,
record roofline terms per variant, emit the hypothesis->change->result log.

Cells (selection rationale in EXPERIMENTS.md §Perf):
  1. gemma-2b    train_4k  multi  — most representative of the paper's technique
  2. mamba2-370m train_4k  single — worst roofline fraction
  3. yi-34b      decode_32k single — most collective-bound runnable cell

Usage: PYTHONPATH=src:. python benchmarks/hillclimb.py [--out results/hillclimb.json]
"""
import argparse
import json

VARIANTS = {
    # ---- cell 1: cross-pod sync (the paper's technique itself) -------------
    "gemma-2b|train_4k|multi": [
        ("baseline_auto", "monolithic cross-pod all-reduce (un-chunked Globus)",
         dict(sync_mode="auto")),
        ("paper_chunked", "paper-faithful: per-pod step + chunked DCN ring "
         "(hypothesis: same bytes, finer messages -> overlappable schedule)",
         dict(sync_mode="chunked")),
        ("beyond_bf16_wire", "beyond-paper: bf16 gradient compression on the "
         "DCN hop (hypothesis: pod-axis bytes halve)",
         dict(sync_mode="chunked_bf16")),
    ],
    # ---- cell 2: worst roofline fraction ------------------------------------
    "mamba2-370m|train_4k|single": [
        ("baseline", "SSD chunk=256, f32 intra-chunk math",
         dict()),
        ("chunk128", "hypothesis: intra-chunk L/M tensors dominate HLO bytes "
         "(~l*Q per layer); Q 256->128 should cut memory term ~30-40%",
         dict(cfg_overrides={"ssm_chunk": 128})),
        ("chunk64", "continue down the Q^2 curve: Q=64 (state-pass overhead "
         "should start to bite)",
         dict(cfg_overrides={"ssm_chunk": 64})),
        ("chunk128_bf16", "hypothesis: bf16 intra-chunk matmuls (decays stay "
         "f32) halve the dominant traffic again",
         dict(cfg_overrides={"ssm_chunk": 128, "ssm_bf16": True})),
        # HLO byte profile (L=1 unrolled probe) refuted the Q hypotheses:
        # the dominant tensors are f32[16,512,50280] xent logits — vocab
        # 50280 is not divisible by the 16-wide model axis, so the whole
        # lm-head path is REPLICATED per device.
        ("vocab_pad16", "hypothesis: pad vocab 50280->50432 (=16*3152) so the "
         "unembed/logits shard over MODEL; replicated-vocab traffic /16",
         dict(cfg_overrides={"vocab": 50432})),
        ("vocab_pad_bf16", "combine vocab padding with bf16 SSD matmuls",
         dict(cfg_overrides={"vocab": 50432, "ssm_bf16": True})),
    ],
    # ---- cell 3: most collective-bound ---------------------------------------
    "yi-34b|decode_32k|single": [
        ("baseline_zero3", "training layout reused for serving: ZeRO-3 "
         "re-gathers ~4 GB of weights per decoded token",
         dict()),
        ("weight_stationary", "hypothesis: shard weights on non-contracted "
         "dims (hd/ffn/vocab on MODEL); gathers vanish, replaced by KB-sized "
         "partial-sum all-reduces",
         dict(weight_stationary=True)),
    ],
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="results/hillclimb.json")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    from repro.launch.dryrun import run_cell
    from benchmarks.roofline import analyze_cell

    results = {}
    if os.path.exists(args.out):
        with open(args.out) as fh:
            results = json.load(fh)

    for cell_key, variants in VARIANTS.items():
        if args.only and args.only not in cell_key:
            continue
        arch, shape, mesh = cell_key.split("|")
        for name, hypothesis, kw in variants:
            key = f"{cell_key}|{name}"
            if key in results and "error" not in results[key]:
                print(f"[cached] {key}")
                continue
            print(f"[run] {key}: {hypothesis}", flush=True)
            try:
                rec = run_cell(arch, shape, mesh, probes=True, **kw)
                rec["variant"] = name
                rec["hypothesis"] = hypothesis
                rec["analysis"] = analyze_cell(rec)
                results[key] = rec
                a = rec["analysis"]
                print(f"  -> compute {a['compute_s']*1e3:.0f}m  memory "
                      f"{a['memory_s']*1e3:.0f}m  collective {a['collective_s']*1e3:.0f}m  "
                      f"dominant={a['dominant']}  frac={a['roofline_fraction']:.3f}",
                      flush=True)
            except Exception as e:  # noqa: BLE001
                import traceback
                traceback.print_exc()
                results[key] = {"error": str(e)[:500], "variant": name}
            with open(args.out, "w") as fh:
                json.dump(results, fh, indent=1)


if __name__ == "__main__":
    main()
