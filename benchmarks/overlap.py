"""Real (wall-clock, CPU) measurements of chunked transfer + checksum overlap.

This is the measured counterpart to the simulator figures: the actual
``core.transfer`` engine moving real bytes through real files with real
fingerprints, demonstrating on hardware-at-hand what the paper demonstrates
on DTNs — chunking + movers parallelizes both movement and integrity
checking, and the visible checksum cost collapses.
"""
from __future__ import annotations

import os
import tempfile
import time

import numpy as np

from repro.core import (
    BufferDest, BufferSource, ChunkedTransfer, fingerprint_bytes, plan_chunks,
)

MiB = 1024 * 1024


def _measure(payload: bytes, movers: int, chunk: int, integrity: bool,
             reps: int = 2) -> float:
    best = float("inf")
    for _ in range(reps):
        plan = plan_chunks(len(payload), movers, chunk_bytes=chunk,
                           min_chunk=1, max_chunk=1 << 40)
        dst = BufferDest(len(payload))
        t0 = time.perf_counter()
        ChunkedTransfer(BufferSource(payload), dst, plan,
                        integrity=integrity).run()
        best = min(best, time.perf_counter() - t0)
    return best


def movers_scaling(size_mib: int = 192):
    """Single 'large file': mover count sweep (paper Fig. 10, 1-file column)."""
    rng = np.random.default_rng(0)
    payload = rng.integers(0, 256, size_mib * MiB, dtype=np.uint8).tobytes()
    rows = []
    base = None
    for movers in (1, 2, 4, 8):
        dt = _measure(payload, movers, 8 * MiB, True)
        base = base or dt
        rows.append((f"overlap/1file/movers{movers}",
                     round(size_mib / dt, 1), "MiB/s"))
    rows.append(("overlap/1file/speedup_8v1", round(base / dt, 2), "x"))
    return rows


def checksum_visibility(size_mib: int = 192):
    """Visible integrity cost, unchunked vs chunked (paper Fig. 8)."""
    rng = np.random.default_rng(1)
    payload = rng.integers(0, 256, size_mib * MiB, dtype=np.uint8).tobytes()
    rows = []
    t_un_no = _measure(payload, 1, len(payload), False)
    t_un_ck = _measure(payload, 1, len(payload), True)
    t_ch_no = _measure(payload, 8, 8 * MiB, False)
    t_ch_ck = _measure(payload, 8, 8 * MiB, True)
    rows.append(("overlap/checksum_cost/unchunked_s", round(t_un_ck - t_un_no, 3), "s"))
    rows.append(("overlap/checksum_cost/chunked_s", round(t_ch_ck - t_ch_no, 3), "s"))
    hidden = 1.0 - (t_ch_ck - t_ch_no) / max(1e-9, t_un_ck - t_un_no)
    rows.append(("overlap/checksum_cost/fraction_hidden", round(hidden, 2), "frac"))
    return rows


def chunk_size_sweep(size_mib: int = 128):
    """Chunk-size rise-and-fall on real threads (paper Fig. 6)."""
    rng = np.random.default_rng(2)
    payload = rng.integers(0, 256, size_mib * MiB, dtype=np.uint8).tobytes()
    rows = []
    for chunk_mib in (1, 4, 16, 64, size_mib):
        dt = _measure(payload, 8, chunk_mib * MiB, True)
        rows.append((f"overlap/chunksize/{chunk_mib}MiB",
                     round(size_mib / dt, 1), "MiB/s"))
    return rows


def kernel_rates():
    """Device-side digest kernel rates (interpret mode — correctness path)."""
    import jax.numpy as jnp
    from repro.kernels import fingerprint_array
    rows = []
    x = jnp.zeros((4 * 1024 * 1024,), jnp.float32)  # 16 MiB
    fingerprint_array(x).block_until_ready()
    t0 = time.perf_counter()
    fingerprint_array(x).block_until_ready()
    dt = time.perf_counter() - t0
    rows.append(("kernel/checksum_interp_rate", round(16 / dt, 1), "MiB/s"))
    rng = np.random.default_rng(3)
    big = rng.integers(0, 256, 64 * MiB, dtype=np.uint8).tobytes()
    t0 = time.perf_counter()
    fingerprint_bytes(big)
    rows.append(("host/checksum_rate", round(64 / (time.perf_counter() - t0), 1),
                 "MiB/s"))
    return rows
