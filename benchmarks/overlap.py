"""Serial vs single-pass vs pipelined data plane — measured overlap gates.

The paper's central overlap claim (§3.2, Fig. 4) is that per-chunk integrity
checking must run concurrently with data movement. This benchmark measures
the three data-plane modes of ``core.transfer`` on the REAL threaded engine
moving real bytes:

  * ``serial``      — read -> digest -> write -> read-back -> digest, all on
                      the mover (two full checksum passes on the critical path);
  * ``single_pass`` — the source digest accumulates while the chunk streams
                      into the destination (one data pass saved; verify inline);
  * ``pipelined``   — single-pass streaming + verification deferred to the
                      decoupled integrity engine's checksum workers.

The wire is a sleep-throttled destination (network time is I/O wait, not
CPU — the same modelling the autotune harness uses), rated against the host
checksum rate ``c`` measured immediately before each leg. One mover + one
checksum worker (the per-mover pipeline of the paper's DTN shape). Two mixes
bound the regimes (per measured file size — 64 MB always, 1 GB in full mode,
1 TB as deterministic fluid-model arithmetic):

  * ``cksum_bound`` — wire at the checksum rate (checksum rate <~ wire
                      rate): the paper's modern-NIC regime where the
                      checksum pass IS the tax. Serial pays 1/w + 2/c wall
                      per byte; pipelined hides one checksum pass behind the
                      wire wait: max(2/c, 1/w + 1/c) — 1.5x in theory.
                      GATED: pipelined >= 1.4x serial goodput;
  * ``wire_bound``  — wire at half the checksum rate: serial 4/c vs
                      pipelined 3/c, 1.33x in theory.
                      GATED: pipelined >= 1.15x serial.

Also gated: 0 integrity escapes on every leg, a pipelined kill+restart leg
with a lagging verifier must re-move 0 journaled-and-verified chunks, and
the digest-algebra microbench must show >= 5x fewer bigint pow() calls per
merge chain than the uncached 4-per-merge cost.

Prints ``name,value,unit`` CSV, writes ``BENCH_overlap.json`` via
``benchmarks._results``, exits non-zero on any gate violation.

Run: PYTHONPATH=src python -m benchmarks.overlap [--quick] [--seed N]
"""
from __future__ import annotations

import argparse
import os
import sys
import tempfile
import threading
import time

# one BLAS thread per digest: each mover/checksum worker is one stream of
# compute (the DTN mover model). A multi-threaded BLAS would let a single
# serial mover silently soak every core during its checksum pass and turn
# the overlap measurement into a BLAS-scheduling benchmark.
os.environ.setdefault("OPENBLAS_NUM_THREADS", "1")
os.environ.setdefault("OMP_NUM_THREADS", "1")
os.environ.setdefault("MKL_NUM_THREADS", "1")

import numpy as np

from benchmarks._results import emit
from repro.core import (
    BufferDest,
    BufferSource,
    ChunkJournal,
    ChunkedTransfer,
    IntegrityEngine,
    VerifyJob,
    fingerprint_bytes,
    fingerprint_many,
    plan_chunks,
)
from repro.core import integrity as integrity_mod
from repro.core.simulator import ALCF, NERSC

MiB = 1024 * 1024
MODES = ("serial", "single_pass", "pipelined")

# one mover + two checksum workers: the per-mover pipeline being measured
# (the comparison is mode-vs-mode at a FIXED mover count; the integrity
# engine is the offload under test, not extra movers). Two verifiers keep
# the digest queue draining while one worker sits in a long read-back.
MOVERS = 1
VERIFIERS = 2


class ThrottledDest:
    """BufferDest behind a sleep-rated wire.

    Network transmission is I/O wait, not CPU — sleeping ``len/rate`` per
    write models the wire the way the autotune harness does, and is exactly
    the window the pipelined mode's checksum workers overlap into. The
    dest-local read-back (verification) runs at memory speed, as on a DTN.
    """

    def __init__(self, total_bytes: int, rate_Bps: float):
        self._inner = BufferDest(total_bytes)
        self.rate_Bps = rate_Bps
        self._lock = threading.Lock()
        self._debt_s = 0.0

    @property
    def buf(self):
        return self._inner.buf

    def write(self, offset, data):
        # token-bucket pacing: accumulate wire debt and sleep it off in
        # >=20 ms quanta, crediting oversleep back — per-write sleeps would
        # add a scheduler-tick of overshoot to every granule and turn the
        # wire model into a timer-resolution benchmark
        with self._lock:
            self._debt_s += len(data) / self.rate_Bps
            owe = self._debt_s if self._debt_s >= 0.02 else 0.0
        if owe:
            t0 = time.perf_counter()
            time.sleep(owe)
            with self._lock:
                self._debt_s -= time.perf_counter() - t0
        self._inner.write(offset, data)

    def read_back(self, offset, length):          # dest-local re-read: full speed
        return self._inner.read_back(offset, length)

    def read_back_into(self, offset, view):
        return self._inner.read_back_into(offset, view)

    def read_back_view(self, offset, length):
        return self._inner.read_back_view(offset, length)


class SlowVerifyDest(BufferDest):
    """Slow read-back: deferred verification lags chunks behind movement."""

    def __init__(self, total_bytes, delay_s=0.005):
        super().__init__(total_bytes)
        self.delay_s = delay_s

    def read_back(self, offset, length):
        time.sleep(self.delay_s)
        return super().read_back(offset, length)

    read_back_into = None   # force the read_back path (not the zero-copy
    read_back_view = None   # variants, which would bypass the delay)


class _HostCrash(Exception):
    """Crash bomb for the kill+restart leg."""


def _payload(seed: int, nbytes: int) -> bytes:
    return np.random.default_rng(seed).integers(
        0, 256, nbytes, dtype=np.uint8).tobytes()


def host_cksum_rate_Bps(seed: int = 0) -> float:
    """Measured single-thread host fingerprint rate (sets the wire rating).

    Median of five warm samples: shared-CPU boxes show sub-second steal
    dips, and a dip caught by a one-shot calibration would mis-rate the
    wire and shift the whole mix out of its intended regime.
    """
    data = _payload(seed, 8 * MiB)
    fingerprint_bytes(data)                       # warm tables + conv scratch
    samples = []
    for _ in range(5):
        t0 = time.perf_counter()
        fingerprint_bytes(data)
        samples.append(len(data) / (time.perf_counter() - t0))
    samples.sort()
    return samples[len(samples) // 2]


def _run_once(payload: bytes, mode: str, dest_factory, chunk: int,
              *, tracer=None, task: str = ""):
    """One transfer in one mode; returns (Bps, escape, report)."""
    plan = plan_chunks(len(payload), MOVERS, chunk_bytes=chunk,
                       min_chunk=1, max_chunk=1 << 40)
    dst = dest_factory()
    eng = ChunkedTransfer(BufferSource(payload), dst, plan,
                          pipeline=mode, integrity_workers=VERIFIERS,
                          tracer=tracer, task=task)
    t0 = time.perf_counter()
    rep = eng.run()
    dt = time.perf_counter() - t0
    return len(payload) / dt, int(bytes(dst.buf) != payload), rep


def mix_rows(tag: str, payload: bytes, wire_frac: float, gate: float,
             violations: list[str], *, seed: int = 0, reps: int = 6,
             chunk: int = 8 * MiB, attempts: int = 2):
    """One mix: modes alternate across ``reps`` rounds against the SAME
    wire rating, and the gate judges best-of-reps per mode. On a quiet
    machine every round gives the same answer; on a shared-CPU box, steal
    dips only ever slow a round down, so per-mode maxima converge to the
    clean-window rates the regime actually defines. A failing attempt is
    re-measured once end-to-end (fresh wire rating) before it counts as a
    violation — a genuine regression fails both attempts."""
    rows: list[tuple[str, float, str]] = []
    for attempt in range(attempts):
        cksum_Bps = host_cksum_rate_Bps(seed)
        rates: dict[str, list[float]] = {m: [] for m in MODES}
        total_escapes = 0
        lag = 0.0
        for _ in range(reps):
            for mode in MODES:
                bps, escape, rep = _run_once(
                    payload, mode,
                    lambda n=len(payload), w=wire_frac * cksum_Bps:
                        ThrottledDest(n, w),
                    chunk)
                rates[mode].append(bps)
                total_escapes += escape
                if mode == "pipelined":
                    lag = max(lag, rep.cksum_lag_s / max(1, len(rep.outcomes)))
        best = {m: max(rates[m]) for m in MODES}
        speedup = best["pipelined"] / best["serial"]
        rows = [
            (f"overlap/{tag}/host_cksum_MBps", round(cksum_Bps / 1e6, 1), "MB/s")
        ] + [
            (f"overlap/{tag}/{mode}_MBps", round(best[mode] / 1e6, 2), "MB/s")
            for mode in MODES
        ]
        for mode in ("single_pass", "pipelined"):
            rows.append((f"overlap/{tag}/{mode}_speedup",
                         round(best[mode] / best["serial"], 3), "x"))
        rows.append((f"overlap/{tag}/pipelined_mean_lag_ms",
                     round(lag * 1e3, 3), "ms"))
        rows.append((f"overlap/{tag}/escapes", total_escapes, "transfers"))
        if total_escapes:
            violations.append(f"{tag}: {total_escapes} integrity escapes")
            break                       # escapes are never an environment flake
        if gate <= 0 or speedup >= gate:
            break
        if attempt == attempts - 1:
            violations.append(
                f"{tag}: pipelined/serial {speedup:.2f}x < {gate}x gate")
        else:
            print(f"# {tag}: {speedup:.2f}x < {gate}x — "
                  "re-measuring once (shared-CPU steal window?)")
    return rows


def restart_rows(seed: int, nbytes: int, tmpdir: str,
                 violations: list[str]):
    """Pipelined kill+restart with a lagging verifier: the journal may hold
    ONLY verified chunks, and the restart must re-move none of them."""
    payload = _payload(seed + 77, nbytes)
    plan = plan_chunks(len(payload), 4, chunk_bytes=256 * 1024,
                       min_chunk=1, max_chunk=1 << 40)
    jpath = os.path.join(tmpdir, "overlap-restart.journal")
    lock = threading.Lock()
    calls = [0]
    bomb_after = plan.n_chunks // 2

    def bomb(_chunk, _attempt):
        with lock:
            calls[0] += 1
            if calls[0] > bomb_after:
                raise _HostCrash("host died mid-transfer")

    dst = SlowVerifyDest(len(payload))
    j = ChunkJournal(jpath)
    try:
        ChunkedTransfer(BufferSource(payload), dst, plan, journal=j,
                        fault_injector=bomb, max_retries=0,
                        pipeline="pipelined", integrity_workers=1).run()
        raise RuntimeError("crash bomb never fired")
    except _HostCrash:
        pass
    finally:
        j.close()

    j2 = ChunkJournal(jpath)
    journaled = [(r.offset, r.length) for r in j2.records.values()]
    moved: list[tuple[int, int]] = []

    def record(chunk, _attempt):
        with lock:
            moved.append((chunk.offset, chunk.length))

    rep2 = ChunkedTransfer(BufferSource(payload), dst, plan, journal=j2,
                           fault_injector=record, pipeline="pipelined").run()
    j2.close()
    escapes = int(bytes(dst.buf) != payload)
    re_moved = sum(
        1 for off, ln in set(moved)
        for joff, jln in journaled
        if off < joff + jln and joff < off + ln       # any byte overlap
    )
    if re_moved:
        violations.append(
            f"restart: {re_moved} journaled-and-verified chunks re-moved")
    if escapes:
        violations.append(f"restart: {escapes} integrity escapes")
    return [
        ("overlap/restart/verified_at_crash", len(journaled), "chunks"),
        ("overlap/restart/resumed_chunks", rep2.skipped_chunks, "chunks"),
        ("overlap/restart/re_moved_verified", re_moved, "chunks"),
        ("overlap/restart/escapes", escapes, "transfers"),
    ]


def trace_attr_rows(seed: int, violations: list[str], *,
                    out_dir: str | None = None, attempts: int = 2):
    """Tracing + attribution leg (the observability acceptance gates).

    1. Tracing overhead: best-of-reps pipelined goodput on the gate mix,
       untraced (NullTracer) vs a live bounded Tracer — gated at <= 2%.
    2. Per-mix attribution: one traced pipelined run per mix; the exported
       trace is a Perfetto-loadable artifact, and ``obs.attr`` must show the
       per-phase shares summing to ~100% of makespan with cksum-dominance
       flipping between the cksum-bound and wire-bound mixes.
    """
    from repro.obs.attr import attribute
    from repro.obs.trace import Tracer

    out_dir = out_dir or os.getcwd()
    nbytes = 96 * MiB
    chunk = 8 * MiB
    payload = _payload(seed + 5, nbytes)
    rows: list[tuple[str, float, str]] = []
    artifacts: list[str] = []

    # ---- 1. tracing overhead on the gate mix: interleaved untraced/traced
    # pairs (steal dips hit both populations equally), best-of per side,
    # min over attempts — the tracer's true cost is a handful of deque
    # appends per chunk, so any apparent overhead beyond noise is a bug
    overhead = float("inf")
    for attempt in range(attempts):
        cksum_Bps = host_cksum_rate_Bps(seed)
        base = traced = 0.0
        for _ in range(4):
            bps, _, _ = _run_once(
                payload, "pipelined",
                lambda n=nbytes, w=cksum_Bps: ThrottledDest(n, w), chunk)
            base = max(base, bps)
            bps, _, _ = _run_once(
                payload, "pipelined",
                lambda n=nbytes, w=cksum_Bps: ThrottledDest(n, w), chunk,
                tracer=Tracer(), task="overhead")
            traced = max(traced, bps)
        overhead = min(overhead, max(0.0, 1.0 - traced / base))
        if overhead <= 0.02:
            break
        if attempt == attempts - 1:
            violations.append(
                f"trace: {overhead * 100:.2f}% tracing overhead (> 2% gate)")
        else:
            print(f"# trace overhead {overhead * 100:.2f}% > 2% — "
                  "re-measuring once (shared-CPU steal window?)")
    rows.append(("overlap/trace/overhead_pct", round(overhead * 100, 2), "%"))

    # ---- 2. per-mix traced run -> Perfetto trace + attribution report
    attr_doc: dict[str, dict] = {}
    for attempt in range(attempts):
        cksum_Bps = host_cksum_rate_Bps(seed)
        mix_rows_local: list[tuple[str, float, str]] = []
        artifacts = []
        attr_doc = {}
        flip_ok = sums_ok = True
        # attribution probes the INTERIOR of each regime: cksum_bound rates
        # the wire well above the checksum rate (the modern-NIC shape where
        # the checksum pass is unambiguously the tax), wire_bound well below
        # it — the speedup-gate mixes above sit nearer the boundary where
        # dominance is a coin toss by construction
        for mix, w_frac in (("cksum_bound", 2.5), ("wire_bound", 0.7)):
            tracer = Tracer()
            _run_once(
                payload, "pipelined",
                lambda n=nbytes, w=w_frac * cksum_Bps: ThrottledDest(n, w),
                chunk, tracer=tracer, task=mix)
            tpath = os.path.join(out_dir, f"BENCH_overlap_trace_{mix}.json")
            tracer.export(tpath)
            artifacts.append(os.path.basename(tpath))
            a = attribute(tracer.spans(mix))
            attr_doc[mix] = a.to_json()
            print(a.format(f"pipelined/{mix}"))
            total_share = sum(a.shares().values())
            sums_ok &= abs(total_share - 1.0) <= 0.01
            for phase in ("wire", "cksum", "stall", "journal", "queue", "idle"):
                mix_rows_local.append((f"overlap/attr/{mix}/{phase}_share",
                                       round(a.share(phase), 4), "frac"))
            mix_rows_local.append((f"overlap/attr/{mix}/share_sum",
                                   round(total_share, 4), "frac"))
        flip_ok = (attr_doc["cksum_bound"]["shares"]["cksum"]
                   > attr_doc["cksum_bound"]["shares"]["wire"]) and \
                  (attr_doc["wire_bound"]["shares"]["wire"]
                   > attr_doc["wire_bound"]["shares"]["cksum"])
        if sums_ok and flip_ok:
            break
        if attempt == attempts - 1:
            if not sums_ok:
                violations.append("attr: per-phase shares do not sum to "
                                  "~100% of makespan")
            if not flip_ok:
                violations.append(
                    "attr: cksum-dominance did not flip between mixes "
                    f"(cksum_bound {attr_doc['cksum_bound']['shares']}, "
                    f"wire_bound {attr_doc['wire_bound']['shares']})")
        else:
            print("# attribution flip/sum check failed — re-measuring once")
    rows += mix_rows_local

    apath = os.path.join(out_dir, "BENCH_overlap_attribution.json")
    import json as _json
    with open(apath, "w", encoding="utf-8") as fh:
        _json.dump(attr_doc, fh, indent=2, sort_keys=True)
        fh.write("\n")
    artifacts.append(os.path.basename(apath))
    return rows, artifacts


def pow_microbench_rows(violations: list[str]):
    """Digest-algebra hot path: bigint pow() calls per merge chain must be
    >= 5x below the uncached 4-per-merge cost (the LRU'd r^len tables)."""
    n = 256
    digests = [fingerprint_bytes(bytes([i % 251]) * 4096) for i in range(n)]
    integrity_mod.clear_pow_caches()
    before = integrity_mod.pow_call_count()
    out = digests[0]
    for d in digests[1:]:
        out = out.merge(d)
    calls = integrity_mod.pow_call_count() - before
    baseline = 4 * (n - 1)                       # NBASES pows per uncached merge
    ratio = baseline / max(1, calls)
    if ratio < 5.0:
        violations.append(
            f"pow microbench: only {ratio:.1f}x fewer pow() calls (< 5x gate)")
    return [
        ("overlap/pow/merge_chain_len", n - 1, "merges"),
        ("overlap/pow/bigint_pow_calls", calls, "calls"),
        ("overlap/pow/uncached_baseline", baseline, "calls"),
        ("overlap/pow/reduction", round(ratio, 1), "x"),
    ]


def virtual_rows():
    """Deterministic 1 TB fluid model on the calibrated site configs.

    Per-mover rates: wire w = min(mover_gbps), checksum c = dst.cksum_gbps.
    Per-byte cost: serial 1/w + 2/c; single-pass max(1/w,1/c) + 1/c (digest
    overlaps the stream, verify inline); pipelined max(1/w,1/c) (verification
    on dedicated checksum workers, one per mover). Pure arithmetic —
    byte-identical across runs."""
    Gb = 1e9 / 8
    total = 1e12
    movers = 64
    rows = []
    w = min(ALCF.mover_gbps, NERSC.mover_gbps) * Gb
    for label, c in (("paper", NERSC.cksum_gbps * Gb),
                     ("cksum_starved", 1.0 * Gb)):
        serial = (1 / w + 2 / c)
        single = (max(1 / w, 1 / c) + 1 / c)
        pipe = max(1 / w, 1 / c)
        pre = f"overlap/virtual_1TB/{label}"
        rows += [
            (f"{pre}/serial_s", round(total / movers * serial, 1), "s"),
            (f"{pre}/single_pass_s", round(total / movers * single, 1), "s"),
            (f"{pre}/pipelined_s", round(total / movers * pipe, 1), "s"),
            (f"{pre}/pipelined_speedup", round(serial / pipe, 3), "x"),
        ]
    return rows


# ---------------------------------------------------------------------------
# striped mode (--striped): intra-chunk striping + fused batch integrity
# ---------------------------------------------------------------------------
class PerStreamThrottledDest:
    """BufferDest where EACH writer thread has its own wire rating.

    This is the per-stream-bottleneck shape intra-chunk striping exists for
    (per-TCP-stream pacing, per-OST bandwidth caps): a single mover tops out
    at ``stream_rate_Bps`` no matter how fast the path's aggregate is, while
    N concurrent stripe movers each get a full stream's worth. Token-bucket
    pacing per thread, same >=20 ms sleep quanta as ThrottledDest."""

    def __init__(self, total_bytes: int, stream_rate_Bps: float):
        self._inner = BufferDest(total_bytes)
        self.rate_Bps = stream_rate_Bps
        self._local = threading.local()

    @property
    def buf(self):
        return self._inner.buf

    def write(self, offset, data):
        debt = getattr(self._local, "debt", 0.0) + len(data) / self.rate_Bps
        if debt >= 0.02:
            t0 = time.perf_counter()
            time.sleep(debt)
            debt -= time.perf_counter() - t0
        self._local.debt = debt
        self._inner.write(offset, data)

    def read_back(self, offset, length):          # dest-local re-read: full speed
        return self._inner.read_back(offset, length)

    def read_back_into(self, offset, view):
        return self._inner.read_back_into(offset, view)

    def read_back_view(self, offset, length):
        return self._inner.read_back_view(offset, length)


def stripe_goodput_rows(payload: bytes, stream_frac: float, gate: float,
                        violations: list[str], *, seed: int = 0,
                        reps: int = 3, stripes: int = 4, attempts: int = 2):
    """Striped vs single-stream pipelined movement of ONE large chunk on a
    per-stream-rated wire. Same chunk boundaries, same verify capacity —
    the only variable is whether the chunk crosses as one stream or as
    ``stripes`` concurrent sub-streams. Gate: striped >= ``gate``x."""
    chunk = len(payload)
    rows: list[tuple[str, float, str]] = []
    for attempt in range(attempts):
        cksum_Bps = host_cksum_rate_Bps(seed)
        rate = stream_frac * cksum_Bps
        best = {"single": 0.0, "striped": 0.0}
        escapes = 0
        striped_chunks = 0
        for _ in range(reps):
            for leg, n_str in (("single", 1), ("striped", stripes)):
                plan = plan_chunks(chunk, max(1, n_str), chunk_bytes=chunk,
                                   min_chunk=1, max_chunk=1 << 40)
                dst = PerStreamThrottledDest(chunk, rate)
                eng = ChunkedTransfer(
                    BufferSource(payload), dst, plan, pipeline="pipelined",
                    integrity_workers=stripes, stripes=n_str,
                    stripe_min_bytes=MiB)
                t0 = time.perf_counter()
                rep = eng.run()
                dt = time.perf_counter() - t0
                best[leg] = max(best[leg], chunk / dt)
                escapes += int(bytes(dst.buf) != payload)
                if leg == "striped":
                    striped_chunks = rep.striped_chunks
        speedup = best["striped"] / best["single"]
        rows = [
            ("stripe/goodput/host_cksum_MBps", round(cksum_Bps / 1e6, 1), "MB/s"),
            ("stripe/goodput/stream_rate_MBps", round(rate / 1e6, 1), "MB/s"),
            ("stripe/goodput/chunk_MB", round(chunk / 1e6), "MB"),
            ("stripe/goodput/stripes", stripes, "streams"),
            ("stripe/goodput/striped_chunks", striped_chunks, "chunks"),
            ("stripe/goodput/single_MBps", round(best["single"] / 1e6, 2), "MB/s"),
            ("stripe/goodput/striped_MBps", round(best["striped"] / 1e6, 2), "MB/s"),
            ("stripe/goodput/speedup", round(speedup, 3), "x"),
            ("stripe/goodput/escapes", escapes, "transfers"),
        ]
        if escapes:
            violations.append(f"stripe goodput: {escapes} integrity escapes")
            break
        if not striped_chunks:
            violations.append("stripe goodput: striping never engaged")
            break
        if speedup >= gate:
            break
        if attempt == attempts - 1:
            violations.append(
                f"stripe goodput: striped/single {speedup:.2f}x < {gate}x gate")
        else:
            print(f"# stripe goodput {speedup:.2f}x < {gate}x — re-measuring "
                  "once (shared-CPU steal window?)")
    return rows


def fused_drain_rows(seed: int, violations: list[str], *, jobs: int = 512,
                     granule: int = 64 * 1024, reps: int = 5,
                     attempts: int = 2, gate: float = 1.2):
    """Fused batch integrity vs per-chunk host calls at the engine drain.

    The same ``jobs`` equal-length verify jobs drain through one checksum
    worker twice: ``fuse=False`` digests each landed granule with its own
    host call; ``fuse=True`` collects up to a batch per drain pass and
    dispatches ONE stacked GEMM over all of them (``fingerprint_rows``).
    The small-granule regime is exactly where a degraded hop's autotuned
    granule lands — and where per-call overhead bites. Gate: >= ``gate``x."""
    total = jobs * granule
    payload = _payload(seed + 9, total)
    dst = BufferDest(total)
    dst.write(0, payload)
    expected = fingerprint_many(
        [payload[i * granule:(i + 1) * granule] for i in range(jobs)])

    def drain_s(fuse: bool) -> tuple[float, int]:
        errs: list[str] = []
        eng = IntegrityEngine(
            workers=1, fuse=fuse, batch=64,
            on_verified=lambda j, l, c: None,
            on_corrupt=lambda j, a, l: errs.append(f"corrupt {j.key}"),
            on_error=lambda j, e: errs.append(f"error {j.key}: {e}"),
        )
        t0 = time.perf_counter()
        for i in range(jobs):
            eng.submit(VerifyJob(key=i, offset=i * granule, length=granule,
                                 expected=expected[i], dest=dst,
                                 enqueued_s=time.perf_counter()))
        if not eng.drain(timeout=120.0):
            errs.append("drain timed out")
        dt = time.perf_counter() - t0
        fused_batches = eng.stats.fused_batches
        eng.close()
        if errs:
            raise RuntimeError("; ".join(errs[:3]))
        return dt, fused_batches

    rows: list[tuple[str, float, str]] = []
    for attempt in range(attempts):
        drain_s(True)                              # warm tables + scratch
        per_chunk = min(drain_s(False)[0] for _ in range(reps))
        fused_best = float("inf")
        fused_batches = 0
        for _ in range(reps):
            dt, nb = drain_s(True)
            if dt < fused_best:
                fused_best, fused_batches = dt, nb
        speedup = per_chunk / fused_best
        rows = [
            ("stripe/fused/jobs", jobs, "granules"),
            ("stripe/fused/granule_KiB", granule // 1024, "KiB"),
            ("stripe/fused/per_chunk_ms", round(per_chunk * 1e3, 2), "ms"),
            ("stripe/fused/fused_ms", round(fused_best * 1e3, 2), "ms"),
            ("stripe/fused/fused_batches", fused_batches, "dispatches"),
            ("stripe/fused/speedup", round(speedup, 3), "x"),
        ]
        if not fused_batches:
            violations.append("fused drain: fusion never engaged")
            break
        if speedup >= gate:
            break
        if attempt == attempts - 1:
            violations.append(
                f"fused drain: fused/per-chunk {speedup:.2f}x < {gate}x gate")
        else:
            print(f"# fused drain {speedup:.2f}x < {gate}x — re-measuring once")

    # detection parity: a corrupted granule must be caught by the FUSED path
    bad = bytearray(payload[:granule])
    bad[granule // 2] ^= 0x41
    dst_bad = BufferDest(total)
    dst_bad.write(0, bytes(bad) + payload[granule:])
    caught: list[int] = []
    eng = IntegrityEngine(workers=1, fuse=True, batch=64,
                          on_verified=lambda j, l, c: None,
                          on_corrupt=lambda j, a, l: caught.append(j.key),
                          on_error=lambda j, e: None)
    for i in range(jobs):
        eng.submit(VerifyJob(key=i, offset=i * granule, length=granule,
                             expected=expected[i], dest=dst_bad,
                             enqueued_s=time.perf_counter()))
    eng.drain(timeout=120.0)
    eng.close()
    missed = int(caught != [0])
    if missed:
        violations.append(
            f"fused drain: corrupted granule escaped fused verification "
            f"(caught={caught!r})")
    rows.append(("stripe/fused/corruption_escapes", missed, "granules"))
    return rows


def stripe_restart_rows(seed: int, nbytes: int, tmpdir: str,
                        violations: list[str], *, stripes: int = 4):
    """Striped pipelined kill+restart: the journal holds only land-AND-
    verified stripes, and the restart must re-move none of their bytes."""
    payload = _payload(seed + 177, nbytes)
    plan = plan_chunks(len(payload), stripes, chunk_bytes=2 * MiB,
                       min_chunk=1, max_chunk=1 << 40)
    jpath = os.path.join(tmpdir, "stripe-restart.journal")
    lock = threading.Lock()
    calls = [0]

    def bomb(_chunk, _attempt):
        with lock:
            calls[0] += 1
            if calls[0] > 3 * stripes:
                raise _HostCrash("host died mid-stripe")

    dst = SlowVerifyDest(len(payload))
    j = ChunkJournal(jpath)
    try:
        ChunkedTransfer(BufferSource(payload), dst, plan, journal=j,
                        fault_injector=bomb, max_retries=0,
                        pipeline="pipelined", integrity_workers=1,
                        stripes=stripes, stripe_min_bytes=256 * 1024).run()
        raise RuntimeError("crash bomb never fired")
    except _HostCrash:
        pass
    finally:
        j.close()

    j2 = ChunkJournal(jpath)
    journaled = [(r.offset, r.length) for r in j2.records.values()]
    moved: list[tuple[int, int]] = []

    def record(chunk, _attempt):
        with lock:
            moved.append((chunk.offset, chunk.length))

    rep2 = ChunkedTransfer(BufferSource(payload), dst, plan, journal=j2,
                           fault_injector=record, pipeline="pipelined",
                           stripes=stripes, stripe_min_bytes=256 * 1024).run()
    j2.close()
    escapes = int(bytes(dst.buf) != payload)
    re_moved = sum(
        1 for off, ln in set(moved)
        for joff, jln in journaled
        if off < joff + jln and joff < off + ln       # any byte overlap
    )
    if re_moved:
        violations.append(
            f"stripe restart: {re_moved} journaled stripes re-moved")
    if escapes:
        violations.append(f"stripe restart: {escapes} integrity escapes")
    if not journaled:
        violations.append("stripe restart: nothing was journaled before "
                          "the crash (leg proved nothing)")
    return [
        ("stripe/restart/verified_at_crash", len(journaled), "stripes"),
        ("stripe/restart/resumed_records", rep2.skipped_chunks, "records"),
        ("stripe/restart/re_moved_journaled", re_moved, "stripes"),
        ("stripe/restart/escapes", escapes, "transfers"),
    ]


def striped_main(args) -> int:
    """--striped: the intra-chunk striping + fused-integrity gate suite.

    Writes BENCH_stripe.json. Gates: striped goodput >= 1.3x single-stream
    pipelined on the per-stream wire-bound mix (one >= 256 MB chunk), fused
    integrity drain >= 1.2x per-chunk host calls, 0 integrity escapes
    everywhere, and a kill+restart leg re-moving 0 journaled stripes."""
    t_start = time.perf_counter()
    rows: list[tuple[str, float, str]] = []
    violations: list[str] = []

    nbytes = 256 * MiB     # the gate is defined at >= 256 MB chunks
    reps = 2 if args.quick else 4
    payload = _payload(args.seed, nbytes)
    rows += stripe_goodput_rows(payload, 0.4, 1.3, violations,
                                seed=args.seed, reps=reps)
    del payload
    rows += fused_drain_rows(args.seed, violations,
                             jobs=256 if args.quick else 512,
                             reps=3 if args.quick else 5)
    tmp_base = "/dev/shm" if os.path.isdir("/dev/shm") else None
    with tempfile.TemporaryDirectory(prefix="stripe-", dir=tmp_base) as tmpdir:
        rows += stripe_restart_rows(args.seed, 8 * MiB, tmpdir, violations)

    total_escapes = sum(v for n, v, _u in rows
                        if n.endswith("/escapes") or n.endswith("_escapes"))
    rows.append(("stripe/total_escapes", total_escapes, "transfers"))

    print("name,value,unit")
    for name, val, unit in rows:
        print(f"{name},{val},{unit}")
    path = emit("stripe", rows, seed=args.seed,
                args={"quick": args.quick, "stripes": 4,
                      "chunk_bytes": nbytes},
                elapsed_s=round(time.perf_counter() - t_start, 3),
                force=args.force)
    print(f"# wrote {path}")
    if violations:
        print("\nSTRIPE GATE VIOLATIONS:", file=sys.stderr)
        for v in violations:
            print(f"  - {v}", file=sys.stderr)
        return 1
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--striped", action="store_true",
                    help="run the striping + fused-integrity gate suite "
                         "(writes BENCH_stripe.json)")
    ap.add_argument("--force", action="store_true",
                    help="overwrite a BENCH_overlap.json from another git rev")
    args = ap.parse_args(argv)
    if args.striped:
        return striped_main(args)

    t_start = time.perf_counter()
    rows: list[tuple[str, float, str]] = []
    violations: list[str] = []

    sizes = [("64MB", 64 * MiB, 6)]
    if not args.quick:
        sizes.append(("1GB", 1024 * MiB, 2))
    for label, nbytes, reps in sizes:
        payload = _payload(args.seed, nbytes)
        # the wire is rated against the checksum rate measured IMMEDIATELY
        # before each mix attempt: the ratio w/c is what defines a regime,
        # not the absolute speed of the box (which drifts under CPU jitter)
        for mix, w_frac, gate in (("cksum_bound", 1.0, 1.4),
                                  ("wire_bound", 0.7, 1.15)):
            rows += mix_rows(f"{label}/{mix}", payload, w_frac, gate,
                             violations, seed=args.seed, reps=reps)

    tmp_base = "/dev/shm" if os.path.isdir("/dev/shm") else None
    with tempfile.TemporaryDirectory(prefix="overlap-", dir=tmp_base) as tmpdir:
        rows += restart_rows(args.seed, 8 * MiB, tmpdir, violations)
    trace_rows, artifacts = trace_attr_rows(args.seed, violations)
    rows += trace_rows
    rows += pow_microbench_rows(violations)
    rows += virtual_rows()

    total_escapes = sum(v for n, v, _u in rows if n.endswith("/escapes"))
    rows.append(("overlap/total_escapes", total_escapes, "transfers"))

    print("name,value,unit")
    for name, val, unit in rows:
        print(f"{name},{val},{unit}")
    path = emit("overlap", rows, seed=args.seed,
                args={"quick": args.quick, "movers": MOVERS,
                      "integrity_workers": VERIFIERS},
                elapsed_s=round(time.perf_counter() - t_start, 3),
                artifacts=artifacts, force=args.force)
    print(f"# wrote {path}")
    if violations:
        print("\nOVERLAP GATE VIOLATIONS:", file=sys.stderr)
        for v in violations:
            print(f"  - {v}", file=sys.stderr)
        return 1
    return 0


# ---------------------------------------------------------------------------
# legacy figure sections (imported by benchmarks/run.py)
# ---------------------------------------------------------------------------
def _measure(payload: bytes, movers: int, chunk: int, integrity: bool,
             reps: int = 2) -> float:
    best = float("inf")
    for _ in range(reps):
        plan = plan_chunks(len(payload), movers, chunk_bytes=chunk,
                           min_chunk=1, max_chunk=1 << 40)
        dst = BufferDest(len(payload))
        t0 = time.perf_counter()
        ChunkedTransfer(BufferSource(payload), dst, plan,
                        integrity=integrity).run()
        best = min(best, time.perf_counter() - t0)
    return best


def movers_scaling(size_mib: int = 192):
    """Single 'large file': mover count sweep (paper Fig. 10, 1-file column)."""
    rng = np.random.default_rng(0)
    payload = rng.integers(0, 256, size_mib * MiB, dtype=np.uint8).tobytes()
    rows = []
    base = None
    for movers in (1, 2, 4, 8):
        dt = _measure(payload, movers, 8 * MiB, True)
        base = base or dt
        rows.append((f"overlap/1file/movers{movers}",
                     round(size_mib / dt, 1), "MiB/s"))
    rows.append(("overlap/1file/speedup_8v1", round(base / dt, 2), "x"))
    return rows


def checksum_visibility(size_mib: int = 192):
    """Visible integrity cost, unchunked vs chunked (paper Fig. 8)."""
    rng = np.random.default_rng(1)
    payload = rng.integers(0, 256, size_mib * MiB, dtype=np.uint8).tobytes()
    rows = []
    t_un_no = _measure(payload, 1, len(payload), False)
    t_un_ck = _measure(payload, 1, len(payload), True)
    t_ch_no = _measure(payload, 8, 8 * MiB, False)
    t_ch_ck = _measure(payload, 8, 8 * MiB, True)
    rows.append(("overlap/checksum_cost/unchunked_s", round(t_un_ck - t_un_no, 3), "s"))
    rows.append(("overlap/checksum_cost/chunked_s", round(t_ch_ck - t_ch_no, 3), "s"))
    hidden = 1.0 - (t_ch_ck - t_ch_no) / max(1e-9, t_un_ck - t_un_no)
    rows.append(("overlap/checksum_cost/fraction_hidden", round(hidden, 2), "frac"))
    return rows


def chunk_size_sweep(size_mib: int = 128):
    """Chunk-size rise-and-fall on real threads (paper Fig. 6)."""
    rng = np.random.default_rng(2)
    payload = rng.integers(0, 256, size_mib * MiB, dtype=np.uint8).tobytes()
    rows = []
    for chunk_mib in (1, 4, 16, 64, size_mib):
        dt = _measure(payload, 8, chunk_mib * MiB, True)
        rows.append((f"overlap/chunksize/{chunk_mib}MiB",
                     round(size_mib / dt, 1), "MiB/s"))
    return rows


def kernel_rates():
    """Device-side digest kernel rates (interpret mode — correctness path)."""
    import jax.numpy as jnp
    from repro.kernels import fingerprint_array
    rows = []
    x = jnp.zeros((4 * 1024 * 1024,), jnp.float32)  # 16 MiB
    fingerprint_array(x).block_until_ready()
    t0 = time.perf_counter()
    fingerprint_array(x).block_until_ready()
    dt = time.perf_counter() - t0
    rows.append(("kernel/checksum_interp_rate", round(16 / dt, 1), "MiB/s"))
    rng = np.random.default_rng(3)
    big = rng.integers(0, 256, 64 * MiB, dtype=np.uint8).tobytes()
    t0 = time.perf_counter()
    fingerprint_bytes(big)
    rows.append(("host/checksum_rate", round(64 / (time.perf_counter() - t0), 1),
                 "MiB/s"))
    return rows


if __name__ == "__main__":
    sys.exit(main())
