"""Autotune benchmark: static vs closed-loop chunking under step changes.

Sweeps the ``repro.tune.harness`` step-change scenarios on the REAL threaded
engine — the path regime shifts mid-flight, at a byte-progress threshold, so
every run hits the step at the same point:

  * ``link_degrade_50pct`` — at 50% the WAN hop degrades for good (4x less
    bandwidth + loss that makes large chunk writes fail). The static plan
    keeps paying full-chunk retries; the tuned engine AIMD-shrinks its tail.
    GATED: tuned goodput must be >= 1.3x static.
  * ``cksum_starvation``   — at 50% read-back verification cost jumps to a
    large per-operation latency; the tuned engine grows its tail chunks to
    amortise it.
  * ``loss_spike``         — a transient lossy window (50%..75%); the tuned
    engine shrinks into it and climbs back out.

Every leg checks byte-exact delivery (integrity escapes MUST be 0). A
kill+restart leg runs the degrade scenario with tuning active, crashes the
host mid-flight (after the warm-start re-plan has changed the journal's
chunk boundaries), restarts, and asserts that no journaled byte region was
moved again (re_moved_journaled MUST be 0).

``virtual_rows()`` adds a deterministic SimTuner sweep on the calibrated
simulator (static 500 MB vs predicted-optimal seed); it is pure model
arithmetic, so two in-process runs must produce identical metrics —
``tests/test_determinism.py`` holds this file to that.

Prints ``name,value,unit`` CSV, writes ``BENCH_autotune.json`` via
``benchmarks._results``, exits non-zero on any gate violation.

Run: PYTHONPATH=src python -m benchmarks.autotune [--quick] [--seed N]
"""
from __future__ import annotations

import argparse
import os
import sys
import tempfile
import threading
import time

import numpy as np

from benchmarks._results import emit
from repro.core.chunker import plan_chunks
from repro.core.journal import ChunkJournal
from repro.core.transfer import BufferSource, ChunkedTransfer, FileDest
from repro.core.simulator import ALCF, NERSC
from repro.tune import ChunkController, SimTuner
from repro.tune.controller import HOLD, MD, SEED
from repro.tune.harness import STEP_SCENARIOS, StepPath

KiB, MiB = 1024, 1024 * 1024

# per-scenario static baseline chunk size (what plan_auto would pin for the
# pre-step regime) and tuned-controller bounds
SCENARIO_CHUNK0 = {
    "link_degrade_50pct": 512 * KiB,
    "cksum_starvation": 128 * KiB,
    "loss_spike": 512 * KiB,
}
TUNE_BOUNDS = (16 * KiB, 2 * MiB)


class _HostCrash(Exception):
    """Crash bomb for the kill+restart leg."""


def _payload(seed: int, nbytes: int) -> bytes:
    return np.random.default_rng(seed).integers(0, 256, nbytes, dtype=np.uint8).tobytes()


def _controller(chunk0: int) -> ChunkController:
    # noise-hardened settings: wall-clock rates on a local harness carry
    # 20-40% CPU noise, so the deadband is wide (25%) and only a halving
    # counts as a step change; epochs of 4 average single-sample jitter out
    lo, hi = TUNE_BOUNDS
    return ChunkController(
        chunk_bytes=chunk0, min_chunk=lo, max_chunk=hi,
        epoch_chunks=4, md_factor=0.35, climb_factor=1.5,
        degrade_threshold=0.5, hysteresis=0.25,
    )


def run_leg(payload: bytes, scenario_name: str, *, tuned: bool, seed: int,
            tmpdir: str, tag: str, movers: int = 2,
            injector=None, journal_path: str | None = None,
            controller: ChunkController | None = None):
    """One transfer through a step-change scenario; returns
    (goodput_Bps, report, controller, escapes)."""
    del seed                       # the harness loss model is deterministic
    chunk0 = SCENARIO_CHUNK0[scenario_name]
    scenario = STEP_SCENARIOS[scenario_name]()
    plan = plan_chunks(len(payload), movers, chunk_bytes=chunk0,
                       min_chunk=1, max_chunk=1 << 50)
    out_path = os.path.join(tmpdir, f"{tag}.out")
    path = StepPath(scenario, len(payload))
    ctrl = controller if controller is not None else (
        _controller(chunk0) if tuned else None)
    jpath = journal_path or os.path.join(tmpdir, f"{tag}.journal")
    journal = ChunkJournal(jpath)
    try:
        eng = ChunkedTransfer(
            path.wrap_source(BufferSource(payload)),
            path.wrap_dest(FileDest(out_path, len(payload))),
            plan, journal=journal,
            tuner=ctrl, max_retries=3000, fault_injector=injector,
        )
        t0 = time.perf_counter()
        report = eng.run()
        t_end = time.perf_counter()
        secs = t_end - t0
    finally:
        journal.close()
    with open(out_path, "rb") as fh:
        escapes = int(fh.read() != payload)
    # post-step goodput: bytes landed after the first phase change over the
    # wall time since it — the regime where adaptation matters (and where
    # the 1.3x gate is judged; whole-transfer goodput is reported too)
    if path.phase_change_walls:
        post_bytes = (1.0 - path.phase_changes[0]) * len(payload)
        post_dt = t_end - path.phase_change_walls[0]
        post_goodput = post_bytes / post_dt if post_dt > 0 else 0.0
    else:
        post_goodput = len(payload) / secs
    return len(payload) / secs, post_goodput, report, ctrl, escapes


def _converge_epochs(ctrl: ChunkController | None) -> int:
    """Epochs between the first MD (the step change registering) and the
    last size-changing decision — how long re-convergence took."""
    if ctrl is None:
        return 0
    moves = [d.epoch for d in ctrl.decisions
             if d.action not in (HOLD, SEED)]
    mds = [d.epoch for d in ctrl.decisions if d.action == MD]
    if not mds or not moves:
        return 0
    return max(moves) - mds[0] + 1


def scenario_rows(name: str, seed: int, nbytes: int, tmpdir: str,
                  violations: list[str]) -> list[tuple[str, float, str]]:
    payload = _payload(seed, nbytes)
    g_static, p_static, rep_s, _c, esc_s = run_leg(
        payload, name, tuned=False, seed=seed, tmpdir=tmpdir,
        tag=f"{name}-static-{seed}")
    g_tuned, p_tuned, rep_t, ctrl, esc_t = run_leg(
        payload, name, tuned=True, seed=seed, tmpdir=tmpdir,
        tag=f"{name}-tuned-{seed}")
    speedup = g_tuned / g_static if g_static > 0 else 0.0
    post_speedup = p_tuned / p_static if p_static > 0 else 0.0
    pre = f"autotune/{name}"
    rows = [
        (f"{pre}/static_goodput_MBps", round(g_static / 1e6, 3), "MB/s"),
        (f"{pre}/tuned_goodput_MBps", round(g_tuned / 1e6, 3), "MB/s"),
        (f"{pre}/speedup", round(speedup, 3), "x"),
        (f"{pre}/static_post_step_MBps", round(p_static / 1e6, 3), "MB/s"),
        (f"{pre}/tuned_post_step_MBps", round(p_tuned / 1e6, 3), "MB/s"),
        (f"{pre}/post_step_speedup", round(post_speedup, 3), "x"),
        (f"{pre}/replans", rep_t.replans, "replans"),
        (f"{pre}/chunk_final_KiB", round(rep_t.chunk_bytes_final / KiB, 1), "KiB"),
        (f"{pre}/converge_epochs", _converge_epochs(ctrl), "epochs"),
        (f"{pre}/escapes", esc_s + esc_t, "transfers"),
    ]
    if esc_s or esc_t:
        violations.append(f"{name}: {esc_s + esc_t} integrity escapes")
    if name == "link_degrade_50pct" and post_speedup < 1.3:
        violations.append(
            f"{name}: tuned/static post-step goodput "
            f"{post_speedup:.2f}x < 1.3x gate")
    return rows


def restart_rows(seed: int, nbytes: int, tmpdir: str,
                 violations: list[str]) -> list[tuple[str, float, str]]:
    """Kill+restart with tuning active: the leg-1 journal holds re-planned
    (non-static) chunk boundaries; leg 2 must resume by byte region and
    never re-move a journaled byte."""
    name = "link_degrade_50pct"
    payload = _payload(seed + 1000, nbytes)
    jpath = os.path.join(tmpdir, f"restart-{seed}.journal")
    lock = threading.Lock()
    calls = [0]
    bomb_after = max(6, (nbytes // SCENARIO_CHUNK0[name]) // 2)

    def bomb(_chunk, _attempt):
        with lock:
            calls[0] += 1
            if calls[0] > bomb_after:
                raise _HostCrash("host died mid-transfer")

    # warm-started controller: its first act is a tail re-plan, so the
    # journal ends up holding tuned (non-static) chunk boundaries
    ctrl1 = _controller(128 * KiB)
    try:
        run_leg(payload, name, tuned=True, seed=seed, tmpdir=tmpdir,
                tag=f"restart-{seed}", injector=bomb, journal_path=jpath,
                controller=ctrl1)
    except (_HostCrash, RuntimeError, IOError):
        pass                       # the crash is the point

    probe = ChunkJournal(jpath)
    journaled = [(r.offset, r.length) for r in probe.records.values()]
    resumed = len(probe.records)
    probe.close()

    moved: list[tuple[int, int]] = []

    def record(chunk, _attempt):
        with lock:
            moved.append((chunk.offset, chunk.length))

    _g, _p, rep2, _c, esc = run_leg(
        payload, name, tuned=True, seed=seed + 7, tmpdir=tmpdir,
        tag=f"restart-{seed}", injector=record, journal_path=jpath)

    re_moved = sum(
        1 for off, ln in set(moved)
        for joff, jln in journaled
        if off < joff + jln and joff < off + ln   # any byte overlap
    )
    if re_moved:
        violations.append(f"restart: {re_moved} journaled regions re-moved")
    if esc:
        violations.append(f"restart: {esc} integrity escapes")
    return [
        ("autotune/restart/journaled_at_crash", resumed, "chunks"),
        ("autotune/restart/resumed_chunks", rep2.skipped_chunks, "chunks"),
        ("autotune/restart/re_moved_journaled", re_moved, "chunks"),
        ("autotune/restart/escapes", esc, "transfers"),
    ]


def virtual_rows() -> list[tuple[str, float, str]]:
    """Deterministic SimTuner sweep on the calibrated simulator: the warm
    start the controller gets for free, vs the paper-default 500 MB static
    chunk. Pure model arithmetic — byte-identical across runs."""
    rows: list[tuple[str, float, str]] = []
    tuner = SimTuner(ALCF, NERSC)
    for gb in (100, 500):
        total = gb * 10**9
        static = 500 * 10**6
        t_static = tuner.predict_seconds(total, static)
        best = tuner.seed_chunk(total)
        t_best = tuner.predict_seconds(total, best)
        lo, hi = tuner.bounds(total)
        pre = f"autotune/virtual/{gb}GB"
        rows += [
            (f"{pre}/sim_seed_MB", round(best / 1e6, 3), "MB"),
            (f"{pre}/bounds_lo_MB", round(lo / 1e6, 3), "MB"),
            (f"{pre}/bounds_hi_MB", round(hi / 1e6, 3), "MB"),
            (f"{pre}/static_500MB_seconds", round(t_static, 3), "s"),
            (f"{pre}/seeded_seconds", round(t_best, 3), "s"),
            (f"{pre}/seed_speedup", round(t_static / t_best, 4), "x"),
        ]
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--force", action="store_true",
                    help="overwrite a BENCH_autotune.json from another git rev")
    args = ap.parse_args(argv)

    t_start = time.perf_counter()
    nbytes = (10 * MiB if args.quick else 16 * MiB) + 4093
    rows: list[tuple[str, float, str]] = []
    violations: list[str] = []

    # prefer tmpfs: the harness measures wire economics; a slow journal
    # filesystem (e.g. 9p) would add ~100ms of fsync per chunk and turn
    # every scenario into a journal benchmark
    tmp_base = "/dev/shm" if os.path.isdir("/dev/shm") else None
    with tempfile.TemporaryDirectory(prefix="autotune-", dir=tmp_base) as tmpdir:
        for name in STEP_SCENARIOS:
            rows += scenario_rows(name, args.seed, nbytes, tmpdir, violations)
        rows += restart_rows(args.seed, nbytes, tmpdir, violations)
    rows += virtual_rows()

    total_escapes = sum(v for n, v, _u in rows if n.endswith("/escapes"))
    rows.append(("autotune/total_escapes", total_escapes, "transfers"))

    print("name,value,unit")
    for name, val, unit in rows:
        print(f"{name},{val},{unit}")
    path = emit("autotune", rows, seed=args.seed,
                args={"quick": args.quick, "payload_bytes": nbytes},
                elapsed_s=round(time.perf_counter() - t_start, 3),
                force=args.force)
    print(f"# wrote {path}")
    if violations:
        print("\nAUTOTUNE GATE VIOLATIONS:", file=sys.stderr)
        for v in violations:
            print(f"  - {v}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
