"""Roofline analysis from dry-run artifacts (EXPERIMENTS.md §Roofline).

Per (arch x shape x mesh) cell, three time lower bounds on TPU v5e:

    compute    = HLO_FLOPs_per_device / 197e12        (bf16 MXU peak)
    memory     = HLO_bytes_per_device / 819e9         (HBM bandwidth)
    collective = interconnect_bytes_per_device / 50e9 (per-link ICI)

FLOPs/bytes come from the loop-aware reduced-layer extrapolation (dry-run
"extrapolated" block — raw cost_analysis counts while bodies once);
collective bytes from ring-model accounting over the partitioned HLO.
MODEL_FLOPS = 6*N*D (dense) or 6*N_active*D (MoE) measures how much of the
compiled compute is "useful" (catches remat/dispatch/capacity waste).
"""
from __future__ import annotations

import json
import os

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
LINK_BW = 50e9               # bytes/s per ICI link (~)

CKEYS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
         "collective-permute")


def model_flops(rec: dict) -> float:
    """6*N(active)*D for train; 2*N*D for prefill; 2*N*B new tokens for decode."""
    n = rec["active_param_count"]
    shape = rec["shape"]
    seq = {"train_4k": 4096, "prefill_32k": 32768, "decode_32k": 1,
           "long_500k": 1}[shape]
    batch = {"train_4k": 256, "prefill_32k": 32, "decode_32k": 128,
             "long_500k": 1}[shape]
    tokens = batch * seq
    mult = 6 if shape == "train_4k" else 2
    return mult * n * tokens


def analyze_cell(rec: dict) -> dict | None:
    if "flops_per_device" not in rec:
        return None
    ex = rec.get("extrapolated") or rec
    n_dev = rec["devices"]
    coll = sum(max(0.0, ex.get(k, 0.0)) for k in CKEYS)
    compute_s = max(0.0, ex["flops_per_device"]) / PEAK_FLOPS
    # extrapolation can go slightly negative for tiny decode bodies: floor at
    # the raw (loop-counted-once) measurement, which is a lower bound.
    memory_s = max(ex.get("bytes_accessed", 0.0),
                   rec.get("bytes_accessed", 0.0)) / HBM_BW
    collective_s = coll / LINK_BW
    dominant = max(
        (("compute", compute_s), ("memory", memory_s), ("collective", collective_s)),
        key=lambda kv: kv[1],
    )[0]
    mf = model_flops(rec)
    hlo_total = max(1.0, ex["flops_per_device"]) * n_dev
    bound = max(compute_s, memory_s, collective_s)
    decode = rec["shape"] in ("decode_32k", "long_500k")
    if decode:
        # decode is memory-bound by construction: roofline fraction = the
        # unavoidable per-step HBM traffic (params + caches, = argument bytes
        # per device) vs the modeled memory/collective bound.
        useful_s = rec["argument_bytes"] / HBM_BW
    else:
        # train/prefill: useful model flops vs the machine-time lower bound
        useful_s = mf / n_dev / PEAK_FLOPS
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "compute_s": compute_s, "memory_s": memory_s, "collective_s": collective_s,
        "dominant": dominant,
        "model_flops": mf, "hlo_flops_total": hlo_total,
        "useful_ratio": mf / hlo_total if hlo_total else 0.0,
        "roofline_fraction": min(1.0, useful_s / bound) if bound else 0.0,
        "peak_gb": rec["peak_bytes"] / 1e9,
        "fits_16gb": rec["peak_bytes"] <= 16e9,
        "collectives": {k: ex.get(k, 0.0) for k in CKEYS},
    }


def load(path: str = None) -> dict:
    path = path or os.path.join(os.path.dirname(__file__), "..", "results", "dryrun.json")
    with open(path) as fh:
        return json.load(fh)


def table(results: dict, mesh: str = "single", sync: str = "auto") -> list[dict]:
    rows = []
    for key, rec in sorted(results.items()):
        if rec.get("mesh") != mesh or rec.get("sync_mode", "auto") != sync:
            continue
        if "skipped" in rec:
            rows.append({"arch": rec["arch"], "shape": rec["shape"],
                         "mesh": mesh, "skipped": rec["skipped"]})
            continue
        a = analyze_cell(rec)
        if a:
            rows.append(a)
    return rows


def render(rows: list[dict]) -> str:
    out = ["| arch | shape | compute s | memory s | collective s | dominant | 6ND/HLO | roofline frac | peak GB |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if "skipped" in r:
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | SKIP | — | — | — |")
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']*1e3:.1f}m | "
            f"{r['memory_s']*1e3:.1f}m | {r['collective_s']*1e3:.1f}m | "
            f"{r['dominant']} | {r['useful_ratio']:.2f} | "
            f"{r['roofline_fraction']:.3f} | {r['peak_gb']:.1f} |")
    return "\n".join(out)


def main() -> None:
    results = load()
    for mesh in ("single", "multi"):
        rows = table(results, mesh)
        print(f"\n=== {mesh}-pod roofline ===")
        print(render(rows))
    # hillclimb candidate ranking
    rows = [r for r in table(results, "single") if "skipped" not in r]
    worst = sorted(rows, key=lambda r: r["roofline_fraction"])[:5]
    cbound = sorted(rows, key=lambda r: -r["collective_s"] /
                    max(1e-9, max(r["compute_s"], r["memory_s"])))[:5]
    print("\nworst roofline fraction:", [(r["arch"], r["shape"],
          round(r["roofline_fraction"], 3)) for r in worst])
    print("most collective-bound:", [(r["arch"], r["shape"],
          round(r["collective_s"] / max(1e-9, max(r["compute_s"], r["memory_s"])), 1))
          for r in cbound])


if __name__ == "__main__":
    main()
