"""Service-level load benchmark: mixed multi-tenant workload, policy sweep.

Measures what the *service* delivers — aggregate Gb/s and p50/p99 task
latency — on the ISSUE's mixed workload (1000 x 100 MB small files + 4 x 1 TB
files across 4 tenants) for each mover-allocation policy, on the calibrated
ALCF->NERSC virtual testbed. The headline result: the chunk-aware "marginal"
policy beats the pre-chunking "file_bound" baseline on aggregate throughput
because terabyte single-file tasks can now absorb a real share of the mover
budget instead of being pinned to one mover each.

Prints ``name,value,unit`` CSV like benchmarks.run and writes
``BENCH_service_load.json`` (metrics + git rev) for trajectory tracking.

Run: PYTHONPATH=src python -m benchmarks.service_load [--quick]
"""
from __future__ import annotations

import sys
import time

from benchmarks._results import emit
from repro.service import BatchConfig, mixed_workload, run_load

MB = 1000 * 1000
GB = 1000 * MB


def sweep(*, quick: bool = False) -> list[tuple[str, float, str]]:
    if quick:
        work = mixed_workload(n_small=120, small_bytes=100 * MB,
                              n_large=2, large_bytes=200 * GB, tenants=2)
        movers, concurrent = 32, 8
    else:
        work = mixed_workload(n_small=1000, small_bytes=100 * MB,
                              n_large=4, large_bytes=1000 * GB, tenants=4)
        movers, concurrent = 64, 16
    rows: list[tuple[str, float, str]] = []
    agg = {}
    for policy in ("fair", "file_bound", "marginal"):
        rep = run_load(
            work,
            policy=policy,
            mover_budget=movers,
            max_concurrent=concurrent,
            chunk_bytes=500 * MB,
            batch=BatchConfig(direct_bytes=500 * MB, batch_files=64),
        )
        agg[policy] = rep.aggregate_gbps
        pre = f"service/mixed/{policy}"
        rows.append((f"{pre}/aggregate_gbps", round(rep.aggregate_gbps, 3), "Gb/s"))
        rows.append((f"{pre}/makespan", round(rep.makespan_s, 1), "s"))
        rows.append((f"{pre}/p50_latency", round(rep.p50_s, 1), "s"))
        rows.append((f"{pre}/p99_latency", round(rep.p99_s, 1), "s"))
        rows.append((f"{pre}/tasks", len(rep.tasks), "tasks"))
    if agg["file_bound"] > 0:
        rows.append((
            "service/mixed/marginal_vs_file_bound",
            round(agg["marginal"] / agg["file_bound"], 2), "x",
        ))
    return rows


def main() -> None:
    quick = "--quick" in sys.argv
    force = "--force" in sys.argv
    t_start = time.perf_counter()
    rows = sweep(quick=quick)
    print("name,value,unit")
    for name, val, unit in rows:
        print(f"{name},{val},{unit}")
    path = emit("service_load", rows, args={"quick": quick},
                elapsed_s=round(time.perf_counter() - t_start, 3),
                force=force)
    print(f"# wrote {path}")


if __name__ == "__main__":
    main()
