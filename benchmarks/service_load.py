"""Service-level load benchmark: policy sweep + million-task control plane.

Two modes, both emitting ``BENCH_service_load.json``:

Default — the original mixed-workload policy sweep: aggregate Gb/s and
p50/p99 task latency for each mover-allocation policy on the calibrated
ALCF->NERSC virtual testbed (1000 x 100 MB + 4 x 1 TB across 4 tenants).

``--scale`` — the control-plane scale gauntlet (10^5 tasks across 10^3
tenants; ``--quick`` shrinks to CI size):

  1. store leg: p99 submit latency of the sharded group-commit TaskStore
     (bulk appends, one fsync per shard per batch) vs the unsharded
     fsync-per-append baseline under the same 32-thread submit storm.
     GATE: sharded bulk p99 * 10 <= unsharded p99.
  2. scheduler leg: a real TransferService holding the full task count
     resident+PENDING (activation quota 0) — scheduler cycle p99 must stay
     flat vs a 10^3-task control. GATE: ratio <= 5. Also gates p99
     status latency (<= 20 ms) and reports bulk submit + cursor-page times.
  3. virtual leg: the full task count through the virtual-time testbed
     (fluid model, indexed activation). GATE: every task completes.
  4. real-engine + kill/restart leg: real chunked transfers at CI size,
     then a mid-flight kill. GATES: a fresh replay of the sharded store
     reconstructs the killed service's TaskRecords exactly (seq included),
     and the restarted service re-moves 0 journaled chunks.

Prints ``name,value,unit`` CSV like benchmarks.run; exits non-zero listing
every violated gate.

Run: PYTHONPATH=src python -m benchmarks.service_load [--scale] [--quick] [--force]
"""
from __future__ import annotations

import os
import random
import shutil
import sys
import tempfile
import threading
import time

from benchmarks._results import emit
from repro.service import BatchConfig, Submission, mixed_workload, run_load

MB = 1000 * 1000
GB = 1000 * MB


def sweep(*, quick: bool = False) -> list[tuple[str, float, str]]:
    if quick:
        work = mixed_workload(n_small=120, small_bytes=100 * MB,
                              n_large=2, large_bytes=200 * GB, tenants=2)
        movers, concurrent = 32, 8
    else:
        work = mixed_workload(n_small=1000, small_bytes=100 * MB,
                              n_large=4, large_bytes=1000 * GB, tenants=4)
        movers, concurrent = 64, 16
    rows: list[tuple[str, float, str]] = []
    agg = {}
    for policy in ("fair", "file_bound", "marginal"):
        rep = run_load(
            work,
            policy=policy,
            mover_budget=movers,
            max_concurrent=concurrent,
            chunk_bytes=500 * MB,
            batch=BatchConfig(direct_bytes=500 * MB, batch_files=64),
        )
        agg[policy] = rep.aggregate_gbps
        pre = f"service/mixed/{policy}"
        rows.append((f"{pre}/aggregate_gbps", round(rep.aggregate_gbps, 3), "Gb/s"))
        rows.append((f"{pre}/makespan", round(rep.makespan_s, 1), "s"))
        rows.append((f"{pre}/p50_latency", round(rep.p50_s, 1), "s"))
        rows.append((f"{pre}/p99_latency", round(rep.p99_s, 1), "s"))
        rows.append((f"{pre}/tasks", len(rep.tasks), "tasks"))
    if agg["file_bound"] > 0:
        rows.append((
            "service/mixed/marginal_vs_file_bound",
            round(agg["marginal"] / agg["file_bound"], 2), "x",
        ))
    return rows


# ---------------------------------------------------------------------------
# --scale legs
# ---------------------------------------------------------------------------
def _pctl(xs: list[float], q: float) -> float:
    if not xs:
        return 0.0
    s = sorted(xs)
    return s[min(len(s) - 1, max(0, int(round(q / 100.0 * len(s))) - 1))]


def _spec_of(store, tenant: str):
    from repro.service.task import TaskSpec, TransferItem
    return TaskSpec(
        task_id=store.next_task_id(tenant), tenant=tenant, label="bench",
        items=(TransferItem("s", "d", 1),),
    )


def store_leg(n_tasks: int, n_tenants: int, *, threads: int = 32,
              batch: int = 128, baseline_samples: int = 2000,
              ) -> tuple[list[tuple[str, float, str]], list[str]]:
    """Sharded bulk appends vs unsharded fsync-per-append, same storm."""
    from repro.service.store import TaskStore

    def storm(store, total: int, per_call: int, lat_ms: list[float],
              bulk: bool) -> None:
        lock = threading.Lock()
        left = [total]

        def worker(wid: int) -> None:
            rng = random.Random(wid)
            my: list[float] = []
            while True:
                with lock:
                    if left[0] <= 0:
                        break
                    n = min(per_call, left[0])
                    left[0] -= n
                tenant = f"tenant{rng.randrange(n_tenants)}"
                specs = [_spec_of(store, tenant) for _ in range(n)]
                t0 = time.perf_counter()
                if bulk:
                    store.append_submit_many(specs)
                else:
                    for sp in specs:
                        t1 = time.perf_counter()
                        store.append_submit(sp)
                        my.append((time.perf_counter() - t1) * 1e3)
                if bulk:
                    dt = (time.perf_counter() - t0) * 1e3
                    my.extend([dt / n] * n)    # per-task amortized latency
            with lock:
                lat_ms.extend(my)

        ts = [threading.Thread(target=worker, args=(i,)) for i in range(threads)]
        for t in ts:
            t.start()
        for t in ts:
            t.join()

    rows: list[tuple[str, float, str]] = []
    violations: list[str] = []
    root = tempfile.mkdtemp(prefix="svcload-store-")
    try:
        # sharded store, bulk appends (the million-task submit path)
        sharded = TaskStore(os.path.join(root, "sharded"))
        lat_bulk: list[float] = []
        t0 = time.perf_counter()
        storm(sharded, n_tasks, batch, lat_bulk, bulk=True)
        bulk_wall = time.perf_counter() - t0
        n_recs, n_fsyncs = len(sharded.records), sharded.fsyncs
        sharded.close()
        # same store, single-call appends (group commit still amortizes
        # fsyncs across the 32 threads)
        single = TaskStore(os.path.join(root, "single"))
        lat_single: list[float] = []
        storm(single, min(n_tasks, 4 * baseline_samples), 1, lat_single, bulk=False)
        single.close()
        # the pre-shard baseline: one log, fsync per append, sampled
        base = TaskStore(os.path.join(root, "base"), n_shards=1,
                         group_commit=False, auto_compact=False)
        lat_base: list[float] = []
        storm(base, baseline_samples, 1, lat_base, bulk=False)
        base.close()

        if n_recs != n_tasks:
            violations.append(
                f"store: bulk storm persisted {n_recs} records, wanted {n_tasks}")
        bulk_p99 = _pctl(lat_bulk, 99)
        base_p99 = _pctl(lat_base, 99)
        speedup = base_p99 / bulk_p99 if bulk_p99 > 0 else float("inf")
        rows += [
            ("scale/store/tasks", n_tasks, "tasks"),
            ("scale/store/bulk_submit_p99_ms", round(bulk_p99, 4), "ms"),
            ("scale/store/bulk_submit_p50_ms", round(_pctl(lat_bulk, 50), 4), "ms"),
            ("scale/store/bulk_rate", round(n_tasks / bulk_wall, 0), "tasks/s"),
            ("scale/store/fsyncs_per_ktask", round(1e3 * n_fsyncs / n_tasks, 2), "fsync"),
            ("scale/store/single_submit_p99_ms", round(_pctl(lat_single, 99), 4), "ms"),
            ("scale/store/unsharded_p99_ms", round(base_p99, 4), "ms"),
            ("scale/store/p99_speedup", round(min(speedup, 1e6), 1), "x"),
        ]
        if speedup < 10.0:
            violations.append(
                f"store: sharded bulk p99 {bulk_p99:.4f} ms only "
                f"{speedup:.1f}x under unsharded {base_p99:.4f} ms (need >= 10x)")
    finally:
        shutil.rmtree(root, ignore_errors=True)
    return rows, violations


def _resident_service(root: str, n_tasks: int, n_tenants: int):
    """A real service holding n_tasks resident and PENDING (quota 0)."""
    from repro.service import ServiceConfig, TenantQuota, TransferService

    svc = TransferService(root, ServiceConfig(
        mover_budget=4, max_concurrent_tasks=4, tick_s=0.002,
        default_quota=TenantQuota(max_active=0),    # hold everything PENDING
    ))
    per = n_tasks // n_tenants
    t0 = time.perf_counter()
    for k in range(n_tenants):
        n = per + (n_tasks % n_tenants if k == n_tenants - 1 else 0)
        svc.submit_many([[("s", f"d{k}-{i}", 1)] for i in range(n)],
                        tenant=f"tenant{k}", batch=False)
    return svc, time.perf_counter() - t0


def scheduler_leg(n_tasks: int, n_tenants: int, *, control_tasks: int = 1000,
                  settle_s: float = 1.5,
                  ) -> tuple[list[tuple[str, float, str]], list[str]]:
    """Scheduler cycle time must not grow with resident task count."""
    rows: list[tuple[str, float, str]] = []
    violations: list[str] = []
    root = tempfile.mkdtemp(prefix="svcload-sched-")
    try:
        small, _ = _resident_service(
            os.path.join(root, "small"), control_tasks, min(n_tenants, control_tasks))
        time.sleep(settle_s)
        small_cycles = [s * 1e3 for s in small.sched_cycles]
        small.kill()

        big, submit_wall = _resident_service(os.path.join(root, "big"), n_tasks, n_tenants)
        time.sleep(settle_s)
        big_cycles = [s * 1e3 for s in big.sched_cycles]

        # status p99 over random ids, bulk status, one cursor page walk
        ids = [f"task-{i:09d}-tenant{min(n_tenants - 1, i // (n_tasks // n_tenants))}"
               for i in range(n_tasks)]
        rng = random.Random(7)
        sample = [ids[rng.randrange(len(ids))] for _ in range(min(2000, n_tasks))]
        lat_status: list[float] = []
        for tid in sample:
            t0 = time.perf_counter()
            big.status(tid)
            lat_status.append((time.perf_counter() - t0) * 1e3)
        t0 = time.perf_counter()
        got = big.status_many(sample)
        many_ms = (time.perf_counter() - t0) * 1e3
        t0 = time.perf_counter()
        page = big.tasks(limit=500)
        page_ms = (time.perf_counter() - t0) * 1e3
        t0 = time.perf_counter()
        page2 = big.tasks(cursor=page[-1].task_id, limit=500)
        page2_ms = (time.perf_counter() - t0) * 1e3
        n_pending = sum(1 for s in page + page2 if s.state == "PENDING")
        big.kill()

        small_p99 = max(_pctl(small_cycles, 99), 0.05)   # epsilon: sub-50us
        big_p99 = _pctl(big_cycles, 99)                  # cycles are noise
        ratio = big_p99 / small_p99
        status_p99 = _pctl(lat_status, 99)
        rows += [
            ("scale/sched/resident_tasks", n_tasks, "tasks"),
            ("scale/sched/tenants", n_tenants, "tenants"),
            ("scale/sched/bulk_submit_per_task_us",
             round(1e6 * submit_wall / n_tasks, 2), "us"),
            ("scale/sched/cycle_p50_ms_1k", round(_pctl(small_cycles, 50), 4), "ms"),
            ("scale/sched/cycle_p99_ms_1k", round(_pctl(small_cycles, 99), 4), "ms"),
            ("scale/sched/cycle_p50_ms_full", round(_pctl(big_cycles, 50), 4), "ms"),
            ("scale/sched/cycle_p99_ms_full", round(big_p99, 4), "ms"),
            ("scale/sched/cycle_p99_ratio", round(ratio, 2), "x"),
            ("scale/sched/status_p99_ms", round(status_p99, 4), "ms"),
            ("scale/sched/status_many_per_task_us",
             round(1e3 * many_ms / max(1, len(sample)), 2), "us"),
            ("scale/sched/tasks_page500_ms", round(page_ms, 3), "ms"),
            ("scale/sched/tasks_page500_cursor_ms", round(page2_ms, 3), "ms"),
        ]
        if not big_cycles or not small_cycles:
            violations.append("sched: no scheduler cycles recorded")
        elif ratio > 5.0:
            violations.append(
                f"sched: cycle p99 grew {ratio:.2f}x from {control_tasks} to "
                f"{n_tasks} resident tasks (need <= 5x — cycle time must be "
                f"independent of task count)")
        if status_p99 > 20.0:
            violations.append(
                f"sched: status p99 {status_p99:.2f} ms at {n_tasks} resident "
                "tasks (need <= 20 ms)")
        if len(got) != len(sample) or len(page) != 500 or len(page2) != 500:
            violations.append("sched: bulk/paginated listing returned short")
        if n_pending != 1000:
            violations.append(
                f"sched: expected 1000 PENDING statuses on pages, got {n_pending}")
    finally:
        shutil.rmtree(root, ignore_errors=True)
    return rows, violations


def virtual_leg(n_tasks: int, n_tenants: int,
                ) -> tuple[list[tuple[str, float, str]], list[str]]:
    """The full task count through the virtual-time testbed."""
    rows: list[tuple[str, float, str]] = []
    violations: list[str] = []
    subs = [
        Submission(i * 0.01, f"tenant{i % n_tenants}", (100 * MB,))
        for i in range(n_tasks)
    ]
    t0 = time.perf_counter()
    rep = run_load(
        subs, policy="fair", mover_budget=64, max_concurrent=16,
        chunk_bytes=100 * MB, batch=BatchConfig(direct_bytes=1, batch_files=1),
    )
    wall = time.perf_counter() - t0
    rows += [
        ("scale/virtual/tasks", len(rep.tasks), "tasks"),
        ("scale/virtual/tenants", n_tenants, "tenants"),
        ("scale/virtual/makespan", round(rep.makespan_s, 1), "s"),
        ("scale/virtual/p50_latency", round(rep.p50_s, 2), "s"),
        ("scale/virtual/p99_latency", round(rep.p99_s, 2), "s"),
        ("scale/virtual/wall", round(wall, 1), "s"),
        ("scale/virtual/sim_rate", round(n_tasks / wall, 0), "tasks/s"),
    ]
    if len(rep.tasks) != n_tasks:
        violations.append(
            f"virtual: {len(rep.tasks)}/{n_tasks} tasks completed")
    if abs(rep.retry_amplification - 1.0) > 1e-6:
        violations.append(
            f"virtual: retry amplification {rep.retry_amplification} on a clean run")
    return rows, violations


def real_leg(n_tasks: int, *, restart_tasks: int = 24,
             ) -> tuple[list[tuple[str, float, str]], list[str]]:
    """Real chunked transfers at CI size + a kill/restart replay check."""
    from repro.service import ServiceConfig, TransferService
    from repro.service.store import TaskStore

    rows: list[tuple[str, float, str]] = []
    violations: list[str] = []
    root = tempfile.mkdtemp(prefix="svcload-real-")
    try:
        # -- throughput: n_tasks real one-file transfers, bulk-submitted
        src_dir = os.path.join(root, "files")
        os.makedirs(src_dir)
        reqs = []
        for i in range(n_tasks):
            p = os.path.join(src_dir, f"f{i}")
            with open(p, "wb") as fh:
                fh.write(random.Random(i).randbytes(48_000))
            reqs.append([(p, p + ".out")])
        svc = TransferService(os.path.join(root, "svc"), ServiceConfig(
            mover_budget=8, max_concurrent_tasks=8, chunk_bytes=16_384,
            tick_s=0.002))
        t0 = time.perf_counter()
        ids = [tid for group in svc.submit_many(reqs, tenant="bench", batch=False)
               for tid in group]
        sts = svc.wait_all(ids, timeout=300)
        wall = time.perf_counter() - t0
        bad = [s.task_id for s in sts if s.state != "SUCCEEDED"]
        svc.close()
        rows += [
            ("scale/real/tasks", n_tasks, "tasks"),
            ("scale/real/completed", len(sts) - len(bad), "tasks"),
            ("scale/real/rate", round(n_tasks / wall, 1), "tasks/s"),
        ]
        if bad:
            violations.append(f"real: {len(bad)} tasks not SUCCEEDED: {bad[:3]}")

        # -- kill mid-flight, then prove replay-identical records + 0 re-moves
        kroot = os.path.join(root, "kill")
        pace = lambda tid, item, chunk, attempt: time.sleep(0.004)  # noqa: E731
        svc1 = TransferService(kroot, ServiceConfig(
            mover_budget=4, max_concurrent_tasks=4, chunk_bytes=8_192,
            tick_s=0.002), fault_injector=pace)
        kids = [tid for group in svc1.submit_many(
                    [[(os.path.join(src_dir, f"f{i}"),
                       os.path.join(root, f"k{i}.out"))]
                     for i in range(restart_tasks)],
                    tenant="bench", batch=False)
                for tid in group]
        deadline = time.perf_counter() + 30
        while time.perf_counter() < deadline:
            if any(s.chunks_done > 0 for s in svc1.status_many(kids)):
                break
            time.sleep(0.01)
        svc1.kill()
        live = {tid: (r.seq, r.state, r.error, r.spec.to_json())
                for tid, r in svc1.store.records.items()}
        journaled = sum(
            len(svc1.store.open_journal(tid).records) for tid in kids)
        # fresh replay of the sharded logs only — no process memory
        replayed = TaskStore(kroot, auto_compact=False)
        disk = {tid: (r.seq, r.state, r.error, r.spec.to_json())
                for tid, r in replayed.records.items()}
        replayed.close()
        identical = int(disk == live)
        rows += [
            ("scale/restart/tasks", restart_tasks, "tasks"),
            ("scale/restart/journaled_at_kill", journaled, "chunks"),
            ("scale/restart/replay_identical", identical, "bool"),
        ]
        if not identical:
            miss = {k for k in set(live) | set(disk)
                    if live.get(k) != disk.get(k)}
            violations.append(
                f"restart: replayed records differ from the killed service's "
                f"on {len(miss)} tasks (e.g. {sorted(miss)[:2]})")
        svc2 = TransferService(kroot, ServiceConfig(
            mover_budget=4, max_concurrent_tasks=4, chunk_bytes=8_192,
            tick_s=0.002))
        sts2 = svc2.wait_all(kids, timeout=300)
        resumed = sum(s.resumed_chunks for s in sts2)
        total_chunks = sum(s.chunks_total for s in sts2)
        re_moved = svc2.moved_chunks - (total_chunks - resumed)
        svc2.close()
        rows += [
            ("scale/restart/resumed_chunks", resumed, "chunks"),
            ("scale/restart/re_moved_chunks", re_moved, "chunks"),
        ]
        if any(s.state != "SUCCEEDED" for s in sts2):
            violations.append("restart: not all tasks SUCCEEDED after restart")
        if resumed < journaled:
            violations.append(
                f"restart: only {resumed} chunks resumed, {journaled} were journaled")
        if re_moved != 0:
            violations.append(
                f"restart: {re_moved} journaled chunks re-moved (need 0)")
    finally:
        shutil.rmtree(root, ignore_errors=True)
    return rows, violations


def scale(*, quick: bool = False) -> tuple[list[tuple[str, float, str]], list[str]]:
    if quick:
        n_tasks, n_tenants, n_real = 20_000, 200, 80
    else:
        n_tasks, n_tenants, n_real = 100_000, 1000, 200
    rows: list[tuple[str, float, str]] = []
    violations: list[str] = []
    for leg in (
        lambda: store_leg(n_tasks, n_tenants,
                          baseline_samples=1000 if quick else 2000),
        lambda: scheduler_leg(n_tasks, n_tenants),
        lambda: virtual_leg(n_tasks, n_tenants),
        lambda: real_leg(n_real),
    ):
        r, v = leg()
        rows += r
        violations += v
    return rows, violations


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    quick = "--quick" in argv
    force = "--force" in argv
    do_scale = "--scale" in argv
    t_start = time.perf_counter()
    violations: list[str] = []
    if do_scale:
        rows, violations = scale(quick=quick)
    else:
        rows = sweep(quick=quick)
    print("name,value,unit")
    for name, val, unit in rows:
        print(f"{name},{val},{unit}")
    path = emit("service_load", rows,
                args={"quick": quick, "scale": do_scale},
                elapsed_s=round(time.perf_counter() - t_start, 3),
                force=force)
    print(f"# wrote {path}")
    if violations:
        print("GATE VIOLATIONS:", file=sys.stderr)
        for v in violations:
            print(f"  - {v}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
