"""Dedup conformance benchmark: content-addressed store wire-byte gates.

Measures the chunk-index dedup path (``repro.cas``) end to end and GATES the
properties the content plane promises:

  * **mutate-10%-republish** — publish a dataset through the service, mutate
    ~10% of its chunks, publish again with ``dedup="on"``: the unchanged 90%
    must be satisfied from the index (local copy, no wire move). Gate:
    wire-byte reduction >= 5x, final bytes identical to the mutated source.
  * **repeat-checkpoint**   — a delta re-save of an UNCHANGED training state
    (``submit_checkpoint(..., delta=True)``) must move near-zero bytes, and a
    one-leaf mutation delta-save must restore bit-identical to a full save.
  * **kill+restart mid-delta** — deduped chunks journal custody at
    negotiation time: after a crash mid-run and a restart, no journaled
    chunk (deduped or moved) may be moved again. Gate: 0 re-moves, 0 escapes.
  * **stale-index demotion** — corrupt the backing bytes behind seeded index
    entries (``faults.corrupt_index_backing``): every poisoned hit must
    re-verify, demote to a wire move, and leave a quarantine record. Gate:
    demotions == quarantines >= victims probed, 0 escapes.

Prints ``name,value,unit`` CSV, writes ``BENCH_dedup.json`` (schema v2), and
exits non-zero on any gate violation so CI can block on it.

Run: PYTHONPATH=src python -m benchmarks.dedup [--seeds N] [--quick] [--force]
"""
from __future__ import annotations

import argparse
import os
import sys
import tempfile
import threading
import time

import numpy as np

from benchmarks._results import emit
from repro.cas import ChunkIndex
from repro.core import (
    BufferSource,
    ChunkJournal,
    ChunkedTransfer,
    FileDest,
    plan_chunks,
)
from repro.faults import corrupt_index_backing


class _HostCrash(Exception):
    """Crash bomb: the host dies mid-transfer (kill+restart leg)."""


def _payload(seed: int, nbytes: int) -> bytes:
    return np.random.default_rng(seed).integers(
        0, 256, nbytes, dtype=np.uint8).tobytes()


def _mutate_chunks(payload: bytes, chunk: int, frac: float, seed: int) -> bytes:
    """Rewrite ~``frac`` of the payload's chunks with fresh random bytes."""
    rng = np.random.default_rng(seed + 1)
    buf = bytearray(payload)
    n_chunks = (len(payload) + chunk - 1) // chunk
    n_mut = max(1, round(n_chunks * frac))
    victims = rng.choice(n_chunks, size=n_mut, replace=False)
    for ci in victims:
        lo = int(ci) * chunk
        hi = min(lo + chunk, len(payload))
        buf[lo:hi] = rng.integers(0, 256, hi - lo, dtype=np.uint8).tobytes()
    return bytes(buf)


def _engine_run(payload, plan, jpath, *, index=None, injector=None,
                max_retries=3):
    dst = FileDest(jpath + ".out", len(payload))
    journal = ChunkJournal(jpath)
    try:
        eng = ChunkedTransfer(
            BufferSource(payload), dst, plan,
            journal=journal, max_retries=max_retries,
            fault_injector=injector,
            dedup_index=index,
            dedup_target=(jpath + ".out") if index is not None else "",
        )
        report = eng.run()
    finally:
        journal.close()
    with open(jpath + ".out", "rb") as fh:
        final = fh.read()
    return report, final


# ---------------------------------------------------------------------------
# leg 1: mutate-10%-republish through the real service
# ---------------------------------------------------------------------------
def republish_leg(seed: int, *, nbytes: int, chunk: int, tmpdir: str) -> dict:
    from repro.service import BatchConfig, ServiceConfig, TransferService

    root = os.path.join(tmpdir, f"pub-{seed}")
    os.makedirs(root, exist_ok=True)
    src = os.path.join(root, "data.bin")
    payload = _payload(seed, nbytes)
    with open(src, "wb") as fh:
        fh.write(payload)
    svc = TransferService(os.path.join(root, "svc"), ServiceConfig(
        mover_budget=4, max_concurrent_tasks=2, chunk_bytes=chunk,
        tick_s=0.002, dedup="on",
        batch=BatchConfig(direct_bytes=1 << 30, batch_files=64),
    ))
    try:
        [t1] = svc.submit([(src, src + ".v1")], batch=False)
        st1 = svc.wait(t1, timeout=120)
        mutated = _mutate_chunks(payload, chunk, 0.10, seed)
        with open(src, "wb") as fh:
            fh.write(mutated)
        [t2] = svc.submit([(src, src + ".v2")], batch=False)
        st2 = svc.wait(t2, timeout=120)
        with open(src + ".v2", "rb") as fh:
            escapes = int(fh.read() != mutated)
        total = st2.bytes_total
        wire = total - st2.wire_bytes_saved
        return dict(
            escapes=escapes + int(st1.state != "SUCCEEDED")
            + int(st2.state != "SUCCEEDED"),
            bytes_total=total, wire_bytes=wire,
            chunks_deduped=st2.chunks_deduped,
            chunks_total=st2.chunks_total,
        )
    finally:
        svc.close()


# ---------------------------------------------------------------------------
# leg 2: repeat-checkpoint (delta saves)
# ---------------------------------------------------------------------------
def checkpoint_leg(seed: int, *, leaf_kb: int, tmpdir: str) -> dict:
    from repro.ckpt.checkpoint import (
        _flatten,
        restore_checkpoint,
        save_checkpoint,
    )
    from repro.service import BatchConfig, ServiceConfig, TransferService
    from repro.service.ckpt_bridge import submit_checkpoint

    root = os.path.join(tmpdir, f"ckpt-{seed}")
    ck = os.path.join(root, "saves")
    os.makedirs(ck, exist_ok=True)
    rng = np.random.default_rng(seed)
    tree = {
        "layer0/w": rng.standard_normal((leaf_kb * 64,)).astype(np.float32),
        "layer0/b": rng.standard_normal((leaf_kb * 16,)).astype(np.float32),
        "emb": rng.integers(0, 255, (leaf_kb * 32,)).astype(np.int32),
    }
    svc = TransferService(os.path.join(root, "svc"), ServiceConfig(
        mover_budget=4, max_concurrent_tasks=2, tick_s=0.002,
        batch=BatchConfig(direct_bytes=1 << 30, batch_files=64),
    ))
    try:
        submit_checkpoint(svc, ck, 1, tree, chunk_bytes=16 * 1024).wait(120)
        # unchanged re-save: the delta must move (near) nothing
        sub2 = submit_checkpoint(svc, ck, 2, tree, delta=True)
        sub2.wait(120)
        st2 = sub2.status()
        repeat_total = st2.bytes_total
        repeat_wire = repeat_total - st2.wire_bytes_saved
        # one-leaf mutation: delta save, then restore must be bit-identical
        # to a plain full save of the same tree
        tree2 = dict(tree)
        tree2["layer0/b"] = tree["layer0/b"] + 1.0
        sub3 = submit_checkpoint(svc, ck, 3, tree2, delta=True)
        rep3 = sub3.wait(120)
        st3 = sub3.status()
        full_dir = os.path.join(root, "full")
        os.makedirs(full_dir, exist_ok=True)
        repf = save_checkpoint(full_dir, 3, tree2, chunk_bytes=16 * 1024)
        td, sd = restore_checkpoint(rep3.path)
        tf, sf = restore_checkpoint(repf.path)
        td, tf = _flatten(td), _flatten(tf)
        escapes = int(sd != 3 or sf != 3)
        for k in tree2:
            if not (np.array_equal(td[k], tree2[k])
                    and np.array_equal(td[k], tf[k])):
                escapes += 1
        return dict(
            escapes=escapes,
            repeat_total=repeat_total, repeat_wire=repeat_wire,
            delta_total=st3.bytes_total,
            delta_wire=st3.bytes_total - st3.wire_bytes_saved,
            delta_deduped=st3.chunks_deduped,
        )
    finally:
        svc.close()


# ---------------------------------------------------------------------------
# leg 3: kill + restart mid-delta (engine-level custody)
# ---------------------------------------------------------------------------
def restart_leg(seed: int, *, nbytes: int, chunk: int, movers: int,
                tmpdir: str) -> dict:
    plan = plan_chunks(nbytes, movers, chunk_bytes=chunk,
                       min_chunk=1, max_chunk=1 << 50)
    payload = _payload(seed, nbytes)
    base = os.path.join(tmpdir, f"restart-{seed}")
    # donor pass populates the index for ~half the (mutated) republish
    index = ChunkIndex(base + ".idx")
    _engine_run(payload, plan, base + "-donor.journal", index=index)
    mutated = _mutate_chunks(payload, chunk, 0.5, seed)

    lock = threading.Lock()
    calls = [0]

    def bomb(_chunk, _attempt):
        with lock:
            calls[0] += 1
            if calls[0] > 1:           # die after the first wire move lands
                raise _HostCrash("host died mid-delta")

    jb = base + "-B.journal"
    try:
        _engine_run(mutated, plan, jb, index=index, injector=bomb,
                    max_retries=0)
        crashed = 0
    except (_HostCrash, RuntimeError):
        crashed = 1
    probe = ChunkJournal(jb)           # deduped chunks journaled custody at
    journaled = set(probe.records)     # negotiation; landed wire chunks too
    probe.close()

    moved2: list[int] = []

    def record(c, _attempt):
        with lock:
            moved2.append(c.index)

    report2, final2 = _engine_run(mutated, plan, jb, index=index,
                                  injector=record)
    index.close()
    return dict(
        escapes=int(final2 != mutated),
        crashed=crashed,
        journaled_at_crash=len(journaled),
        re_moved_journaled=len(set(moved2) & journaled),
        resumed=report2.skipped_chunks,
    )


# ---------------------------------------------------------------------------
# leg 4: stale-index demotion + quarantine
# ---------------------------------------------------------------------------
def stale_leg(seed: int, *, nbytes: int, chunk: int, movers: int,
              tmpdir: str) -> dict:
    plan = plan_chunks(nbytes, movers, chunk_bytes=chunk,
                       min_chunk=1, max_chunk=1 << 50)
    payload = _payload(seed, nbytes)
    base = os.path.join(tmpdir, f"stale-{seed}")
    index = ChunkIndex(base + ".idx")
    _engine_run(payload, plan, base + "-donor.journal", index=index)
    victims = corrupt_index_backing(index, count=2, seed=seed)
    report, final = _engine_run(payload, plan, base + "-B.journal", index=index)
    index.close()
    return dict(
        escapes=int(final != payload),
        victims=len(victims),
        demoted=report.dedup_demoted,
        quarantined=len(report.quarantined),
        deduped=report.deduped_chunks,
    )


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------
def _merge(agg: dict, one: dict) -> None:
    for k, v in one.items():
        agg[k] = agg.get(k, 0) + v


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--seeds", type=int, default=3)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--force", action="store_true",
                    help="overwrite a BENCH result from a different git rev")
    args = ap.parse_args(argv)
    t_start = time.perf_counter()

    nbytes = (512 * 1024 + 4093) if args.quick else (2 * 1024 * 1024 + 4093)
    chunk, movers = 32 * 1024, 8
    leaf_kb = 2 if args.quick else 8
    seeds = 1 if args.quick else args.seeds
    rows: list[tuple[str, float, str]] = []
    violations: list[str] = []

    with tempfile.TemporaryDirectory(prefix="dedup-") as tmpdir:
        # ---- leg 1: mutate-10% republish
        agg: dict = {}
        for seed in range(seeds):
            _merge(agg, republish_leg(seed, nbytes=nbytes, chunk=chunk,
                                      tmpdir=tmpdir))
        ratio = (agg["bytes_total"] / agg["wire_bytes"]
                 if agg["wire_bytes"] else float(agg["bytes_total"] or 1))
        rows += [
            ("dedup/republish/escapes", agg["escapes"], "tasks"),
            ("dedup/republish/chunks_deduped", agg["chunks_deduped"], "chunks"),
            ("dedup/republish/chunks_total", agg["chunks_total"], "chunks"),
            ("dedup/republish/wire_bytes", agg["wire_bytes"], "bytes"),
            ("dedup/republish/bytes_total", agg["bytes_total"], "bytes"),
            ("dedup/republish/wire_reduction", round(ratio, 2), "x"),
        ]
        if agg["escapes"]:
            violations.append(f"republish: {agg['escapes']} integrity escapes")
        if ratio < 5.0:
            violations.append(
                f"republish: wire reduction {ratio:.2f}x < 5x gate "
                f"(mutate-10% must dedup the unchanged 90%)")

        # ---- leg 2: repeat checkpoint (delta saves)
        agg = {}
        for seed in range(seeds):
            _merge(agg, checkpoint_leg(seed, leaf_kb=leaf_kb, tmpdir=tmpdir))
        repeat_frac = (agg["repeat_wire"] / agg["repeat_total"]
                       if agg["repeat_total"] else 0.0)
        rows += [
            ("dedup/checkpoint/escapes", agg["escapes"], "leaves"),
            ("dedup/checkpoint/repeat_wire_bytes", agg["repeat_wire"], "bytes"),
            ("dedup/checkpoint/repeat_total_bytes", agg["repeat_total"], "bytes"),
            ("dedup/checkpoint/repeat_wire_frac", round(repeat_frac, 4), "frac"),
            ("dedup/checkpoint/delta_wire_bytes", agg["delta_wire"], "bytes"),
            ("dedup/checkpoint/delta_deduped", agg["delta_deduped"], "chunks"),
        ]
        if agg["escapes"]:
            violations.append(
                f"checkpoint: {agg['escapes']} restore mismatches "
                f"(delta save must restore bit-identical to a full save)")
        if repeat_frac > 0.01:
            violations.append(
                f"checkpoint: repeat-save moved {repeat_frac:.1%} of its "
                f"bytes (an unchanged delta re-save must be near-zero wire)")

        # ---- leg 3: kill + restart mid-delta
        agg = {}
        for seed in range(seeds):
            _merge(agg, restart_leg(seed, nbytes=nbytes, chunk=chunk,
                                    movers=movers, tmpdir=tmpdir))
        rows += [
            ("dedup/restart/escapes", agg["escapes"], "runs"),
            ("dedup/restart/crashed_runs", agg["crashed"], "runs"),
            ("dedup/restart/journaled_at_crash", agg["journaled_at_crash"], "chunks"),
            ("dedup/restart/re_moved_journaled", agg["re_moved_journaled"], "chunks"),
            ("dedup/restart/resumed_chunks", agg["resumed"], "chunks"),
        ]
        if agg["escapes"]:
            violations.append(f"restart: {agg['escapes']} integrity escapes")
        if agg["re_moved_journaled"]:
            violations.append(
                f"restart: {agg['re_moved_journaled']} journaled chunks moved "
                f"again after restart (deduped custody must survive a crash)")

        # ---- leg 4: stale index demotion
        agg = {}
        for seed in range(seeds):
            _merge(agg, stale_leg(seed, nbytes=nbytes, chunk=chunk,
                                  movers=movers, tmpdir=tmpdir))
        rows += [
            ("dedup/stale/escapes", agg["escapes"], "runs"),
            ("dedup/stale/victim_entries", agg["victims"], "entries"),
            ("dedup/stale/demoted_to_wire", agg["demoted"], "chunks"),
            ("dedup/stale/quarantined", agg["quarantined"], "records"),
            ("dedup/stale/still_deduped", agg["deduped"], "chunks"),
        ]
        if agg["escapes"]:
            violations.append(
                f"stale: {agg['escapes']} integrity escapes (a lying index "
                f"served bytes that differ from the source)")
        if agg["demoted"] < agg["victims"]:
            violations.append(
                f"stale: only {agg['demoted']} demotions for "
                f"{agg['victims']} poisoned entries (stale hits must "
                f"re-verify and fall back to the wire)")
        if agg["quarantined"] != agg["demoted"]:
            violations.append(
                f"stale: {agg['demoted']} demotions but {agg['quarantined']} "
                f"quarantine records (every demotion must leave evidence)")

    total_escapes = sum(v for n, v, _u in rows if n.endswith("/escapes"))
    rows.append(("dedup/total_escapes", total_escapes, "chunks"))
    rows.append(("dedup/seeds", seeds, "seeds"))

    print("name,value,unit")
    for name, val, unit in rows:
        print(f"{name},{val},{unit}")
    path = emit("dedup", rows,
                args={"quick": args.quick, "seeds": list(range(seeds))},
                elapsed_s=round(time.perf_counter() - t_start, 3),
                force=args.force)
    print(f"# wrote {path}")
    if violations:
        print("\nDEDUP GATE VIOLATIONS:", file=sys.stderr)
        for v in violations:
            print(f"  - {v}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
