"""Paper-figure reproductions (Figs. 5-10) on the calibrated simulator.

Each function mirrors one experiment family from §4 and returns rows of
(name, value, paper_reference) so run.py can emit the standard CSV. The
simulator's calibration is validated independently in tests/test_simulator.py.
"""
from __future__ import annotations

from repro.core.simulator import ALCF, NERSC, OLCF, SITES, TransferSpec, simulate_transfer

GB = 1e9
MB = 1024 * 1024


def _run(src, dst, files, chunk, integrity, stripes=16):
    spec = TransferSpec(tuple(files), chunk_bytes=chunk, integrity=integrity,
                        stripe_count=stripes)
    return simulate_transfer(src, dst, spec)


def fig5_lustre_striping():
    """1x2.5TB A<->N, stripe count sweep, with/without chunking (no integrity)."""
    rows = []
    for sname, dname in (("ALCF", "NERSC"), ("NERSC", "ALCF")):
        src, dst = SITES[sname], SITES[dname]
        for stripes in (1, 4, 16, 64):
            for chunk in (None, 200 * MB):
                r = _run(src, dst, [2500 * GB], chunk, False, stripes)
                tag = "chunk" if chunk else "nochunk"
                rows.append((f"fig5/{sname[0]}2{dname[0]}/stripe{stripes}/{tag}",
                             round(r.gbps, 2), "Gb/s"))
    return rows


def fig6_chunk_size():
    """500 GB in 1/5/20 files, chunk size sweep (integrity on)."""
    rows = []
    for files, per in ((1, 500), (5, 100), (20, 25)):
        for s in (50, 100, 200, 500, 1000, 5000):
            r = _run(ALCF, NERSC, [per * GB] * files, s * MB, True)
            rows.append((f"fig6/{files}x{per}GB/chunk{s}MB", round(r.gbps, 2), "Gb/s"))
    return rows


def fig7_integrity_throughput():
    """1/5/20-file transfers, +-integrity, +-chunking, three site pairs."""
    rows = []
    pairs = (("ALCF", "NERSC"), ("NERSC", "ALCF"), ("OLCF", "NERSC"))
    for sname, dname in pairs:
        src, dst = SITES[sname], SITES[dname]
        for files, per in ((1, 500), (5, 100), (20, 25)):
            for chunk in (None, 200 * MB):
                for integ in (False, True):
                    r = _run(src, dst, [per * GB] * files, chunk, integ)
                    tag = f"{'chunk' if chunk else 'nochunk'}/{'int' if integ else 'noint'}"
                    rows.append((f"fig7/{sname[0]}2{dname[0]}/{files}f/{tag}",
                                 round(r.gbps, 2), "Gb/s"))
    return rows


def fig8_checksum_times():
    """Visible transfer vs checksum seconds (A2N/N2A), as in the stacked bars."""
    rows = []
    for sname, dname in (("ALCF", "NERSC"), ("NERSC", "ALCF")):
        src, dst = SITES[sname], SITES[dname]
        for files, per in ((1, 500), (5, 100), (20, 25)):
            for chunk in (None, 200 * MB):
                base = _run(src, dst, [per * GB] * files, chunk, False)
                with_ck = _run(src, dst, [per * GB] * files, chunk, True)
                tag = "chunk" if chunk else "nochunk"
                rows.append((f"fig8/{sname[0]}2{dname[0]}/{files}f/{tag}/transfer_s",
                             round(base.seconds, 1), "s"))
                rows.append((f"fig8/{sname[0]}2{dname[0]}/{files}f/{tag}/checksum_s",
                             round(with_ck.seconds - base.seconds, 1), "s"))
    return rows


def fig9_file_count():
    """500 GB as 1..500 files, +-chunking (integrity on)."""
    rows = []
    for sname, dname in (("ALCF", "NERSC"), ("NERSC", "ALCF"), ("OLCF", "ALCF")):
        src, dst = SITES[sname], SITES[dname]
        for files, per in ((1, 500), (5, 100), (20, 25), (100, 5), (500, 1)):
            for chunk in (None, 200 * MB):
                r = _run(src, dst, [per * GB] * files, chunk, True)
                tag = "chunk" if chunk else "nochunk"
                rows.append((f"fig9/{sname[0]}2{dname[0]}/{files}f/{tag}",
                             round(r.gbps, 2), "Gb/s"))
    return rows


def fig10_chunking_speedup():
    """Headline: chunking speedup by file count across site pairs."""
    rows = []
    pairs = (("ALCF", "NERSC"), ("NERSC", "ALCF"), ("ALCF", "OLCF"),
             ("OLCF", "NERSC"))
    for sname, dname in pairs:
        src, dst = SITES[sname], SITES[dname]
        for files, per in ((1, 500), (5, 100), (20, 25)):
            base = _run(src, dst, [per * GB] * files, None, True)
            fast = _run(src, dst, [per * GB] * files, 200 * MB, True)
            rows.append((f"fig10/{sname[0]}2{dname[0]}/{files}f/speedup",
                         round(fast.gbps / base.gbps, 2), "x"))
    return rows
