"""Fabric benchmark: fan-out replication campaigns vs naive per-destination.

Three legs, mirroring the continental-scale replication case study:

  1. VIRTUAL campaigns — star / shared-trunk (chain) / fat-tree topologies,
     1->2 / 1->4 / 1->8 fan-out: build the distribution tree, execute it in
     virtual time on the calibrated fabric model, and compare wire bytes and
     makespan against N naive per-destination transfers contending for the
     same links. Conformance gate: the 1->4 shared-trunk campaign must cut
     wire bytes by >= 2x.

  2. REAL relay chaos — the ``FABRIC_MATRIX`` scenarios (link outages,
     degraded intermediate DTNs, silent corruption — alone and composed)
     against the real store-and-forward relay engine, each with a full
     faulted leg AND a crash + restart leg. Conformance gates: 0 integrity
     escapes, 0 re-moved journaled chunks across any hop, and every corrupt
     landing healed by exactly one hop-local re-fetch.

  3. REAL fan-out campaign — a 1->4 shared-trunk campaign decomposed into
     service tasks on local directories, replicas verified byte-for-byte
     and by merge-law digest chain.

Prints ``name,value,unit`` CSV, writes ``BENCH_fabric.json`` (metrics +
seeds + git rev), and exits non-zero on any conformance violation so CI can
gate on it.

Run: PYTHONPATH=src python -m benchmarks.fabric [--seeds N] [--quick]
"""
from __future__ import annotations

import argparse
import os
import sys
import tempfile
import threading
import time

import numpy as np

from benchmarks._results import emit
from repro.core import BufferSource, ChunkJournal, FileDest
from repro.fabric import (
    BUILTIN_TOPOLOGIES,
    CampaignRunner,
    RelayTransfer,
    RoutePlanner,
    build_distribution_tree,
    naive_wire_hops,
    shared_trunk_topology,
    simulate_campaign,
    simulate_naive,
)
from repro.fabric.relay import realize_hop_campaigns
from repro.faults import FABRIC_MATRIX, parse_scenario
from repro.service import BatchConfig, ServiceConfig, TransferService

GB = 10**9


# ---------------------------------------------------------------------------
# leg 1: virtual campaigns over canonical topologies (the same factory map
# the CLI resolves --topology names against)
# ---------------------------------------------------------------------------
def virtual_sweep(fanouts: tuple[int, ...], nbytes: int,
                  rows: list, violations: list) -> None:
    for topo_name, factory in BUILTIN_TOPOLOGIES.items():
        for n in fanouts:
            topo = factory(n)
            planner = RoutePlanner(topo)
            dests = [f"d{i}" for i in range(n)]
            tree = build_distribution_tree(planner, "src", dests, nbytes)
            camp = simulate_campaign(topo, tree, nbytes)
            naive = simulate_naive(topo, "src", dests, nbytes)
            n_hops = naive_wire_hops(RoutePlanner(topo), "src", dests, nbytes)
            reduction = (n_hops * nbytes) / tree.wire_bytes(nbytes)
            speedup = naive.makespan_s / camp.makespan_s if camp.makespan_s else 1.0
            pre = f"fabric/virtual/{topo_name}/fanout{n}"
            rows += [
                (f"{pre}/tree_wire_GB", round(camp.wire_bytes / GB, 2), "GB"),
                (f"{pre}/naive_wire_GB", round(naive.wire_bytes / GB, 2), "GB"),
                (f"{pre}/wire_reduction", round(reduction, 2), "x"),
                (f"{pre}/tree_makespan", round(camp.makespan_s, 1), "s"),
                (f"{pre}/naive_makespan", round(naive.makespan_s, 1), "s"),
                (f"{pre}/makespan_speedup", round(speedup, 2), "x"),
            ]
            if not camp.all_done or not naive.all_done:
                violations.append(f"virtual/{topo_name}/fanout{n}: unfinished flows")
            if topo_name == "chain" and n == 4 and reduction < 2.0:
                violations.append(
                    f"virtual/chain/fanout4: wire-byte reduction {reduction:.2f}x "
                    f"< required 2x"
                )


# ---------------------------------------------------------------------------
# leg 2: real relay chaos (full faulted run + crash/restart custody check)
# ---------------------------------------------------------------------------
class _HostCrash(Exception):
    """Crash bomb: the relay host dies mid-transfer."""


def _payload(seed: int, nbytes: int) -> bytes:
    return np.random.default_rng(seed).integers(0, 256, nbytes, dtype=np.uint8).tobytes()


def relay_campaign(expr: str, seed: int, *, nbytes: int, chunk: int,
                   movers: int, tmpdir: str) -> dict:
    scenario = parse_scenario(expr).scaled_to(nbytes, target_events=4.0)
    payload = _payload(seed, nbytes)
    topo = shared_trunk_topology(1, trunk_hops=2)
    route = RoutePlanner(topo).best_route("src", "d0", nbytes)
    tag = expr.replace("+", "_")
    out = dict(escapes=0, re_moved_journaled=0, corrupt_writes=0, healed=0,
               mover_deaths=0, outage_retries=0)

    def run(wd: str, dst: str, camps, injector=None, **kw):
        os.makedirs(wd, exist_ok=True)
        rep = RelayTransfer(
            route, BufferSource(payload), FileDest(dst, nbytes),
            workdir=wd, chunk_bytes=chunk, movers=movers,
            source_wrapper=lambda h, s: camps[h].wrap_source(s),
            dest_wrapper=lambda h, d: camps[h].wrap_dest(d),
            fault_injector=injector, **kw,
        ).run()
        with open(dst, "rb") as fh:
            return rep, fh.read()

    # ---- leg A: full faulted relay
    wd = os.path.join(tmpdir, f"A-{tag}-{seed}")
    camps, _victims = realize_hop_campaigns(
        scenario, route, total_bytes=nbytes, seed=seed, movers=movers)
    rep, final = run(wd, os.path.join(wd, "out.bin"), camps)
    out["escapes"] += int(final != payload)
    out["corrupt_writes"] += sum(c.stats.corrupt_writes for c in camps.values())
    out["healed"] += rep.refetches
    out["mover_deaths"] += rep.mover_deaths
    out["outage_retries"] += sum(h.outage_retries for h in rep.hops)

    # ---- leg B: crash mid-relay, restart, count re-moved journaled chunks
    wd = os.path.join(tmpdir, f"B-{tag}-{seed}")
    dst = os.path.join(wd, "out.bin")
    camps1, _ = realize_hop_campaigns(
        scenario, route, total_bytes=nbytes, seed=seed + 101, movers=movers)
    lock = threading.Lock()
    calls = [0]
    n_chunks = max(1, -(-nbytes // chunk))
    bomb_after = max(2, (n_chunks * route.n_hops) // 2)

    def bomb(_hop, _chunk, _attempt):
        with lock:
            calls[0] += 1
            if calls[0] > bomb_after:
                raise _HostCrash("relay host died mid-transfer")

    try:
        run(wd, dst, camps1, injector=bomb, max_retries=0)
    except (_HostCrash, RuntimeError):
        pass                     # the crash (or a fault it raced) is the point
    journaled: dict[int, set[int]] = {}
    for h, p in enumerate(RelayTransfer.journal_paths(wd, route)):
        if os.path.exists(p):
            probe = ChunkJournal(p)
            journaled[h] = set(probe.records)
            probe.close()

    camps2, _ = realize_hop_campaigns(
        scenario, route, total_bytes=nbytes, seed=seed + 202, movers=movers)
    moved: list[tuple[int, int]] = []

    def record(hop, c, _attempt):
        with lock:
            moved.append((hop, c.index))

    rep2, final2 = run(wd, dst, camps2, injector=record)
    out["escapes"] += int(final2 != payload)
    out["re_moved_journaled"] += sum(
        1 for (h, i) in set(moved) if i in journaled.get(h, set()))
    out["corrupt_writes"] += sum(c.stats.corrupt_writes for c in camps2.values())
    out["healed"] += rep2.refetches
    out["mover_deaths"] += rep2.mover_deaths
    return out


def relay_sweep(seeds: int, *, nbytes: int, chunk: int, movers: int,
                rows: list, violations: list) -> None:
    with tempfile.TemporaryDirectory(prefix="fabric-relay-") as tmpdir:
        for expr in FABRIC_MATRIX:
            agg: dict = {}
            for seed in range(seeds):
                one = relay_campaign(
                    expr, seed, nbytes=nbytes, chunk=chunk, movers=movers,
                    tmpdir=tmpdir)
                for k, v in one.items():
                    agg[k] = agg.get(k, 0) + v
            pre = f"fabric/relay/{expr}"
            rows += [
                (f"{pre}/escapes", agg["escapes"], "replicas"),
                (f"{pre}/re_moved_journaled", agg["re_moved_journaled"], "chunks"),
                (f"{pre}/corrupt_writes", agg["corrupt_writes"], "events"),
                (f"{pre}/healed_by_refetch", agg["healed"], "events"),
                (f"{pre}/mover_deaths", agg["mover_deaths"], "movers"),
                (f"{pre}/outage_retries", agg["outage_retries"], "ops"),
            ]
            if agg["escapes"]:
                violations.append(f"relay/{expr}: {agg['escapes']} integrity escapes")
            if agg["re_moved_journaled"]:
                violations.append(
                    f"relay/{expr}: {agg['re_moved_journaled']} journaled chunks "
                    f"re-moved across a hop")
            if agg["healed"] != agg["corrupt_writes"]:
                violations.append(
                    f"relay/{expr}: {agg['corrupt_writes']} corrupt writes but "
                    f"{agg['healed']} healed by re-fetch")


# ---------------------------------------------------------------------------
# leg 3: real fan-out campaign through the service
# ---------------------------------------------------------------------------
def service_campaign(seed: int, *, nbytes: int, chunk: int,
                     rows: list, violations: list) -> None:
    topo = shared_trunk_topology(4, trunk_hops=3)
    payload = _payload(seed, nbytes)
    dests = [f"d{i}" for i in range(4)]
    with tempfile.TemporaryDirectory(prefix="fabric-svc-") as td:
        dirs = {}
        for name in topo.endpoints:
            dirs[name] = os.path.join(td, name)
            os.makedirs(dirs[name])
        with open(os.path.join(dirs["src"], "replica.bin"), "wb") as fh:
            fh.write(payload)
        svc = TransferService(os.path.join(td, "svc"), ServiceConfig(
            mover_budget=4, max_concurrent_tasks=4, chunk_bytes=chunk,
            tick_s=0.002, batch=BatchConfig(direct_bytes=1 << 30, batch_files=64),
        ))
        try:
            t0 = time.perf_counter()
            rep = CampaignRunner(svc, topo, dirs).replicate(
                "replica.bin", "src", dests, tenant="climate", timeout=120)
            secs = time.perf_counter() - t0
        finally:
            svc.close()
        byte_identical = sum(
            1 for d in dests
            if open(os.path.join(dirs[d], "replica.bin"), "rb").read() == payload
        )
    rows += [
        ("fabric/service_campaign/replicas_verified", rep.replicas_verified, "replicas"),
        ("fabric/service_campaign/byte_identical", byte_identical, "replicas"),
        ("fabric/service_campaign/escapes", rep.integrity_escapes, "replicas"),
        ("fabric/service_campaign/wire_MB", round(rep.wire_bytes / 1e6, 2), "MB"),
        ("fabric/service_campaign/naive_wire_MB",
         round(rep.naive_wire_bytes / 1e6, 2), "MB"),
        ("fabric/service_campaign/wire_reduction", round(rep.wire_reduction, 2), "x"),
        ("fabric/service_campaign/edge_tasks", len(rep.edge_tasks), "tasks"),
        ("fabric/service_campaign/seconds", round(secs, 2), "s"),
    ]
    if rep.state != "SUCCEEDED":
        violations.append(f"service_campaign: state {rep.state}: {rep.error}")
    if rep.integrity_escapes or byte_identical != len(dests):
        violations.append(
            f"service_campaign: {rep.integrity_escapes} digest-chain escapes, "
            f"{byte_identical}/{len(dests)} replicas byte-identical")
    if rep.wire_reduction < 2.0:
        violations.append(
            f"service_campaign: wire reduction {rep.wire_reduction:.2f}x < 2x")


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------
def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--seeds", type=int, default=3)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out-dir", default=None, help="where BENCH_fabric.json lands")
    ap.add_argument("--force", action="store_true",
                    help="overwrite a BENCH_fabric.json from another git rev")
    args = ap.parse_args(argv)

    t_start = time.perf_counter()
    fanouts = (2, 4) if args.quick else (2, 4, 8)
    v_bytes = 100 * GB
    r_bytes = (1 * 1024 * 1024 + 4093) if args.quick else (2 * 1024 * 1024 + 4093)
    s_bytes = (192 * 1024 + 17) if args.quick else (512 * 1024 + 17)
    chunk, movers = 96 * 1024, 4
    seeds = max(1, args.seeds if not args.quick else min(args.seeds, 2))

    rows: list[tuple[str, float, str]] = []
    violations: list[str] = []
    virtual_sweep(fanouts, v_bytes, rows, violations)
    relay_sweep(seeds, nbytes=r_bytes, chunk=chunk, movers=movers,
                rows=rows, violations=violations)
    service_campaign(0, nbytes=s_bytes, chunk=chunk,
                     rows=rows, violations=violations)
    rows.append(("fabric/seeds", seeds, "seeds"))

    print("name,value,unit")
    for name, val, unit in rows:
        print(f"{name},{val},{unit}")
    path = emit("fabric", rows,
                args={"quick": args.quick, "fanouts": list(fanouts),
                      "seeds": list(range(seeds))},
                out_dir=args.out_dir,
                elapsed_s=round(time.perf_counter() - t_start, 3),
                force=args.force)
    print(f"# wrote {path}")
    if violations:
        print("\nCONFORMANCE VIOLATIONS:", file=sys.stderr)
        for v in violations:
            print(f"  - {v}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
