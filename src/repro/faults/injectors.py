"""Deterministic, seedable fault injection for the transfer stack.

A ``FaultCampaign`` is one concrete realisation of a ``Scenario`` against one
transfer (or one service workload): it wraps the transfer's ``ByteSource`` /
``ByteDest`` endpoints and injects

  * **silent bit-flip corruption** — one-shot byte flips at a configured
    bytes-per-error rate (the paper's Globus logs: ~1 per 1.26 TB, §2.3),
    applied to the data *after* the source-side fingerprint was taken, so
    only the destination read-back digest can catch them;
  * **mover deaths mid-chunk** — after a partial (torn) chunk write the
    worker thread is killed with ``MoverCrash``; the chunk must be re-queued
    and re-moved by a surviving (or respawned) mover;
  * **stalled/straggler movers** — one-shot wall-clock stalls in the write
    path (speculative duplication territory);
  * **endpoint outages** — once the transfer crosses a progress fraction,
    the next N reads/writes raise ``EndpointOutage`` (the engine/service must
    wait the window out on the outage budget, not the chunk retry budget);
  * **torn journal tails** — ``tear_journal_tail`` truncates a journal
    mid-way through its final record, the on-disk state a crash mid-append
    leaves behind.

Everything is deterministic given ``(scenario, seed, total_bytes)``: the
random realisation comes from a private ``random.Random`` seeded through
SHA-256 (never the process-salted ``hash``), so a failing campaign replays
bit-for-bit. All counters live in ``FaultCampaign.stats`` so conformance
suites can assert *every* injected fault was observed and healed.
"""
from __future__ import annotations

import dataclasses
import hashlib
import os
import random
import threading
import time

from repro.core.transfer import ByteDest, ByteSource, EndpointOutage, MoverCrash
from repro.faults.scenarios import Scenario


def _seed_int(*parts) -> int:
    blob = "|".join(repr(p) for p in parts).encode()
    return int.from_bytes(hashlib.sha256(blob).digest()[:8], "big")


@dataclasses.dataclass
class FaultStats:
    """What a campaign actually injected (the conformance ground truth)."""

    corruptions_injected: int = 0    # individual byte flips
    corrupt_writes: int = 0          # writes that landed >=1 flip (each must
                                     # cost exactly one read-back catch + re-fetch)
    corrupted_bytes: int = 0
    mover_kills: int = 0
    outage_rejections: int = 0       # window rejections (outage/down/flap)
    brownout_rejections: int = 0     # single-op brownout rejections
    stalls: int = 0
    torn_tail_bytes: int = 0
    stale_index_corruptions: int = 0  # chunk-index entries whose backing
                                      # bytes were corrupted under them
    landed_bitrot_flips: int = 0      # post-landing bit flips in verified
                                      # destination regions (scrub territory)


class FaultCampaign:
    """Binds a Scenario to one transfer: wrapped endpoints + injected faults.

    ``total_bytes`` is the goodput size of the transfer (sum of item sizes
    for a service task set); progress fractions and the corruption stream are
    measured against it. ``movers`` caps mover kills at the pool size (so the
    ``kill_all_movers`` scenario kills each mover once, forcing a respawn,
    instead of killing replacements forever). ``item_bytes`` lists the item
    sizes of ONE service task, in item order, so each item's local write
    offsets map into a distinct region of the [0, total_bytes) corruption
    plan; a campaign is scoped to a single task (or a single raw transfer) —
    use one campaign per task for multi-task workloads.
    """

    def __init__(
        self,
        scenario: Scenario,
        *,
        total_bytes: int,
        seed: int = 0,
        movers: int | None = None,
        item_bytes: "list[int] | tuple[int, ...] | None" = None,
    ):
        self.scenario = scenario
        self.total_bytes = int(total_bytes)
        self.seed = seed
        self.stats = FaultStats()
        self._lock = threading.Lock()
        self._rng = random.Random(_seed_int(seed, scenario.name, total_bytes))

        # corruption plan: one-shot byte OFFSETS in [0, total_bytes), drawn
        # by exponential inter-arrival skips so the expected count is
        # total/bytes_per_error. Keyed by offset (not stream position) and
        # popped on application: a re-fetched chunk re-writes the same
        # offsets, finds its positions consumed, and is guaranteed to heal —
        # matching reality, where re-reading after a random corruption does
        # not re-corrupt the same bytes.
        self._corrupt: dict[int, int] = {}
        if scenario.bytes_per_error is not None and self.total_bytes > 0:
            pos = self._rng.expovariate(1.0 / scenario.bytes_per_error)
            while pos < self.total_bytes:
                mask = 1 << self._rng.randrange(8)          # one flipped bit
                self._corrupt[int(pos)] = mask
                pos += self._rng.expovariate(1.0 / scenario.bytes_per_error)
        self.planned_corruptions = len(self._corrupt)

        # per-item offset bases: a service task's items each see LOCAL write
        # offsets in [0, item_size), but the corruption plan spans the whole
        # workload [0, total_bytes). ``item_bytes`` maps item i to the base
        # sum(sizes[:i]) so every planned offset is reachable and two items
        # never collide on the same plan position; without it (single-item /
        # raw-engine campaigns) the base is 0.
        self._item_base: dict[int, int] = {}
        if item_bytes is not None:
            base = 0
            for i, nb in enumerate(item_bytes):
                self._item_base[i] = base
                base += int(nb)

        # brownout marks: seeded byte positions whose covering write is
        # rejected once (keyed by position, like the corruption plan, so the
        # realisation is deterministic regardless of mover interleaving)
        self._brownout: set[int] = set()
        if scenario.brownout_events > 0 and self.total_bytes > 0:
            want = min(scenario.brownout_events, self.total_bytes)
            while len(self._brownout) < want:
                self._brownout.add(self._rng.randrange(self.total_bytes))

        self._written = 0            # stream position: bytes successfully written
        kills = scenario.kill_movers
        if movers is not None:
            kills = min(kills, movers)
        self._kills_left = kills
        self._kill_at = int(scenario.kill_at_frac * self.total_bytes)
        # outage windows, generalised: (arm-at-bytes, rejected-ops) pairs.
        # The classic single window, the hard endpoint-death window, and the
        # evenly-spread flap windows all share one arming mechanism.
        self._windows: list[tuple[int, int]] = []
        if scenario.outage_at_frac is not None:
            self._windows.append((int(scenario.outage_at_frac * self.total_bytes),
                                  scenario.outage_ops))
        if scenario.down_at_frac is not None:
            self._windows.append((int(scenario.down_at_frac * self.total_bytes),
                                  scenario.down_ops))
        for i in range(scenario.link_flaps):
            frac = (i + 1) / (scenario.link_flaps + 1)
            self._windows.append((int(frac * self.total_bytes),
                                  scenario.flap_ops))
        self._windows.sort()
        self._outage_ops_left = 0
        self._stalls_left = scenario.stall_movers

    # ------------------------------------------------------------------
    # per-op fault decisions (all under the campaign lock)
    # ------------------------------------------------------------------
    def _check_outage(self) -> None:
        while self._windows and self._written >= self._windows[0][0]:
            self._outage_ops_left += self._windows.pop(0)[1]
        if self._outage_ops_left > 0:
            self._outage_ops_left -= 1
            self.stats.outage_rejections += 1
            raise EndpointOutage(
                f"endpoint outage window: {self._outage_ops_left} rejections left"
            )

    def _check_brownout(self, offset: int, length: int) -> None:
        """Reject the write covering an unconsumed brownout mark (one-shot:
        the retry of the same write finds its mark consumed and succeeds)."""
        if not self._brownout:
            return
        lo, hi = offset, offset + length
        for p in self._brownout:
            if lo <= p < hi:
                self._brownout.discard(p)
                self.stats.brownout_rejections += 1
                raise EndpointOutage(f"brownout: op covering byte {p} refused")

    def _maybe_kill(self) -> bool:
        if self._kills_left > 0 and self._written >= self._kill_at:
            self._kills_left -= 1
            self.stats.mover_kills += 1
            return True
        return False

    def _maybe_stall(self) -> float:
        if self._stalls_left > 0:
            self._stalls_left -= 1
            self.stats.stalls += 1
            return self.scenario.stall_s
        return 0.0

    def _apply_corruption(self, offset: int, data: bytes) -> bytes:
        """Consume corruption offsets covered by this write (one-shot)."""
        if not self._corrupt:
            return data
        lo, hi = offset, offset + len(data)
        hits = [p for p in self._corrupt if lo <= p < hi]
        if not hits:
            return data
        buf = bytearray(data)
        for p in hits:
            buf[p - lo] ^= self._corrupt.pop(p)
            self.stats.corruptions_injected += 1
            self.stats.corrupted_bytes += 1
        self.stats.corrupt_writes += 1
        return bytes(buf)

    # ------------------------------------------------------------------
    # endpoint wrappers
    # ------------------------------------------------------------------
    def wrap_source(self, inner: ByteSource) -> "FaultySource":
        return FaultySource(self, inner)

    def wrap_dest(self, inner: ByteDest, *, base: int = 0) -> "FaultyDest":
        return FaultyDest(self, inner, base=base)

    # service-flavoured wrappers (TransferService passes task/item context).
    # Only the dest needs the per-item base: corruption is applied on the
    # write path, sources only see outage windows.
    def service_source_wrapper(self, task_id: str, item_idx: int,
                               inner: ByteSource) -> "FaultySource":
        return self.wrap_source(inner)

    def service_dest_wrapper(self, task_id: str, item_idx: int,
                             inner: ByteDest) -> "FaultyDest":
        return self.wrap_dest(inner, base=self._item_base.get(item_idx, 0))


class FaultySource:
    """ByteSource wrapper: outage windows hit reads too."""

    def __init__(self, campaign: FaultCampaign, inner: ByteSource):
        self._c, self._inner = campaign, inner
        self.nbytes = inner.nbytes

    def read(self, offset: int, length: int) -> bytes:
        with self._c._lock:
            self._c._check_outage()
        return self._inner.read(offset, length)


class FaultyDest:
    """ByteDest wrapper: the write path is where corruption lands, movers
    die mid-chunk (torn writes), and stragglers stall. Verification reads
    (``read_back``) pass through untouched — the read-back must see exactly
    the bytes that landed, or the integrity check would be theatre."""

    def __init__(self, campaign: FaultCampaign, inner: ByteDest, *, base: int = 0):
        self._c, self._inner = campaign, inner
        self._base = base

    def write(self, offset: int, data: bytes) -> None:
        c = self._c
        with c._lock:
            c._check_outage()
            c._check_brownout(self._base + offset, len(data))
            kill = c._maybe_kill()
            stall = 0.0 if kill else c._maybe_stall()
            if not kill:
                data = c._apply_corruption(self._base + offset, data)
                c._written += len(data)
        if kill:
            # torn chunk write: half the bytes land, then the mover dies.
            self._inner.write(offset, data[: len(data) // 2])
            raise MoverCrash(f"mover killed mid-write at offset {offset}")
        if stall:
            time.sleep(stall)
        self._inner.write(offset, data)

    def read_back(self, offset: int, length: int) -> bytes:
        return self._inner.read_back(offset, length)


# ---------------------------------------------------------------------------
# torn journal tails
# ---------------------------------------------------------------------------
def tear_journal_tail(path: str | os.PathLike, *, seed: int = 0,
                      cut_at: int | None = None) -> int:
    """Truncate a journal mid-way through its final record (crash mid-append).

    Picks a cut point strictly inside the last line (seeded, deterministic)
    unless ``cut_at`` gives an absolute byte offset. Returns the number of
    bytes removed. Replay must stop cleanly at the torn record and keep every
    complete record before it (core.journal's crash-consistency contract).
    """
    path = str(path)
    with open(path, "rb") as fh:
        data = fh.read()
    stripped = data.rstrip(b"\n")
    if not stripped:
        return 0
    start = stripped.rfind(b"\n") + 1        # first byte of the last record
    if cut_at is None:
        if len(stripped) - start < 2:
            cut_at = start               # 1-byte record: drop it whole
        else:
            rng = random.Random(_seed_int(seed, "tear", len(data)))
            # keep >=1 byte of the record, never its trailing newline:
            # the on-disk result is a genuinely torn, unterminated line
            cut_at = rng.randrange(start + 1, len(stripped))
    if not (0 <= cut_at <= len(data)):
        raise ValueError(f"cut_at {cut_at} outside file of {len(data)} bytes")
    with open(path, "r+b") as fh:
        fh.truncate(cut_at)
    return len(data) - cut_at


# ---------------------------------------------------------------------------
# stale chunk-index entries
# ---------------------------------------------------------------------------
def corrupt_index_backing(index, *, count: int, seed: int = 0,
                          stats: FaultStats | None = None) -> list:
    """Flip one byte behind each of ``count`` seeded victim chunk-index
    entries — the on-disk state an overwrite/bit-rot leaves behind: the index
    still promises content its backing path no longer holds.

    Victims are drawn deterministically from the index's live entries (seeded
    through SHA-256, one flipped bit at a seeded offset inside the entry's
    byte region). Returns the victim entries. The dedup path's contract under
    this fault: every probe that hits a victim must re-verify the backing
    bytes, demote the chunk to a wire move, and quarantine the entry — a
    lying index must never become an integrity escape.
    """
    entries = sorted(index.entries(),
                     key=lambda e: (e.path, e.offset, e.digest_hex))
    entries = [e for e in entries if e.length > 0 and os.path.exists(e.path)]
    if not entries or count <= 0:
        return []
    rng = random.Random(_seed_int(seed, "stale_index", len(entries)))
    victims = rng.sample(entries, min(count, len(entries)))
    for e in victims:
        flip_at = e.offset + rng.randrange(e.length)
        mask = 1 << rng.randrange(8)
        with open(e.path, "r+b") as fh:
            fh.seek(flip_at)
            byte = fh.read(1)
            if not byte:
                continue
            fh.seek(flip_at)
            fh.write(bytes([byte[0] ^ mask]))
        if stats is not None:
            stats.stale_index_corruptions += 1
    return victims


# ---------------------------------------------------------------------------
# landed bit-rot
# ---------------------------------------------------------------------------
def corrupt_landed_regions(regions, *, count: int, seed: int = 0,
                           stats: FaultStats | None = None) -> list:
    """Flip one bit inside each of ``count`` seeded victim LANDED regions —
    the decay storage inflicts after a transfer already read-back verified,
    journaled, and reported success (the Petascale DTN finding: corruption
    discovered *after* "successful" transfers).

    ``regions`` is an iterable of ``(path, offset, length)`` triples (e.g.
    built from a SUCCEEDED task's item-report chunks). Victims and flip
    positions are drawn deterministically through SHA-256, mirroring
    ``corrupt_index_backing``. Returns the victim triples. The scrub daemon's
    contract under this fault: every flipped region must be detected against
    its journal digest and either repaired from a verified replica or
    quarantined — never trusted again silently.
    """
    regions = sorted(
        (str(p), int(o), int(ln)) for p, o, ln in regions
        if int(ln) > 0 and os.path.exists(str(p))
    )
    if not regions or count <= 0:
        return []
    rng = random.Random(_seed_int(seed, "bitrot_landed", len(regions)))
    victims = rng.sample(regions, min(count, len(regions)))
    for path, offset, length in victims:
        flip_at = offset + rng.randrange(length)
        mask = 1 << rng.randrange(8)
        with open(path, "r+b") as fh:
            fh.seek(flip_at)
            byte = fh.read(1)
            if not byte:
                continue
            fh.seek(flip_at)
            fh.write(bytes([byte[0] ^ mask]))
        if stats is not None:
            stats.landed_bitrot_flips += 1
    return victims
