"""Fault injection + chaos conformance for the transfer stack.

The paper's operational core is that integrity checking and chunk-granular
restart are *essential* at exascale: Globus logs show silent corruption about
once per 1.26 TB moved (§2.3), and production transfers survive on automated
recovery from mover crashes, endpoint outages, and checksum mismatches. This
package makes those failure modes executable:

  * ``scenarios``  — the composable campaign DSL (``corrupt_1_per_TiB +
    kill_2_movers + outage_at_50pct``) and the conformance ``FULL_MATRIX``;
  * ``injectors``  — deterministic seeded realisations: wrapped
    ByteSource/ByteDest endpoints, mover-pool kills, outage windows, torn
    journal tails, with full injected-fault accounting (``FaultStats``).

Consumed by the real threaded engine (``core.transfer`` / ``service``), the
virtual-time testbed (``service.testbed.run_load(scenario=...)``), the chaos
benchmark (``benchmarks/chaos.py``), and the scenario conformance suite
(``tests/test_faults.py``).
"""
from repro.faults.injectors import (
    FaultCampaign,
    FaultStats,
    FaultyDest,
    FaultySource,
    corrupt_index_backing,
    corrupt_landed_regions,
    tear_journal_tail,
)
from repro.faults.scenarios import (
    CLEAN,
    FABRIC_MATRIX,
    FULL_MATRIX,
    PAPER_BYTES_PER_ERROR,
    SCENARIOS,
    Scenario,
    parse_scenario,
)

__all__ = [
    "CLEAN", "FABRIC_MATRIX", "FULL_MATRIX", "FaultCampaign", "FaultStats",
    "FaultyDest", "FaultySource", "PAPER_BYTES_PER_ERROR", "SCENARIOS",
    "Scenario", "corrupt_index_backing", "corrupt_landed_regions",
    "parse_scenario", "tear_journal_tail",
]
