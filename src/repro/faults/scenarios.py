"""Composable fault-campaign scenarios — the chaos DSL.

A ``Scenario`` names a set of faults to inject into one transfer (or one
service workload): silent bit-flip corruption at a bytes-per-error rate (the
paper's Globus logs: ~one corruption per 1.26 TB moved, §2.3), mover deaths
mid-chunk, stalled/straggler movers, endpoint outage windows, and torn
journal tails. Scenarios compose with ``+``::

    parse_scenario("corrupt_1_per_TiB+kill_2_movers+outage_at_50pct")

and the same scenario object drives BOTH backends:

  * the real threaded engine/service via ``repro.faults.injectors.FaultCampaign``
    (wrapped ByteSource/ByteDest endpoints + mover-pool injection), and
  * the virtual-time testbed via ``repro.service.testbed.run_load(scenario=...)``
    (fluid-model equivalents: re-moved bytes, mover-budget kills, rate-zero
    outage windows).

These are the repo's executable conformance campaigns: `benchmarks/chaos.py`
runs the ``FULL_MATRIX`` across seeds and asserts zero integrity escapes and
zero re-moved journaled chunks — the invariants every future PR must keep.
"""
from __future__ import annotations

import dataclasses

TiB = 1024**4
PAPER_BYTES_PER_ERROR = 1.26e12     # one silent corruption per 1.26 TB (§2.3)


@dataclasses.dataclass(frozen=True)
class Scenario:
    """One named fault campaign. All fields are deterministic *plans*; the
    random realisation (which byte flips, which op dies) comes from the seed
    given to the injector/testbed, never from global state."""

    name: str = "clean"
    # silent corruption: mean bytes between injected bit flips (None = off).
    bytes_per_error: float | None = None
    # mover deaths: kill this many movers mid-chunk, starting once the
    # transfer has moved ``kill_at_frac`` of its bytes.
    kill_movers: int = 0
    kill_at_frac: float = 0.25
    # endpoint outage: at ``outage_at_frac`` progress the endpoints reject
    # the next ``outage_ops`` operations (real engine) / go rate-zero for
    # ``outage_s`` virtual seconds (testbed).
    outage_at_frac: float | None = None
    outage_ops: int = 24
    outage_s: float = 30.0
    # stragglers: this many one-shot stalls of ``stall_s`` wall-clock seconds.
    stall_movers: int = 0
    stall_s: float = 0.02
    # torn journal: after a crash, truncate the journal mid-way through its
    # final record before restarting (exercised by chaos restart legs).
    torn_journal: bool = False
    # --- fabric faults (multi-hop relays / replication campaigns) ----------
    # link outage: at ``link_outage_at_frac`` campaign progress, one link on
    # the route/tree (seeded victim) goes dark — its endpoints reject the
    # next ``link_outage_ops`` operations (real relay) / carry zero bandwidth
    # for ``link_outage_s`` virtual seconds (fabric.virtual).
    link_outage_at_frac: float | None = None
    link_outage_ops: int = 24
    link_outage_s: float = 30.0
    # degraded intermediate endpoint: a seeded victim DTN on the route slows
    # down — every write stalls (real relay) / endpoint rates are multiplied
    # by ``degrade_factor`` (fabric.virtual).
    degrade_hops: int = 0
    degrade_factor: float = 0.25
    # --- content-plane faults (dedup against chunk indexes) ----------------
    # stale index entries: corrupt the backing bytes behind this many seeded
    # victim entries in the pre-populated chunk index before the dedup pass —
    # the lookup hit re-verifies, demotes the chunk to a wire move, and
    # quarantines the entry (the 0-escape invariant must survive a lying
    # index).
    stale_index: int = 0
    # --- resilience-plane faults (health breakers, failover, scrub) --------
    # hard endpoint death: at ``down_at_frac`` progress the endpoint rejects
    # the next ``down_ops`` operations — a window long enough to exhaust any
    # reasonable per-hop outage patience (failover territory) yet finite, so
    # a single-pipe transfer with no alternate route still waits it out.
    down_at_frac: float | None = None
    down_ops: int = 120
    # link flap: ``link_flaps`` short outage windows of ``flap_ops`` rejected
    # operations each, spread evenly across transfer progress — the
    # intermittent link that trips EWMA breakers without ever being hard down.
    link_flaps: int = 0
    flap_ops: int = 12
    # brownout: ``brownout_events`` seeded single-op rejections keyed to byte
    # positions in [0, total_bytes) — an endpoint that intermittently refuses
    # work rather than dying (each rejected op heals on its retry).
    brownout_events: int = 0
    # landed bit-rot: flip one bit in each of this many landed (verified,
    # journaled) destination regions AFTER the transfer succeeded — the
    # post-landing decay the scrub daemon exists to catch (injected by
    # ``corrupt_landed_regions``; no in-flight effect).
    bitrot_landed: int = 0

    def __post_init__(self):
        if self.bytes_per_error is not None and self.bytes_per_error <= 0:
            raise ValueError("bytes_per_error must be > 0")
        if not (0.0 <= self.kill_at_frac <= 1.0):
            raise ValueError("kill_at_frac must be in [0, 1]")
        if self.outage_at_frac is not None and not (0.0 <= self.outage_at_frac <= 1.0):
            raise ValueError("outage_at_frac must be in [0, 1]")
        if self.link_outage_at_frac is not None and not (0.0 <= self.link_outage_at_frac <= 1.0):
            raise ValueError("link_outage_at_frac must be in [0, 1]")
        if not (0.0 < self.degrade_factor <= 1.0):
            raise ValueError("degrade_factor must be in (0, 1]")
        if self.down_at_frac is not None and not (0.0 <= self.down_at_frac <= 1.0):
            raise ValueError("down_at_frac must be in [0, 1]")
        if self.down_ops <= 0:
            raise ValueError("down_ops must be > 0")
        if self.link_flaps < 0 or self.flap_ops <= 0:
            raise ValueError("link_flaps must be >= 0 and flap_ops > 0")
        if self.brownout_events < 0:
            raise ValueError("brownout_events must be >= 0")
        if self.bitrot_landed < 0:
            raise ValueError("bitrot_landed must be >= 0")

    # -- composition --------------------------------------------------------
    def __add__(self, other: "Scenario") -> "Scenario":
        """Merge two campaigns: for every field, the non-default wins (the
        right side wins when both differ from the default)."""
        merged = {}
        for f in dataclasses.fields(self):
            if f.name == "name":
                continue
            default = f.default
            a, b = getattr(self, f.name), getattr(other, f.name)
            merged[f.name] = b if b != default else a
        name = self.name if other.name == "clean" else (
            other.name if self.name == "clean" else f"{self.name}+{other.name}"
        )
        return Scenario(name=name, **merged)

    def replace(self, **kw) -> "Scenario":
        return dataclasses.replace(self, **kw)

    def scaled_to(self, total_bytes: int, *, target_events: float = 4.0) -> "Scenario":
        """Rescale the corruption rate so ~``target_events`` strikes hit a
        payload of ``total_bytes`` — the paper's per-TB rates would inject
        nothing into a test-sized payload; conformance runs scale the rate,
        not the mechanism."""
        if self.bytes_per_error is None or total_bytes <= 0:
            return self
        return dataclasses.replace(
            self, bytes_per_error=max(1.0, total_bytes / target_events)
        )

    @property
    def is_clean(self) -> bool:
        return (
            self.bytes_per_error is None and self.kill_movers == 0
            and self.outage_at_frac is None and self.stall_movers == 0
            and not self.torn_journal
            and self.link_outage_at_frac is None and self.degrade_hops == 0
            and self.stale_index == 0
            and self.down_at_frac is None and self.link_flaps == 0
            and self.brownout_events == 0 and self.bitrot_landed == 0
        )


# ---------------------------------------------------------------------------
# named registry
# ---------------------------------------------------------------------------
CLEAN = Scenario()
SCENARIOS: dict[str, Scenario] = {
    "clean": CLEAN,
    # corruption at the paper's calibrated Globus-log rate (§2.3) and at a
    # round per-TiB rate; conformance runs call .scaled_to(payload) on these.
    "corrupt_paper_rate": Scenario(name="corrupt_paper_rate",
                                   bytes_per_error=PAPER_BYTES_PER_ERROR),
    "corrupt_1_per_TiB": Scenario(name="corrupt_1_per_TiB", bytes_per_error=float(TiB)),
    "kill_2_movers": Scenario(name="kill_2_movers", kill_movers=2),
    "kill_all_movers": Scenario(name="kill_all_movers", kill_movers=1 << 10),
    "outage_at_50pct": Scenario(name="outage_at_50pct", outage_at_frac=0.5),
    "stall_1_mover": Scenario(name="stall_1_mover", stall_movers=1),
    "torn_journal_tail": Scenario(name="torn_journal_tail", torn_journal=True),
    # fabric faults: one link dies mid-campaign / one intermediate DTN slows
    "link_outage_at_50pct": Scenario(name="link_outage_at_50pct",
                                     link_outage_at_frac=0.5),
    "degrade_hop": Scenario(name="degrade_hop", degrade_hops=1),
    # content-plane fault: the chunk index promises bytes it no longer has
    "stale_index": Scenario(name="stale_index", stale_index=2),
    # resilience-plane faults: a hard endpoint death window, a flapping
    # link, an intermittently-refusing endpoint, and post-landing bit-rot
    "endpoint_down_at_50pct": Scenario(name="endpoint_down_at_50pct",
                                       down_at_frac=0.5),
    "link_flap": Scenario(name="link_flap", link_flaps=3),
    "brownout": Scenario(name="brownout", brownout_events=24),
    "bitrot_landed": Scenario(name="bitrot_landed", bitrot_landed=3),
}


def parse_scenario(expr: str) -> Scenario:
    """``"corrupt_1_per_TiB+kill_2_movers"`` -> the composed Scenario."""
    parts = [p.strip() for p in expr.split("+") if p.strip()]
    if not parts:
        raise ValueError(f"empty scenario expression {expr!r}")
    out = CLEAN
    for p in parts:
        if p not in SCENARIOS:
            raise ValueError(f"unknown scenario {p!r} (known: {sorted(SCENARIOS)})")
        out = out + SCENARIOS[p]
    return out


# The conformance matrix benchmarks/chaos.py sweeps: every fault class alone,
# then the compound campaigns (the paper's failure cocktail).
FULL_MATRIX: tuple[str, ...] = (
    "corrupt_1_per_TiB",
    "kill_2_movers",
    "outage_at_50pct",
    "stall_1_mover",
    "corrupt_1_per_TiB+kill_2_movers",
    "corrupt_1_per_TiB+outage_at_50pct",
    "corrupt_1_per_TiB+kill_2_movers+outage_at_50pct",
    "torn_journal_tail",
    "corrupt_1_per_TiB+torn_journal_tail",
    "stale_index",
    "endpoint_down_at_50pct",
    "link_flap",
    "brownout",
    "bitrot_landed",
)


# The fabric conformance matrix benchmarks/fabric.py sweeps over multi-hop
# relays and fan-out campaigns: link outages and degraded intermediate DTNs,
# alone and composed with the paper's silent-corruption rate.
FABRIC_MATRIX: tuple[str, ...] = (
    "link_outage_at_50pct",
    "degrade_hop",
    "link_outage_at_50pct+degrade_hop",
    "corrupt_1_per_TiB+link_outage_at_50pct+degrade_hop",
)
