"""Chunked, integrity-checked, restartable checkpointing.

Each host is a DTN (DESIGN.md §2): its addressable shard of every leaf is cut
into chunks by the planner (``core.chunker``), moved by the chunked transfer
engine (``core.transfer``) with per-chunk fingerprints computed in the same
pass as the write (paper Fig. 4), journaled for partial restart (paper §3.1),
and verified chunk-by-chunk on restore — a corrupted chunk is re-read and, if
persistently bad, reported *by chunk*, so repair means re-fetching chunk
ranges rather than whole multi-GB files (the paper's fault-recovery claim).

Layout of one checkpoint:

    <root>/step_000123/            (renamed from .tmp on completion)
        MANIFEST.json              tree structure + per-leaf digests/plans
        <leaf-key>.bin             raw little-endian bytes
        <leaf-key>.journal         chunk-completion journal (kept for audit)

Concurrency: leaves are saved by a pool of ``io_workers`` (cross-leaf
overlap) and each leaf's chunks by ``plan.movers`` mover threads (intra-leaf
overlap), so fingerprinting of chunk k-1 rides under the write of chunk k.
"""
from __future__ import annotations

import dataclasses
import json
import os
import shutil
import threading
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Callable

import numpy as np
import jax
import ml_dtypes

from repro.core.chunker import ChunkPlan, plan_chunks
from repro.core.integrity import Digest, fingerprint_bytes, verify
from repro.core.journal import ChunkJournal
from repro.core.transfer import BufferSource, ChunkedTransfer, FileDest, IntegrityError

_DTYPES = {
    "float32": np.float32, "float16": np.float16, "bfloat16": ml_dtypes.bfloat16,
    "int32": np.int32, "int8": np.int8, "uint8": np.uint8, "int16": np.int16,
    "uint32": np.uint32, "float64": np.float64, "int64": np.int64, "bool": np.bool_,
}


class CorruptionError(RuntimeError):
    def __init__(self, leaf: str, bad_chunks: list[int]):
        super().__init__(f"leaf {leaf!r}: corrupted chunks {bad_chunks}")
        self.leaf = leaf
        self.bad_chunks = bad_chunks


# ---------------------------------------------------------------------------
# pytree <-> flat leaves
# ---------------------------------------------------------------------------
def _flatten(tree: Any) -> dict[str, np.ndarray]:
    leaves = {}
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    for path, leaf in flat:
        key = "/".join(_path_elem(p) for p in path)
        leaves[key] = np.asarray(jax.device_get(leaf))
    return leaves


def _path_elem(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


def _unflatten(leaves: dict[str, np.ndarray]) -> dict:
    root: dict = {}
    for key, val in leaves.items():
        parts = key.split("/")
        node = root
        for part in parts[:-1]:
            node = node.setdefault(part, {})
        node[parts[-1]] = val
    return root


# ---------------------------------------------------------------------------
# save
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class SaveReport:
    step: int
    path: str
    total_bytes: int
    seconds: float
    n_leaves: int
    resumed_chunks: int


def save_checkpoint(
    root: str | os.PathLike,
    step: int,
    tree: Any,
    *,
    movers: int = 8,
    io_workers: int = 4,
    chunk_bytes: int | None = None,
    process_index: int | None = None,
) -> SaveReport:
    """Write one checkpoint; safe to re-invoke after a crash (partial restart)."""
    import time

    t0 = time.perf_counter()
    proc = jax.process_index() if process_index is None else process_index
    final = os.path.join(str(root), f"step_{step:08d}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)

    leaves = _flatten(tree)
    manifest: dict[str, Any] = {"step": step, "process": proc, "leaves": {}}
    total = 0
    resumed = 0
    lock = threading.Lock()

    def save_leaf(item):
        nonlocal total, resumed
        key, arr = item
        safe = key.replace("/", "__")
        data = np.ascontiguousarray(arr).view(np.uint8).reshape(-1)
        plan = plan_chunks(
            data.nbytes, movers,
            chunk_bytes=chunk_bytes, min_chunk=4 * 1024 * 1024,
            max_chunk=256 * 1024 * 1024, alignment=max(1, arr.dtype.itemsize),
        ) if data.nbytes else plan_chunks(0, movers)
        bin_path = os.path.join(tmp, f"{safe}.bin")
        journal = ChunkJournal(os.path.join(tmp, f"{safe}.journal"))
        dest = FileDest(bin_path, data.nbytes)
        if data.nbytes:
            report = ChunkedTransfer(
                BufferSource(data), dest, plan, integrity=True, journal=journal,
            ).run()
            digest = report.file_digest
            skipped = report.skipped_chunks
        else:
            digest = fingerprint_bytes(b"")
            skipped = 0
        journal.close()
        entry = {
            "shape": list(arr.shape),
            "dtype": str(arr.dtype),
            "nbytes": int(data.nbytes),
            "file": f"{safe}.bin",
            "digest": digest.hexdigest(),
            "chunk_bytes": plan.chunk_bytes,
            "chunks": [
                {"index": c.index, "offset": c.offset, "length": c.length,
                 "digest": journal.records[c.index].digest_hex
                 if c.index in journal.records else None}
                for c in plan.chunks
            ],
        }
        with lock:
            manifest["leaves"][key] = entry
            total += data.nbytes
            resumed += skipped

    with ThreadPoolExecutor(max_workers=io_workers) as ex:
        list(ex.map(save_leaf, leaves.items()))

    with open(os.path.join(tmp, "MANIFEST.json"), "w") as fh:
        json.dump(manifest, fh, indent=1, sort_keys=True)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    return SaveReport(step, final, total, time.perf_counter() - t0, len(leaves), resumed)


# ---------------------------------------------------------------------------
# restore
# ---------------------------------------------------------------------------
def restore_checkpoint(
    path: str | os.PathLike,
    *,
    verify_chunks: bool = True,
    movers: int = 8,
) -> tuple[dict, int]:
    """Read + verify a checkpoint directory -> (nested-dict tree, step).

    Verification is per-chunk and parallel across movers; all bad chunks of a
    leaf are collected before raising CorruptionError (so an operator — or the
    elastic launcher — knows the exact byte ranges to re-replicate).
    """
    path = str(path)
    with open(os.path.join(path, "MANIFEST.json")) as fh:
        manifest = json.load(fh)
    leaves: dict[str, np.ndarray] = {}

    def load_leaf(item):
        key, entry = item
        dt = _DTYPES[entry["dtype"]]
        raw = np.fromfile(os.path.join(path, entry["file"]), dtype=np.uint8)
        if raw.nbytes != entry["nbytes"]:
            raise CorruptionError(key, [-1])  # truncated file
        if verify_chunks and entry["nbytes"]:
            bad = []
            def check(c):
                expect = c["digest"]
                got = fingerprint_bytes(raw[c["offset"] : c["offset"] + c["length"]])
                if expect is None or got.hexdigest() != expect:
                    bad.append(c["index"])
            with ThreadPoolExecutor(max_workers=movers) as ex:
                list(ex.map(check, entry["chunks"]))
            if bad:
                raise CorruptionError(key, sorted(bad))
            whole = Digest.from_bytes(bytes.fromhex(entry["digest"]))
            if whole.length != entry["nbytes"]:
                raise CorruptionError(key, [-1])
        arr = raw.view(dt)
        leaves[key] = arr.reshape(entry["shape"])

    for item in manifest["leaves"].items():
        load_leaf(item)
    return _unflatten(leaves), int(manifest["step"])


# ---------------------------------------------------------------------------
# manager
# ---------------------------------------------------------------------------
class CheckpointManager:
    """Retention, latest-step discovery, and restore-or-init."""

    def __init__(self, root: str | os.PathLike, *, keep: int = 3, movers: int = 8):
        self.root = str(root)
        self.keep = keep
        self.movers = movers
        os.makedirs(self.root, exist_ok=True)

    def steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.root):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    out.append(int(name[5:]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> int | None:
        s = self.steps()
        return s[-1] if s else None

    def save(self, step: int, tree: Any, **kw) -> SaveReport:
        rep = save_checkpoint(self.root, step, tree, movers=self.movers, **kw)
        self._gc()
        return rep

    def restore(self, step: int | None = None, **kw) -> tuple[dict, int]:
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.root}")
        return restore_checkpoint(
            os.path.join(self.root, f"step_{step:08d}"), movers=self.movers, **kw
        )

    def restore_or_init(self, init_fn: Callable[[], Any]) -> tuple[Any, int]:
        if self.latest_step() is None:
            return init_fn(), 0
        tree, step = self.restore()
        return tree, step

    def _gc(self) -> None:
        steps = self.steps()
        for s in steps[: max(0, len(steps) - self.keep)]:
            shutil.rmtree(os.path.join(self.root, f"step_{s:08d}"), ignore_errors=True)
