"""Chunked, integrity-checked, restartable checkpointing (paper §3 on disk)."""
from repro.ckpt.checkpoint import (
    CheckpointManager,
    CorruptionError,
    SaveReport,
    restore_checkpoint,
    save_checkpoint,
)

__all__ = [
    "CheckpointManager", "CorruptionError", "SaveReport",
    "restore_checkpoint", "save_checkpoint",
]
