"""Chunked collectives — client-driven chunking applied to ICI/DCN transfers.

The paper's mechanism, transposed to a TPU mesh (DESIGN.md §2): a large
tensor moving across an axis is cut into chunks that travel as independent
``ppermute`` ring steps, so (a) every link hop carries fine-grained messages
that the scheduler can overlap with compute, and (b) a consumer (matmul) can
start on chunk k-1 while chunk k is in flight — the Fig. 4 transfer/verify
overlap with the MXU playing the role of the checksum pipeline.

All functions are *manual-SPMD*: call them inside ``jax.shard_map``. The
monolithic baselines (``jax.lax.all_gather`` / ``psum`` / ``psum_scatter``)
are what the paper's un-chunked Globus corresponds to; benchmarks and the
§Perf hillclimb compare the two by collective schedule in the lowered HLO.

Chunk-count choice mirrors ``core.chunker``: enough chunks to keep the ring
pipelined (>= pipeline_depth per hop), but each message large enough to
amortize per-ppermute latency (~1 us on ICI => >= ~1 MiB messages).
"""
from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp


# Old-JAX shim hooks (see distributed.mesh.shard_map). In partially-manual
# shard_map regions, jax<0.5's XLA partitioner cannot lower lax.axis_index
# (PartitionId) and hard-crashes on ppermute/all_gather/psum_scatter (manual-
# subgroup sharding checks); only psum survives. The compat shard_map
# therefore (a) threads each manual axis's index in as a sharded operand,
# registered in _AXIS_INDEX_OVERRIDE for the trace, and (b) lists the axes in
# _PSUM_FALLBACK_AXES so the collectives below drop to psum-based equivalents
# — numerically identical, bandwidth-suboptimal, and only ever taken on the
# legacy-JAX partial-manual path.
_AXIS_INDEX_OVERRIDE: dict[str, jax.Array] = {}
_PSUM_FALLBACK_AXES: set[str] = set()


def _axis_index(axis_name: str) -> jax.Array:
    ov = _AXIS_INDEX_OVERRIDE.get(axis_name)
    return ov if ov is not None else jax.lax.axis_index(axis_name)


def _ring_perm(axis_size: int, reverse: bool = False):
    if reverse:
        return [((i + 1) % axis_size, i) for i in range(axis_size)]
    return [(i, (i + 1) % axis_size) for i in range(axis_size)]


def default_n_chunks(nbytes: int, *, pipeline_depth: int = 4, min_chunk_bytes: int = 1 << 20) -> int:
    """Paper §3.1 heuristic at ICI scale: depth chunks, >= 1 MiB messages."""
    if nbytes <= min_chunk_bytes:
        return 1
    return max(1, min(pipeline_depth, nbytes // min_chunk_bytes))


# ---------------------------------------------------------------------------
# all-gather
# ---------------------------------------------------------------------------
def chunked_all_gather(
    x: jax.Array, axis_name: str, axis_size: int, *, n_chunks: int = 4
) -> jax.Array:
    """Ring all-gather of the local shard, moved in ``n_chunks`` sub-messages.

    x: (s, ...) local shard -> (axis_size * s, ...), identical to
    jax.lax.all_gather(x, axis_name, tiled=True) (the monolithic baseline).
    """
    s = x.shape[0]
    if axis_name in _PSUM_FALLBACK_AXES:
        # legacy-JAX partial-manual region: place the shard, sum across axis
        out = jnp.zeros((axis_size * s,) + x.shape[1:], x.dtype)
        out = jax.lax.dynamic_update_slice_in_dim(out, x, _axis_index(axis_name) * s, axis=0)
        return jax.lax.psum(out, axis_name)
    if n_chunks > 1 and s % n_chunks != 0:
        n_chunks = 1  # fall back rather than mis-chunk
    idx = _axis_index(axis_name)
    perm = _ring_perm(axis_size)

    pieces = jnp.split(x, n_chunks, axis=0) if n_chunks > 1 else [x]
    out_rows = axis_size * s
    out = jnp.zeros((out_rows,) + x.shape[1:], x.dtype)

    # Interleave the chunk rings: all chunks advance one hop per "step", so at
    # any instant n_chunks fine messages are in flight on each link instead of
    # one monolithic message — the ERET/ESTO pipelining of §3.1.
    bufs = list(pieces)
    cs = s // n_chunks
    for c, piece in enumerate(pieces):
        start = idx * s + c * cs
        out = jax.lax.dynamic_update_slice_in_dim(out, piece, start, axis=0)
    for step in range(1, axis_size):
        src = (idx - step) % axis_size
        for c in range(n_chunks):
            bufs[c] = jax.lax.ppermute(bufs[c], axis_name, perm)
            start = src * s + c * cs
            out = jax.lax.dynamic_update_slice_in_dim(out, bufs[c], start, axis=0)
    return out


# ---------------------------------------------------------------------------
# reduce-scatter
# ---------------------------------------------------------------------------
def chunked_reduce_scatter(
    x: jax.Array, axis_name: str, axis_size: int, *, n_chunks: int = 4
) -> jax.Array:
    """Ring reduce-scatter: x (A*s, ...) on every device -> (s, ...) summed shard.

    Equivalent to jax.lax.psum_scatter(x, axis_name, tiled=True).
    """
    rows = x.shape[0]
    assert rows % axis_size == 0, (rows, axis_size)
    s = rows // axis_size
    if axis_name in _PSUM_FALLBACK_AXES:
        # legacy-JAX partial-manual region: sum everything, keep our block
        full = jax.lax.psum(x, axis_name)
        return jax.lax.dynamic_slice_in_dim(full, _axis_index(axis_name) * s, s, axis=0)
    if n_chunks > 1 and s % n_chunks != 0:
        n_chunks = 1
    idx = _axis_index(axis_name)
    perm = _ring_perm(axis_size)
    cs = s // n_chunks

    def block(owner: jax.Array, c: int) -> jax.Array:
        return jax.lax.dynamic_slice_in_dim(x, owner * s + c * cs, cs, axis=0)

    # Ring invariant (derivation in tests/test_chunked_collectives.py): at
    # step t rank r receives the running partial for block (r-1-t) mod A and
    # adds its local contribution; after A-1 steps rank r holds block r,
    # summed over all ranks — matching psum_scatter(tiled=True).
    own0 = jnp.mod(idx - 1, axis_size)
    acc = [block(own0, c) for c in range(n_chunks)]
    for step in range(1, axis_size):
        own = jnp.mod(idx - 1 - step, axis_size)
        for c in range(n_chunks):
            acc[c] = jax.lax.ppermute(acc[c], axis_name, perm)
            acc[c] = acc[c] + block(own, c)
    return jnp.concatenate(acc, axis=0) if n_chunks > 1 else acc[0]


def chunked_all_reduce(
    x: jax.Array, axis_name: str, axis_size: int, *, n_chunks: int = 4
) -> jax.Array:
    """Bandwidth-optimal all-reduce = chunked reduce-scatter + chunked all-gather.

    Equivalent to jax.lax.psum(x, axis_name). This is the pod-axis gradient
    synchronization path: the cross-pod (DCN) hop is the slow WAN-like link
    where the paper's chunking pays most.
    """
    if axis_name in _PSUM_FALLBACK_AXES:
        return jax.lax.psum(x, axis_name)   # legacy-JAX partial-manual region
    shape = x.shape
    flat = x.reshape(-1)
    groups = axis_size * n_chunks
    pad = (-flat.size) % groups
    if pad:
        flat = jnp.pad(flat, (0, pad))
    mat = flat.reshape(groups, -1)                      # (A*n_chunks, m)
    shard = chunked_reduce_scatter(mat, axis_name, axis_size, n_chunks=n_chunks)
    full = chunked_all_gather(shard, axis_name, axis_size, n_chunks=n_chunks)
    return full.reshape(-1)[: x.size].reshape(shape)


# ---------------------------------------------------------------------------
# overlapped all-gather matmul (collective matmul)
# ---------------------------------------------------------------------------
def ag_matmul(
    x: jax.Array, w_shard: jax.Array, axis_name: str, axis_size: int
) -> jax.Array:
    """y = x @ all_gather(w_shard) with transfer/compute overlap.

    x: (B, K) replicated on the axis; w_shard: (K/A, N) local rows of W.
    Each step multiplies the weight block currently resident while the ring
    permute moves the next one — the MXU consumes chunk k-1 as chunk k moves,
    the paper's Fig. 4 overlap with compute in place of checksumming. The
    weight blocks are the chunks; chunk size is fixed by the FSDP shard.
    """
    B, K = x.shape
    kA, N = w_shard.shape
    assert kA * axis_size == K, (x.shape, w_shard.shape, axis_size)
    if axis_name in _PSUM_FALLBACK_AXES:
        # legacy-JAX partial-manual region: gather W via psum, then one matmul
        full = jnp.zeros((K, N), w_shard.dtype)
        full = jax.lax.dynamic_update_slice_in_dim(
            full, w_shard, _axis_index(axis_name) * kA, axis=0)
        return x @ jax.lax.psum(full, axis_name)
    idx = _axis_index(axis_name)
    perm = _ring_perm(axis_size, reverse=True)  # pull blocks from the right

    def x_block(owner: jax.Array) -> jax.Array:
        return jax.lax.dynamic_slice_in_dim(x, owner * kA, kA, axis=1)

    acc = x_block(idx) @ w_shard
    buf = w_shard
    for step in range(1, axis_size):
        buf = jax.lax.ppermute(buf, axis_name, perm)
        owner = (idx + step) % axis_size
        acc = acc + x_block(owner) @ buf
    return acc


def matmul_rs(
    x: jax.Array, w: jax.Array, axis_name: str, axis_size: int, *, n_chunks: int = 1
) -> jax.Array:
    """y_shard = reduce_scatter(x_partial @ w_partial) — the row-parallel pair.

    x: (B, K/A) local columns; w: (K/A, N) local rows; output (B/A, N).
    Partial products are reduce-scattered chunk-wise so early output blocks
    ship while later blocks are still in the MXU.
    """
    part = x @ w                                    # (B, N) partial sum
    B = part.shape[0]
    assert B % axis_size == 0
    return chunked_reduce_scatter(part, axis_name, axis_size, n_chunks=n_chunks)
