"""Mesh axis conventions and sharding-rule helpers.

Axis names (fixed across the framework):
  pod    — cross-pod data parallelism over DCN (the slow, WAN-like hop where
           the paper's chunking matters most)
  data   — intra-pod FSDP/DP (+ sequence/context sharding of activations)
  model  — tensor parallelism (heads / ffn / vocab / experts)

Logical dimension names used by model definitions are mapped here to mesh
axes; a model never hardcodes a mesh axis.
"""
from __future__ import annotations

import dataclasses

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

POD, DATA, MODEL = "pod", "data", "model"

# logical dim -> mesh axis (None = replicate)
_RULES: dict[str, str | None] = {
    "batch": DATA,         # + pod, applied by batch_spec()
    "seq": None,           # sequence sharding is opt-in (context parallelism)
    "embed": None,         # activations' feature dim stays unsharded
    "vocab": MODEL,
    "heads": MODEL,
    "kv_heads": MODEL,
    "head_dim": None,
    "ffn": MODEL,
    "experts": MODEL,
    "expert_ffn": None,
    "fsdp": DATA,          # parameter dim chosen for ZeRO-3 sharding
    "state": None,         # SSM / RG-LRU recurrent state dim
    "conv": None,
}


def spec(*logical: str | None) -> P:
    """PartitionSpec from logical dim names, e.g. spec('fsdp','ffn')."""
    axes = []
    for name in logical:
        if name is None:
            axes.append(None)
        else:
            axes.append(_RULES.get(name, None) if isinstance(name, str) else name)
    return P(*axes)


def batch_spec(mesh: Mesh, *, seq_sharded: bool = False) -> P:
    """(batch, seq, ...) activation spec: batch over pod+data when present."""
    batch_axes = tuple(a for a in (POD, DATA) if a in mesh.axis_names)
    b = batch_axes if len(batch_axes) > 1 else (batch_axes[0] if batch_axes else None)
    return P(b, MODEL if seq_sharded else None)


def shard(mesh: Mesh, x, pspec: P):
    return jax.device_put(x, NamedSharding(mesh, pspec))


def axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.axis_names else 1


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    """Resolved parallelism plan for a given mesh."""

    mesh: Mesh

    @property
    def n_pods(self) -> int:
        return axis_size(self.mesh, POD)

    @property
    def dp(self) -> int:
        return axis_size(self.mesh, DATA)

    @property
    def tp(self) -> int:
        return axis_size(self.mesh, MODEL)

    @property
    def n_devices(self) -> int:
        return self.mesh.size

    def describe(self) -> str:
        return (
            f"mesh{tuple(self.mesh.shape.values())} axes={self.mesh.axis_names} "
            f"pods={self.n_pods} dp={self.dp} tp={self.tp} devices={self.n_devices}"
        )
