"""Mesh axis conventions and sharding-rule helpers.

Axis names (fixed across the framework):
  pod    — cross-pod data parallelism over DCN (the slow, WAN-like hop where
           the paper's chunking matters most)
  data   — intra-pod FSDP/DP (+ sequence/context sharding of activations)
  model  — tensor parallelism (heads / ffn / vocab / experts)

Logical dimension names used by model definitions are mapped here to mesh
axes; a model never hardcodes a mesh axis.
"""
from __future__ import annotations

import dataclasses
import math

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

POD, DATA, MODEL = "pod", "data", "model"


def make_mesh(axis_shapes, axis_names, *, devices=None):
    """Version-portable mesh construction (the jax.sharding.AxisType shim).

    Newer JAX exposes ``jax.sharding.AxisType`` and ``jax.make_mesh(...,
    axis_types=...)``; older releases (e.g. 0.4.x) have neither, and some
    mid versions have ``make_mesh`` without the kwarg. All call sites build
    Auto-typed meshes, so this helper requests AxisType.Auto when the
    installed JAX understands it and silently degrades otherwise (Auto is
    the implicit behaviour of the older APIs).
    """
    kw = {} if devices is None else {"devices": devices}
    axis_type_cls = getattr(jax.sharding, "AxisType", None)
    if axis_type_cls is not None:
        try:
            return jax.make_mesh(
                tuple(axis_shapes), tuple(axis_names),
                axis_types=(axis_type_cls.Auto,) * len(tuple(axis_names)), **kw,
            )
        except TypeError:      # make_mesh predates the axis_types kwarg
            pass
    if hasattr(jax, "make_mesh"):
        return jax.make_mesh(tuple(axis_shapes), tuple(axis_names), **kw)
    import numpy as np         # oldest fallback: raw Mesh over a device grid

    devs = list(devices) if devices is not None else jax.devices()[: math.prod(axis_shapes)]
    return Mesh(np.asarray(devs).reshape(tuple(axis_shapes)), tuple(axis_names))


def shard_map(f, *, mesh, in_specs, out_specs, axis_names=None, check_vma=False):
    """Version-portable shard_map (the jax.shard_map / check_vma shim).

    New JAX: ``jax.shard_map(f, mesh=..., axis_names={manual axes},
    check_vma=...)``. Older JAX only has ``jax.experimental.shard_map`` whose
    knobs are inverted: ``auto`` lists the axes that STAY automatic
    (complement of axis_names) and replication checking is ``check_rep``.
    """
    if hasattr(jax, "shard_map"):
        kw = dict(mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=check_vma)
        if axis_names is not None:
            kw["axis_names"] = set(axis_names)
        return jax.shard_map(f, **kw)
    from jax.experimental.shard_map import shard_map as _shard_map

    auto = frozenset()
    if axis_names is not None:
        auto = frozenset(mesh.axis_names) - frozenset(axis_names)
    if not auto:
        return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                          check_rep=check_vma, auto=auto)

    # Partial-manual region on old JAX: lax.axis_index lowers to an XLA
    # PartitionId op that the old SPMD partitioner rejects inside
    # partially-manual computations. Thread each manual axis's index in as a
    # sharded iota operand instead and register it with the chunked-collective
    # shim (repro.distributed.chunked._axis_index) for the trace.
    from repro.distributed import chunked as _chunked
    import jax.numpy as jnp

    manual = [a for a in mesh.axis_names if a in frozenset(axis_names)]

    def wrapped(idx_ops, *args):
        for a, ix in zip(manual, idx_ops):
            _chunked._AXIS_INDEX_OVERRIDE[a] = ix[0]
        _chunked._PSUM_FALLBACK_AXES.update(manual)
        try:
            return f(*args)
        finally:
            for a in manual:
                _chunked._AXIS_INDEX_OVERRIDE.pop(a, None)
                _chunked._PSUM_FALLBACK_AXES.discard(a)

    def outer(*args):
        # one spec (pytree) per argument; note PartitionSpec is a tuple
        # subclass, so a bare P(...) means "one arg", not a tuple of specs
        if isinstance(in_specs, tuple) and not isinstance(in_specs, P) \
                and len(in_specs) == len(args):
            specs = in_specs
        else:
            specs = (in_specs,) * len(args)
        inner = _shard_map(
            wrapped, mesh=mesh,
            in_specs=(tuple(P(a) for a in manual),) + specs,
            out_specs=out_specs, check_rep=check_vma, auto=auto,
        )
        idx_ops = tuple(
            jnp.arange(mesh.shape[a], dtype=jnp.int32) for a in manual
        )
        return inner(idx_ops, *args)

    return outer

# logical dim -> mesh axis (None = replicate)
_RULES: dict[str, str | None] = {
    "batch": DATA,         # + pod, applied by batch_spec()
    "seq": None,           # sequence sharding is opt-in (context parallelism)
    "embed": None,         # activations' feature dim stays unsharded
    "vocab": MODEL,
    "heads": MODEL,
    "kv_heads": MODEL,
    "head_dim": None,
    "ffn": MODEL,
    "experts": MODEL,
    "expert_ffn": None,
    "fsdp": DATA,          # parameter dim chosen for ZeRO-3 sharding
    "state": None,         # SSM / RG-LRU recurrent state dim
    "conv": None,
}


def spec(*logical: str | None) -> P:
    """PartitionSpec from logical dim names, e.g. spec('fsdp','ffn')."""
    axes = []
    for name in logical:
        if name is None:
            axes.append(None)
        else:
            axes.append(_RULES.get(name, None) if isinstance(name, str) else name)
    return P(*axes)


def batch_spec(mesh: Mesh, *, seq_sharded: bool = False) -> P:
    """(batch, seq, ...) activation spec: batch over pod+data when present."""
    batch_axes = tuple(a for a in (POD, DATA) if a in mesh.axis_names)
    b = batch_axes if len(batch_axes) > 1 else (batch_axes[0] if batch_axes else None)
    return P(b, MODEL if seq_sharded else None)


def shard(mesh: Mesh, x, pspec: P):
    return jax.device_put(x, NamedSharding(mesh, pspec))


def axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.axis_names else 1


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    """Resolved parallelism plan for a given mesh."""

    mesh: Mesh

    @property
    def n_pods(self) -> int:
        return axis_size(self.mesh, POD)

    @property
    def dp(self) -> int:
        return axis_size(self.mesh, DATA)

    @property
    def tp(self) -> int:
        return axis_size(self.mesh, MODEL)

    @property
    def n_devices(self) -> int:
        return self.mesh.size

    def describe(self) -> str:
        return (
            f"mesh{tuple(self.mesh.shape.values())} axes={self.mesh.axis_names} "
            f"pods={self.n_pods} dp={self.dp} tp={self.tp} devices={self.n_devices}"
        )
