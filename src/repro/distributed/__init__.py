"""Distribution layer: mesh conventions, chunked collectives, cross-pod sync."""
from repro.distributed.chunked import (
    ag_matmul,
    chunked_all_gather,
    chunked_all_reduce,
    chunked_reduce_scatter,
    default_n_chunks,
    matmul_rs,
)
from repro.distributed.fsdp import cross_pod_mean, manual_pod
from repro.distributed.mesh import (
    DATA, MODEL, POD, MeshPlan, axis_size, batch_spec, make_mesh, shard,
    shard_map, spec,
)

__all__ = [
    "ag_matmul", "chunked_all_gather", "chunked_all_reduce",
    "chunked_reduce_scatter", "default_n_chunks", "matmul_rs",
    "cross_pod_mean", "manual_pod",
    "DATA", "MODEL", "POD", "MeshPlan", "axis_size", "batch_spec",
    "make_mesh", "shard", "shard_map", "spec",
]
