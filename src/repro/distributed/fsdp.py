"""Cross-pod gradient synchronization helpers.

Two gradient-sync paths, mirroring the paper's baseline-vs-chunked pair:

  * **auto** (the un-chunked baseline): batch is sharded over (pod, data) in
    pjit; autodiff+GSPMD emit one monolithic all-reduce per gradient tensor
    spanning both axes. This corresponds to Globus moving a large file as a
    single stream.
  * **chunked** (the paper's contribution): the entire train step runs inside
    ``manual_pod`` — shard_map manual over the *pod* axis only, data/model
    axes left to GSPMD. Per-pod partial gradients are synchronized explicitly
    with ``cross_pod_mean``: a bandwidth-optimal reduce-scatter+all-gather
    ring whose messages are cut into planner-sized chunks, pipelining the
    slow, WAN-like DCN hop (DESIGN.md §2) and letting the optimizer math that
    consumes each chunk overlap subsequent chunk transfers.

The per-leaf chunk count follows ``core.chunker``'s rule transposed to the
interconnect: >= ~1 MiB per message, at most ``pipeline_depth`` chunks.
"""
from __future__ import annotations

from typing import Any

import jax
from jax.sharding import Mesh

from repro.distributed import chunked as C
from repro.distributed.mesh import POD, axis_size, shard_map


def cross_pod_mean(tree: Any, n_pods: int, *, n_chunks: int = 4) -> Any:
    """Chunked mean-all-reduce of a gradient pytree over the pod axis.

    Call *inside* a ``manual_pod`` region. Chunk count is clamped per-leaf so
    small tensors ship whole (the paper: chunking only pays for large files)
    while large tensors are pipelined in up to ``n_chunks`` ring messages.
    """
    if n_pods == 1:
        return tree

    def leaf(g):
        nc = min(n_chunks, C.default_n_chunks(g.size * g.dtype.itemsize))
        return C.chunked_all_reduce(g, POD, n_pods, n_chunks=nc) / n_pods

    return jax.tree.map(leaf, tree)


def manual_pod(fn, mesh: Mesh, *, in_specs, out_specs):
    """shard_map ``fn`` manually over POD only; data/model stay GSPMD-auto.

    With no pod axis in the mesh this is the identity wrapper, so the same
    train-step code serves single-pod and multi-pod launches.
    """
    if axis_size(mesh, POD) == 1:
        return fn
    return shard_map(
        fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        axis_names={POD}, check_vma=False,
    )
