"""whisper-large-v3 [audio]: 32+32L d1280 20H (MHA kv=20) d_ff 5120 vocab 51866.

Encoder-decoder; conv frontend STUBBED (input_specs provides precomputed
frame embeddings, enc context 1500). [arXiv:2212.04356; unverified]
"""
import jax.numpy as jnp
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3", family="encdec", n_layers=32, n_enc_layers=32,
    d_model=1280, n_heads=20, n_kv_heads=20, d_ff=5120, vocab=51866,
    head_dim=64, act="gelu", enc_positions=1500, tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="whisper-smoke", family="encdec", n_layers=2, n_enc_layers=2,
    d_model=64, n_heads=4, n_kv_heads=4, d_ff=128, vocab=128, head_dim=16,
    act="gelu", enc_positions=24, tie_embeddings=True,
    dtype=jnp.float32, remat="none",
)
