"""Per-architecture configs (exact published configurations) + registry."""
from repro.configs.registry import ARCHS, SHAPES, build_model, cells, get_config, skip_reason

__all__ = ["ARCHS", "SHAPES", "build_model", "cells", "get_config", "skip_reason"]
