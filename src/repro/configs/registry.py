"""Architecture registry: assigned configs, input shapes, and skip rules.

Each ``src/repro/configs/<arch>.py`` defines ``CONFIG`` (the exact published
configuration) and ``SMOKE`` (a reduced same-family config for CPU tests).
This registry maps arch ids to model classes and defines the 4 assigned
input-shape cells plus the documented skips (DESIGN.md §6).
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Any

from jax.sharding import Mesh

from repro.models.common import ModelConfig

ARCHS = (
    "gemma-2b", "gemma2-2b", "yi-34b", "mistral-nemo-12b", "whisper-large-v3",
    "mamba2-370m", "qwen3-moe-30b-a3b", "grok-1-314b", "recurrentgemma-2b",
    "internvl2-2b",
)


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str              # train | prefill | decode


SHAPES = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}

# long_500k requires a sub-quadratic/stateful path (assignment brief):
# run for SSM / hybrid / local+global archs; skip for pure full attention
# and for the audio enc-dec (context capped by encoder semantics).
LONG_OK = {"mamba2-370m", "recurrentgemma-2b", "gemma2-2b"}


def skip_reason(arch: str, shape: str) -> str | None:
    if shape == "long_500k" and arch not in LONG_OK:
        if arch == "whisper-large-v3":
            return "enc-dec audio model: context capped by 30s encoder windows"
        return "pure full-attention arch: no sub-quadratic path at 524k"
    return None


def _module(arch: str):
    return importlib.import_module(f"repro.configs.{arch.replace('-', '_')}")


def get_config(arch: str, *, smoke: bool = False) -> ModelConfig:
    mod = _module(arch)
    return mod.SMOKE if smoke else mod.CONFIG


def model_class(cfg: ModelConfig):
    from repro.models.transformer import DenseLM
    from repro.models.moe import MoELM
    from repro.models.ssm import Mamba2LM
    from repro.models.hybrid import RecurrentGemmaLM
    from repro.models.encdec import WhisperLM
    from repro.models.vlm import InternVLM

    return {
        "dense": DenseLM, "moe": MoELM, "ssm": Mamba2LM,
        "hybrid": RecurrentGemmaLM, "encdec": WhisperLM, "vlm": InternVLM,
    }[cfg.family]


def build_model(arch: str, mesh: Mesh | None = None, *, smoke: bool = False,
                shape: str | None = None, **kw: Any):
    cfg = get_config(arch, smoke=smoke)
    cls = model_class(cfg)
    if cfg.family == "encdec":
        cell = SHAPES.get(shape or "", None)
        max_target = max(kw.pop("max_target", 448),
                         (cell.seq_len if cell else 448))
        return cls(cfg, mesh, max_target=max_target, **kw)
    return cls(cfg, mesh, **kw)


def cells(include_skipped: bool = False):
    """All 40 (arch, shape) cells; skipped ones annotated."""
    out = []
    for arch in ARCHS:
        for shape in SHAPES:
            reason = skip_reason(arch, shape)
            if reason is None or include_skipped:
                out.append((arch, shape, reason))
    return out
