"""gemma2-2b [dense]: 26L d2304 8H (GQA kv=4) d_ff 9216 vocab 256000.

Alternating local(4096)/global attention, attn/final logit softcaps (50/30),
post-norms, GeGLU, head_dim 256, tied + scaled embeddings. [arXiv:2408.00118; hf]
"""
import jax.numpy as jnp
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-2b", family="dense", n_layers=26, d_model=2304, n_heads=8,
    n_kv_heads=4, d_ff=9216, vocab=256000, head_dim=256, act="gelu",
    attn_pattern="lg", window=4096, attn_softcap=50.0, final_softcap=30.0,
    post_norms=True, tie_embeddings=True, embed_scale=True,
    rope_theta=10000.0, subquadratic=True,  # local layers keep long_500k viable
)

SMOKE = ModelConfig(
    name="gemma2-2b-smoke", family="dense", n_layers=4, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=128, vocab=128, head_dim=16, act="gelu",
    attn_pattern="lg", window=8, attn_softcap=50.0, final_softcap=30.0,
    post_norms=True, tie_embeddings=True, embed_scale=True,
    dtype=jnp.float32, remat="none",
)
