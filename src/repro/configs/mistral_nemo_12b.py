"""mistral-nemo-12b [dense]: 40L d5120 32H (GQA kv=8) d_ff 14336 vocab 131072.

128k-context llama-family model, SwiGLU, head_dim 128, untied.
[hf:mistralai/Mistral-Nemo-Base-2407; hf]
"""
import jax.numpy as jnp
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="mistral-nemo-12b", family="dense", n_layers=40, d_model=5120,
    n_heads=32, n_kv_heads=8, d_ff=14336, vocab=131072, head_dim=128,
    act="silu", attn_pattern="g", tie_embeddings=False, rope_theta=1_000_000.0,
)

SMOKE = ModelConfig(
    name="mistral-nemo-12b-smoke", family="dense", n_layers=3, d_model=64,
    n_heads=4, n_kv_heads=2, d_ff=128, vocab=128, head_dim=16, act="silu",
    attn_pattern="g", tie_embeddings=False, dtype=jnp.float32, remat="none",
)
