"""internvl2-2b [vlm]: 24L d2048 16H (GQA kv=8) d_ff 8192 vocab 92553.

InternViT frontend STUBBED (input_specs provides projected patch embeddings,
256 visual tokens) + InternLM2 backbone. [arXiv:2404.16821; hf]
"""
import jax.numpy as jnp
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b", family="vlm", n_layers=24, d_model=2048, n_heads=16,
    n_kv_heads=8, d_ff=8192, vocab=92553, head_dim=128, act="silu",
    tie_embeddings=False, n_vis_tokens=256, rope_theta=1_000_000.0,
)

SMOKE = ModelConfig(
    name="internvl2-smoke", family="vlm", n_layers=2, d_model=32, n_heads=4,
    n_kv_heads=2, d_ff=64, vocab=128, head_dim=8, act="silu",
    tie_embeddings=False, n_vis_tokens=8, dtype=jnp.float32, remat="none",
)
