"""qwen3-moe-30b-a3b [moe]: 48L d2048 32H (GQA kv=4) expert d_ff 768,
vocab 151936, 128 experts top-8. [hf:Qwen/Qwen3-30B-A3B; hf]

Note: Qwen3's qk-norm is not modeled (structural nicety orthogonal to the
paper's technique); noted in DESIGN.md.
"""
import jax.numpy as jnp
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b", family="moe", n_layers=48, d_model=2048,
    n_heads=32, n_kv_heads=4, d_ff=768, vocab=151936, head_dim=128,
    act="silu", n_experts=128, top_k=8, tie_embeddings=False,
    rope_theta=1_000_000.0,
)

SMOKE = ModelConfig(
    name="qwen3-moe-smoke", family="moe", n_layers=2, d_model=32, n_heads=4,
    n_kv_heads=2, d_ff=64, vocab=128, head_dim=8, act="silu",
    n_experts=8, top_k=2, tie_embeddings=False, dtype=jnp.float32, remat="none",
)
