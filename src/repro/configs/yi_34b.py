"""yi-34b [dense]: 60L d7168 56H (GQA kv=8) d_ff 20480 vocab 64000.

Llama-architecture GQA, SwiGLU, untied embeddings. [arXiv:2403.04652; hf]
"""
import jax.numpy as jnp
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="yi-34b", family="dense", n_layers=60, d_model=7168, n_heads=56,
    n_kv_heads=8, d_ff=20480, vocab=64000, head_dim=128, act="silu",
    attn_pattern="g", tie_embeddings=False, rope_theta=5_000_000.0,
)

SMOKE = ModelConfig(
    name="yi-34b-smoke", family="dense", n_layers=3, d_model=48, n_heads=6,
    n_kv_heads=2, d_ff=96, vocab=128, head_dim=8, act="silu",
    attn_pattern="g", tie_embeddings=False, dtype=jnp.float32, remat="none",
)
