"""recurrentgemma-2b [hybrid]: 26L d2560 10H (MQA kv=1) d_ff 7680 vocab 256000.

Griffin: RG-LRU + local attention (window 2048), 2:1 recurrent:attention,
lru_width 2560, GeGLU, tied + scaled embeddings. [arXiv:2402.19427; hf]
"""
import jax.numpy as jnp
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b", family="hybrid", n_layers=26, d_model=2560,
    n_heads=10, n_kv_heads=1, d_ff=7680, vocab=256000, head_dim=256,
    act="gelu", window=2048, lru_width=2560, conv1d_size=4,
    tie_embeddings=True, embed_scale=True, subquadratic=True,
)

SMOKE = ModelConfig(
    name="recurrentgemma-smoke", family="hybrid", n_layers=8, d_model=32,
    n_heads=4, n_kv_heads=1, d_ff=64, vocab=128, head_dim=8, act="gelu",
    window=8, lru_width=32, conv1d_size=4, tie_embeddings=True,
    embed_scale=True, dtype=jnp.float32, remat="none", subquadratic=True,
)
