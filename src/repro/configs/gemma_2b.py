"""gemma-2b [dense]: 18L d2048 8H (MQA kv=1) d_ff 16384 vocab 256000.

GeGLU, head_dim 256, tied embeddings scaled by sqrt(d). [arXiv:2403.08295; hf]
"""
import jax.numpy as jnp
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="gemma-2b", family="dense", n_layers=18, d_model=2048, n_heads=8,
    n_kv_heads=1, d_ff=16384, vocab=256000, head_dim=256, act="gelu",
    attn_pattern="g", tie_embeddings=True, embed_scale=True,
    rope_theta=10000.0,
)

SMOKE = ModelConfig(
    name="gemma-2b-smoke", family="dense", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=1, d_ff=128, vocab=128, head_dim=16, act="gelu",
    attn_pattern="g", tie_embeddings=True, embed_scale=True,
    dtype=jnp.float32, remat="none",
)
