"""grok-1-314b [moe]: 64L d6144 48H (GQA kv=8) expert d_ff 32768,
vocab 131072, 8 experts top-2, attention logit softcap 30.
[hf:xai-org/grok-1; unverified]

On the 16-wide model axis the 8 experts are placed with SPLIT=2 (each expert's
FFN split across 2 columns) — see models/moe.py. Optimizer state is bf16 to
fit 16 GB/chip (DESIGN.md §5).
"""
import jax.numpy as jnp
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="grok-1-314b", family="moe", n_layers=64, d_model=6144, n_heads=48,
    n_kv_heads=8, d_ff=32768, vocab=131072, head_dim=128, act="gelu",
    n_experts=8, top_k=2, attn_softcap=30.0, final_softcap=30.0,
    tie_embeddings=True, embed_scale=True,
)

SMOKE = ModelConfig(
    name="grok-1-smoke", family="moe", n_layers=2, d_model=32, n_heads=4,
    n_kv_heads=2, d_ff=64, vocab=128, head_dim=8, act="gelu",
    n_experts=2, top_k=2, attn_softcap=30.0, final_softcap=30.0,
    tie_embeddings=True, embed_scale=True, dtype=jnp.float32, remat="none",
)
