"""mamba2-370m [ssm]: 48L d1024 attn-free, ssm_state=128, vocab 50280.

SSD (state-space duality), expand 2, head_dim 64. [arXiv:2405.21060; unverified]
"""
import jax.numpy as jnp
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-370m", family="ssm", n_layers=48, d_model=1024, n_heads=1,
    n_kv_heads=1, d_ff=0, vocab=50280, ssm_state=128, ssm_expand=2,
    ssm_head_dim=64, ssm_chunk=256, tie_embeddings=True, subquadratic=True,
)

SMOKE = ModelConfig(
    name="mamba2-smoke", family="ssm", n_layers=3, d_model=32, n_heads=1,
    n_kv_heads=1, d_ff=0, vocab=128, ssm_state=16, ssm_expand=2,
    ssm_head_dim=8, ssm_chunk=8, dtype=jnp.float32, remat="none",
    subquadratic=True,
)
