"""Transfer Service — multi-tenant async task manager over the chunked engine.

The service layer of the reproduction (the part of Globus the paper's
client-driven chunking ships inside): tasks are submitted by many tenants,
batched (service.batcher), scheduled under a global mover budget with
chunk-aware marginal-benefit allocation and tenant fairness
(service.scheduler), executed with per-chunk integrity + journaling
(service.service), and survivable across service crashes (service.store).

    from repro.service import TransferService, ServiceConfig
    svc = TransferService("/srv/transferd", ServiceConfig(mover_budget=16))
    [tid] = svc.submit([(src, dst)], tenant="alice")
    svc.wait(tid)

Virtual-time analysis of the same scheduling stack at testbed scale lives in
service.testbed (used by benchmarks/service_load.py and repro.launch.transferd).
"""
from repro.service.batcher import BatchConfig, Batcher
from repro.service.ckpt_bridge import CheckpointSubmission, submit_checkpoint
from repro.service.events import EventBus, TaskEvent
from repro.service.scheduler import (
    ActivationIndex,
    AllocationEngine,
    TenantQuota,
    select_activations,
)
from repro.service.service import ServiceConfig, TransferService
from repro.service.store import TaskRecord, TaskStore
from repro.service.task import (
    ACTIVE,
    CANCELED,
    FAILED,
    PAUSED,
    PENDING,
    SUCCEEDED,
    TERMINAL,
    FaultReport,
    ItemReport,
    TaskSpec,
    TaskStatus,
    TransferItem,
)
from repro.service.testbed import (
    FaultLog,
    LoadReport,
    Submission,
    SimTask,
    mixed_workload,
    run_load,
)

__all__ = [
    "ACTIVE", "CANCELED", "FAILED", "PAUSED", "PENDING", "SUCCEEDED", "TERMINAL",
    "ActivationIndex", "AllocationEngine", "BatchConfig", "Batcher",
    "CheckpointSubmission",
    "EventBus", "FaultLog", "FaultReport", "ItemReport", "LoadReport",
    "ServiceConfig", "SimTask", "Submission", "TaskEvent", "TaskRecord",
    "TaskSpec", "TaskStatus", "TaskStore", "TenantQuota", "TransferItem",
    "TransferService", "mixed_workload", "run_load", "select_activations",
    "submit_checkpoint",
]
