"""The multi-tenant asynchronous transfer-task service.

This is the service layer the paper's client-driven chunking lives inside of:
clients *submit* transfer tasks and walk away; the service batches, schedules,
monitors, retries, integrity-checks and journals them across tenants.

Architecture (one TransferService per service root):

  * submit() batches requests into tasks (service.batcher), persists them to
    the TaskStore and returns task ids immediately;
  * one scheduler thread activates PENDING tasks under the global
    concurrent-task cap with tenant-fair selection (service.scheduler), and
    reallocates the global mover budget across ACTIVE tasks with the
    chunk-aware marginal-benefit policy whenever the active set changes;
  * each ACTIVE task runs a _TaskRunner thread owning a work queue of chunks
    (natural work stealing) and a dynamic pool of mover threads sized by the
    current allocation; chunk moves are fingerprinted, verified by dest
    read-back, retried with exponential backoff, and journaled;
  * a crash (or kill()) loses nothing: on construction the service replays the
    task log, re-queues durable non-terminal tasks, and their journals make
    the runners skip every chunk that already landed.

Client API: submit / submit_many / submit_buffers / status / status_many /
tasks (cursor-paginated) / wait / wait_all / cancel / pause / resume /
subscribe (cursor-resumable) / events_from / flush / close / kill.
"""
from __future__ import annotations

import collections
import dataclasses
import itertools
import os
import queue
import threading
import time
from typing import Any, Callable, Sequence

import numpy as np

from repro.core.backoff import Backoff
from repro.core.chunker import (
    Chunk,
    ChunkPlan,
    MiB,
    merge_regions,
    partition_regions,
    plan_chunks,
    plan_stripes,
    subtract_regions,
)
from repro.core.integrity import (
    EMPTY_DIGEST,
    combine_at_offsets,
    fingerprint_bytes,
    verify,
)
from repro.core.dataplane import (
    DEFAULT_STREAM_GRANULE,
    BufferPool,
    IntegrityEngine,
    VerifyJob,
    stream_chunk,
)
from repro.cas import ChunkIndex
from repro.resil.scrub import Scrubber, ScrubReport, ScrubTarget
from repro.core.journal import ChunkJournal, JournalRecord
from repro.core.scheduler import TransferRequest
from repro.obs import metrics as obsmetrics
from repro.obs.clock import mono_s, wall_s
from repro.obs.recorder import FlightRecorder
from repro.obs.trace import Tracer
from repro.core.simulator import ALCF, DEFAULT_LINK, NERSC, LinkConfig, SiteConfig
from repro.core.transfer import (
    BufferSource,
    ByteDest,
    ByteSource,
    EndpointOutage,
    FileDest,
    FileSource,
    IntegrityError,
    MoverCrash,
)
from repro.service import events as ev
from repro.service.batcher import BatchConfig, Batcher
from repro.service.events import EventBus
from repro.service.scheduler import (
    DEFAULT_QUOTA,
    ActivationIndex,
    AllocationEngine,
    TenantQuota,
)
from repro.service import task as tk
from repro.service.store import TaskStore
from repro.service.task import (
    FaultReport,
    ItemReport,
    TaskSpec,
    TaskStatus,
    TransferItem,
    TransitionError,
    classify_fault,
)
from repro.tune.controller import ChunkController
from repro.tune.probe import ChunkSample
from repro.tune.simtune import SimTuner

# Journal ids for re-planned (tuned) chunks live in a reserved band far above
# any static plan's ids, partitioned per item, so a record always names its
# item and can never collide with a static chunk id across restarts.
TUNE_GID_BASE = 1 << 40
TUNE_ITEM_STRIDE = 1 << 28

# Stripe work items get their own band ABOVE the tuned band (the band test in
# item_of_gidx must check this one first): each stripe is journaled as its own
# custody record, so a restart re-moves only the stripes that never verified.
STRIPE_GID_BASE = 1 << 50
STRIPE_ITEM_STRIDE = 1 << 28


@dataclasses.dataclass(frozen=True)
class ServiceConfig:
    mover_budget: int = 8            # global mover threads across tasks
    max_concurrent_tasks: int = 4    # ACTIVE task cap (<= mover_budget)
    policy: str = "marginal"         # fair | file_bound | marginal
    chunk_bytes: int = 8 * MiB       # default chunk size for task items
    integrity: bool = True           # dest read-back verification per chunk
    max_retries: int = 3             # per-chunk generic-I/O retries
    max_refetches: int = 3           # per-chunk source re-reads on digest mismatch
    outage_retries: int = 64         # per-chunk endpoint-outage budget
    max_mover_deaths: int = 16       # per-task mover-crash budget
    retry_backoff_s: float = 0.01    # exponential backoff base
    tick_s: float = 0.005            # scheduler/runner poll period
    batch: BatchConfig = dataclasses.field(default_factory=BatchConfig)
    quotas: dict[str, TenantQuota] = dataclasses.field(default_factory=dict)
    default_quota: TenantQuota = DEFAULT_QUOTA
    src_site: SiteConfig = ALCF      # cost-model endpoints for allocation
    dst_site: SiteConfig = NERSC
    link: LinkConfig = DEFAULT_LINK
    alloc_step: int = 2              # water-filling granularity
    # ---- autotuning (closed-loop chunk sizing) ---------------------------
    tuning: str = "static"           # default per-task policy: static | auto
    tune_min_chunk: int = 64 * 1024  # controller lower bound for tuned tasks
    tune_max_chunk: int = 64 * MiB   # controller upper bound for tuned tasks
    tune_epoch_chunks: int = 4       # chunks per controller decision epoch
    tune_seed: str = "none"          # "sim" warm-starts from the simulator
    # ---- data plane (zero-copy pipelined movement + integrity) -----------
    pipeline: str = "serial"         # serial | single_pass | pipelined
    integrity_workers: int = 2       # per-task checksum workers (pipelined)
    stream_granule: int = DEFAULT_STREAM_GRANULE
    # ---- intra-chunk striping (concurrent sub-streams per large chunk) ---
    stripes: int = 1                 # stripe count per eligible chunk
    stripe_min_bytes: int = 4 * MiB  # smallest stripe worth its overhead
    # ---- content plane (dedup against the endpoint chunk index) ----------
    dedup: str = "off"               # default per-task policy: off | on
    # ---- resilience plane (route failover by route-aware layers) ---------
    failover: str = "off"            # default per-task policy: off | auto

    def __post_init__(self):
        if self.max_concurrent_tasks > self.mover_budget:
            raise ValueError(
                f"max_concurrent_tasks ({self.max_concurrent_tasks}) must be "
                f"<= mover_budget ({self.mover_budget}): every active task "
                "needs at least one mover"
            )
        if self.tuning not in ("static", "auto"):
            raise ValueError(f"tuning must be 'static' or 'auto', got {self.tuning!r}")
        if self.tune_seed not in ("none", "sim"):
            raise ValueError(f"tune_seed must be 'none' or 'sim', got {self.tune_seed!r}")
        if self.pipeline not in ("serial", "single_pass", "pipelined"):
            raise ValueError(
                f"pipeline must be 'serial', 'single_pass' or 'pipelined', "
                f"got {self.pipeline!r}"
            )
        if self.integrity_workers < 1:
            raise ValueError("integrity_workers must be >= 1")
        if self.stripes < 1:
            raise ValueError(f"stripes must be >= 1, got {self.stripes}")
        if self.stripe_min_bytes < 1:
            raise ValueError(
                f"stripe_min_bytes must be >= 1, got {self.stripe_min_bytes}")
        if self.dedup not in ("off", "on"):
            raise ValueError(f"dedup must be 'off' or 'on', got {self.dedup!r}")
        if self.failover not in ("off", "auto"):
            raise ValueError(
                f"failover must be 'off' or 'auto', got {self.failover!r}")


class _Task:
    """Service-internal mutable task state (specs stay frozen)."""

    def __init__(self, spec: TaskSpec, seq: int, chunk_bytes: int,
                 tuning: str = "static", dedup: str = "off"):
        self.spec = spec
        self.seq = seq
        self.tuning = tuning                     # effective policy (spec or default)
        self.dedup = dedup                       # content-plane policy (spec or default)
        self.failovers = 0                       # route re-plans recorded
        self.scrub_repairs = 0                   # scrub heals on landed regions
        self.chunks_deduped = 0
        self.wire_bytes_saved = 0
        self.dedup_demoted = 0
        self.controller: ChunkController | None = None
        self.replans = 0
        self.chunk_bytes_now = spec.chunk_bytes or chunk_bytes
        # per-item sequence allocators for tuned-band / stripe-band journal ids
        self.next_tune_seq = [0] * len(spec.items)
        self.next_stripe_seq = [0] * len(spec.items)
        self.striped_chunks = 0
        self.state = tk.PENDING
        self.error: str | None = None
        self.lock = threading.Lock()
        # observability: per-worker lane ids, queue-entry timestamps (queue-
        # wait spans), the task's monotonic activation mark and root span id
        self.worker_seq = 0
        self.enq_t: dict[int, float] = {}
        self.t0_mono: float | None = None
        self.root_sid = 0
        self.pause_evt = threading.Event()
        self.cancel_evt = threading.Event()
        self.target_movers = 1
        self.n_workers = 0
        self.failed_error: str | None = None
        self.fault: FaultReport | None = None
        self.started_s: float | None = None
        self.finished_s: float | None = None
        self.retries = 0
        self.refetches = 0
        self.outages = 0
        self.mover_deaths = 0
        self.resumed_chunks = 0
        self.item_reports: tuple[ItemReport, ...] = ()
        # data-plane accounting + pipelined-verification state
        self.cksum_s = 0.0
        self.cksum_lag_s = 0.0
        self.pool: BufferPool | None = None
        self.engine: IntegrityEngine | None = None
        self.verify_refetches: dict[int, int] = {}   # per-gidx deferred heals

        # Deterministic chunk plans (same across service incarnations): the
        # journal's global chunk ids must mean the same byte ranges forever.
        self.plans: list[ChunkPlan] = []
        self.chunk_base: list[int] = []
        base = 0
        for it in spec.items:
            plan = (
                plan_chunks(
                    it.nbytes, 1, chunk_bytes=spec.chunk_bytes or chunk_bytes,
                    min_chunk=1, max_chunk=1 << 62, alignment=1,
                )
                if it.nbytes
                else plan_chunks(0, 1)
            )
            self.plans.append(plan)
            self.chunk_base.append(base)
            base += plan.n_chunks
        self.chunks_total = base
        self.chunks_done = 0
        self.bytes_total = spec.total_bytes
        self.bytes_done = 0

        # lazily-opened per-item endpoints (shared by this task's movers)
        self._sources: dict[int, ByteSource] = {}
        self._dests: dict[int, ByteDest] = {}

    # -- journal-id bands ---------------------------------------------------
    def item_of_gidx(self, gidx: int) -> int:
        """Which item a journaled chunk id belongs to (any band). The stripe
        band sits ABOVE the tuned band, so it must be tested first — the
        tune-band test alone would assign a stripe gid a nonsense item."""
        if gidx >= STRIPE_GID_BASE:
            return (gidx - STRIPE_GID_BASE) // STRIPE_ITEM_STRIDE
        if gidx >= TUNE_GID_BASE:
            return (gidx - TUNE_GID_BASE) // TUNE_ITEM_STRIDE
        for i in reversed(range(len(self.chunk_base))):
            if gidx >= self.chunk_base[i]:
                return i
        return 0

    def tune_gidx(self, item_idx: int, seq: int) -> int:
        return TUNE_GID_BASE + item_idx * TUNE_ITEM_STRIDE + seq

    def stripe_gidx(self, item_idx: int, seq: int) -> int:
        return STRIPE_GID_BASE + item_idx * STRIPE_ITEM_STRIDE + seq

    def static_record_ok(self, gidx: int, rec) -> bool:
        """Does this journal record match the static plan byte-for-byte?"""
        if gidx >= TUNE_GID_BASE:
            return False
        i = self.item_of_gidx(gidx)
        local = gidx - self.chunk_base[i]
        if not (0 <= local < self.plans[i].n_chunks):
            return False
        c = self.plans[i].chunks[local]
        return c.offset == rec.offset and c.length == rec.length


class TransferService:
    """Multi-tenant async task manager over the chunked-transfer engine."""

    def __init__(
        self,
        root: str | os.PathLike,
        config: ServiceConfig | None = None,
        *,
        fault_injector: Callable[[str, int, Any, int], None] | None = None,
        source_wrapper: Callable[[str, int, ByteSource], ByteSource] | None = None,
        dest_wrapper: Callable[[str, int, ByteDest], ByteDest] | None = None,
        tracer: Tracer | None = None,
    ):
        self.config = config or ServiceConfig()
        self.store = TaskStore(root)
        # event spill log beside the task shards: cursor subscribers can
        # resume from any seq, and numbering survives restarts
        self.events = EventBus(
            spill_path=os.path.join(str(root), "events.log"))
        # observability: a bounded span tracer, a flight recorder fed from
        # the event stream (auto-dumps a post-mortem bundle next to the task
        # log when a fault fails a task), and per-task metric families
        self.tracer = tracer if tracer is not None else Tracer()
        self.recorder = FlightRecorder(
            tracer=self.tracer,
            dump_dir=os.path.join(str(root), "flight"))
        self.events.subscribe(
            lambda e: self.recorder.record(
                e.task_id, e.kind, e.payload, t=e.time_s))
        self._m_chunks = obsmetrics.REGISTRY.counter(
            "service_chunks_total", "landed chunks", ("tenant", "task"))
        self._m_bytes = obsmetrics.REGISTRY.counter(
            "service_bytes_total", "landed bytes", ("tenant", "task"))
        self._m_faults = obsmetrics.REGISTRY.counter(
            "service_faults_total", "chunk-level fault observations",
            ("tenant", "task", "kind"))
        self._m_wire = obsmetrics.REGISTRY.histogram(
            "service_chunk_wire_seconds",
            "fault-excluded per-chunk mover time", ("task",), scale=1e-4)
        self._m_active = obsmetrics.REGISTRY.gauge(
            "service_active_tasks", "tasks in ACTIVE state", ("tenant",))
        self._m_failovers = obsmetrics.REGISTRY.counter(
            "service_failovers_total",
            "route failovers recorded against tasks", ("tenant", "task"))
        self._m_scrub_repairs = obsmetrics.REGISTRY.counter(
            "service_scrub_repairs_total",
            "landed regions the scrubber healed", ("tenant", "task"))
        self.batcher = Batcher(self.config.batch)
        self.engine = AllocationEngine(
            policy=self.config.policy,
            mover_budget=self.config.mover_budget,
            src=self.config.src_site,
            dst=self.config.dst_site,
            link=self.config.link,
            step=self.config.alloc_step,
            quotas=self.config.quotas,
            default_quota=self.config.default_quota,
        )
        self._fault_injector = fault_injector
        # chaos hooks: wrap the per-item endpoints ((task_id, item_idx,
        # endpoint) -> endpoint) so fault campaigns can corrupt/outage/kill
        # the data path without the service knowing
        self._source_wrapper = source_wrapper
        self._dest_wrapper = dest_wrapper
        self._lock = threading.RLock()
        self._cond = threading.Condition(self._lock)
        self._tasks: dict[str, _Task] = {}
        self._mem_sources: dict[tuple[str, int], ByteSource] = {}
        self._runners: dict[str, threading.Thread] = {}
        self._stop_evt = threading.Event()
        self._kill_evt = threading.Event()
        self._alloc_dirty = True
        self._served: dict[str, int] = {}    # per-tenant activation history
        # control-plane indexes — scheduler and listing cost must not scale
        # with the total task count:
        #   _order / _order_pos: submission-ordered ids for cursor pagination
        #   _active_ids: the ACTIVE set (allocation requests are O(active))
        #   _activation: heap-indexed PENDING queues (O(log n) activation)
        self._order: list[str] = []
        self._order_pos: dict[str, int] = {}
        self._active_ids: set[str] = set()
        self._activation = ActivationIndex(served=self._served)
        # wall time of recent scheduler passes (activation + request build
        # + allocation), for the cycle-time flatness gate in service_load
        self.sched_cycles: collections.deque[float] = collections.deque(maxlen=512)
        self.moved_chunks = 0        # chunks physically moved by THIS incarnation
        # content plane: the service root's endpoint chunk index, opened
        # lazily (first dedup-enabled task) or eagerly when the configured
        # default is "on" — non-dedup services never pay index appends
        self.cas: ChunkIndex | None = None
        if self.config.dedup == "on":
            self.cas_index()
        # resilience plane: one scrubber per service so its round-robin
        # cursor persists across scrub() calls (budgeted cadence resumes
        # where the last pass stopped instead of re-reading the same head)
        self._scrubber: Scrubber | None = None

        self._recover()
        self._scheduler = threading.Thread(
            target=self._scheduler_loop, name="transferd-sched", daemon=True
        )
        self._scheduler.start()

    # ------------------------------------------------------------------
    # content plane
    # ------------------------------------------------------------------
    def cas_index(self) -> ChunkIndex:
        """This service root's endpoint chunk index (lazily opened).

        Lives at ``<root>/cas/index.log`` — a self-checksummed append log
        with torn-tail repair and compaction, surviving service restarts the
        same way journals do. Populated as verified chunks commit; probed by
        dedup-enabled tasks before their movers start.
        """
        with self._lock:
            if self.cas is None:
                self.cas = ChunkIndex(
                    os.path.join(str(self.store.root), "cas", "index.log"),
                    scope="service")
            return self.cas

    # ------------------------------------------------------------------
    # recovery
    # ------------------------------------------------------------------
    def _recover(self) -> None:
        """Rebuild tasks from the log; re-queue durable non-terminal tasks."""
        for task_id, rec in sorted(self.store.records.items(), key=lambda kv: kv[1].seq):
            t = _Task(rec.spec, rec.seq, self.config.chunk_bytes,
                      tuning=rec.spec.tuning or self.config.tuning,
                      dedup=rec.spec.dedup or self.config.dedup)
            t.state = rec.state
            t.error = rec.error
            if rec.state in tk.TERMINAL:
                t.finished_s = rec.spec.submitted_s   # best effort: log has no ts
                if rec.state == tk.SUCCEEDED:
                    t.chunks_done = t.chunks_total
                    t.bytes_done = t.bytes_total
                self._index_task(task_id, t)
                continue
            if not rec.spec.durable:
                # in-memory sources died with the previous process
                t.state = tk.FAILED
                t.error = "ephemeral source lost across service restart"
                t.finished_s = wall_s()
                self.store.append_state(task_id, tk.FAILED, t.error)
                self._index_task(task_id, t)
                self.events.emit(ev.FAILED, task_id, rec.spec.tenant, error=t.error)
                continue
            # ACTIVE at crash time -> PENDING; PAUSED stays PAUSED.
            if rec.state in (tk.ACTIVE, tk.PENDING):
                t.state = tk.PENDING
                if rec.state == tk.ACTIVE:
                    self.store.append_state(task_id, tk.PENDING, "recovered after restart")
            elif rec.state == tk.PAUSED:
                t.pause_evt.set()
            self._index_task(task_id, t)

    def _index_task(self, task_id: str, t: _Task) -> None:
        """Publish a task into every control-plane index (caller ordered by
        seq during recovery; under the service lock during submission)."""
        self._tasks[task_id] = t
        self._order_pos[task_id] = len(self._order)
        self._order.append(task_id)
        if t.state == tk.PENDING:
            self._activation.add(t.seq, task_id, t.spec.tenant)

    # ------------------------------------------------------------------
    # client API: submission
    # ------------------------------------------------------------------
    def submit(
        self,
        items: Sequence[TransferItem | tuple[str, str] | tuple[str, str, int]],
        *,
        tenant: str = "default",
        label: str = "",
        chunk_bytes: int | None = None,
        batch: bool = True,
        tuning: str | None = None,
        dedup: str | None = None,
        failover: str | None = None,
    ) -> list[str]:
        """Submit a transfer request; returns the task ids it was split into.

        Items are (src_path, dst_path[, nbytes]) or TransferItem. With
        ``batch=True`` the Batcher coalesces small files into shared tasks and
        routes large files to dedicated chunked tasks; ``batch=False`` forces
        a single task for the whole request. ``tuning="auto"`` closes the
        chunk-size loop over these tasks ("static" pins the plan; None defers
        to ``ServiceConfig.tuning``). ``dedup="on"`` probes the endpoint's
        chunk index before moving — chunks the destination already holds are
        satisfied by a local copy instead of wire moves ("off" bypasses the
        index; None defers to ``ServiceConfig.dedup``). ``failover="auto"``
        lets route-aware layers (relay, campaigns) re-plan this task's path
        around dead endpoints mid-flight ("off" pins the route; None defers
        to ``ServiceConfig.failover``).
        """
        norm = [self._norm_item(it) for it in items]
        if not norm:
            raise ValueError("empty submission")
        if tuning not in (None, "static", "auto"):
            raise ValueError(f"tuning must be 'static', 'auto' or None, got {tuning!r}")
        if dedup not in (None, "off", "on"):
            raise ValueError(f"dedup must be 'off', 'on' or None, got {dedup!r}")
        if failover not in (None, "off", "auto"):
            raise ValueError(
                f"failover must be 'off', 'auto' or None, got {failover!r}")
        groups = self.batcher.split(norm) if batch else [list(norm)]
        return [self._submit_group(g, tenant, label, chunk_bytes, tuning,
                                   dedup, failover)
                for g in groups]

    def submit_buffers(
        self,
        buffers: Sequence[tuple[bytes | np.ndarray | ByteSource, str]],
        *,
        tenant: str = "default",
        label: str = "",
        chunk_bytes: int | None = None,
        tuning: str | None = None,
        dedup: str | None = None,
    ) -> str:
        """Submit in-memory payloads (e.g. checkpoint arrays) as ONE task.

        Ephemeral by construction: if the service dies before the task
        completes, recovery fails the task (the bytes are gone) — callers at
        a higher level (repro.ckpt) re-submit and the destination journals
        still prevent re-moving landed chunks.
        """
        if dedup not in (None, "off", "on"):
            raise ValueError(f"dedup must be 'off', 'on' or None, got {dedup!r}")
        items, sources = [], []
        for i, (payload, dst) in enumerate(buffers):
            src = payload if hasattr(payload, "read") else BufferSource(payload)
            items.append(TransferItem(f"mem:{i}", str(dst), src.nbytes, mem=True))
            sources.append(src)
        # register the sources under the SAME lock hold that publishes the
        # task: the scheduler may activate it the instant the lock drops,
        # and a dedup-enabled runner reads the source at seeding time
        with self._lock:
            task_id = self._submit_group(items, tenant, label, chunk_bytes,
                                         tuning, dedup)
            for i, src in enumerate(sources):
                self._mem_sources[(task_id, i)] = src
        return task_id

    def _norm_item(self, it) -> TransferItem:
        if isinstance(it, TransferItem):
            return it
        if len(it) == 2:
            src, dst = it
            return TransferItem(str(src), str(dst), os.path.getsize(src))
        src, dst, nbytes = it
        return TransferItem(str(src), str(dst), int(nbytes))

    def _submit_group(
        self, items: Sequence[TransferItem], tenant: str, label: str,
        chunk_bytes: int | None, tuning: str | None = None,
        dedup: str | None = None, failover: str | None = None,
    ) -> str:
        with self._cond:
            if self._stop_evt.is_set():
                raise RuntimeError("service is shut down")
            task_id = self.store.next_task_id(tenant)
            # pin the EFFECTIVE chunk size (and tuning/dedup policies) into
            # the persisted spec: chunk plans (and so the journal's global
            # chunk ids) must mean the same byte ranges even if the service
            # restarts with a different configured default
            spec = TaskSpec(
                task_id=task_id, tenant=tenant, label=label,
                items=tuple(items),
                chunk_bytes=chunk_bytes or self.config.chunk_bytes,
                tuning=tuning or self.config.tuning,
                dedup=dedup or self.config.dedup,
                failover=failover or self.config.failover,
            )
            rec = self.store.append_submit(spec)
            t = _Task(spec, rec.seq, self.config.chunk_bytes,
                      tuning=spec.tuning or self.config.tuning,
                      dedup=spec.dedup or self.config.dedup)
            self._index_task(task_id, t)
            self._cond.notify_all()
        self.events.emit(
            ev.SUBMITTED, task_id, tenant,
            files=len(items), bytes=sum(i.nbytes for i in items), label=label,
        )
        return task_id

    def submit_many(
        self,
        requests: Sequence[Sequence[TransferItem | tuple[str, str] | tuple[str, str, int]]],
        *,
        tenant: str = "default",
        label: str = "",
        chunk_bytes: int | None = None,
        batch: bool = True,
        tuning: str | None = None,
        dedup: str | None = None,
        failover: str | None = None,
    ) -> list[list[str]]:
        """Bulk submission: one lock hold and one fsync per store shard for
        the whole batch, instead of a lock round-trip and fsync per task.
        Returns one task-id list per request (same split rules as submit).
        """
        if tuning not in (None, "static", "auto"):
            raise ValueError(f"tuning must be 'static', 'auto' or None, got {tuning!r}")
        if dedup not in (None, "off", "on"):
            raise ValueError(f"dedup must be 'off', 'on' or None, got {dedup!r}")
        if failover not in (None, "off", "auto"):
            raise ValueError(
                f"failover must be 'off', 'auto' or None, got {failover!r}")
        groups_per_req: list[list[list[TransferItem]]] = []
        for items in requests:
            norm = [self._norm_item(it) for it in items]
            if not norm:
                raise ValueError("empty submission in bulk request")
            groups_per_req.append(
                [list(g) for g in (self.batcher.split(norm) if batch else [norm])])
        out: list[list[str]] = []
        emits: list[tuple[str, int, int]] = []
        with self._cond:
            if self._stop_evt.is_set():
                raise RuntimeError("service is shut down")
            specs: list[TaskSpec] = []
            for groups in groups_per_req:
                ids: list[str] = []
                for group in groups:
                    task_id = self.store.next_task_id(tenant)
                    specs.append(TaskSpec(
                        task_id=task_id, tenant=tenant, label=label,
                        items=tuple(group),
                        chunk_bytes=chunk_bytes or self.config.chunk_bytes,
                        tuning=tuning or self.config.tuning,
                        dedup=dedup or self.config.dedup,
                        failover=failover or self.config.failover,
                    ))
                    ids.append(task_id)
                    emits.append((task_id, len(group),
                                  sum(i.nbytes for i in group)))
                out.append(ids)
            for spec, rec in zip(specs, self.store.append_submit_many(specs)):
                self._index_task(spec.task_id, _Task(
                    spec, rec.seq, self.config.chunk_bytes,
                    tuning=spec.tuning or self.config.tuning,
                    dedup=spec.dedup or self.config.dedup))
            self._cond.notify_all()
        for task_id, files, nbytes in emits:
            self.events.emit(ev.SUBMITTED, task_id, tenant,
                             files=files, bytes=nbytes, label=label)
        return out

    # ------------------------------------------------------------------
    # client API: lifecycle
    # ------------------------------------------------------------------
    def status(self, task_id: str) -> TaskStatus:
        with self._lock:
            t = self._require(task_id)
            return self._snapshot(t)

    def status_many(self, task_ids: Sequence[str]) -> list[TaskStatus]:
        """Bulk status: one lock hold for the whole batch."""
        with self._lock:
            return [self._snapshot(self._require(tid)) for tid in task_ids]

    def tasks(
        self,
        *,
        tenant: str | None = None,
        state: str | None = None,
        cursor: str | None = None,
        limit: int | None = None,
    ) -> list[TaskStatus]:
        """List tasks in submission order, optionally filtered and paginated.

        ``cursor`` is the last task_id of the previous page: the listing
        resumes strictly after it, so walking ``cursor=page[-1].task_id``
        until an empty page visits every task exactly once even while new
        submissions land (they append after the cursor). Only the returned
        page is snapshotted — a page over a million-task service does not
        materialize a million statuses.
        """
        with self._lock:
            start = 0
            if cursor is not None:
                pos = self._order_pos.get(cursor)
                if pos is None:
                    raise KeyError(f"unknown cursor task {cursor!r}")
                start = pos + 1
            picked: list[_Task] = []
            for tid in itertools.islice(self._order, start, None):
                t = self._tasks[tid]
                if tenant is not None and t.spec.tenant != tenant:
                    continue
                if state is not None and t.state != state:
                    continue
                picked.append(t)
                if limit is not None and len(picked) >= limit:
                    break
            return [self._snapshot(t) for t in picked]

    def wait(self, task_id: str, timeout: float | None = None) -> TaskStatus:
        """Block until the task reaches a terminal state."""
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._cond:
            t = self._require(task_id)
            while t.state not in tk.TERMINAL:
                remaining = None if deadline is None else deadline - time.monotonic()
                if remaining is not None and remaining <= 0:
                    raise TimeoutError(f"task {task_id} still {t.state} after {timeout}s")
                self._cond.wait(remaining if remaining is not None else 0.5)
            return self._snapshot(t)

    def wait_all(self, task_ids: Sequence[str], timeout: float | None = None) -> list[TaskStatus]:
        deadline = None if timeout is None else time.monotonic() + timeout
        return [
            self.wait(tid, None if deadline is None else max(0.0, deadline - time.monotonic()))
            for tid in task_ids
        ]

    def cancel(self, task_id: str) -> TaskStatus:
        with self._cond:
            t = self._require(task_id)
            if t.state in tk.TERMINAL:
                return self._snapshot(t)
            if t.state in (tk.PENDING, tk.PAUSED):
                self._transition(t, tk.CANCELED)
                self.events.emit(ev.CANCELED, task_id, t.spec.tenant)
            else:
                t.cancel_evt.set()     # runner finalizes the transition
            self._cond.notify_all()
        return self.status(task_id)

    def pause(self, task_id: str) -> TaskStatus:
        with self._cond:
            t = self._require(task_id)
            if t.state == tk.PENDING:
                self._transition(t, tk.PAUSED)
                t.pause_evt.set()
                self.events.emit(ev.PAUSED, task_id, t.spec.tenant)
            elif t.state == tk.ACTIVE:
                t.pause_evt.set()      # runner drains in-flight chunks first
            self._cond.notify_all()
        return self.status(task_id)

    def resume(self, task_id: str) -> TaskStatus:
        with self._cond:
            t = self._require(task_id)
            if t.state == tk.PAUSED:
                t.pause_evt.clear()
                self._transition(t, tk.PENDING)
                self.events.emit(ev.RESUMED, task_id, t.spec.tenant)
                self._cond.notify_all()
            elif t.state == tk.ACTIVE and t.pause_evt.is_set():
                # pause still draining: withdraw it; _finish() sees the
                # cleared event and re-queues instead of landing on PAUSED
                t.pause_evt.clear()
                self.events.emit(ev.RESUMED, task_id, t.spec.tenant)
                self._cond.notify_all()
        return self.status(task_id)

    def subscribe(self, cb, *, from_seq: int | None = None) -> Callable[[], None]:
        """Register an event callback. With ``from_seq``, the subscriber is
        first caught up from that event sequence number (served from the
        spill log if the ring has wrapped), then receives live events — a
        late joiner resumes exactly where its cursor left off."""
        return self.events.subscribe(cb, from_seq=from_seq)

    def events_from(self, start_seq: int, *, limit: int | None = None):
        """Read historical events at seq >= start_seq (cursor polling)."""
        return self.events.read_from(start_seq, limit=limit)

    # ------------------------------------------------------------------
    # client API: resilience plane
    # ------------------------------------------------------------------
    def record_failover(self, task_id: str, **payload: Any) -> None:
        """Record a mid-flight route failover executed on this task's behalf.

        Route-aware layers (relay transfers, campaign re-parenting) own the
        actual re-plan; the service is the system of record — it bumps the
        task's failover counter, the per-tenant metric, and emits a FAILOVER
        event carrying the re-plan detail (sick_link, new_path,
        resumed_chunks).
        """
        with self._lock:
            t = self._require(task_id)
            t.failovers += 1
            tenant = t.spec.tenant
        self._m_failovers.inc(1, tenant=tenant, task=task_id)
        self.events.emit(ev.FAILOVER, task_id, tenant, **payload)

    def scrub_targets(self, task_id: str | None = None) -> list[ScrubTarget]:
        """Landed regions eligible for scrubbing, journal digests attached.

        Every chunk of every SUCCEEDED task (or just ``task_id``) becomes one
        target: the destination file region plus the digest custody recorded
        at landing time. The scrubber re-fingerprints each region against
        that digest — bit-rot after landing is the only way they diverge.
        """
        with self._lock:
            if task_id is not None:
                tasks = [self._require(task_id)]
            else:
                tasks = [self._tasks[tid] for tid in self._order]
            out: list[ScrubTarget] = []
            for t in tasks:
                if t.state != tk.SUCCEEDED:
                    continue
                if t.item_reports:
                    for i, rep in enumerate(t.item_reports):
                        for c in rep.chunks:
                            if not c.get("digest") or not int(c["length"]):
                                continue
                            out.append(ScrubTarget(
                                path=os.path.abspath(rep.dst),
                                offset=int(c["offset"]), length=int(c["length"]),
                                digest_hex=c["digest"], task_id=t.spec.task_id,
                                item=i, chunk=int(c.get("index", 0))))
                    continue
                # restart-replayed task: the in-memory reports are gone but
                # the chunk journal on disk still holds every landed region's
                # digest custody — scrub works across service restarts
                try:
                    journal = self.store.open_journal(t.spec.task_id)
                except OSError:
                    continue
                try:
                    recs = dict(journal.records)
                finally:
                    journal.close()
                for g in sorted(recs):
                    r = recs[g]
                    if r.status != "done" or not r.length or not r.digest_hex:
                        continue
                    i = t.item_of_gidx(g)
                    if i >= len(t.spec.items):
                        continue
                    out.append(ScrubTarget(
                        path=os.path.abspath(t.spec.items[i].dst),
                        offset=int(r.offset), length=int(r.length),
                        digest_hex=r.digest_hex, task_id=t.spec.task_id,
                        item=i, chunk=int(r.chunk_index)))
        return out

    def scrub(self, task_id: str | None = None, *,
              budget_bytes: int | None = None,
              repair: bool = True) -> ScrubReport:
        """One scrub pass over landed regions (all SUCCEEDED tasks or one).

        Re-verifies each region against its journal digest, repairs rot from
        replicas via the CAS index when a verified donor exists, quarantines
        (and emits a FAULT event) when none does. ``budget_bytes`` caps the
        bytes read this pass; the cursor persists so the next call resumes
        where this one stopped.
        """
        targets = self.scrub_targets(task_id)
        # open the chunk index even when dedup never did: the index log on
        # disk is the donor map for repairs, whatever populated it
        index = self.cas_index()
        with self._lock:
            if self._scrubber is None:
                self._scrubber = Scrubber(index=index)
            scrubber = self._scrubber
            scrubber.index = index
            scrubber.budget_bytes = budget_bytes
        report = scrubber.scrub(targets, repair=repair)
        # charge outcomes back to their tasks, then tell the event stream
        touched: dict[str, dict[str, int]] = {}
        for tgt in report.repairs:
            with self._lock:
                t = self._tasks.get(tgt.task_id)
                if t is not None:
                    t.scrub_repairs += 1
                    self._m_scrub_repairs.inc(
                        1, tenant=t.spec.tenant, task=tgt.task_id)
            d = touched.setdefault(tgt.task_id, collections.Counter())
            d["repaired"] += 1
        for tgt in report.quarantines:
            d = touched.setdefault(tgt.task_id, collections.Counter())
            d["quarantined"] += 1
            with self._lock:
                t = self._tasks.get(tgt.task_id)
                tenant = t.spec.tenant if t is not None else "default"
            self.events.emit(
                ev.FAULT, tgt.task_id, tenant, fault="bitrot",
                item=tgt.item, chunk=tgt.chunk, offset=tgt.offset,
                fatal=False, quarantined=True)
        for tid, counts in touched.items():
            with self._lock:
                t = self._tasks.get(tid)
                tenant = t.spec.tenant if t is not None else "default"
            self.events.emit(
                ev.SCRUB, tid, tenant, scanned=report.scanned,
                rot_detected=counts["repaired"] + counts["quarantined"],
                repaired=counts["repaired"],
                quarantined=counts["quarantined"])
        return report

    # ------------------------------------------------------------------
    # shutdown
    # ------------------------------------------------------------------
    def close(self, *, drain: bool = False, timeout: float | None = None) -> None:
        """Graceful stop. ``drain=True`` waits for active+pending work first;
        otherwise non-terminal tasks stay journaled and resume on restart."""
        if drain:
            open_ids = [t.spec.task_id for t in self._tasks.values()
                        if t.state not in tk.TERMINAL and not t.pause_evt.is_set()]
            self.wait_all(open_ids, timeout)
        self._stop_evt.set()
        with self._cond:
            still_active = any(t.state == tk.ACTIVE for t in self._tasks.values())
            self._cond.notify_all()
        if still_active:
            # suspend in-flight movers crash-consistently: journals keep what
            # landed, the log keeps ACTIVE, and a restart re-queues the tasks
            self._kill_evt.set()
        self._scheduler.join(timeout=5.0)
        for r in list(self._runners.values()):
            r.join(timeout=5.0)
        self.store.close()
        self.events.close()
        if self.cas is not None:
            self.cas.close()

    def kill(self) -> None:
        """Crash simulation: abandon all threads mid-flight, record nothing.

        Chunk journals and the task log keep whatever had already been
        fsynced — exactly the state a SIGKILL would leave behind.
        """
        self._kill_evt.set()
        self._stop_evt.set()
        with self._cond:
            self._cond.notify_all()
        self._scheduler.join(timeout=5.0)
        for r in list(self._runners.values()):
            r.join(timeout=5.0)
        self.store.close()
        self.events.close()

    # ------------------------------------------------------------------
    # scheduler loop
    # ------------------------------------------------------------------
    def _scheduler_loop(self) -> None:
        while not self._stop_evt.is_set():
            t0 = mono_s()
            with self._cond:
                self._activate_locked()
                dirty = self._alloc_dirty
                self._alloc_dirty = False
                reqs = self._allocation_requests_locked() if dirty else None
            if reqs:
                # predictions may run the event-stepped simulator on cache
                # misses — keep the service lock free while they do
                movers = self.engine.allocate(reqs)
                self._apply_allocation(movers)
            self.sched_cycles.append(mono_s() - t0)
            with self._cond:
                self._cond.wait(self.config.tick_s)

    def _activate_locked(self) -> None:
        free = self.config.max_concurrent_tasks - len(self._active_ids)
        if free <= 0:
            return
        # heap-indexed selection: cost scales with the decision count, not
        # with how many tasks are resident. The validate hook lazily drops
        # entries whose task left PENDING (canceled, paused) since add().
        chosen = self._activation.select(
            free,
            quotas=self.config.quotas, default_quota=self.config.default_quota,
            validate=lambda tid: (
                (tt := self._tasks.get(tid)) is not None
                and tt.state == tk.PENDING),
        )
        for task_id in chosen:
            t = self._tasks[task_id]
            self._transition(t, tk.ACTIVE)
            self._active_ids.add(task_id)
            t.started_s = t.started_s or wall_s()
            t.t0_mono = mono_s()
            # the root span id rides on every task-level event so an event
            # stream entry can be located inside an exported trace
            t.root_sid = self.tracer.mark(
                "activated", "task", task=task_id, tenant=t.spec.tenant)
            self._m_active.add(1, tenant=t.spec.tenant)
            runner = threading.Thread(
                target=self._run_task, args=(t,), name=f"runner-{task_id}", daemon=True
            )
            self._runners[task_id] = runner
            runner.start()
            self.events.emit(ev.ACTIVATED, task_id, t.spec.tenant,
                             span=t.root_sid)
            self._alloc_dirty = True

    def _allocation_requests_locked(self) -> list[tuple[str, str, TransferRequest]]:
        # O(active): iterate the maintained ACTIVE set, not every task ever
        # submitted (sorted for deterministic allocation order)
        out: list[tuple[str, str, TransferRequest]] = []
        for tid in sorted(self._active_ids):
            t = self._tasks.get(tid)
            if t is None or t.state != tk.ACTIVE:
                continue
            out.append((
                t.spec.task_id,
                t.spec.tenant,
                TransferRequest(
                    name=t.spec.task_id,
                    src=self.config.src_site,
                    dst=self.config.dst_site,
                    file_bytes=tuple(max(1, it.nbytes) for it in t.spec.items),
                    chunk_bytes=t.spec.chunk_bytes or self.config.chunk_bytes,
                    integrity=self.config.integrity,
                ),
            ))
        return out

    def _apply_allocation(self, movers: dict[str, int]) -> None:
        with self._lock:
            for tid, m in movers.items():
                t = self._tasks.get(tid)
                if t is not None and t.state == tk.ACTIVE:
                    t.target_movers = max(1, m)
        self.events.emit(
            ev.REALLOC, "-", "-",
            allocation=dict(movers), policy=self.config.policy,
        )

    # ------------------------------------------------------------------
    # task runner (one thread per ACTIVE task)
    # ------------------------------------------------------------------
    def _run_task(self, t: _Task) -> None:
        task_id = t.spec.task_id
        try:
            journal = self.store.open_journal(task_id)
        except Exception as e:  # noqa: BLE001
            self._finish(t, tk.FAILED, error=f"journal open failed: {e}")
            return
        jlock = threading.Lock()
        try:
            recs = dict(journal.records)
            with t.lock:
                t.resumed_chunks = len(recs)
                t.chunks_done = len(recs)
                t.bytes_done = sum(r.length for r in recs.values())
            work: "queue.Queue[tuple[int, int, Any]]" = queue.Queue()
            n_work = 0
            # Static seeding works whenever every journaled record matches
            # the deterministic static plans byte-for-byte (all untuned
            # tasks, and tuned tasks that never re-planned). A journal left
            # by a re-planned incarnation has records at other boundaries:
            # then the pending tail is region-based — journaled custody is
            # subtracted per item and fresh tuned-band chunks are carved
            # from the gaps, so a journaled chunk is never re-moved.
            if all(t.static_record_ok(g, r) for g, r in recs.items()):
                for i, plan in enumerate(t.plans):
                    if plan.n_chunks == 0:
                        self._dest(t, i)    # zero-byte item: materialize the file
                        continue
                    base = t.chunk_base[i]
                    entries = [(base + c.index, i, c) for c in plan.chunks
                               if base + c.index not in recs]
                    # content plane: satisfy index hits locally before any
                    # mover starts (deduped chunks journal custody and are
                    # counted done; only misses become wire work items)
                    if t.dedup == "on":
                        entries = self._dedup_entries(t, journal, jlock, i,
                                                      entries)
                    with t.lock:
                        expanded = self._expand_entries_locked(t, entries)
                    for e in expanded:
                        self._enq(t, work, e)
                        n_work += 1
            else:
                per_item: dict[int, list] = {i: [] for i in range(len(t.spec.items))}
                for g, r in recs.items():
                    per_item[t.item_of_gidx(g)].append(r)
                for i, item in enumerate(t.spec.items):
                    if t.plans[i].n_chunks == 0:
                        self._dest(t, i)
                        continue
                    with t.lock:
                        t.next_tune_seq[i] = max(
                            ((g - TUNE_GID_BASE) % TUNE_ITEM_STRIDE
                             for g in recs if TUNE_GID_BASE <= g < STRIPE_GID_BASE
                             and t.item_of_gidx(g) == i),
                            default=-1,
                        ) + 1
                        # resume the stripe allocator past journaled stripe
                        # ids: reusing one would overwrite custody in the
                        # journal's replay dict on the NEXT restart
                        t.next_stripe_seq[i] = max(
                            ((g - STRIPE_GID_BASE) % STRIPE_ITEM_STRIDE
                             for g in recs if g >= STRIPE_GID_BASE
                             and t.item_of_gidx(g) == i),
                            default=-1,
                        ) + 1
                        gaps = subtract_regions(
                            item.nbytes,
                            [(r.offset, r.length) for r in per_item[i]],
                        )
                        fresh = partition_regions(
                            gaps, t.chunk_bytes_now,
                            start_index=t.next_tune_seq[i],
                        )
                        t.next_tune_seq[i] += len(fresh)
                    raw = [(t.tune_gidx(i, c.index), i, c) for c in fresh]
                    if t.dedup == "on":
                        # dedup runs OUTSIDE t.lock: it opens endpoints
                        # (_source/_dest take the lock) and probes the index
                        raw = self._dedup_entries(t, journal, jlock, i, raw)
                    with t.lock:
                        entries = self._expand_entries_locked(t, raw)
                    for e in entries:
                        self._enq(t, work, e)
                        n_work += 1
            # total = done so far (resumed + deduped) + queued work items:
            # stripe expansion and dedup both change the count, so it is
            # recomputed here for every seeding path (for the plain static
            # case this equals the plans' chunk total exactly)
            with t.lock:
                t.chunks_total = t.chunks_done + n_work
            if t.tuning == "auto":
                self._arm_tuner(t, work)
            if self.config.pipeline != "serial":
                t.pool = BufferPool(
                    max(self.config.stream_granule,
                        min(t.chunk_bytes_now or 1, 64 * MiB)),
                    capacity=(self.config.mover_budget
                              + self.config.integrity_workers + 2),
                )
            if self.config.pipeline == "pipelined" and self.config.integrity:
                # decoupled integrity engine: movers enqueue, checksum
                # workers verify concurrently with later chunk moves. The
                # custody rule lives in _verify_pass: the journal record
                # commits only once the deferred verification lands.
                t.engine = IntegrityEngine(
                    workers=self.config.integrity_workers, pool=t.pool,
                    on_verified=lambda job, lag, ck: self._verify_pass(
                        t, work, journal, jlock, job, lag),
                    on_corrupt=lambda job, actual, lag: self._verify_fail(
                        t, work, job),
                    on_error=lambda job, exc: self._verify_error(t, job, exc),
                    tracer=self.tracer, task=task_id,
                )

            reason = self._drive_workers(t, work, journal, jlock, n_work)
            if t.engine is not None:
                if reason is None:
                    t.engine.close(abandon=True)   # kill(): crash mid-flight
                else:
                    # drain before finalizing: a paused/canceled/failed task
                    # still journals every chunk its verifiers vouch for, so
                    # a resume re-moves only genuinely unverified chunks
                    t.engine.drain()
                    t.engine.close()
            if reason is None:          # killed: vanish without a trace
                return
            if reason == tk.SUCCEEDED:
                try:
                    reports = self._build_reports(t, journal)
                except Exception as e:  # noqa: BLE001
                    self._finish(t, tk.FAILED, error=f"finalize failed: {e}")
                    return
                self._finish(t, tk.SUCCEEDED, reports=reports)
            elif reason == tk.PAUSED:
                self._finish(t, tk.PAUSED)
            elif reason == tk.CANCELED:
                self._finish(t, tk.CANCELED)
            else:
                self._finish(t, tk.FAILED, error=t.failed_error or "unknown failure")
        finally:
            # on kill() the handle is left open, as a real SIGKILL would leave
            # it: a straggler mover may still be appending its last record
            if not self._kill_evt.is_set():
                journal.close()
            with self._lock:
                # a resumed task may already have a NEW runner registered
                if self._runners.get(task_id) is threading.current_thread():
                    self._runners.pop(task_id, None)

    def _drive_workers(self, t, work, journal, jlock, n_work) -> str | None:
        """Spawn/trim movers until the task reaches an outcome; returns the
        outcome state, or None when the service was killed mid-flight."""
        while True:
            if self._kill_evt.is_set():
                return None
            if t.cancel_evt.is_set():
                outcome = tk.CANCELED
            elif t.pause_evt.is_set():
                outcome = tk.PAUSED
            else:
                with t.lock:
                    if t.failed_error:
                        outcome = tk.FAILED
                    elif t.chunks_done >= t.chunks_total:
                        outcome = tk.SUCCEEDED
                    else:
                        outcome = ""
            if outcome:
                break
            with t.lock:
                want = min(max(1, t.target_movers), max(1, t.chunks_total - t.chunks_done))
                # don't spawn movers that would find an empty queue: the last
                # chunks are in flight with the workers already holding them
                want = min(want, work.qsize() + t.n_workers)
                short = want - t.n_workers
                for _ in range(max(0, short)):
                    t.n_workers += 1
                    t.worker_seq += 1
                    threading.Thread(
                        target=self._worker,
                        args=(t, work, journal, jlock, t.worker_seq),
                        daemon=True,
                    ).start()
            time.sleep(self.config.tick_s)
        # wind down: workers observe the same events/counters and drain
        while True:
            with t.lock:
                if t.n_workers == 0:
                    return outcome
            if self._kill_evt.is_set():
                return None
            time.sleep(self.config.tick_s / 2)

    # ------------------------------------------------------------------
    # autotuning (closed-loop chunk sizing per task)
    # ------------------------------------------------------------------
    def _arm_tuner(self, t: _Task, work) -> None:
        """Create the task's ChunkController (optionally SimTuner-seeded)
        and apply the warm-start re-plan before any byte moves."""
        chunk0 = t.chunk_bytes_now
        lo = min(self.config.tune_min_chunk, chunk0)
        hi = max(self.config.tune_max_chunk, chunk0)
        target0 = chunk0
        if self.config.tune_seed == "sim" and t.bytes_total > 0:
            sim = SimTuner(self.config.src_site, self.config.dst_site,
                           self.config.link)
            target0 = max(lo, min(hi, sim.seed_chunk(t.bytes_total)))
        t.controller = ChunkController(
            chunk_bytes=target0, min_chunk=lo, max_chunk=hi,
            epoch_chunks=self.config.tune_epoch_chunks,
        )
        if target0 != chunk0:
            self._replan_task(t, work, target0, rate_Bps=0.0)

    def _replan_task(self, t: _Task, work, new_bytes: int, *,
                     rate_Bps: float = 0.0, cksum_lag_s: float = 0.0) -> int:
        """Re-partition the task's un-started tail at ``new_bytes``.

        Drains the work queue (chunks never handed to a mover — journaled
        custody and in-flight chunks are untouchable by construction),
        re-cuts each item's drained regions, and re-enqueues under fresh
        tuned-band journal ids. Emits a TUNE event.
        """
        drained: list[tuple[int, int, Any]] = []
        while True:
            try:
                drained.append(work.get_nowait())
            except queue.Empty:
                break
        if not drained:
            return 0
        # stripe work items keep their boundaries (their journaled siblings
        # pin the partition) — only whole un-started plain chunks are re-cut
        kept = [e for e in drained if e[0] >= STRIPE_GID_BASE]
        plain = [e for e in drained if e[0] < STRIPE_GID_BASE]
        if not plain:
            for e in kept:
                self._enq(t, work, e)
            return 0
        by_item: dict[int, list[tuple[int, int]]] = {}
        for _g, i, c in plain:
            by_item.setdefault(i, []).append((c.offset, c.length))
        entries: list[tuple[int, int, Any]] = []
        with t.lock:
            for i in sorted(by_item):
                fresh = partition_regions(
                    merge_regions(by_item[i]), new_bytes,
                    start_index=t.next_tune_seq[i],
                )
                t.next_tune_seq[i] += len(fresh)
                entries.extend(self._expand_entries_locked(
                    t, [(t.tune_gidx(i, c.index), i, c) for c in fresh]))
            t.chunks_total += len(entries) - len(plain)
            t.replans += 1
            old = t.chunk_bytes_now
            t.chunk_bytes_now = int(new_bytes)
        for e in kept:
            self._enq(t, work, e)
        for e in entries:
            self._enq(t, work, e)
        self.tracer.mark("replan", "plan", task=t.spec.task_id,
                         chunk_bytes=int(new_bytes), recut=len(entries))
        self.events.emit(
            ev.TUNE, t.spec.task_id, t.spec.tenant,
            old_chunk_bytes=old, chunk_bytes=int(new_bytes),
            drained=len(drained), requeued=len(entries),
            rate_Bps=round(rate_Bps, 3),
            cksum_lag_s=round(cksum_lag_s, 6),
        )
        return len(drained)

    def _feed_tuner(self, t: _Task, work, chunk, sample: ChunkSample) -> None:
        with t.lock:
            ctrl = t.controller
            if ctrl is None:
                return
            new = ctrl.observe(sample)
            cur = t.chunk_bytes_now
        if new is not None and new != cur:
            self._replan_task(t, work, new, rate_Bps=sample.rate_Bps,
                              cksum_lag_s=sample.cksum_lag_s)

    def _expand_entries_locked(self, t: _Task, entries):
        """Split stripe-eligible work entries into stripe-band entries.

        Caller holds ``t.lock`` (or is the single-threaded runner during
        seeding). Each stripe is an independent work item with its own
        stripe-band journal id: custody is per-stripe, so a restart re-moves
        only the stripes whose verification never landed — the journaled
        ones are subtracted as regions like any other custody record.
        """
        cfg = self.config
        if cfg.stripes <= 1:
            return entries
        out = []
        for gidx, i, c in entries:
            sp = plan_stripes(c, cfg.stripes,
                              stripe_min_bytes=cfg.stripe_min_bytes)
            if sp.n_stripes <= 1:
                out.append((gidx, i, c))
                continue
            t.striped_chunks += 1
            for s in sp.stripes:
                seq = t.next_stripe_seq[i]
                t.next_stripe_seq[i] = seq + 1
                out.append((t.stripe_gidx(i, seq), i,
                            Chunk(index=seq, offset=s.offset,
                                  length=s.length, mover=0)))
        return out

    def _enq(self, t: _Task, work, entry) -> None:
        """Queue a work entry, timestamping it for the queue-wait span."""
        t.enq_t[entry[0]] = mono_s()
        work.put(entry)

    # ------------------------------------------------------------------
    # content plane (dedup negotiation during task seeding)
    # ------------------------------------------------------------------
    def _dedup_entries(self, t: _Task, journal, jlock, item_idx: int,
                       entries):
        """Probe one item's pending work entries against the chunk index;
        returns the entries that still need wire moves.

        Runs during seeding, before any mover spawns (and outside
        ``t.lock``). Each pending chunk's source bytes are fingerprinted and
        probed; a hit is satisfied locally — alias entries (the destination
        already holds the bytes at the right offset) need only read-back
        verification, other entries' backing bytes are re-verified, copied
        in, and verified again after landing. Satisfied chunks journal
        custody immediately and count as done; a stale entry is discarded
        (demotion to wire, ``stale_index`` fault metric), so a wrong index
        can cost a wire move but never an integrity escape. Deduped chunks
        never reach ``_move_chunk``: they feed neither the tuner's
        congestion signal nor ``moved_chunks`` (the chaos re-move counter).
        """
        index = self.cas_index()
        item = t.spec.items[item_idx]
        dst_path = os.path.abspath(item.dst)
        src = self._source(t, item_idx)
        dst = self._dest(t, item_idx)
        tid = t.spec.task_id
        keep = []
        hits = saved = demoted = 0
        for gidx, i, chunk in entries:
            t_p = mono_s()
            try:
                data = src.read(chunk.offset, chunk.length)
            except Exception:  # noqa: BLE001 — probe failure = wire move
                keep.append((gidx, i, chunk))
                continue
            if len(data) != chunk.length:
                keep.append((gidx, i, chunk))
                continue
            want = fingerprint_bytes(data)
            del data
            satisfied = aliased = stale_here = False
            for e in index.lookup(want.hexdigest(), chunk.length):
                alias = (os.path.abspath(e.path) == dst_path
                         and e.offset == chunk.offset)
                backing = index.verify_entry(e)
                if backing is None:
                    # stale: backing bytes vanished or rotted — drop the
                    # entry and keep probing other locations
                    index.discard(e.digest_hex, e.length, e.path, e.offset)
                    index.note_stale()
                    stale_here = True
                    continue
                try:
                    if not alias:
                        dst.write(chunk.offset, backing)
                    back = dst.read_back(chunk.offset, chunk.length)
                except Exception:  # noqa: BLE001 — local copy failed
                    stale_here = True
                    continue
                if not verify(want, fingerprint_bytes(back)):
                    stale_here = True     # copy landed corrupt: wire instead
                    continue
                satisfied, aliased = True, alias
                break
            now = mono_s()
            if not satisfied:
                if stale_here:
                    demoted += 1
                    with t.lock:
                        t.dedup_demoted += 1
                    self._m_faults.inc(1, tenant=t.spec.tenant, task=tid,
                                       kind="stale_index")
                    self.tracer.add("dedup_demote", "dedup", t_p, now,
                                    task=tid, lane="dedup",
                                    offset=chunk.offset, item=item_idx)
                else:
                    self.tracer.add("dedup_probe", "dedup", t_p, now,
                                    task=tid, lane="dedup",
                                    offset=chunk.offset, item=item_idx)
                keep.append((gidx, i, chunk))
                continue
            # custody first: a kill+restart must see the deduped chunk as
            # landed (journaled bytes are never re-moved — the same rule
            # wire moves live by)
            try:
                with jlock:
                    journal.append(JournalRecord(
                        gidx, chunk.offset, chunk.length, want.hexdigest()))
            except Exception:  # noqa: BLE001 — no custody, no dedup
                keep.append((gidx, i, chunk))
                continue
            if not aliased:
                try:
                    index.put(want.hexdigest(), chunk.length, dst_path,
                              chunk.offset)
                except Exception:  # noqa: BLE001 — cache: failed put = miss
                    pass
            with t.lock:
                t.chunks_done += 1
                t.bytes_done += chunk.length
                t.chunks_deduped += 1
                t.wire_bytes_saved += chunk.length
            hits += 1
            saved += chunk.length
            self.tracer.add("dedup_hit", "dedup", t_p, now, task=tid,
                            lane="dedup", offset=chunk.offset, item=item_idx,
                            alias=int(aliased))
        if hits or demoted:
            self.events.emit(
                ev.DEDUP, tid, t.spec.tenant, item=item_idx, chunks=hits,
                bytes_saved=saved, demoted=demoted, span=t.root_sid,
            )
        return keep

    def _worker(self, t: _Task, work, journal, jlock, wid: int = 0) -> None:
        lane = f"mover{wid}"
        try:
            while True:
                if (
                    self._kill_evt.is_set()
                    or t.cancel_evt.is_set()
                    or t.pause_evt.is_set()
                ):
                    return
                with t.lock:
                    if t.failed_error:
                        return
                    if t.n_workers > max(1, t.target_movers):
                        return               # trimmed by reallocation
                try:
                    gidx, item_idx, chunk = work.get_nowait()
                except queue.Empty:
                    return
                enq = t.enq_t.get(gidx)
                if enq is not None:
                    self.tracer.add(
                        "queue_wait", "queue", enq, mono_s(),
                        task=t.spec.task_id, lane=lane,
                        offset=chunk.offset, item=item_idx)
                try:
                    digest, sample = self._move_chunk(t, item_idx, chunk,
                                                      lane=lane)
                except MoverCrash as e:
                    # the mover thread dies; the chunk survives it. Re-queue
                    # the chunk for the remaining movers (the driver tops the
                    # pool back up) unless the death budget is exhausted.
                    with t.lock:
                        t.mover_deaths += 1
                        over = t.mover_deaths > self.config.max_mover_deaths
                        if over:
                            t.failed_error = (
                                f"mover-death budget exhausted "
                                f"({t.mover_deaths} > {self.config.max_mover_deaths})"
                            )
                            t.fault = self._fault_report(t, "mover_death", item_idx, chunk, e)
                    self._m_faults.inc(1, tenant=t.spec.tenant,
                                       task=t.spec.task_id, kind="mover_death")
                    self.events.emit(
                        ev.FAULT, t.spec.task_id, t.spec.tenant,
                        fault="mover_death", item=item_idx, chunk=chunk.index,
                        fatal=over, span=t.root_sid,
                    )
                    if not over:
                        self._enq(t, work, (gidx, item_idx, chunk))
                    return
                except Exception as e:  # noqa: BLE001
                    with t.lock:
                        t.failed_error = (
                            f"item {item_idx} chunk {chunk.index} "
                            f"(offset={chunk.offset}): {e}"
                        )
                        t.fault = self._fault_report(t, classify_fault(e), item_idx, chunk, e)
                    return
                if t.engine is not None:
                    # pipelined: the move landed; enqueue the deferred
                    # verification and pull the next chunk NOW. Journal +
                    # progress commit in _verify_pass (the custody rule).
                    t.engine.submit(VerifyJob(
                        key=gidx, offset=chunk.offset, length=chunk.length,
                        expected=digest, dest=self._dest(t, item_idx),
                        enqueued_s=time.perf_counter(),
                        payload=(gidx, item_idx, chunk, sample),
                    ))
                    continue
                if not self._commit_chunk(t, work, journal, jlock,
                                          gidx, item_idx, chunk, digest, sample):
                    return
        finally:
            with t.lock:
                t.n_workers -= 1

    def _commit_chunk(self, t: _Task, work, journal, jlock, gidx: int,
                      item_idx: int, chunk, digest, sample: ChunkSample) -> bool:
        """Make one verified chunk durable and visible: journal custody,
        counters, PROGRESS event, tuner feed. Shared by the serial mover
        path and the integrity engine's verdict callbacks; returns False
        when the task was failed instead."""
        t_j = time.perf_counter()
        try:
            with jlock:
                journal.append(JournalRecord(
                    gidx, chunk.offset, chunk.length, digest.hexdigest()
                ))
        except Exception as e:  # noqa: BLE001
            if self._kill_evt.is_set():
                return False    # kill() closed the journal under us
            # a dead journal (ENOSPC, pulled mount) must FAIL the
            # task with a report, not strand it ACTIVE: completions
            # that can't be made durable are not completions
            with t.lock:
                t.failed_error = (
                    f"journal append failed for item {item_idx} chunk "
                    f"{chunk.index}: {e}"
                )
                t.fault = self._fault_report(t, "io", item_idx, chunk, e)
            return False
        self.tracer.add("journal_append", "journal", t_j, time.perf_counter(),
                        task=t.spec.task_id, lane="journal",
                        offset=chunk.offset, item=item_idx)
        if self.cas is not None:
            # index population: every verified, journaled chunk is content a
            # future task (or checkpoint save) may dedup against
            try:
                self.cas.put(digest.hexdigest(), chunk.length,
                             os.path.abspath(t.spec.items[item_idx].dst),
                             chunk.offset)
            except Exception:  # noqa: BLE001 — cache: failed put = miss
                pass
        self._m_chunks.inc(1, tenant=t.spec.tenant, task=t.spec.task_id)
        self._m_bytes.inc(chunk.length, tenant=t.spec.tenant,
                          task=t.spec.task_id)
        with self._lock:
            self.moved_chunks += 1
        with t.lock:
            t.chunks_done += 1
            t.bytes_done += chunk.length
            t.cksum_s += sample.cksum_seconds
            t.cksum_lag_s += sample.cksum_lag_s
            done, total = t.chunks_done, t.chunks_total
        self.events.emit(
            ev.PROGRESS, t.spec.task_id, t.spec.tenant,
            chunks_done=done, chunks_total=total,
        )
        if t.controller is not None:
            # fold the journal fsync into the sample: it is a real
            # per-chunk control-plane cost the tuner must weigh
            j_secs = time.perf_counter() - t_j
            sample = dataclasses.replace(
                sample, seconds=sample.seconds + j_secs,
                attempt_seconds=sample.attempt_seconds + j_secs,
            )
            self._feed_tuner(t, work, chunk, sample)
        if done >= total:
            with self._cond:
                self._cond.notify_all()
        return True

    # ------------------------------------------------------------------
    # integrity-engine verdicts (pipelined data plane, verifier threads)
    # ------------------------------------------------------------------
    def _verify_pass(self, t: _Task, work, journal, jlock,
                     job: VerifyJob, lag_s: float) -> None:
        gidx, item_idx, chunk, sample = job.payload
        sample = dataclasses.replace(sample, cksum_lag_s=lag_s)
        self._commit_chunk(t, work, journal, jlock,
                           gidx, item_idx, chunk, job.expected, sample)

    def _verify_fail(self, t: _Task, work, job: VerifyJob) -> None:
        """A lagging verifier caught a corrupt landing: quarantine + re-queue
        the chunk for a source re-fetch, on the same re-fetch budget the
        inline path uses; the budget exhausting fails the task with a
        structured corruption report."""
        gidx, item_idx, chunk, _sample = job.payload
        with t.lock:
            t.retries += 1
            t.refetches += 1
            n = t.verify_refetches.get(gidx, 0) + 1
            t.verify_refetches[gidx] = n
            over = n > self.config.max_refetches
            if over:
                exc = IntegrityError(
                    f"deferred read-back digest mismatch persisted through "
                    f"{self.config.max_refetches} re-fetches "
                    f"(item {item_idx} @ {chunk.offset})"
                )
                t.failed_error = (
                    f"item {item_idx} chunk {chunk.index} "
                    f"(offset={chunk.offset}): {exc}"
                )
                t.fault = self._fault_report(t, "corruption", item_idx, chunk, exc)
        self._m_faults.inc(1, tenant=t.spec.tenant, task=t.spec.task_id,
                           kind="corruption")
        self.events.emit(
            ev.FAULT, t.spec.task_id, t.spec.tenant,
            fault="corruption", item=item_idx, chunk=chunk.index,
            deferred=True, fatal=over, span=t.root_sid,
        )
        if not over:
            self._enq(t, work, (gidx, item_idx, chunk))

    def _verify_error(self, t: _Task, job: VerifyJob, exc: BaseException) -> None:
        gidx, item_idx, chunk, _sample = job.payload
        if self._kill_evt.is_set():
            return                  # kill() tore the endpoints down under us
        with t.lock:
            t.failed_error = (
                f"deferred verification read-back failed for item {item_idx} "
                f"chunk {chunk.index}: {exc}"
            )
            t.fault = self._fault_report(t, classify_fault(exc), item_idx, chunk, exc)

    def _fault_report(self, t: _Task, kind: str, item_idx: int, chunk,
                      exc: BaseException) -> FaultReport:
        """Structured terminal-fault description (caller holds t.lock)."""
        return FaultReport(
            kind=kind, item=item_idx, chunk=chunk.index, offset=chunk.offset,
            error=str(exc), retries=t.retries, refetches=t.refetches,
            outages=t.outages, mover_deaths=t.mover_deaths,
        )

    def _move_chunk(self, t: _Task, item_idx: int, chunk, *,
                    lane: str = "mover0"):
        """One chunk: read -> fingerprint -> write -> read-back verify, with
        per-failure-class recovery budgets (chunk-granular fault recovery):

        * digest mismatch -> immediate re-fetch from source (quarantine the
          landing), up to ``max_refetches``;
        * endpoint outage -> wait the window out with backoff on the (larger)
          ``outage_retries`` budget — outages heal on their own clock;
        * mover crash -> propagates to the worker, which re-queues the chunk;
        * anything else -> exponential-backoff retries up to ``max_retries``.

        Every fault is propagated through the event stream (FAULT/RETRY); the
        task only FAILs — with a structured FaultReport — after the budget of
        the terminal failure class is exhausted.
        """
        item = t.spec.items[item_idx]
        src = self._source(t, item_idx)
        dst = self._dest(t, item_idx)
        attempts = generic = refetches = outages = 0
        t0 = time.perf_counter()
        signal_s = 0.0   # fault-excluded work time: generic retries count
        # (congestion), corruption re-fetches and outage waits do not
        while True:
            attempts += 1
            t_att = time.perf_counter()
            try:
                if self._fault_injector is not None:
                    self._fault_injector(t.spec.task_id, item_idx, chunk, attempts)
                if self.config.pipeline == "serial" or t.pool is None:
                    data = src.read(chunk.offset, chunk.length)
                    if len(data) != chunk.length:
                        raise IOError(
                            f"short read at {chunk.offset}: {len(data)}/{chunk.length}"
                        )
                    t_ck = time.perf_counter()
                    digest = fingerprint_bytes(data)
                    cksum_s = time.perf_counter() - t_ck
                    dst.write(chunk.offset, data)
                else:
                    # single-pass streaming: the source fingerprint
                    # accumulates while each granule streams into the
                    # destination through a pooled zero-copy buffer
                    digest, cksum_s = stream_chunk(
                        src, dst, chunk.offset, chunk.length,
                        pool=t.pool, granule=self.config.stream_granule,
                    )
                if self.config.integrity and self.config.pipeline != "pipelined":
                    t_ck = time.perf_counter()
                    back = dst.read_back(chunk.offset, chunk.length)
                    ok = verify(digest, fingerprint_bytes(back))
                    cksum_s += time.perf_counter() - t_ck
                    if not ok:
                        raise IntegrityError(
                            f"read-back digest mismatch ({item.dst} @ {chunk.offset})"
                        )
                now = time.perf_counter()
                # retroactive spans: the successful attempt minus its inline
                # checksum share is wire; the checksum share sits at the tail
                wire_end = max(t_att, now - cksum_s)
                tid = t.spec.task_id
                self.tracer.add("move", "wire", t_att, wire_end, task=tid,
                                lane=lane, offset=chunk.offset, item=item_idx,
                                attempt=attempts)
                if cksum_s > 0.0:
                    self.tracer.add("cksum_inline", "cksum", wire_end, now,
                                    task=tid, lane=lane, offset=chunk.offset,
                                    item=item_idx)
                self._m_wire.observe(signal_s + (now - t_att), task=tid)
                return digest, ChunkSample(
                    offset=chunk.offset, length=chunk.length,
                    seconds=now - t0,
                    attempt_seconds=signal_s + (now - t_att),
                    cksum_seconds=cksum_s, attempts=attempts,
                    refetches=refetches,
                )
            except MoverCrash:
                raise                      # the mover is gone; no in-place retry
            except IntegrityError:
                refetches += 1
                with t.lock:
                    t.retries += 1
                    t.refetches += 1
                sid = self.tracer.add(
                    "refetch", "stall", t_att, time.perf_counter(),
                    task=t.spec.task_id, lane=lane, offset=chunk.offset,
                    item=item_idx, attempt=attempts)
                self._m_faults.inc(1, tenant=t.spec.tenant,
                                   task=t.spec.task_id, kind="corruption")
                self.events.emit(
                    ev.FAULT, t.spec.task_id, t.spec.tenant,
                    fault="corruption", item=item_idx, chunk=chunk.index,
                    attempt=attempts, fatal=refetches > self.config.max_refetches,
                    span=sid,
                )
                if refetches > self.config.max_refetches:
                    raise
            except EndpointOutage:
                outages += 1
                with t.lock:
                    t.outages += 1
                over = outages > self.config.outage_retries
                if not over:
                    Backoff(self.config.retry_backoff_s, mode="linear",
                            lane=f"{t.spec.task_id}:{lane}:c{chunk.index}",
                            ).sleep(outages)
                # the rejected op plus its backoff is fault recovery, not
                # congestion (the tuner's fault-exclusion rule)
                sid = self.tracer.add(
                    "outage_wait", "stall", t_att, time.perf_counter(),
                    task=t.spec.task_id, lane=lane, offset=chunk.offset,
                    item=item_idx)
                self._m_faults.inc(1, tenant=t.spec.tenant,
                                   task=t.spec.task_id, kind="outage")
                self.events.emit(
                    ev.FAULT, t.spec.task_id, t.spec.tenant,
                    fault="outage", item=item_idx, chunk=chunk.index,
                    attempt=attempts, fatal=over, span=sid,
                )
                if over:
                    raise
            except Exception:
                generic += 1
                now = time.perf_counter()
                signal_s += now - t_att   # congestion-like
                # generic retries ARE the path slowing down: wire, not stall
                sid = self.tracer.add(
                    "move_retry", "wire", t_att, now, task=t.spec.task_id,
                    lane=lane, offset=chunk.offset, item=item_idx,
                    attempt=attempts)
                self._m_faults.inc(1, tenant=t.spec.tenant,
                                   task=t.spec.task_id, kind="generic")
                if generic > self.config.max_retries:
                    raise
                with t.lock:
                    t.retries += 1
                self.events.emit(
                    ev.RETRY, t.spec.task_id, t.spec.tenant,
                    item=item_idx, chunk=chunk.index, attempt=attempts,
                    span=sid,
                )
                Backoff(self.config.retry_backoff_s,
                        lane=f"{t.spec.task_id}:{lane}:c{chunk.index}",
                        ).sleep(generic)

    def _source(self, t: _Task, item_idx: int) -> ByteSource:
        with t.lock:
            src = t._sources.get(item_idx)
            if src is None:
                item = t.spec.items[item_idx]
                if item.mem:
                    src = self._mem_sources[(t.spec.task_id, item_idx)]
                else:
                    src = FileSource(item.src)
                if self._source_wrapper is not None:
                    src = self._source_wrapper(t.spec.task_id, item_idx, src)
                t._sources[item_idx] = src
            return src

    def _dest(self, t: _Task, item_idx: int) -> ByteDest:
        with t.lock:
            dst = t._dests.get(item_idx)
            if dst is None:
                item = t.spec.items[item_idx]
                parent = os.path.dirname(item.dst)
                if parent:
                    os.makedirs(parent, exist_ok=True)
                dst = FileDest(item.dst, item.nbytes)
                if self._dest_wrapper is not None:
                    dst = self._dest_wrapper(t.spec.task_id, item_idx, dst)
                t._dests[item_idx] = dst
            return dst

    def _build_reports(self, t: _Task, journal: ChunkJournal) -> tuple[ItemReport, ...]:
        if any(g >= TUNE_GID_BASE for g in journal.records):
            return self._build_reports_regions(t, journal)
        reports = []
        for i, (item, plan) in enumerate(zip(t.spec.items, t.plans)):
            base = t.chunk_base[i]
            chunks, parts = [], []
            for c in plan.chunks:
                rec = journal.records[base + c.index]
                parts.append((rec.offset, rec.digest()))
                chunks.append({
                    "index": c.index, "offset": c.offset,
                    "length": c.length, "digest": rec.digest_hex,
                })
            digest = combine_at_offsets(parts, item.nbytes) if parts else EMPTY_DIGEST
            reports.append(ItemReport(
                src=item.src, dst=item.dst, nbytes=item.nbytes,
                digest_hex=digest.hexdigest(),
                chunk_bytes=plan.chunk_bytes, chunks=tuple(chunks),
            ))
        return tuple(reports)

    def _build_reports_regions(self, t: _Task, journal: ChunkJournal) -> tuple[ItemReport, ...]:
        """Item reports for a re-planned (tuned) task: the journal's byte
        regions are authoritative — the merge-law combine works over any
        boundary set that tiles each item exactly."""
        per_item: dict[int, list] = {i: [] for i in range(len(t.spec.items))}
        for g, rec in journal.records.items():
            per_item[t.item_of_gidx(g)].append(rec)
        reports = []
        for i, item in enumerate(t.spec.items):
            rl = sorted(per_item[i], key=lambda r: r.offset)
            parts = [(r.offset, r.digest()) for r in rl]
            digest = combine_at_offsets(parts, item.nbytes) if parts else EMPTY_DIGEST
            chunks = tuple(
                {"index": r.chunk_index, "offset": r.offset,
                 "length": r.length, "digest": r.digest_hex}
                for r in rl
            )
            reports.append(ItemReport(
                src=item.src, dst=item.dst, nbytes=item.nbytes,
                digest_hex=digest.hexdigest(),
                chunk_bytes=t.chunk_bytes_now, chunks=chunks,
            ))
        return tuple(reports)

    # ------------------------------------------------------------------
    # bookkeeping
    # ------------------------------------------------------------------
    def _require(self, task_id: str) -> _Task:
        t = self._tasks.get(task_id)
        if t is None:
            raise KeyError(f"unknown task {task_id!r}")
        return t

    def _transition(self, t: _Task, state: str, error: str | None = None) -> None:
        if not tk.can_transition(t.state, state):
            raise TransitionError(t.spec.task_id, t.state, state)
        prev, task_id = t.state, t.spec.task_id
        t.state = state
        t.error = error
        # keep the control-plane indexes in lockstep with the state machine
        # (callers hold the service lock): leaving ACTIVE shrinks the active
        # set and the tenant's quota usage; re-entering PENDING (resume, a
        # withdrawn pause) re-queues the task for activation
        if prev == tk.ACTIVE and state != tk.ACTIVE:
            self._active_ids.discard(task_id)
            self._activation.active_delta(t.spec.tenant, -1)
        if state == tk.PENDING and prev != tk.PENDING:
            self._activation.add(t.seq, task_id, t.spec.tenant)
        self.store.append_state(task_id, state, error)

    def _finish(self, t: _Task, state: str, *, error: str | None = None,
                reports: tuple[ItemReport, ...] = ()) -> None:
        with self._cond:
            if state == tk.PAUSED and not t.pause_evt.is_set():
                state = tk.PENDING      # resume() raced the pause drain
            self._transition(t, state, error)
            if state in tk.TERMINAL:
                t.finished_s = wall_s()
            if state == tk.SUCCEEDED:
                t.item_reports = reports
            self._alloc_dirty = True
        # waiters are notified AFTER the terminal event is emitted (below),
        # so a client woken by wait() observes the event-stream effect of the
        # transition too — subscribers never lag a returned wait()
        if t.t0_mono is not None:
            # task root span: the makespan window obs.attr sweeps by default
            self.tracer.add("task", "task", t.t0_mono, mono_s(),
                            task=t.spec.task_id, tenant=t.spec.tenant,
                            state=state)
            self._m_active.add(-1, tenant=t.spec.tenant)
            t.t0_mono = None
        kind = {
            tk.SUCCEEDED: ev.SUCCEEDED, tk.FAILED: ev.FAILED,
            tk.CANCELED: ev.CANCELED, tk.PAUSED: ev.PAUSED,
            tk.PENDING: ev.RESUMED,     # pause withdrawn mid-drain
        }[state]
        payload: dict[str, Any] = {"chunks_done": t.chunks_done,
                                   "span": t.root_sid}
        if error:
            payload["error"] = error
        if state == tk.FAILED and t.fault is not None:
            payload["fault"] = t.fault.to_json()
        try:
            self.events.emit(kind, t.spec.task_id, t.spec.tenant, **payload)
            if state == tk.FAILED and t.fault is not None:
                # post-mortem flight-recorder bundle: the event ring, the
                # faulted chunk's span chain, a metrics snapshot, journal tail
                try:
                    self.recorder.dump(
                        t.spec.task_id, t.fault.kind, offset=t.fault.offset,
                        journal_path=self.store.journal_path(t.spec.task_id),
                        extra={"error": t.fault.error,
                               "chunk": t.fault.chunk, "item": t.fault.item})
                except Exception:  # noqa: BLE001 — a failing dump must never
                    pass           # mask the task failure it is documenting
        finally:
            with self._cond:
                self._cond.notify_all()

    def _task_metrics(self, t: _Task) -> dict[str, Any]:
        """The TaskStatus ``metrics`` view: per-task registry readout."""
        tid = t.spec.task_id
        ten = t.spec.tenant
        lag = obsmetrics.REGISTRY.histogram(
            "verify_lag_seconds", "move-landed -> verified delay",
            ("task",), scale=1e-5)
        return {
            "chunks": self._m_chunks.value(tenant=ten, task=tid),
            "bytes": self._m_bytes.value(tenant=ten, task=tid),
            "wire_p50_s": round(self._m_wire.quantile(0.5, task=tid), 6),
            "wire_p99_s": round(self._m_wire.quantile(0.99, task=tid), 6),
            "verify_lag_p50_s": round(lag.quantile(0.5, task=tid), 6),
            "verify_lag_p99_s": round(lag.quantile(0.99, task=tid), 6),
            "faults": {
                kind: self._m_faults.value(tenant=ten, task=tid, kind=kind)
                for kind in ("corruption", "outage", "generic", "mover_death",
                             "stale_index")
            },
            "spans": len(self.tracer.spans(tid)),
        }

    def _snapshot(self, t: _Task) -> TaskStatus:
        metrics_view = self._task_metrics(t)
        with t.lock:
            return TaskStatus(
                task_id=t.spec.task_id,
                tenant=t.spec.tenant,
                label=t.spec.label,
                state=t.state,
                error=t.error or t.failed_error,
                n_files=t.spec.n_files,
                bytes_total=t.bytes_total,
                bytes_done=t.bytes_done,
                chunks_total=t.chunks_total,
                chunks_done=t.chunks_done,
                resumed_chunks=t.resumed_chunks,
                retries=t.retries,
                movers=t.target_movers if t.state == tk.ACTIVE else 0,
                submitted_s=t.spec.submitted_s,
                started_s=t.started_s,
                finished_s=t.finished_s,
                item_reports=t.item_reports,
                refetches=t.refetches,
                outages=t.outages,
                mover_deaths=t.mover_deaths,
                failovers=t.failovers,
                scrub_repairs=t.scrub_repairs,
                fault=t.fault,
                tuning=t.tuning,
                replans=t.replans,
                chunk_bytes_current=t.chunk_bytes_now,
                stripes=self.config.stripes,
                striped_chunks=t.striped_chunks,
                chunks_deduped=t.chunks_deduped,
                wire_bytes_saved=t.wire_bytes_saved,
                dedup_demoted=t.dedup_demoted,
                pipeline=self.config.pipeline,
                cksum_seconds=round(t.cksum_s, 6),
                cksum_lag_s=round(t.cksum_lag_s, 6),
                metrics=metrics_view,
            )
