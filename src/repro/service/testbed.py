"""Virtual-time service testbed — the calibrated-simulator backend.

Runs the *same* batching (service.batcher), tenant-fair activation and mover
allocation (service.scheduler) as the real TransferService, but executes
tasks in virtual time against the calibrated WAN model (core.simulator)
instead of moving real bytes. This is how service-level questions — aggregate
Gb/s and p50/p99 task latency under mixed multi-tenant load, policy A vs
policy B — are answered at testbed scale (terabyte files, 100 Gb/s WAN)
without a testbed.

Fluid model: each ACTIVE task drains at the steady-state rate the calibrated
simulator predicts for its (files, chunking, movers) configuration; the WAN
cap is enforced max-min fair across active tasks; allocations are recomputed
at every arrival/activation/completion. Chunk-level transients inside one
task (pipelining warm-up, checksum tails) are already folded into the
predicted rate because predictions come from the event-stepped per-chunk
simulator.

Fault campaigns (``run_load(scenario=..., seed=...)``) execute the same
``repro.faults`` scenarios the real engine runs, translated to fluid-model
equivalents:

  * corruption at ``bytes_per_error`` -> seeded Poisson draw of corrupt-chunk
    events per task, each costing one chunk re-move (extra bytes on the
    task's remaining counter — the chunk-granular re-fetch cost);
  * ``kill_movers`` -> the global mover budget shrinks when total progress
    crosses ``kill_at_frac`` (dead movers are not replaced at testbed scale);
  * outage windows  -> every active task's rate is zero for ``outage_s``
    virtual seconds once progress crosses ``outage_at_frac``;
  * ``torn_journal`` has no fluid equivalent (journals are a real-engine
    artifact) and is a no-op here.

The injected totals are accounted in ``LoadReport.faults`` so chaos sweeps
can report goodput retention and retry amplification against the clean run.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import numpy as np

from repro.core.scheduler import TransferRequest
from repro.core.simulator import ALCF, DEFAULT_LINK, NERSC, LinkConfig, SiteConfig
from repro.core.vclock import VirtualClock, Window
from repro.faults.scenarios import Scenario
from repro.service.batcher import BatchConfig, Batcher
from repro.service.scheduler import (
    DEFAULT_QUOTA,
    ActivationIndex,
    AllocationEngine,
    TenantQuota,
)
from repro.service.task import TransferItem


@dataclasses.dataclass(frozen=True)
class Submission:
    """One client request: a set of files submitted at ``time_s``."""

    time_s: float
    tenant: str
    file_bytes: tuple[int, ...]
    label: str = ""


@dataclasses.dataclass
class SimTask:
    task_id: str
    tenant: str
    label: str
    file_bytes: tuple[int, ...]
    chunk_bytes: int | None
    submit_s: float
    seq: int
    start_s: float | None = None
    done_s: float | None = None
    movers: int = 0
    remaining_bytes: float = 0.0
    rate_gbps: float = 0.0

    @property
    def total_bytes(self) -> int:
        return sum(self.file_bytes)

    @property
    def latency_s(self) -> float:
        assert self.done_s is not None
        return self.done_s - self.submit_s

    @property
    def wait_s(self) -> float:
        assert self.start_s is not None
        return self.start_s - self.submit_s


@dataclasses.dataclass
class FaultLog:
    """Faults injected into one virtual-time run (fluid-model accounting)."""

    corruptions: int = 0          # corrupt-chunk events drawn across tasks
    re_moved_bytes: float = 0.0   # extra bytes moved to heal them
    mover_kills: int = 0
    outage_s: float = 0.0         # virtual seconds of rate-zero window


@dataclasses.dataclass
class LoadReport:
    policy: str
    tasks: list[SimTask]
    makespan_s: float
    aggregate_gbps: float
    scenario: str = "clean"
    faults: FaultLog = dataclasses.field(default_factory=FaultLog)
    goodput_bytes: float = 0.0    # client-useful bytes (sum of task sizes)
    moved_bytes: float = 0.0      # bytes actually moved (goodput + re-moves)

    @property
    def retry_amplification(self) -> float:
        """moved/goodput — 1.0 means no byte was moved twice."""
        return self.moved_bytes / self.goodput_bytes if self.goodput_bytes else 1.0

    def latencies(self, *, large_bytes: int | None = None) -> list[float]:
        sel = self.tasks
        if large_bytes is not None:
            sel = [t for t in sel if max(t.file_bytes) >= large_bytes]
        return sorted(t.latency_s for t in sel)

    def percentile(self, q: float, **kw) -> float:
        lat = self.latencies(**kw)
        if not lat:
            return 0.0
        idx = min(len(lat) - 1, max(0, math.ceil(q / 100.0 * len(lat)) - 1))
        return lat[idx]

    @property
    def p50_s(self) -> float:
        return self.percentile(50)

    @property
    def p99_s(self) -> float:
        return self.percentile(99)


def run_load(
    submissions: Sequence[Submission],
    *,
    policy: str = "marginal",
    mover_budget: int = 64,
    max_concurrent: int = 16,
    chunk_bytes: int | None = 500 * 1000 * 1000,
    src: SiteConfig = ALCF,
    dst: SiteConfig = NERSC,
    link: LinkConfig = DEFAULT_LINK,
    batch: BatchConfig | None = None,
    quotas: dict[str, TenantQuota] | None = None,
    default_quota: TenantQuota = DEFAULT_QUOTA,
    alloc_step: int = 4,
    integrity: bool = True,
    scenario: Scenario | None = None,
    seed: int = 0,
    tracer=None,
) -> LoadReport:
    """Drive the service scheduling stack over a workload in virtual time.

    ``tracer`` (an ``obs.trace.Tracer``) receives one deterministic span set
    per task — queue wait, fluid drain, outage stalls — stamped with VIRTUAL
    timestamps, so two same-seed runs export byte-identical traces.
    """
    if max_concurrent > mover_budget:
        raise ValueError("max_concurrent must be <= mover_budget")
    engine = AllocationEngine(
        policy=policy, mover_budget=mover_budget, src=src, dst=dst, link=link,
        step=alloc_step, quotas=quotas, default_quota=default_quota,
    )
    batcher = Batcher(batch)

    # ---- batch every submission into tasks (the service's submit() path)
    tasks: list[SimTask] = []
    for sub in sorted(submissions, key=lambda s: s.time_s):
        items = [TransferItem(f"f{i}", f"f{i}", nb) for i, nb in enumerate(sub.file_bytes)]
        for group in batcher.split(items):
            sizes = tuple(it.nbytes for it in group)
            tasks.append(SimTask(
                task_id=f"task-{len(tasks):09d}-{sub.tenant}",
                tenant=sub.tenant,
                label=sub.label,
                file_bytes=sizes,
                chunk_bytes=chunk_bytes,
                submit_s=sub.time_s,
                seq=len(tasks),
                remaining_bytes=float(sum(sizes)),
            ))

    # ---- fault campaign: seeded fluid-model realisation
    flog = FaultLog()
    goodput_bytes = float(sum(t.total_bytes for t in tasks))
    if scenario is not None and scenario.bytes_per_error is not None:
        rng = np.random.default_rng(seed)
        for task in tasks:
            n = int(rng.poisson(task.total_bytes / scenario.bytes_per_error))
            if n:
                eff_chunk = min(task.chunk_bytes or task.total_bytes, task.total_bytes)
                extra = float(min(n * eff_chunk, 4 * task.total_bytes))
                task.remaining_bytes += extra     # chunk-granular re-fetch cost
                flog.corruptions += n
                flog.re_moved_bytes += extra
    grand_total = float(sum(t.remaining_bytes for t in tasks))
    kill_at = outage_at = None
    if scenario is not None and scenario.kill_movers > 0:
        kill_at = scenario.kill_at_frac * grand_total
    if scenario is not None and scenario.outage_at_frac is not None:
        outage_at = scenario.outage_at_frac * grand_total
    outage_win: Window | None = None
    outage_log: list[tuple[float, float]] = []   # closed windows, for spans
    moved_bytes = 0.0

    # heap-indexed pending set (same policy as the real scheduler): each
    # reschedule costs O(decisions log tenants), not a scan of every queued
    # task — the difference between 10^3-task and 10^6-task workloads here
    pending: dict[str, SimTask] = {}
    activation = ActivationIndex()
    active: list[SimTask] = []
    finished: list[SimTask] = []
    arrivals = sorted(tasks, key=lambda t: (t.submit_s, t.seq))
    ai = 0
    clock = VirtualClock(guard=20 * len(tasks) + 1000, label="testbed")

    def request_of(task: SimTask) -> TransferRequest:
        return TransferRequest(
            name=task.task_id, src=src, dst=dst,
            file_bytes=task.file_bytes, chunk_bytes=task.chunk_bytes,
            integrity=integrity,
        )

    def reschedule() -> None:
        # activation (tenant-fair), then mover allocation + fluid rates
        free = max_concurrent - len(active)
        if free > 0 and pending:
            chosen = activation.select(
                free, quotas=quotas, default_quota=default_quota,
                validate=lambda tid: tid in pending,
            )
            for tid in chosen:
                task = pending.pop(tid)
                task.start_s = clock.now
                active.append(task)
        if not active:
            return
        movers = engine.allocate([(a.task_id, a.tenant, request_of(a)) for a in active])
        for a in active:
            a.movers = max(1, movers.get(a.task_id, 1))
            secs = engine.predict_seconds(request_of(a), a.movers)
            a.rate_gbps = a.total_bytes * 8 / 1e9 / secs if secs > 0 else float("inf")
        # WAN is shared across tasks: max-min fair clamp (progressive filling)
        cap = link.wan_gbps
        todo = sorted(active, key=lambda a: a.rate_gbps)
        n_left = len(todo)
        for a in todo:
            share = cap / n_left
            got = min(a.rate_gbps, share)
            a.rate_gbps = got
            cap -= got
            n_left -= 1

    while ai < len(arrivals) or pending or active:
        # admit all submissions at the current time
        moved = False
        while ai < len(arrivals) and arrivals[ai].submit_s <= clock.now + 1e-12:
            task = arrivals[ai]
            pending[task.task_id] = task
            activation.add(task.seq, task.task_id, task.tenant)
            ai += 1
            moved = True
        if moved or active or pending:
            reschedule()
        # endpoint outage window: every active task's rate is zero
        in_outage = outage_win is not None and outage_win.contains(clock.now)
        if in_outage:
            for a in active:
                a.rate_gbps = 0.0
        agg_Bps = sum(a.rate_gbps for a in active) * 1e9 / 8
        # next event: earliest completion vs next arrival vs fault events
        cands = [
            a.remaining_bytes * 8 / 1e9 / a.rate_gbps
            for a in active if a.rate_gbps > 0
        ]
        if ai < len(arrivals):
            cands.append(arrivals[ai].submit_s - clock.now)
        if in_outage:
            cands.append(outage_win.until_end(clock.now))
        for trigger in (kill_at, outage_at):
            if trigger is not None and agg_Bps > 0 and moved_bytes < trigger:
                cands.append((trigger - moved_bytes) / agg_Bps)
        dt = clock.tick(*cands)
        for a in active:
            a.remaining_bytes -= a.rate_gbps * 1e9 / 8 * dt
        moved_bytes += agg_Bps * dt
        # fault triggers crossed by this step
        if kill_at is not None and moved_bytes >= kill_at - 1e-6:
            engine.mover_budget = max(1, engine.mover_budget - scenario.kill_movers)
            flog.mover_kills = scenario.kill_movers
            kill_at = None
        if outage_at is not None and moved_bytes >= outage_at - 1e-6:
            outage_win = Window(clock.now, scenario.outage_s)
            flog.outage_s = scenario.outage_s
            outage_at = None
        if outage_win is not None and clock.now >= outage_win.end - 1e-12:
            outage_log.append((outage_win.start, outage_win.end))
            outage_win = None
        done_now = [a for a in active if a.remaining_bytes <= 1e-6]
        for a in done_now:
            a.done_s = clock.now
            a.remaining_bytes = 0.0
            active.remove(a)
            activation.active_delta(a.tenant, -1)
            finished.append(a)

    if outage_win is not None:
        outage_log.append((outage_win.start, min(outage_win.end, clock.now)))

    # ---- deterministic trace emission (virtual timestamps, seq order)
    if tracer is not None:
        for t in sorted(finished, key=lambda t: t.seq):
            end = t.done_s if t.done_s is not None else clock.now
            start = t.start_s if t.start_s is not None else end
            tracer.add(
                "queue_wait", "queue", t.submit_s, start,
                task=t.task_id, lane="scheduler", tenant=t.tenant,
            )
            tracer.add(
                "drain", "wire", start, end, task=t.task_id, lane="fluid",
                tenant=t.tenant, bytes=t.total_bytes,
            )
            for (o0, o1) in outage_log:
                lo, hi = max(o0, start), min(o1, end)
                if hi > lo:
                    tracer.add(
                        "outage", "stall", lo, hi, task=t.task_id,
                        lane="fluid", kind="outage",
                    )
            tracer.add(
                "task", "task", t.submit_s, end, task=t.task_id,
                tenant=t.tenant, state="SUCCEEDED",
            )

    total_bytes = sum(t.total_bytes for t in tasks)
    t0 = min((t.submit_s for t in tasks), default=0.0)
    makespan = max((t.done_s or 0.0 for t in tasks), default=0.0) - t0
    return LoadReport(
        policy=policy,
        tasks=finished,
        makespan_s=makespan,
        aggregate_gbps=total_bytes * 8 / 1e9 / makespan if makespan > 0 else 0.0,
        scenario=scenario.name if scenario is not None else "clean",
        faults=flog,
        goodput_bytes=goodput_bytes,
        moved_bytes=moved_bytes,
    )


# ---------------------------------------------------------------------------
# canonical workloads
# ---------------------------------------------------------------------------
def mixed_workload(
    *,
    n_small: int = 1000,
    small_bytes: int = 100 * 1000 * 1000,
    n_large: int = 4,
    large_bytes: int = 1_000_000_000_000,
    tenants: int = 4,
) -> list[Submission]:
    """The ISSUE's mixed workload: many small files + a few terabyte files,
    spread round-robin over tenants, all submitted at t=0."""
    subs: list[Submission] = []
    per = max(1, n_small // max(1, tenants))
    for k in range(tenants):
        lo, hi = k * per, min(n_small, (k + 1) * per) if k < tenants - 1 else n_small
        if hi > lo:
            subs.append(Submission(
                0.0, f"tenant{k}", tuple([small_bytes] * (hi - lo)), label="small",
            ))
    for j in range(n_large):
        subs.append(Submission(
            0.0, f"tenant{j % max(1, tenants)}", (large_bytes,), label="large",
        ))
    return subs
