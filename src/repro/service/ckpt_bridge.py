"""Checkpoint writes as transfer-service tasks.

``repro.ckpt.save_checkpoint`` drives its own movers synchronously. This
bridge instead *submits* the checkpoint's leaves to a TransferService — the
write becomes one async task competing (fairly) with every other tenant's
traffic, scheduled under the global mover budget, journaled, and
integrity-fingerprinted by the service's movers. The resulting directory is
byte- and manifest-compatible with ``repro.ckpt.restore_checkpoint``.

The leaf arrays are in-memory (ephemeral) sources: if the service dies before
the task completes, recovery marks the task FAILED, the ``.tmp`` directory
keeps its journaled chunks, and a re-submission after restart skips every
chunk that already landed (the destination files and service journals are
both keyed by the same deterministic chunk plan).

Delta checkpoints (``delta=True``): successive saves of a training state
differ by a few percent, yet every save re-moves every byte. The previous
save's MANIFEST.json already catalogs each leaf's chunks with their
merge-law digests — it IS a content index of that directory. Seeding the
service's chunk index from it and submitting with ``dedup="on"`` turns the
save into a delta: unchanged chunks are satisfied by a local copy from the
previous save's files, only changed chunks ride the wire, and the landed
directory stays byte- and manifest-compatible with a full save (restore
cannot tell the difference).
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Any

import numpy as np

from repro.cas import seed_index_from_manifest
from repro.ckpt.checkpoint import SaveReport, _flatten
from repro.obs.clock import mono_s, wall_s
from repro.service.task import SUCCEEDED, TaskStatus


@dataclasses.dataclass
class CheckpointSubmission:
    """Handle for an in-flight checkpoint-save task."""

    service: Any
    task_id: str
    step: int
    tmp_dir: str
    final_dir: str
    leaf_meta: list[tuple[str, tuple[int, ...], str]]   # (key, shape, dtype)
    submitted_s: float          # wall-clock timestamp (display only)
    t0_mono: float = 0.0        # monotonic mark: elapsed-time math only —
    #                             wall clock steps (NTP slew) must not be
    #                             able to produce a negative save duration

    def status(self) -> TaskStatus:
        return self.service.status(self.task_id)

    def wait(self, timeout: float | None = None) -> SaveReport:
        """Block until the save task finishes; finalize MANIFEST + rename."""
        st = self.service.wait(self.task_id, timeout)
        if st.state != SUCCEEDED:
            raise RuntimeError(
                f"checkpoint task {self.task_id} ended {st.state}: {st.error}"
            )
        manifest: dict[str, Any] = {"step": self.step, "process": 0, "leaves": {}}
        total = 0
        for (key, shape, dtype), rep in zip(self.leaf_meta, st.item_reports):
            manifest["leaves"][key] = {
                "shape": list(shape),
                "dtype": dtype,
                "nbytes": rep.nbytes,
                "file": os.path.basename(rep.dst),
                "digest": rep.digest_hex,
                "chunk_bytes": rep.chunk_bytes,
                "chunks": [dict(c) for c in rep.chunks],
            }
            total += rep.nbytes
        with open(os.path.join(self.tmp_dir, "MANIFEST.json"), "w") as fh:
            json.dump(manifest, fh, indent=1, sort_keys=True)
        if os.path.exists(self.final_dir):
            import shutil

            shutil.rmtree(self.final_dir)
        os.replace(self.tmp_dir, self.final_dir)
        return SaveReport(
            step=self.step,
            path=self.final_dir,
            total_bytes=total,
            seconds=mono_s() - self.t0_mono,
            n_leaves=len(self.leaf_meta),
            resumed_chunks=st.resumed_chunks,
        )


def _previous_save(root: str, step: int) -> tuple[str, dict] | None:
    """The newest completed save below ``step``: (dir, manifest) or None."""
    best: tuple[int, str] | None = None
    try:
        names = os.listdir(root)
    except OSError:
        return None
    for name in names:
        if not name.startswith("step_") or name.endswith(".tmp"):
            continue
        try:
            s = int(name[len("step_"):])
        except ValueError:
            continue
        if s < step and (best is None or s > best[0]):
            best = (s, os.path.join(root, name))
    if best is None:
        return None
    try:
        with open(os.path.join(best[1], "MANIFEST.json")) as fh:
            return best[1], json.load(fh)
    except (OSError, ValueError):
        return None


def submit_checkpoint(
    service,
    root: str | os.PathLike,
    step: int,
    tree: Any,
    *,
    tenant: str = "ckpt",
    chunk_bytes: int | None = None,
    delta: bool = False,
) -> CheckpointSubmission:
    """Submit one checkpoint save as a single service task; returns a handle.

    The caller keeps training while the service's movers drain the task; call
    ``.wait()`` (or poll ``.status()``) before relying on the checkpoint.

    ``delta=True`` fingerprints this save against the newest previous save
    under ``root``: the previous MANIFEST seeds the service's chunk index and
    the task submits with ``dedup="on"``, so only changed chunks are moved
    (unchanged ones are locally copied from the previous save's files). The
    chunk size is pinned to the previous save's unless the caller overrides
    it — dedup matches whole chunks, so boundaries must line up. With no
    previous save, delta degrades to a normal full save.
    """
    final = os.path.join(str(root), f"step_{step:08d}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)

    dedup: str | None = None
    if delta:
        prev = _previous_save(str(root), step)
        if prev is not None:
            prev_dir, manifest = prev
            seed_index_from_manifest(service.cas_index(), manifest, prev_dir)
            dedup = "on"
            if chunk_bytes is None:
                # Leaves smaller than the plan's chunk size record a clamped
                # per-leaf chunk_bytes (== nbytes), so the true plan size is
                # the one multi-chunk leaves agree on; fall back to the max
                # when every leaf fit in a single chunk.
                leaves_meta = manifest.get("leaves", {}).values()
                sizes = {int(lv["chunk_bytes"]) for lv in leaves_meta
                         if lv.get("chunk_bytes") and len(lv.get("chunks", ())) > 1}
                if not sizes:
                    sizes = {int(lv["chunk_bytes"]) for lv in leaves_meta
                             if lv.get("chunk_bytes")}
                if sizes:
                    chunk_bytes = max(sizes)

    leaves = _flatten(tree)
    buffers: list[tuple[np.ndarray, str]] = []
    leaf_meta: list[tuple[str, tuple[int, ...], str]] = []
    for key, arr in leaves.items():
        safe = key.replace("/", "__")
        data = np.ascontiguousarray(arr).view(np.uint8).reshape(-1)
        buffers.append((data, os.path.join(tmp, f"{safe}.bin")))
        leaf_meta.append((key, tuple(arr.shape), str(arr.dtype)))

    task_id = service.submit_buffers(
        buffers, tenant=tenant, label=f"ckpt-step{step}", chunk_bytes=chunk_bytes,
        dedup=dedup,
    )
    return CheckpointSubmission(
        service=service,
        task_id=task_id,
        step=step,
        tmp_dir=tmp,
        final_dir=final,
        leaf_meta=leaf_meta,
        submitted_s=wall_s(),
        t0_mono=mono_s(),
    )
