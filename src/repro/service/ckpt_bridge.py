"""Checkpoint writes as transfer-service tasks.

``repro.ckpt.save_checkpoint`` drives its own movers synchronously. This
bridge instead *submits* the checkpoint's leaves to a TransferService — the
write becomes one async task competing (fairly) with every other tenant's
traffic, scheduled under the global mover budget, journaled, and
integrity-fingerprinted by the service's movers. The resulting directory is
byte- and manifest-compatible with ``repro.ckpt.restore_checkpoint``.

The leaf arrays are in-memory (ephemeral) sources: if the service dies before
the task completes, recovery marks the task FAILED, the ``.tmp`` directory
keeps its journaled chunks, and a re-submission after restart skips every
chunk that already landed (the destination files and service journals are
both keyed by the same deterministic chunk plan).
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Any

import numpy as np

from repro.ckpt.checkpoint import SaveReport, _flatten
from repro.obs.clock import mono_s, wall_s
from repro.service.task import SUCCEEDED, TaskStatus


@dataclasses.dataclass
class CheckpointSubmission:
    """Handle for an in-flight checkpoint-save task."""

    service: Any
    task_id: str
    step: int
    tmp_dir: str
    final_dir: str
    leaf_meta: list[tuple[str, tuple[int, ...], str]]   # (key, shape, dtype)
    submitted_s: float          # wall-clock timestamp (display only)
    t0_mono: float = 0.0        # monotonic mark: elapsed-time math only —
    #                             wall clock steps (NTP slew) must not be
    #                             able to produce a negative save duration

    def status(self) -> TaskStatus:
        return self.service.status(self.task_id)

    def wait(self, timeout: float | None = None) -> SaveReport:
        """Block until the save task finishes; finalize MANIFEST + rename."""
        st = self.service.wait(self.task_id, timeout)
        if st.state != SUCCEEDED:
            raise RuntimeError(
                f"checkpoint task {self.task_id} ended {st.state}: {st.error}"
            )
        manifest: dict[str, Any] = {"step": self.step, "process": 0, "leaves": {}}
        total = 0
        for (key, shape, dtype), rep in zip(self.leaf_meta, st.item_reports):
            manifest["leaves"][key] = {
                "shape": list(shape),
                "dtype": dtype,
                "nbytes": rep.nbytes,
                "file": os.path.basename(rep.dst),
                "digest": rep.digest_hex,
                "chunk_bytes": rep.chunk_bytes,
                "chunks": [dict(c) for c in rep.chunks],
            }
            total += rep.nbytes
        with open(os.path.join(self.tmp_dir, "MANIFEST.json"), "w") as fh:
            json.dump(manifest, fh, indent=1, sort_keys=True)
        if os.path.exists(self.final_dir):
            import shutil

            shutil.rmtree(self.final_dir)
        os.replace(self.tmp_dir, self.final_dir)
        return SaveReport(
            step=self.step,
            path=self.final_dir,
            total_bytes=total,
            seconds=mono_s() - self.t0_mono,
            n_leaves=len(self.leaf_meta),
            resumed_chunks=st.resumed_chunks,
        )


def submit_checkpoint(
    service,
    root: str | os.PathLike,
    step: int,
    tree: Any,
    *,
    tenant: str = "ckpt",
    chunk_bytes: int | None = None,
) -> CheckpointSubmission:
    """Submit one checkpoint save as a single service task; returns a handle.

    The caller keeps training while the service's movers drain the task; call
    ``.wait()`` (or poll ``.status()``) before relying on the checkpoint.
    """
    final = os.path.join(str(root), f"step_{step:08d}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)

    leaves = _flatten(tree)
    buffers: list[tuple[np.ndarray, str]] = []
    leaf_meta: list[tuple[str, tuple[int, ...], str]] = []
    for key, arr in leaves.items():
        safe = key.replace("/", "__")
        data = np.ascontiguousarray(arr).view(np.uint8).reshape(-1)
        buffers.append((data, os.path.join(tmp, f"{safe}.bin")))
        leaf_meta.append((key, tuple(arr.shape), str(arr.dtype)))

    task_id = service.submit_buffers(
        buffers, tenant=tenant, label=f"ckpt-step{step}", chunk_bytes=chunk_bytes,
    )
    return CheckpointSubmission(
        service=service,
        task_id=task_id,
        step=step,
        tmp_dir=tmp,
        final_dir=final,
        leaf_meta=leaf_meta,
        submitted_s=wall_s(),
        t0_mono=mono_s(),
    )
