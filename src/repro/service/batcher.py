"""Request batching — Balsam-style coalescing of small files into one task.

Balsam's Globus plugin batches up to ``transfer_batch_size`` staged files into
a single Globus transfer task so that task-submission overhead (and the
service's per-task bookkeeping) is amortized over many files. Terabyte-scale
files go the other way: each becomes its *own* task so the chunked movers and
the marginal-benefit allocator can spread a whole mover share across it.

The Batcher is pure policy (no threads): ``split`` batches one request's
items; ``add``/``flush`` support streaming accumulation per tenant.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence

from repro.core.chunker import MiB
from repro.service.task import TransferItem


@dataclasses.dataclass(frozen=True)
class BatchConfig:
    direct_bytes: int = 512 * MiB    # >= this: route straight to a chunked task
    batch_files: int = 64            # max small files coalesced into one task
    batch_bytes: int = 4_000 * MiB   # max total bytes of one coalesced task


class Batcher:
    """Coalesce small items into batched tasks; route big items directly."""

    def __init__(self, config: BatchConfig | None = None):
        self.config = config or BatchConfig()
        self._staged: dict[str, list[TransferItem]] = {}

    # -- one-shot: batch the items of a single request ---------------------
    def split(self, items: Sequence[TransferItem]) -> list[list[TransferItem]]:
        """Group one request's items into task-sized groups.

        Large items become singleton groups (dedicated chunked-mover tasks);
        the rest are coalesced FIFO under the file-count and byte caps.
        """
        cfg = self.config
        groups: list[list[TransferItem]] = []
        batch: list[TransferItem] = []
        batch_bytes = 0
        for it in items:
            if it.nbytes >= cfg.direct_bytes:
                groups.append([it])
                continue
            if batch and (
                len(batch) >= cfg.batch_files
                or batch_bytes + it.nbytes > cfg.batch_bytes
            ):
                groups.append(batch)
                batch, batch_bytes = [], 0
            batch.append(it)
            batch_bytes += it.nbytes
        if batch:
            groups.append(batch)
        return groups

    # -- streaming: accumulate across requests, cut when a batch fills -----
    def add(self, tenant: str, items: Iterable[TransferItem]) -> list[list[TransferItem]]:
        """Stage items; return any groups that became ready (full batches and
        all direct-routed large items)."""
        cfg = self.config
        ready: list[list[TransferItem]] = []
        staged = self._staged.setdefault(tenant, [])
        for it in items:
            if it.nbytes >= cfg.direct_bytes:
                ready.append([it])
                continue
            staged.append(it)
            if (
                len(staged) >= cfg.batch_files
                or sum(s.nbytes for s in staged) >= cfg.batch_bytes
            ):
                ready.append(staged[:])
                staged.clear()
        return ready

    def flush(self, tenant: str | None = None) -> list[list[TransferItem]]:
        """Cut all partially-filled batches (for one tenant, or all)."""
        tenants = [tenant] if tenant is not None else list(self._staged)
        out = []
        for t in tenants:
            staged = self._staged.get(t) or []
            if staged:
                out.append(staged[:])
                staged.clear()
        return out

    def staged_count(self, tenant: str) -> int:
        return len(self._staged.get(tenant, ()))
