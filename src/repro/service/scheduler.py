"""Service-level scheduling: tenant-fair activation + mover allocation.

Two decisions, both shared by the real (wall-clock) service and the
virtual-time testbed:

  1. *Which pending tasks go ACTIVE* — bounded by the global concurrent-task
     cap and per-tenant quotas, selected round-robin by tenant load so a
     tenant with one task is not starved behind another tenant's backlog
     (max-min fairness over task slots).

  2. *How many movers each ACTIVE task gets* — delegated to the chunk-aware
     allocator (core.scheduler): "fair", "file_bound" (the pre-chunking
     baseline), or "marginal" (greedy water-filling on simulated marginal
     throughput gain). Predictions are memoized here because the service
     reallocates on every active-set change over mostly-identical requests.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Callable, Sequence

from repro.core.scheduler import TransferRequest, allocate
from repro.core.simulator import ALCF, DEFAULT_LINK, NERSC, LinkConfig, SiteConfig


@dataclasses.dataclass(frozen=True)
class TenantQuota:
    """Per-tenant rate limits. None = unlimited (global caps still apply)."""

    max_active: int | None = None    # concurrent ACTIVE tasks
    max_movers: int | None = None    # movers summed over the tenant's tasks


DEFAULT_QUOTA = TenantQuota()


# ---------------------------------------------------------------------------
# Activation: tenant-fair selection of pending tasks
# ---------------------------------------------------------------------------
def select_activations(
    pending: Sequence[tuple[int, str, str]],    # (submit_seq, task_id, tenant)
    active_by_tenant: dict[str, int],
    *,
    free_slots: int,
    quotas: dict[str, TenantQuota] | None = None,
    default_quota: TenantQuota = DEFAULT_QUOTA,
    served_by_tenant: dict[str, int] | None = None,
) -> list[str]:
    """Pick up to ``free_slots`` pending task_ids, fairly across tenants.

    Stride-style fairness: each tenant's priority is (currently ACTIVE +
    historically served) task count, so a tenant submitting one task is not
    starved behind another tenant's backlog even when only one slot frees at
    a time. FIFO within a tenant; ``max_active`` quotas are respected; the
    quota check uses ACTIVE counts only.
    """
    quotas = quotas or {}
    served = dict(served_by_tenant or {})     # local copy: stay side-effect free
    by_tenant: dict[str, list[tuple[int, str]]] = {}
    for seq, task_id, tenant in sorted(pending):
        by_tenant.setdefault(tenant, []).append((seq, task_id))
    active = dict(active_by_tenant)
    chosen: list[str] = []
    while len(chosen) < free_slots:
        best_tenant, best_key = None, None
        for tenant, queue in by_tenant.items():
            if not queue:
                continue
            quota = quotas.get(tenant, default_quota)
            if quota.max_active is not None and active.get(tenant, 0) >= quota.max_active:
                continue
            key = (active.get(tenant, 0) + served.get(tenant, 0), queue[0][0])
            if best_key is None or key < best_key:
                best_tenant, best_key = tenant, key
        if best_tenant is None:
            break
        _seq, task_id = by_tenant[best_tenant].pop(0)
        chosen.append(task_id)
        active[best_tenant] = active.get(best_tenant, 0) + 1
        served[best_tenant] = served.get(best_tenant, 0) + 1
    return chosen


# ---------------------------------------------------------------------------
# Indexed activation: the same policy at O(log n) per decision
# ---------------------------------------------------------------------------
class ActivationIndex:
    """Incremental heap index implementing select_activations' policy.

    ``select_activations`` rescans every pending task per scheduler pass —
    fine at 10^3 tasks, fatal at 10^6. This index keeps a heap of
    ``(submit_seq, task_id)`` per tenant plus a tenant-level heap keyed by
    the same stride-fairness priority ``(active + served, head_seq)``, so
    each activation decision is O(log tenants) regardless of how many tasks
    are queued. Head seqs are globally unique (submission order), so the
    greedy argmin here selects exactly what the scan-based function would —
    tests assert the equivalence on randomized scenarios.

    Staleness is handled by lazy deletion: every mutation bumps the
    tenant's version and pushes a fresh heap entry; entries with old
    versions are discarded when popped. Tasks that left PENDING without
    being selected (canceled, paused) are dropped via the ``validate``
    callback at pop time — callers re-``add`` a task when it becomes
    PENDING again (resume). Not thread-safe: callers hold their own lock
    (the service's scheduler lock / the testbed is single-threaded).
    """

    def __init__(self, served: dict[str, int] | None = None):
        self._queues: dict[str, list[tuple[int, str]]] = {}
        self._tenant_heap: list[tuple[int, int, str, int]] = []
        self._version: dict[str, int] = {}
        self._active: dict[str, int] = {}
        # shared with the caller so historical fairness survives the index
        # (the service exposes it as served_by_tenant)
        self._served = served if served is not None else {}

    def _load(self, tenant: str) -> int:
        return self._active.get(tenant, 0) + self._served.get(tenant, 0)

    def _push_tenant(self, tenant: str) -> None:
        """Invalidate the tenant's live heap entry; push a fresh one if it
        still has queued tasks."""
        v = self._version.get(tenant, 0) + 1
        self._version[tenant] = v
        queue = self._queues.get(tenant)
        if queue:
            heapq.heappush(
                self._tenant_heap,
                (self._load(tenant), queue[0][0], tenant, v),
            )

    def add(self, seq: int, task_id: str, tenant: str) -> None:
        """Register a PENDING task (call again after a resume re-pends it)."""
        heapq.heappush(self._queues.setdefault(tenant, []), (seq, task_id))
        self._push_tenant(tenant)

    def active_delta(self, tenant: str, delta: int) -> None:
        """Adjust a tenant's ACTIVE count (selection already counts +1; call
        with -1 when a task leaves ACTIVE)."""
        self._active[tenant] = self._active.get(tenant, 0) + delta
        self._push_tenant(tenant)

    def active_count(self, tenant: str) -> int:
        return self._active.get(tenant, 0)

    def pending_count(self) -> int:
        """Upper bound on queued tasks (stale entries linger until popped)."""
        return sum(len(q) for q in self._queues.values())

    def select(
        self,
        free_slots: int,
        *,
        quotas: dict[str, TenantQuota] | None = None,
        default_quota: TenantQuota = DEFAULT_QUOTA,
        validate: Callable[[str], bool] | None = None,
    ) -> list[str]:
        """Pick up to ``free_slots`` task_ids; same contract as
        select_activations. Selected tasks are counted ACTIVE and served
        immediately (mirroring what the caller is about to do)."""
        quotas = quotas or {}
        chosen: list[str] = []
        seen: set[str] = set()      # a task must not be chosen twice even if
        blocked: list[str] = []     # pause/resume left duplicate entries
        while len(chosen) < free_slots and self._tenant_heap:
            load, head_seq, tenant, ver = heapq.heappop(self._tenant_heap)
            if ver != self._version.get(tenant):
                continue                      # stale: a fresher entry exists
            queue = self._queues.get(tenant)
            while queue:
                _seq, tid = queue[0]
                if tid in seen or (validate is not None and not validate(tid)):
                    heapq.heappop(queue)      # lazy deletion at pop time
                    continue
                break
            if not queue:
                self._version[tenant] = ver + 1   # nothing left: no re-push
                continue
            if (self._load(tenant), queue[0][0]) != (load, head_seq):
                self._push_tenant(tenant)     # key drifted: re-enter fresh
                continue
            quota = quotas.get(tenant, default_quota)
            if (quota.max_active is not None
                    and self._active.get(tenant, 0) >= quota.max_active):
                blocked.append(tenant)        # re-pushed after the loop so a
                continue                      # full-quota tenant can't spin us
            _seq, tid = heapq.heappop(queue)
            seen.add(tid)
            chosen.append(tid)
            self._active[tenant] = self._active.get(tenant, 0) + 1
            self._served[tenant] = self._served.get(tenant, 0) + 1
            self._push_tenant(tenant)
        for tenant in blocked:
            self._push_tenant(tenant)
        return chosen


# ---------------------------------------------------------------------------
# Allocation: movers across active tasks, with memoized predictions
# ---------------------------------------------------------------------------
class AllocationEngine:
    """Memoizing wrapper around core.scheduler.allocate for one service."""

    def __init__(
        self,
        *,
        policy: str = "marginal",
        mover_budget: int = 64,
        src: SiteConfig = ALCF,
        dst: SiteConfig = NERSC,
        link: LinkConfig = DEFAULT_LINK,
        step: int = 4,
        quotas: dict[str, TenantQuota] | None = None,
        default_quota: TenantQuota = DEFAULT_QUOTA,
    ):
        self.policy = policy
        self.mover_budget = mover_budget
        self.src, self.dst, self.link = src, dst, link
        self.step = step
        self.quotas = quotas or {}
        self.default_quota = default_quota
        self._cache: dict[tuple, float] = {}

    # requests are rebuilt each round from stable task signatures, so the
    # cache key is the request content, not object identity.
    def _predict(self, req: TransferRequest, movers: int) -> float:
        key = (req.src, req.dst, req.file_bytes, req.chunk_bytes,
               req.integrity, req.stripe_count, movers)
        t = self._cache.get(key)
        if t is None:
            from repro.core.scheduler import _predict
            t = _predict(req, movers, self.link)
            self._cache[key] = t
        return t

    def predict_seconds(self, req: TransferRequest, movers: int) -> float:
        return self._predict(req, movers)

    def allocate(
        self, tasks: Sequence[tuple[str, str, TransferRequest]]
    ) -> dict[str, int]:
        """(task_id, tenant, request) -> task_id -> movers.

        Applies the configured policy under the global budget, then clamps
        each tenant to its ``max_movers`` quota (freed movers are handed to
        unclamped tenants in allocation order).
        """
        if not tasks:
            return {}
        reqs = [req for _tid, _ten, req in tasks]
        allocs = allocate(
            reqs,
            total_movers=self.mover_budget,
            policy=self.policy,
            link=self.link,
            step=self.step,
            predict=self._predict,
        )
        movers = {tid: a.movers for (tid, _ten, _req), a in zip(tasks, allocs)}

        # per-tenant mover caps: proportional clamp with a floor of 1
        by_tenant: dict[str, list[str]] = {}
        tenant_of: dict[str, str] = {}
        for tid, tenant, _req in tasks:
            by_tenant.setdefault(tenant, []).append(tid)
            tenant_of[tid] = tenant
        freed = 0
        uncapped: list[str] = []
        for tenant, tids in by_tenant.items():
            quota = self.quotas.get(tenant, self.default_quota)
            total = sum(movers[t] for t in tids)
            if quota.max_movers is None or total <= quota.max_movers:
                uncapped.extend(tids)
                continue
            scale = quota.max_movers / total
            for t in tids:
                new = max(1, int(movers[t] * scale))
                freed += movers[t] - new
                movers[t] = new
        # hand freed movers to other tasks, re-checking each RECIPIENT
        # tenant's own cap so redistribution never pushes it over quota
        for t in uncapped:
            if freed <= 0:
                break
            tenant = tenant_of[t]
            cap = self.quotas.get(tenant, self.default_quota).max_movers
            if cap is not None:
                total = sum(movers[x] for x in by_tenant[tenant])
                if total >= cap:
                    continue
            movers[t] += 1
            freed -= 1
        return movers
