"""Persistent task store — the service's crash-recoverable source of truth.

Sharded for the million-task control plane. On-disk state under one root:

    <root>/tasks/shard-NNN.log          tenant-hash-sharded task event logs
    <root>/journals/<task_id>.journal   per-task chunk-completion journal
    <root>/tasks.log.migrated           pre-shard log, kept after migration

Each shard log records submissions and every state transition for the
tenants hashed onto it. Like the chunk journal (core.journal) each line is
self-checksummed; replay keeps every verified record (damaged lines in
between are skipped — each record vouches for itself) and truncates the
torn tail after the last verified record per shard before reopening for
append, so recovery never glues a new record onto a half-written line.

Submission order is NOT derived from file order: every submit record
carries its global ``seq`` explicitly, assigned under the same lock hold
that appends the record, so two interleaved submitters can never persist in
one order and number in the other — replay agrees with the live process by
construction. State records for one task always live in that task's shard
(tasks are sharded by tenant), so in-file order is authoritative for them;
when ``n_shards`` changes between incarnations a task's submit and its
newer states can sit in different files, so replay visits orphaned
wider-incarnation shards first and defers any state record seen before its
task's submit until every file has replayed.

Durability model — group commit: appends write+flush under the shard lock,
then wait for an fsync that covers them. Whoever finds the sync slot free
fsyncs ONCE for every record flushed so far (its cohort); concurrent
appenders piggyback on that fsync instead of issuing their own, and bulk
appends (``append_submit_many``) pay one fsync per touched shard for the
whole batch. Every append is still durable before it returns — the batch is
whatever accumulated while the previous fsync was in flight, so flush
latency is bounded by ~2 fsyncs. ``group_commit=False`` restores the legacy
fsync-per-append behaviour (the benchmark baseline).

Background compaction: shards accumulate dead state records forever;
when a shard's append count sufficiently exceeds its live-task count a
daemon thread rewrites it to one combined record per task (submit + last
state, seq preserved), fsyncs the temp file and atomically renames it over
the shard — replay of the compacted shard reconstructs the identical
record set. A crash leaves either the old shard or the new one, never a mix.
"""
from __future__ import annotations

import dataclasses
import glob
import os
import threading
import zlib
from typing import IO

from repro.core.journal import ChunkJournal, checked_line, replay_checked_lines
from repro.service.task import PENDING, STATES, TaskSpec

DEFAULT_SHARDS = 16
# dead records a shard may accumulate before the compactor rewrites it
DEFAULT_COMPACT_SLACK = 4096

# task ids are zero-padded so lexicographic order == submission order; 9
# digits clears the million-task target with three orders of headroom (the
# legacy 06d format wrapped exactly at 10^6 tasks)
ID_WIDTH = 9


def shard_of(tenant: str, n_shards: int) -> int:
    """Stable tenant -> shard mapping (crc32: Python's str hash is salted
    per process, which would scatter a tenant across shards on restart)."""
    return zlib.crc32(tenant.encode("utf-8")) % n_shards


@dataclasses.dataclass
class TaskRecord:
    """Replayed view of one task: spec + last persisted state."""

    seq: int                     # submission order (persisted in the record)
    spec: TaskSpec
    state: str = PENDING
    error: str | None = None


class _Shard:
    """One append log: a write lock plus group-commit sync state."""

    __slots__ = ("path", "lock", "cond", "fh", "written", "synced",
                 "syncing", "appends", "task_ids")

    def __init__(self, path: str):
        self.path = path
        self.lock = threading.Lock()        # serializes write+flush and swap
        self.cond = threading.Condition()   # guards synced/syncing
        self.fh: IO[str] | None = None
        self.written = 0        # records flushed to the OS so far
        self.synced = 0         # records covered by a completed fsync
        self.syncing = False
        self.appends = 0        # records appended since the last compaction
        self.task_ids: set[str] = set()     # tasks homed on this shard


class TaskStore:
    """Sharded, self-checksummed task log + per-task chunk journals."""

    def __init__(
        self,
        root: str | os.PathLike,
        *,
        n_shards: int = DEFAULT_SHARDS,
        group_commit: bool = True,
        compact_slack: int = DEFAULT_COMPACT_SLACK,
        auto_compact: bool = True,
    ):
        if n_shards < 1:
            raise ValueError(f"n_shards must be >= 1, got {n_shards}")
        self.root = str(root)
        self.n_shards = n_shards
        self.group_commit = group_commit
        self.compact_slack = compact_slack
        os.makedirs(os.path.join(self.root, "journals"), exist_ok=True)
        os.makedirs(os.path.join(self.root, "tasks"), exist_ok=True)
        self.log_path = os.path.join(self.root, "tasks.log")   # legacy location
        # _lock guards records / seq counters / id reservations; shard locks
        # guard file appends. Lock order: shard.lock -> self._lock.
        self._lock = threading.Lock()
        self.records: dict[str, TaskRecord] = {}
        self._n_submitted = 0
        self._next_id = 0            # id reservation counter (>= _n_submitted)
        self.torn_tail_bytes = 0     # bytes dropped from crashed appends (all shards)
        self.fsyncs = 0              # fsync calls issued (group-commit visibility)
        self.compactions = 0

        self._shards = [
            _Shard(os.path.join(self.root, "tasks", f"shard-{i:03d}.log"))
            for i in range(n_shards)
        ]
        self._replay_seq = 0         # fallback numbering for legacy records
        # during shard replay only: state records whose task is not known
        # yet (its submit record lives in a shard that replays later —
        # possible whenever n_shards changed between incarnations)
        self._deferred_states: list[dict] | None = None
        if os.path.exists(self.log_path):
            self._migrate_legacy()
        self._replay_shards()
        with self._lock:
            if self.records:
                self._n_submitted = max(r.seq for r in self.records.values()) + 1
                self._next_id = max(
                    self._n_submitted,
                    max((_id_number(tid) for tid in self.records), default=-1) + 1,
                )
        for sh in self._shards:
            sh.fh = open(sh.path, "a", encoding="utf-8")
            sh.written = sh.synced = 0
        self._stop_evt = threading.Event()
        self._compact_evt = threading.Event()
        self._compactor: threading.Thread | None = None
        if auto_compact:
            self._compactor = threading.Thread(
                target=self._compact_loop, name="taskstore-compact", daemon=True
            )
            self._compactor.start()

    # records per submit_batch line: bounds both the line length a torn tail
    # can lose (none of it was acked) and the replay memory per line
    BATCH_LINE_CAP = 512

    # -- replay ------------------------------------------------------------
    def _replay_shards(self) -> None:
        # Shard files beyond n_shards (a previous incarnation ran wider) are
        # still replayed, and replay FIRST: they may hold a task's only
        # submit record while its newer state records live on the re-hashed
        # current shard. Replay order between files is otherwise not
        # authoritative (submits carry seq; a task's states normally share
        # its file), so states that arrive before their task's submit —
        # possible for any n_shards change, not just widening — are
        # deferred and applied once every file has replayed.
        paths = {sh.path for sh in self._shards}
        extra = sorted(
            p for p in glob.glob(os.path.join(self.root, "tasks", "shard-*.log"))
            if p not in paths
        )
        self._deferred_states = []
        for path in extra + [sh.path for sh in self._shards]:
            if not os.path.exists(path):
                continue
            data, valid_end = replay_checked_lines(path, self._apply)
            torn = len(data) - valid_end
            if torn:
                self.torn_tail_bytes += torn
                with open(path, "r+b") as fh:
                    fh.truncate(valid_end)
        deferred, self._deferred_states = self._deferred_states, None
        for body in deferred:
            self._apply_state(body)
        # home every replayed task on its shard (for compaction bookkeeping)
        for tid, rec in self.records.items():
            sh = self._shards[shard_of(rec.spec.tenant, self.n_shards)]
            sh.task_ids.add(tid)
            sh.appends += 1

    def _apply(self, body: dict) -> None:
        kind = body["type"]
        if kind == "submit":
            self._apply_submit(body)
        elif kind == "submit_batch":
            for entry in body["entries"]:
                self._apply_submit(entry)
        elif kind == "state":
            self._apply_state(body)

    def _apply_state(self, body: dict) -> None:
        rec = self.records.get(body.get("task_id"))
        if rec is None:
            # unknown task: during shard replay the submit may simply live
            # in a later-replaying shard — hold the record and retry after
            # all files are in. Outside replay (migration), drop it.
            if self._deferred_states is not None and body.get("task_id"):
                self._deferred_states.append(body)
            return
        if body.get("state") in STATES:
            rec.state = body["state"]
            rec.error = body.get("error")

    def _apply_submit(self, body: dict) -> None:
        spec = TaskSpec.from_json(body["spec"])
        seq = body.get("seq")
        if seq is None:                   # legacy record: file order numbers it
            seq = self._replay_seq
        self._replay_seq = max(self._replay_seq, int(seq) + 1)
        rec = TaskRecord(int(seq), spec)
        if "state" in body and body["state"] in STATES:       # compacted record
            rec.state = body["state"]
            rec.error = body.get("error")
        self.records[spec.task_id] = rec

    def _migrate_legacy(self) -> None:
        """One-time move of a pre-shard ``tasks.log`` into the shard files.

        Replays the legacy log, appends one combined record per task to its
        tenant's shard, fsyncs, then renames the legacy file out of the
        append path. A crash mid-migration re-runs it idempotently (replay
        overwrites by task_id; the rename is the commit point).
        """
        data, valid_end = replay_checked_lines(self.log_path, self._apply)
        self.torn_tail_bytes += len(data) - valid_end
        touched: set[int] = set()
        for tid, rec in sorted(self.records.items(), key=lambda kv: kv[1].seq):
            idx = shard_of(rec.spec.tenant, self.n_shards)
            touched.add(idx)
            with open(self._shards[idx].path, "a", encoding="utf-8") as fh:
                fh.write(checked_line(_combined_body(rec)) + "\n")
                fh.flush()
                os.fsync(fh.fileno())
        self.records.clear()            # shards are authoritative from here
        self._replay_seq = 0
        os.replace(self.log_path, self.log_path + ".migrated")

    # -- appends -----------------------------------------------------------
    def _write_locked(self, sh: _Shard, body: dict, n_records: int = 1) -> int:
        """Append one checked line to a shard (caller holds ``sh.lock``);
        returns the write watermark a commit must cover. ``n_records`` is how
        many task records the line carries (batch lines hold many)."""
        assert sh.fh is not None
        sh.fh.write(checked_line(body) + "\n")
        sh.fh.flush()
        sh.written += 1
        sh.appends += n_records
        return sh.written

    def _commit(self, sh: _Shard, upto: int) -> None:
        """Group commit: return once an fsync covering ``upto`` completed.

        The first waiter to find the sync slot free fsyncs for everyone
        flushed so far; the rest piggyback. A record is never reported
        durable before its bytes are fsynced.
        """
        if not self.group_commit:
            # legacy mode: fsync under the shard write lock, per append
            with sh.lock:
                if sh.fh is not None and sh.synced < upto:
                    os.fsync(sh.fh.fileno())
                    self.fsyncs += 1
                    sh.synced = sh.written
            return
        while True:
            with sh.cond:
                if sh.synced >= upto:
                    return
                if sh.syncing:
                    sh.cond.wait(0.05)
                    continue
                sh.syncing = True
            # target: everything flushed before the fsync starts is covered
            with sh.lock:
                target = sh.written
                fh = sh.fh
            try:
                if fh is not None:
                    os.fsync(fh.fileno())
                    self.fsyncs += 1
            finally:
                with sh.cond:
                    sh.syncing = False
                    sh.synced = max(sh.synced, target)
                    sh.cond.notify_all()

    def append_submit(self, spec: TaskSpec) -> TaskRecord:
        """Persist one submission; seq assignment, the log append and the
        in-memory record commit happen under ONE shard-lock hold, so replay
        order and live order can never disagree."""
        sh = self._shards[shard_of(spec.tenant, self.n_shards)]
        with sh.lock:
            with self._lock:
                seq = self._n_submitted
                self._n_submitted += 1
                self._next_id = max(self._next_id, seq + 1)
                rec = TaskRecord(seq, spec)
                self.records[spec.task_id] = rec
            sh.task_ids.add(spec.task_id)
            upto = self._write_locked(
                sh, {"type": "submit", "seq": seq, "spec": spec.to_json()})
        self._commit(sh, upto)
        self._maybe_compact(sh)
        return rec

    def append_submit_many(self, specs: list[TaskSpec]) -> list[TaskRecord]:
        """Bulk submission: per touched shard, ONE self-checksummed batch
        line (amortizing serialization + checksum over the batch) and ONE
        fsync — the group-commit amortization bulk clients rely on. Seqs are
        assigned in input order and persisted inside each entry, so replay
        reconstructs the exact submission order regardless of how the batch
        interleaved with concurrent single submits on other shards. Nothing
        is acknowledged until every touched shard's fsync covers it; a torn
        batch line on crash loses only unacknowledged submissions.
        """
        recs: list[TaskRecord] = []
        by_shard: dict[int, list[tuple[int, TaskSpec]]] = {}
        with self._lock:
            for spec in specs:
                seq = self._n_submitted
                self._n_submitted += 1
                self._next_id = max(self._next_id, seq + 1)
                rec = TaskRecord(seq, spec)
                self.records[spec.task_id] = rec
                recs.append(rec)
                by_shard.setdefault(
                    shard_of(spec.tenant, self.n_shards), []).append((seq, spec))
        marks: dict[int, int] = {}          # shard idx -> write watermark
        for idx, entries in by_shard.items():
            sh = self._shards[idx]
            with sh.lock:
                for i in range(0, len(entries), self.BATCH_LINE_CAP):
                    part = entries[i:i + self.BATCH_LINE_CAP]
                    marks[idx] = self._write_locked(
                        sh,
                        {"type": "submit_batch",
                         "entries": [{"seq": s, "spec": sp.to_json()}
                                     for s, sp in part]},
                        n_records=len(part))
                sh.task_ids.update(sp.task_id for _s, sp in entries)
        for idx, upto in marks.items():
            self._commit(self._shards[idx], upto)
        for idx in marks:
            self._maybe_compact(self._shards[idx])
        return recs

    def append_state(self, task_id: str, state: str, error: str | None = None) -> None:
        with self._lock:
            rec = self.records.get(task_id)
        if rec is None:
            return
        sh = self._shards[shard_of(rec.spec.tenant, self.n_shards)]
        with sh.lock:
            upto = self._write_locked(
                sh, {"type": "state", "task_id": task_id, "state": state,
                     "error": error})
            # memory commit under the same lock hold as the append: state
            # records replay in file order, which is now also update order
            rec.state = state
            rec.error = error
        self._commit(sh, upto)
        self._maybe_compact(sh)

    # -- compaction --------------------------------------------------------
    def _maybe_compact(self, sh: _Shard) -> None:
        if self._compactor is None:
            return
        with sh.lock:
            needed = self._needs_compact(sh)
        if needed:
            self._compact_evt.set()

    def _needs_compact(self, sh: _Shard) -> bool:
        dead = sh.appends - len(sh.task_ids)
        return dead > self.compact_slack and sh.appends > 2 * len(sh.task_ids)

    def _compact_loop(self) -> None:
        while not self._stop_evt.is_set():
            self._compact_evt.wait(0.5)
            self._compact_evt.clear()
            if self._stop_evt.is_set():
                return
            for sh in self._shards:
                with sh.lock:
                    needed = self._needs_compact(sh)
                if needed:
                    try:
                        self.compact_shard(sh)
                    except Exception:  # noqa: BLE001 — compaction is an
                        pass           # optimization; appends must survive it

    def _quiesce_and_lock(self, sh: _Shard) -> None:
        """Acquire ``sh.lock`` with no group-commit fsync in flight.

        Never waits for ``syncing`` while holding ``sh.lock``: a committer
        claims the sync slot under ``sh.cond`` and then needs ``sh.lock``
        to capture the fd and watermark, so waiting here with the lock held
        would deadlock against it (each side holding what the other needs,
        wedging every later append on the shard). Instead wait first, then
        take the lock and re-check — if a committer claimed the slot in the
        gap, back off and wait again. Once this returns, no committer can
        touch the old fd: claiming the slot is not enough, capturing the fd
        needs the lock we now hold.
        """
        while True:
            with sh.cond:
                while sh.syncing:
                    sh.cond.wait()
            sh.lock.acquire()
            with sh.cond:
                if not sh.syncing:
                    return
            sh.lock.release()

    def compact_shard(self, sh: _Shard) -> dict:
        """Rewrite one shard to combined live records only; atomic replace."""
        self._quiesce_and_lock(sh)      # excludes appends and in-flight fsyncs
        try:
            before = os.path.getsize(sh.path) if os.path.exists(sh.path) else 0
            with self._lock:
                live = sorted(
                    (self.records[tid] for tid in sh.task_ids
                     if tid in self.records),
                    key=lambda r: r.seq,
                )
                lines = [checked_line(_combined_body(rec)) for rec in live]
            tmp = sh.path + ".compact.tmp"
            with open(tmp, "w", encoding="utf-8") as fh:
                for line in lines:
                    fh.write(line + "\n")
                fh.flush()
                os.fsync(fh.fileno())
            if sh.fh is not None:
                sh.fh.close()
            os.replace(tmp, sh.path)
            sh.fh = open(sh.path, "a", encoding="utf-8")
            with sh.cond:
                sh.synced = sh.written      # everything live is in the new file
            sh.appends = len(lines)
            after = os.path.getsize(sh.path)
            self.compactions += 1
        finally:
            sh.lock.release()
        return {"records": len(lines), "bytes_before": before,
                "bytes_after": after}

    def compact(self) -> dict:
        """Force-compact every shard (tests / CLI); returns totals."""
        totals = {"records": 0, "bytes_before": 0, "bytes_after": 0}
        for sh in self._shards:
            out = self.compact_shard(sh)
            for k in totals:
                totals[k] += out[k]
        return totals

    # -- journals ----------------------------------------------------------
    def journal_path(self, task_id: str) -> str:
        return os.path.join(self.root, "journals", f"{task_id}.journal")

    def open_journal(self, task_id: str) -> ChunkJournal:
        return ChunkJournal(self.journal_path(task_id))

    def next_task_id(self, tenant: str) -> str:
        """Mint a unique task id. Each call RESERVES its number (the legacy
        implementation read the submit counter without reserving, so two
        concurrent callers minted the same id and the second submit silently
        overwrote the first's TaskRecord)."""
        with self._lock:
            n = self._next_id
            self._next_id += 1
        return f"task-{n:0{ID_WIDTH}d}-{tenant}"

    def shard_paths(self) -> list[str]:
        return [sh.path for sh in self._shards]

    def close(self) -> None:
        self._stop_evt.set()
        self._compact_evt.set()
        if self._compactor is not None:
            self._compactor.join(timeout=5.0)
        for sh in self._shards:
            # quiesce first: closing under sh.lock alone could yank the fd
            # out from under a committer that captured it and is about to
            # fsync (ValueError mid-shutdown). A committer arriving after
            # the close finds fh=None and skips the fsync.
            self._quiesce_and_lock(sh)
            try:
                if sh.fh is not None:
                    sh.fh.close()
                    sh.fh = None
            finally:
                sh.lock.release()

    def __enter__(self) -> "TaskStore":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def _combined_body(rec: TaskRecord) -> dict:
    """Submit record folding in the last persisted state (compaction and
    migration write these; replay reconstructs the identical TaskRecord)."""
    body = {"type": "submit", "seq": rec.seq, "spec": rec.spec.to_json()}
    if rec.state != PENDING or rec.error is not None:
        body["state"] = rec.state
        body["error"] = rec.error
    return body


def _id_number(task_id: str) -> int:
    """Numeric reservation component of ``task-NNN...-tenant`` ids (used to
    resume the id allocator past every id ever persisted)."""
    try:
        return int(task_id.split("-", 2)[1])
    except (IndexError, ValueError):
        return -1
