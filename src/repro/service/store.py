"""Persistent task store — the service's crash-recoverable source of truth.

Two kinds of on-disk state under one service root:

    <root>/tasks.log                append-only task event log (JSONL)
    <root>/journals/<task_id>.journal   per-task chunk-completion journal

``tasks.log`` records submissions and every state transition. Like the chunk
journal (core.journal) each line is self-checksummed; replay keeps every
verified record (damaged lines in between are skipped — each record vouches
for itself) and truncates the torn tail after the last verified record
before reopening for append, so recovery never glues a new record onto a
half-written line. Replay order reconstructs submission order (used for
FIFO fairness).
"""
from __future__ import annotations

import dataclasses
import json
import os
import threading
from typing import IO

from repro.core.integrity import fingerprint_bytes
from repro.core.journal import ChunkJournal, replay_checked_lines
from repro.service.task import PENDING, STATES, TaskSpec


def _self_check(payload: str) -> str:
    return fingerprint_bytes(payload.encode()).hexdigest()[:16]


@dataclasses.dataclass
class TaskRecord:
    """Replayed view of one task: spec + last persisted state."""

    seq: int                     # submission order
    spec: TaskSpec
    state: str = PENDING
    error: str | None = None


class TaskStore:
    """Append-only, self-checksummed task log + per-task chunk journals."""

    def __init__(self, root: str | os.PathLike):
        self.root = str(root)
        os.makedirs(os.path.join(self.root, "journals"), exist_ok=True)
        self.log_path = os.path.join(self.root, "tasks.log")
        self._lock = threading.Lock()
        self._fh: IO[str] | None = None
        self._n_submitted = 0
        self.records: dict[str, TaskRecord] = {}
        self.torn_tail_bytes = 0          # bytes dropped from a crashed append
        if os.path.exists(self.log_path):
            self._replay()
        self._fh = open(self.log_path, "a", encoding="utf-8")

    # -- replay ------------------------------------------------------------
    def _replay(self) -> None:
        data, valid_end = replay_checked_lines(self.log_path, self._apply)
        self.torn_tail_bytes = len(data) - valid_end
        if self.torn_tail_bytes:
            with open(self.log_path, "r+b") as fh:
                fh.truncate(valid_end)

    def _apply(self, body: dict) -> None:
        kind = body["type"]
        if kind == "submit":
            spec = TaskSpec.from_json(body["spec"])
            self.records[spec.task_id] = TaskRecord(self._n_submitted, spec)
            self._n_submitted += 1
        elif kind == "state":
            rec = self.records.get(body.get("task_id"))
            if rec is not None and body.get("state") in STATES:
                rec.state = body["state"]
                rec.error = body.get("error")

    # -- appends -----------------------------------------------------------
    def _append(self, body: dict) -> None:
        line = json.dumps(
            {"body": body, "check": _self_check(json.dumps(body, sort_keys=True))}
        )
        with self._lock:
            assert self._fh is not None
            self._fh.write(line + "\n")
            self._fh.flush()
            os.fsync(self._fh.fileno())

    def append_submit(self, spec: TaskSpec) -> TaskRecord:
        self._append({"type": "submit", "spec": spec.to_json()})
        with self._lock:
            rec = TaskRecord(self._n_submitted, spec)
            self._n_submitted += 1
            self.records[spec.task_id] = rec
        return rec

    def append_state(self, task_id: str, state: str, error: str | None = None) -> None:
        self._append({"type": "state", "task_id": task_id, "state": state, "error": error})
        with self._lock:
            rec = self.records.get(task_id)
            if rec is not None:
                rec.state = state
                rec.error = error

    # -- journals ----------------------------------------------------------
    def journal_path(self, task_id: str) -> str:
        return os.path.join(self.root, "journals", f"{task_id}.journal")

    def open_journal(self, task_id: str) -> ChunkJournal:
        return ChunkJournal(self.journal_path(task_id))

    def next_task_id(self, tenant: str) -> str:
        with self._lock:
            return f"task-{self._n_submitted:06d}-{tenant}"

    def close(self) -> None:
        with self._lock:
            if self._fh is not None:
                self._fh.close()
                self._fh = None

    def __enter__(self) -> "TaskStore":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
