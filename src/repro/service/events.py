"""Task event stream — progress callbacks for service clients.

Subscribers get every TaskEvent in emission order — globally, across
emitting threads. Emission (seq assignment) happens under the bus lock;
delivery drains a FIFO queue under a separate delivery lock, so two events
emitted back-to-back from different service threads can never reach
subscribers reversed. Callbacks run on service threads, so they must be
quick and must not raise; a raising subscriber is isolated (the error is
recorded, other subscribers still fire). A bounded ring buffer keeps recent
history for quick lookups.

Cursor subscription: with ``spill_path`` set, every event is also appended
to a plain JSONL spill log, and ``read_from(seq)`` / ``subscribe(cb,
from_seq=N)`` replay from an arbitrary sequence number — late joiners are
not limited to the bounded ring. The spill is an observability stream, not
the source of truth (that's the TaskStore), so it is flushed but not
fsynced; on reopen the bus resumes numbering after the last spilled seq.

Event payloads may carry a ``span`` key — the obs.trace span id of the
interval the event describes (fault events name their stall span, terminal
events the task's root span), linking the event stream to exported traces.
"""
from __future__ import annotations

import collections
import dataclasses
import json
import os
import threading
from typing import Any, Callable, Iterator

from repro.obs.clock import wall_s

# event kinds
SUBMITTED = "SUBMITTED"
ACTIVATED = "ACTIVATED"
PROGRESS = "PROGRESS"
RETRY = "RETRY"
# chunk-level fault observation: payload carries fault= "corruption" |
# "outage" | "mover_death", the (item, chunk, attempt) coordinates, and
# fatal=True when the fault exhausted its retry budget and failed the task.
FAULT = "FAULT"
# autotuner re-plan: the task's untransferred tail was re-partitioned.
# Payload: old_chunk_bytes, chunk_bytes (new), drained, requeued, rate_Bps.
TUNE = "TUNE"
# content-plane dedup: chunks satisfied from the endpoint's chunk index
# instead of wire moves. Payload: item, chunks (deduped count), bytes_saved,
# demoted (stale hits demoted back to wire moves).
DEDUP = "DEDUP"
# resilience plane: a route-aware layer re-planned this task's path around a
# sick endpoint/link. Payload: sick_link, new_path, resumed_chunks.
FAILOVER = "FAILOVER"
# resilience plane: a scrub pass touched this task's landed regions.
# Payload: scanned, rot_detected, repaired, quarantined.
SCRUB = "SCRUB"
REALLOC = "REALLOC"
PAUSED = "PAUSED"
RESUMED = "RESUMED"
CANCELED = "CANCELED"
SUCCEEDED = "SUCCEEDED"
FAILED = "FAILED"


@dataclasses.dataclass(frozen=True)
class TaskEvent:
    seq: int
    time_s: float
    kind: str
    task_id: str
    tenant: str
    payload: dict[str, Any]

    def to_json(self) -> dict:
        return {"seq": self.seq, "time_s": self.time_s, "kind": self.kind,
                "task_id": self.task_id, "tenant": self.tenant,
                "payload": self.payload}

    @classmethod
    def from_json(cls, body: dict) -> "TaskEvent":
        return cls(int(body["seq"]), float(body["time_s"]), body["kind"],
                   body["task_id"], body["tenant"], body.get("payload") or {})


class EventBus:
    def __init__(self, history: int = 4096, spill_path: str | None = None):
        self._lock = threading.Lock()
        self._subs: list[Callable[[TaskEvent], None]] = []
        self._seq = 0
        self._history: collections.deque[TaskEvent] = collections.deque(maxlen=history)
        self.subscriber_errors = 0
        # ordered delivery: emit enqueues under _lock, then whoever holds
        # _deliver_lock drains the queue in seq order. _delivered_seq is the
        # last seq handed to subscribers (cursor catch-up stops there —
        # anything later is queued and will arrive through the live path).
        self._pending: collections.deque[TaskEvent] = collections.deque()
        self._deliver_lock = threading.Lock()
        self._delivered_seq = -1
        self._delivered_cond = threading.Condition(self._lock)
        self._local = threading.local()     # reentrant-drain detection
        self._spill_path = spill_path
        self._spill_fh = None
        if spill_path is not None:
            self._seq = _resume_seq(spill_path)
            self._delivered_seq = self._seq - 1
            self._spill_fh = open(spill_path, "a", encoding="utf-8")

    def subscribe(
        self,
        cb: Callable[[TaskEvent], None],
        *,
        from_seq: int | None = None,
    ) -> Callable[[], None]:
        """Register a callback; returns an unsubscribe function.

        With ``from_seq``, the subscriber is first caught up with every
        already-delivered event at seq >= from_seq (from the ring or the
        spill log), then registered for live delivery — no gap and no
        duplicate at the seam: catch-up runs while holding the delivery
        lock, so nothing can be delivered live until the cursor replay ends
        exactly where live delivery will resume.
        """
        if from_seq is None:
            with self._lock:
                self._subs.append(cb)
        else:
            with self._deliver_lock:
                self._local.draining = True     # a cb that emits must not
                try:                            # block on its own delivery
                    with self._lock:
                        upto = self._delivered_seq
                    for ev in self.read_from(from_seq, upto=upto):
                        try:
                            cb(ev)
                        except Exception:
                            with self._lock:
                                self.subscriber_errors += 1
                    with self._lock:
                        self._subs.append(cb)
                finally:
                    self._local.draining = False
            self._drain()   # deliver anything queued while we caught up

        def unsubscribe() -> None:
            with self._lock:
                if cb in self._subs:
                    self._subs.remove(cb)

        return unsubscribe

    def emit(self, kind: str, task_id: str, tenant: str, **payload: Any) -> TaskEvent:
        with self._lock:
            ev = TaskEvent(self._seq, wall_s(), kind, task_id, tenant, payload)
            self._seq += 1
            self._history.append(ev)
            self._pending.append(ev)
            if self._spill_fh is not None:
                # flush (not fsync): the spill is a stream, not custody
                self._spill_fh.write(
                    json.dumps(ev.to_json(), default=str) + "\n")
                self._spill_fh.flush()
        self._drain()
        # emit() returns only after THIS event reached subscribers (the
        # pre-queue bus delivered synchronously; callers rely on it) — unless
        # we're inside a callback of an in-progress drain, where waiting
        # would deadlock: the queued event is delivered when the callback
        # returns to the drain loop.
        while not getattr(self._local, "draining", False):
            with self._lock:
                if self._delivered_seq >= ev.seq:
                    break
            self._drain()   # self-heal: the previous holder may be gone
            with self._delivered_cond:
                if self._delivered_seq >= ev.seq:
                    break
                self._delivered_cond.wait(0.02)
        return ev

    def _drain(self) -> None:
        """Deliver queued events in seq order.

        Exactly one thread holds _deliver_lock and delivers; emitters that
        lose the race return immediately — their event is already queued and
        the holder will deliver it. After releasing, the holder re-checks
        the queue (an emit may have enqueued between its last pop and the
        release) and loops if needed, so nothing is stranded. Reentrant
        emits from a callback land on the queue and are drained by the
        in-progress inner loop.
        """
        while True:
            if not self._deliver_lock.acquire(blocking=False):
                return
            self._local.draining = True
            try:
                while True:
                    with self._lock:
                        if not self._pending:
                            break
                        ev = self._pending.popleft()
                        subs = list(self._subs)
                    for cb in subs:
                        try:
                            cb(ev)
                        except Exception:
                            with self._lock:
                                self.subscriber_errors += 1
                    with self._lock:
                        self._delivered_seq = max(self._delivered_seq, ev.seq)
                        self._delivered_cond.notify_all()
            finally:
                self._local.draining = False
                self._deliver_lock.release()
            with self._lock:
                if not self._pending:
                    return

    def read_from(
        self,
        start_seq: int,
        *,
        limit: int | None = None,
        upto: int | None = None,
    ) -> list[TaskEvent]:
        """Events with ``start_seq <= seq`` (``<= upto`` if given), oldest
        first. Served from the ring when it still covers start_seq, else
        from the spill log; without a spill, events older than the ring are
        gone (the ring is bounded by design)."""
        with self._lock:
            ring = list(self._history)
        ring_start = ring[0].seq if ring else self._seq
        out: list[TaskEvent] = []
        if start_seq >= ring_start:
            out = [e for e in ring if e.seq >= start_seq]
        elif self._spill_path is not None:
            out = [e for e in self._iter_spill() if e.seq >= start_seq]
        else:
            out = list(ring)
        if upto is not None:
            out = [e for e in out if e.seq <= upto]
        if limit is not None:
            out = out[:limit]
        return out

    def _iter_spill(self) -> Iterator[TaskEvent]:
        if self._spill_path is None or not os.path.exists(self._spill_path):
            return
        with self._lock:
            if self._spill_fh is not None:
                self._spill_fh.flush()
        with open(self._spill_path, "r", encoding="utf-8") as fh:
            for line in fh:
                try:
                    yield TaskEvent.from_json(json.loads(line))
                except (ValueError, KeyError, TypeError):
                    continue    # torn/damaged spill line: skip, keep reading

    def history(self, kind: str | None = None) -> list[TaskEvent]:
        with self._lock:
            evs = list(self._history)
        return evs if kind is None else [e for e in evs if e.kind == kind]

    @property
    def next_seq(self) -> int:
        with self._lock:
            return self._seq

    def close(self) -> None:
        self._drain()
        with self._lock:
            if self._spill_fh is not None:
                self._spill_fh.close()
                self._spill_fh = None


def _resume_seq(spill_path: str) -> int:
    """Next seq after the last parseable spilled event.

    Tail scan with a widening window: one event line can exceed any fixed
    window (large payloads), and resuming at 0 on a parse miss would mint
    duplicate seqs, so on a miss the window doubles backwards until a
    parseable line or start-of-file is reached.
    """
    try:
        size = os.path.getsize(spill_path)
    except OSError:
        return 0
    window = 65536
    with open(spill_path, "rb") as fh:
        while True:
            start = max(0, size - window)
            fh.seek(start)
            tail = fh.read().decode("utf-8", errors="replace")
            lines = tail.splitlines()
            if start > 0 and lines:
                lines = lines[1:]   # first line may start mid-record
            for line in reversed(lines):
                try:
                    return int(json.loads(line)["seq"]) + 1
                except (ValueError, KeyError, TypeError):
                    continue
            if start == 0:
                return 0
            window *= 2
