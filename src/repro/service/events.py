"""Task event stream — progress callbacks for service clients.

Subscribers get every TaskEvent in emission order. Callbacks run on service
threads, so they must be quick and must not raise; a raising subscriber is
isolated (the error is recorded, other subscribers still fire). A bounded
ring buffer keeps recent history for late joiners / tests.

Event payloads may carry a ``span`` key — the obs.trace span id of the
interval the event describes (fault events name their stall span, terminal
events the task's root span), linking the event stream to exported traces.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
from typing import Any, Callable

from repro.obs.clock import wall_s

# event kinds
SUBMITTED = "SUBMITTED"
ACTIVATED = "ACTIVATED"
PROGRESS = "PROGRESS"
RETRY = "RETRY"
# chunk-level fault observation: payload carries fault= "corruption" |
# "outage" | "mover_death", the (item, chunk, attempt) coordinates, and
# fatal=True when the fault exhausted its retry budget and failed the task.
FAULT = "FAULT"
# autotuner re-plan: the task's untransferred tail was re-partitioned.
# Payload: old_chunk_bytes, chunk_bytes (new), drained, requeued, rate_Bps.
TUNE = "TUNE"
# content-plane dedup: chunks satisfied from the endpoint's chunk index
# instead of wire moves. Payload: item, chunks (deduped count), bytes_saved,
# demoted (stale hits demoted back to wire moves).
DEDUP = "DEDUP"
REALLOC = "REALLOC"
PAUSED = "PAUSED"
RESUMED = "RESUMED"
CANCELED = "CANCELED"
SUCCEEDED = "SUCCEEDED"
FAILED = "FAILED"


@dataclasses.dataclass(frozen=True)
class TaskEvent:
    seq: int
    time_s: float
    kind: str
    task_id: str
    tenant: str
    payload: dict[str, Any]


class EventBus:
    def __init__(self, history: int = 4096):
        self._lock = threading.Lock()
        self._subs: list[Callable[[TaskEvent], None]] = []
        self._seq = 0
        self._history: collections.deque[TaskEvent] = collections.deque(maxlen=history)
        self.subscriber_errors = 0

    def subscribe(self, cb: Callable[[TaskEvent], None]) -> Callable[[], None]:
        """Register a callback; returns an unsubscribe function."""
        with self._lock:
            self._subs.append(cb)

        def unsubscribe() -> None:
            with self._lock:
                if cb in self._subs:
                    self._subs.remove(cb)

        return unsubscribe

    def emit(self, kind: str, task_id: str, tenant: str, **payload: Any) -> TaskEvent:
        with self._lock:
            ev = TaskEvent(self._seq, wall_s(), kind, task_id, tenant, payload)
            self._seq += 1
            self._history.append(ev)
            subs = list(self._subs)
        for cb in subs:
            try:
                cb(ev)
            except Exception:
                with self._lock:
                    self.subscriber_errors += 1
        return ev

    def history(self, kind: str | None = None) -> list[TaskEvent]:
        with self._lock:
            evs = list(self._history)
        return evs if kind is None else [e for e in evs if e.kind == kind]
