"""Transfer-task model: specs, the task state machine, and status snapshots.

A *task* is the service-side unit of work (the Globus "transfer task"): a set
of (source, destination) items owned by one tenant, moved chunk-by-chunk with
per-chunk integrity fingerprints and a journal that makes a restarted service
resume the task at chunk granularity.

State machine (persisted transition-by-transition in the TaskStore):

    PENDING ──► ACTIVE ──► SUCCEEDED
       │           │  ╲──► FAILED
       │           │  ╲──► CANCELED
       │           ▼
       │        PAUSED ──► PENDING   (resume re-queues; journal is kept)
       ╰──────────────────► CANCELED

A service crash records nothing: recovery treats on-disk ACTIVE as PENDING
(durable tasks) or FAILED (ephemeral in-memory sources), and the chunk journal
ensures already-moved chunks are never moved again.
"""
from __future__ import annotations

import dataclasses
from typing import Any

from repro.obs.clock import wall_s

# ---------------------------------------------------------------------------
# States
# ---------------------------------------------------------------------------
PENDING = "PENDING"
ACTIVE = "ACTIVE"
PAUSED = "PAUSED"
SUCCEEDED = "SUCCEEDED"
FAILED = "FAILED"
CANCELED = "CANCELED"

STATES = (PENDING, ACTIVE, PAUSED, SUCCEEDED, FAILED, CANCELED)
TERMINAL = frozenset({SUCCEEDED, FAILED, CANCELED})

_ALLOWED: dict[str, frozenset[str]] = {
    PENDING: frozenset({ACTIVE, CANCELED, FAILED}),
    ACTIVE: frozenset({SUCCEEDED, FAILED, CANCELED, PAUSED, PENDING}),
    PAUSED: frozenset({PENDING, ACTIVE, CANCELED, FAILED}),
    SUCCEEDED: frozenset(),
    FAILED: frozenset(),
    CANCELED: frozenset(),
}


def can_transition(src: str, dst: str) -> bool:
    return dst in _ALLOWED.get(src, frozenset())


class TransitionError(RuntimeError):
    def __init__(self, task_id: str, src: str, dst: str):
        super().__init__(f"task {task_id}: illegal transition {src} -> {dst}")
        self.src, self.dst = src, dst


# ---------------------------------------------------------------------------
# Specs (persisted)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class TransferItem:
    """One (source, destination) pair inside a task.

    ``mem=True`` marks an ephemeral in-process source (e.g. a checkpoint
    array); such tasks are not crash-recoverable and are failed on restart.
    """

    src: str
    dst: str
    nbytes: int
    mem: bool = False

    def to_json(self) -> dict[str, Any]:
        return {"src": self.src, "dst": self.dst, "nbytes": self.nbytes, "mem": self.mem}

    @staticmethod
    def from_json(obj: dict[str, Any]) -> "TransferItem":
        return TransferItem(obj["src"], obj["dst"], int(obj["nbytes"]), bool(obj.get("mem")))


@dataclasses.dataclass(frozen=True)
class TaskSpec:
    """The persisted description of a task — enough to re-create it on restart."""

    task_id: str
    tenant: str
    label: str
    items: tuple[TransferItem, ...]
    chunk_bytes: int | None = None
    # per-task tuning policy: "auto" closes the chunk-size loop over this
    # task's tail, "static" pins the plan; None defers to the service default
    tuning: str | None = None
    # per-task dedup policy: "on" probes the destination endpoint's chunk
    # index before moving, "off" bypasses it; None defers to the service
    dedup: str | None = None
    # per-task failover policy: "auto" lets route-aware layers (relay,
    # campaigns) re-plan around dead endpoints mid-flight, "off" pins the
    # original route; None defers to the service default
    failover: str | None = None
    submitted_s: float = dataclasses.field(default_factory=wall_s)

    @property
    def durable(self) -> bool:
        return all(not it.mem for it in self.items)

    @property
    def total_bytes(self) -> int:
        return sum(it.nbytes for it in self.items)

    @property
    def n_files(self) -> int:
        return len(self.items)

    def to_json(self) -> dict[str, Any]:
        return {
            "task_id": self.task_id,
            "tenant": self.tenant,
            "label": self.label,
            "items": [it.to_json() for it in self.items],
            "chunk_bytes": self.chunk_bytes,
            "tuning": self.tuning,
            "dedup": self.dedup,
            "failover": self.failover,
            "submitted_s": self.submitted_s,
        }

    @staticmethod
    def from_json(obj: dict[str, Any]) -> "TaskSpec":
        return TaskSpec(
            task_id=obj["task_id"],
            tenant=obj["tenant"],
            label=obj.get("label", ""),
            items=tuple(TransferItem.from_json(o) for o in obj["items"]),
            chunk_bytes=obj.get("chunk_bytes"),
            tuning=obj.get("tuning"),
            dedup=obj.get("dedup"),
            failover=obj.get("failover"),
            submitted_s=float(obj.get("submitted_s", 0.0)),
        )


# ---------------------------------------------------------------------------
# Reports / status snapshots (API surface)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class FaultReport:
    """Structured description of the fault that failed a task.

    Attached to TaskStatus (and the FAILED event payload) only after the
    per-class retry budgets exhausted: ``kind`` names the terminal failure
    class, the coordinates pin the chunk that could not be recovered, and the
    counters record how much recovery was attempted before giving up.
    """

    kind: str          # "corruption" | "outage" | "mover_death" | "io" | "error"
    item: int
    chunk: int
    offset: int
    error: str
    retries: int = 0
    refetches: int = 0
    outages: int = 0
    mover_deaths: int = 0

    def to_json(self) -> dict[str, Any]:
        return dataclasses.asdict(self)


def classify_fault(exc: BaseException) -> str:
    """Map an exception from the chunk-move path to a FaultReport kind."""
    from repro.core.transfer import EndpointOutage, IntegrityError, MoverCrash

    if isinstance(exc, IntegrityError):
        return "corruption"
    if isinstance(exc, EndpointOutage):
        return "outage"
    if isinstance(exc, MoverCrash):
        return "mover_death"
    if isinstance(exc, OSError):
        return "io"
    return "error"


@dataclasses.dataclass(frozen=True)
class ItemReport:
    """Per-item outcome of a SUCCEEDED task (digests come from the journal)."""

    src: str
    dst: str
    nbytes: int
    digest_hex: str
    chunk_bytes: int
    chunks: tuple[dict[str, Any], ...]   # {"index", "offset", "length", "digest"}


@dataclasses.dataclass(frozen=True)
class TaskStatus:
    """Immutable snapshot returned by the client API (status/wait)."""

    task_id: str
    tenant: str
    label: str
    state: str
    error: str | None
    n_files: int
    bytes_total: int
    bytes_done: int
    chunks_total: int
    chunks_done: int
    resumed_chunks: int
    retries: int
    movers: int
    submitted_s: float
    started_s: float | None
    finished_s: float | None
    item_reports: tuple[ItemReport, ...] = ()
    # chunk-level fault/recovery accounting (chaos-hardened recovery):
    refetches: int = 0        # corrupt chunk landings healed by source re-read
    outages: int = 0          # ops rejected by endpoint outage windows
    mover_deaths: int = 0     # movers lost mid-chunk (chunks re-queued)
    # resilience-plane accounting:
    failovers: int = 0        # route re-plans recorded against this task
    scrub_repairs: int = 0    # landed regions the scrubber healed from donors
    fault: FaultReport | None = None    # set when state == FAILED
    # autotuner accounting (tuned-vs-static visibility):
    tuning: str = "static"    # effective policy this task ran under
    replans: int = 0          # mid-flight tail re-partitions
    chunk_bytes_current: int | None = None   # nominal tail chunk size now
    # intra-chunk striping accounting (stripe-band work items):
    stripes: int = 1          # configured stripe count per eligible chunk
    striped_chunks: int = 0   # parent chunks that were split into stripes
    # content-plane accounting (dedup against the endpoint chunk index):
    chunks_deduped: int = 0   # chunks satisfied locally, no wire move
    wire_bytes_saved: int = 0 # bytes those chunks would have moved
    dedup_demoted: int = 0    # stale index hits demoted to wire moves
    # data-plane accounting (pipelined integrity engine visibility):
    pipeline: str = "serial"  # serial | single_pass | pipelined
    cksum_seconds: float = 0.0   # checksum work on the mover path (cumulative)
    cksum_lag_s: float = 0.0     # deferred-verification lag (cumulative; the
    #                              distance integrity ran behind movement)
    # observability view: per-task numbers pulled from the obs metrics
    # registry at snapshot time (wire-time quantiles, verify lag, retry
    # counts by class) — what ``transferd top`` renders per row
    metrics: dict[str, Any] = dataclasses.field(default_factory=dict)

    @property
    def done(self) -> bool:
        return self.state in TERMINAL

    @property
    def latency_s(self) -> float | None:
        if self.finished_s is None:
            return None
        return self.finished_s - self.submitted_s

    @property
    def progress(self) -> float:
        return self.bytes_done / self.bytes_total if self.bytes_total else 1.0
