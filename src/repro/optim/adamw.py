"""AdamW with global-norm clipping and configurable state dtype.

State dtype matters at assigned-architecture scale: grok-1 (~314B params)
keeps m/v in bf16 so params+optimizer fit the 16 GB/chip v5e budget under
FSDP x TP sharding (DESIGN.md §5); smaller models default to f32 state.
Updates are always computed in f32 regardless of storage dtype.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    state_dtype: Any = jnp.float32
    warmup_steps: int = 100


class OptState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


def init(params: Any, cfg: AdamWConfig) -> OptState:
    zeros = lambda p: jnp.zeros(p.shape, cfg.state_dtype)  # noqa: E731
    return OptState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
    )


def state_specs(param_specs: Any) -> OptState:
    """Optimizer-state PartitionSpecs mirror the param specs (ZeRO-sharded)."""
    from jax.sharding import PartitionSpec as P
    return OptState(step=P(), m=param_specs, v=param_specs)


def _schedule(step: jax.Array, cfg: AdamWConfig) -> jax.Array:
    warm = jnp.minimum(step.astype(jnp.float32) / max(cfg.warmup_steps, 1), 1.0)
    return cfg.lr * warm


def apply(params: Any, grads: Any, state: OptState, cfg: AdamWConfig):
    """Returns (new_params, new_state, stats)."""
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    step = state.step + 1
    lr = _schedule(step, cfg)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * jnp.square(g)
        mh = m32 / b1c
        vh = v32 / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr * delta
        return newp.astype(p.dtype), m32.astype(m.dtype), v32.astype(v.dtype)

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state.m)
    flat_v = tdef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, OptState(step, new_m, new_v), {"grad_norm": gnorm, "lr": lr}
