from repro.optim import adamw
from repro.optim.adamw import AdamWConfig, OptState

__all__ = ["adamw", "AdamWConfig", "OptState"]
