"""Pure-jnp oracles for the Pallas integrity kernels.

The digest algebra is defined in ``repro.core.integrity`` (host/numpy, exact).
These oracles compute the *same* fingerprints with plain jnp ops — no Pallas —
so kernel tests can assert_allclose (exact integer equality here) against an
independent implementation, and the host implementation cross-checks both.

All device-side digests are defined over the little-endian byte image of the
array, exactly like the host ``fingerprint_bytes``; arrays whose byte count is
not a multiple of 4 are zero-padded and the padding is divided back out
(multiplying by the modular inverse of r^pad — valid because GF(p) is a field).
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.integrity import BASES, NBASES, P, Digest

_LANE = 128  # bytes folded per modular reduction: 128*255*46336 < 2^31


def _pow_mod(base: int, exp: int) -> int:
    return pow(int(base), int(exp), P)


def to_byte_stream(x: jax.Array) -> tuple[jax.Array, int]:
    """Flatten any array to its little-endian uint8 byte stream (+true length)."""
    flat = x.reshape(-1)
    if flat.dtype == jnp.uint8:
        return flat, flat.size
    # bitcast elementwise to a same-width unsigned type, then split bytes.
    nbits = flat.dtype.itemsize * 8
    udtype = {8: jnp.uint8, 16: jnp.uint16, 32: jnp.uint32, 64: jnp.uint64}[nbits]
    u = jax.lax.bitcast_convert_type(flat, udtype)
    nbytes_per = flat.dtype.itemsize
    u32 = u.astype(jnp.uint32)
    parts = [((u32 >> (8 * k)) & 0xFF).astype(jnp.uint8) for k in range(nbytes_per)]
    return jnp.stack(parts, axis=-1).reshape(-1), flat.size * nbytes_per


def fingerprint_bytes_ref(b: jax.Array) -> jax.Array:
    """Digest residues of a uint8 vector; returns (NBASES,) int32.

    Two-level fold: within 128-byte groups a weighted lane sum (safe in int32),
    then an in-order fold across groups with the merge law H <- H*r^128 + h_g.
    """
    n = int(b.shape[0])
    pad = (-n) % _LANE
    bp = jnp.pad(b, (0, pad)).astype(jnp.int32).reshape(-1, _LANE)
    ngroups = bp.shape[0]
    out = []
    for r in BASES:
        w = np.empty(_LANE, np.int32)
        acc = 1
        for k in range(_LANE - 1, -1, -1):
            w[k] = acc
            acc = (acc * r) % P
        w = jnp.asarray(w)
        group = jnp.sum(bp * w[None, :], axis=1) % P          # (ngroups,)
        r_lane = _pow_mod(r, _LANE)

        def step(h, g):
            return (h * r_lane + g) % P, None

        h, _ = jax.lax.scan(step, jnp.int32(0), group)
        if pad:
            inv = _pow_mod(_pow_mod(r, pad), P - 2)           # divide out zero pad
            h = (h * inv) % P
        out.append(h)
    return jnp.stack(out).astype(jnp.int32)


def fingerprint_array_ref(x: jax.Array) -> jax.Array:
    """Digest residues (NBASES,) int32 of an array's byte image."""
    b, _ = to_byte_stream(x)
    return fingerprint_bytes_ref(b)


def digest_of_ref(x: jax.Array) -> Digest:
    b, n = to_byte_stream(x)
    h = np.asarray(jax.jit(fingerprint_bytes_ref)(b))
    return Digest(tuple(int(v) for v in h), n)


def blocked_view(a: jax.Array, bm: int, bk: int) -> jax.Array:
    """Rearrange (M,K) into tile-major order: (M/bm, K/bk, bm, bk) flattened.

    The fused matmul+digest kernel consumes A tile-by-tile, so its digest is
    defined over this canonical blocked byte order; the oracle uses the same.
    """
    M, K = a.shape
    assert M % bm == 0 and K % bk == 0, (a.shape, bm, bk)
    return (
        a.reshape(M // bm, bm, K // bk, bk)
        .transpose(0, 2, 1, 3)
        .reshape(-1)
    )


def matmul_digest_ref(a: jax.Array, b: jax.Array, bm: int = 128, bk: int = 128):
    """Oracle for the fused kernel: (a @ b, digest residues of blocked a)."""
    out = jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32))
    h = fingerprint_array_ref(blocked_view(a, bm, bk))
    return out, h
