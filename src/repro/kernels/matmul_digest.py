"""Fused matmul + operand fingerprint — compute/integrity overlap in one pass.

The paper's Fig. 4 insight is that integrity checking should ride along with
data movement instead of serializing after it. On a TPU the analogous fusion
is at the kernel level: when a transferred tensor is about to be *consumed* by
a matmul (e.g. an FSDP all-gathered weight entering the MXU), the digest can
be computed from the very tiles the MXU is already pulling through VMEM —
zero extra HBM traffic, versus a separate verification pass that re-reads the
whole operand (exactly the "re-read at destination" cost the paper measures
at 773 s for a 500 GB file).

Grid (i, j, k) with k innermost: the f32 accumulator scratch carries the C
block across k; A tiles are digested only on the j == 0 pass, in block-row-
major order — the canonical "blocked" byte order defined by ref.blocked_view.
"""
from __future__ import annotations

import functools

import numpy as np
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.integrity import BASES, NBASES, P

LANES = 128


def _pow_mod(base: int, exp: int) -> int:
    return pow(int(base), int(exp), P)


@functools.lru_cache(maxsize=None)
def _tables16(bm: int, bk: int) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Weights for digesting a (bm, bk) bf16 tile as its u16 code units.

    Element m (tile row-major) occupies bytes 2m (lo) and 2m+1 (hi);
    lo-weight = r^(T-1-2m) with T = 2*bm*bk, hi-weight = lo * r^-1.
    """
    tile_elems = bm * bk
    tile_bytes = 2 * tile_elems
    w16 = np.empty((NBASES, bm, bk), np.int32)
    rinv1 = np.empty((NBASES, 1), np.int32)
    rpow = np.empty((NBASES, 1), np.int32)
    for b, r in enumerate(BASES):
        r2inv = _pow_mod(_pow_mod(r, 2), P - 2)
        acc = _pow_mod(r, tile_bytes - 1)
        flat = np.empty(tile_elems, np.int64)
        for m in range(tile_elems):
            flat[m] = acc
            acc = (acc * r2inv) % P
        w16[b] = flat.reshape(bm, bk)
        rinv1[b, 0] = _pow_mod(r, P - 2)
        rpow[b, 0] = _pow_mod(r, tile_bytes)
    return w16, rinv1, rpow


def _mm_digest_kernel(a_ref, b_ref, w16_ref, rinv_ref, rpow_ref,
                      out_ref, dig_ref, acc_ref, *, nk: int):
    i, j, k = pl.program_id(0), pl.program_id(1), pl.program_id(2)

    @pl.when((i == 0) & (j == 0) & (k == 0))
    def _init_digest():
        dig_ref[...] = jnp.zeros((1, NBASES), jnp.int32)

    @pl.when(k == 0)
    def _init_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...]
    acc_ref[...] += jnp.dot(
        a.astype(jnp.float32), b_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )

    # Digest the A tile on its first (and only) digesting visit: j == 0.
    @pl.when(j == 0)
    def _digest():
        codes = jax.lax.bitcast_convert_type(a, jnp.uint16).astype(jnp.int32)
        lo = jnp.bitwise_and(codes, 255)
        hi = jax.lax.shift_right_logical(codes, 8)
        dig = dig_ref[...]
        new = []
        for bb in range(NBASES):
            w = w16_ref[bb]
            s_lo = jnp.sum(jnp.sum(lo * w, axis=1) % P) % P
            s_hi = jnp.sum(jnp.sum(hi * w, axis=1) % P) % P
            th = (s_lo + s_hi * rinv_ref[bb, 0]) % P
            new.append((dig[0, bb] * rpow_ref[bb, 0] + th) % P)
        dig_ref[...] = jnp.stack(new)[None, :]

    @pl.when(k == nk - 1)
    def _emit():
        out_ref[...] = acc_ref[...].astype(out_ref.dtype)


def matmul_digest(
    a: jax.Array,
    b: jax.Array,
    *,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """C = A @ B (f32 accumulate) plus digest residues of A's blocked bytes.

    A must be bf16 (the transfer dtype) with shape divisible by (bm, bk);
    B is (K, N) divisible by (bk, bn). Returns (C f32 (M,N), residues (NBASES,)).
    """
    assert a.dtype == jnp.bfloat16, a.dtype
    M, K = a.shape
    K2, N = b.shape
    assert K == K2 and M % bm == 0 and K % bk == 0 and N % bn == 0, (a.shape, b.shape)
    nk = K // bk
    w16, rinv1, rpow = _tables16(bm, bk)
    kernel = functools.partial(_mm_digest_kernel, nk=nk)
    out, dig = pl.pallas_call(
        kernel,
        grid=(M // bm, N // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((NBASES, bm, bk), lambda i, j, k: (0, 0, 0)),
            pl.BlockSpec((NBASES, 1), lambda i, j, k: (0, 0)),
            pl.BlockSpec((NBASES, 1), lambda i, j, k: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
            pl.BlockSpec((1, NBASES), lambda i, j, k: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((M, N), jnp.float32),
            jax.ShapeDtypeStruct((1, NBASES), jnp.int32),
        ],
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
        name="matmul_digest",
    )(a, b, jnp.asarray(w16), jnp.asarray(rinv1), jnp.asarray(rpow))
    return out, dig[0]
