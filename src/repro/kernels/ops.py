"""Public, jit-friendly wrappers over the Pallas integrity kernels.

Entry points:
  fingerprint_array(x)        -> (NBASES,) int32 residues of x's byte image
  fingerprint_and_copy(x)     -> (residues, copy) — single-pass mover kernel
  digest_of(x)                -> core.integrity.Digest (host convenience)
  matmul_with_digest(a, b)    -> (a @ b, residues of a) — fused consume+verify

Packing: any array is flattened and bitcast to little-endian int32 words
(verified identical to numpy ``.view``). Byte counts not divisible by 4 or by
the kernel tile are zero-padded; padding is divided back out with the modular
inverse of r^pad (GF(p) is a field), so the returned residues equal the digest
of the *true* byte stream — host `fingerprint_bytes` agrees bit-for-bit, which
is exactly what lets device-side chunk digests be verified against host-side
file digests in the checkpoint path.
"""
from __future__ import annotations

import functools

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.integrity import BASES, NBASES, P, Digest
from repro.kernels import checksum as _ck
from repro.kernels import matmul_digest as _mm


def _pow_mod(base: int, exp: int) -> int:
    return pow(int(base), int(exp), P)


def _to_words(x: jax.Array) -> tuple[jax.Array, int]:
    """Flatten + bitcast to int32 words (little-endian), zero-padding to 4B."""
    flat = x.reshape(-1)
    isz = flat.dtype.itemsize
    nbytes = flat.size * isz
    if isz == 4:
        words = jax.lax.bitcast_convert_type(flat, jnp.int32)
    elif isz == 2:
        if flat.size % 2:
            flat = jnp.pad(flat, (0, 1))
        words = jax.lax.bitcast_convert_type(flat.reshape(-1, 2), jnp.int32)
    elif isz == 1:
        pad = (-flat.size) % 4
        if pad:
            flat = jnp.pad(flat, (0, pad))
        words = jax.lax.bitcast_convert_type(flat.reshape(-1, 4), jnp.int32)
    else:
        raise NotImplementedError(f"unsupported itemsize {isz} for {flat.dtype}")
    return words.reshape(-1), nbytes


def _unpad_residues(res: jax.Array, padded_bytes: int, true_bytes: int) -> jax.Array:
    """Divide out the trailing zero padding: H_true = H_pad * r^-(pad)."""
    pad = padded_bytes - true_bytes
    if pad == 0:
        return res
    inv = jnp.asarray(
        [_pow_mod(_pow_mod(r, pad), P - 2) for r in BASES], dtype=jnp.int32
    )
    return (res * inv) % P


@functools.partial(jax.jit, static_argnames=("rows", "interpret"))
def fingerprint_array(x: jax.Array, *, rows: int = _ck.ROWS, interpret: bool = True) -> jax.Array:
    """Digest residues (NBASES,) int32 of an array's little-endian byte image."""
    words, nbytes = _to_words(x)
    tile = rows * _ck.LANES
    padw = (-words.size) % tile
    if words.size == 0:
        return jnp.zeros((NBASES,), jnp.int32)
    if padw:
        words = jnp.pad(words, (0, padw))
    res = _ck.checksum_words(words, rows=rows, interpret=interpret)
    return _unpad_residues(res, words.size * 4, nbytes)


@functools.partial(jax.jit, static_argnames=("rows", "interpret"))
def fingerprint_and_copy(
    x: jax.Array, *, rows: int = _ck.ROWS, interpret: bool = True
) -> tuple[jax.Array, jax.Array]:
    """Single-HBM-pass mover: returns (residues, copy-of-x)."""
    words, nbytes = _to_words(x)
    tile = rows * _ck.LANES
    padw = (-words.size) % tile
    padded = jnp.pad(words, (0, padw)) if padw else words
    res, copy_words = _ck.checksum_copy_words(padded, rows=rows, interpret=interpret)
    res = _unpad_residues(res, padded.size * 4, nbytes)
    flat = x.reshape(-1)
    isz = flat.dtype.itemsize
    if isz == 4:
        copy = jax.lax.bitcast_convert_type(copy_words[: flat.size], x.dtype)
    else:
        n_units = (flat.size * isz + isz - 1) // isz
        unit = {2: jnp.uint16, 1: jnp.uint8}[isz]
        units = jax.lax.bitcast_convert_type(copy_words, unit).reshape(-1)[: flat.size]
        copy = jax.lax.bitcast_convert_type(units, x.dtype)
    return res, copy.reshape(x.shape)


def digest_of(x: jax.Array, *, interpret: bool = True) -> Digest:
    """Host-side Digest of a device array (residues via the Pallas kernel)."""
    res = np.asarray(fingerprint_array(x, interpret=interpret))
    nbytes = x.size * x.dtype.itemsize
    return Digest(tuple(int(v) for v in res), int(nbytes))


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def matmul_with_digest(
    a: jax.Array, b: jax.Array, *, bm: int = 128, bn: int = 128, bk: int = 128,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Fused C = A @ B and digest of A (blocked order — see ref.blocked_view)."""
    return _mm.matmul_digest(a, b, bm=bm, bn=bn, bk=bk, interpret=interpret)
