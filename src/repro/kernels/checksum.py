"""Pallas TPU kernels for mergeable integrity fingerprints.

Hardware adaptation (DESIGN.md §2): MD5's sequential 64-byte chain is replaced
by a degree-weighted polynomial fingerprint over GF(46337) — see
``repro.core.integrity`` for the algebra. Everything here is int32: the prime
was chosen so that every product of residues fits a signed 32-bit lane, i.e.
the whole digest runs on the TPU VPU (8x128 int32 lanes) with no 64-bit
emulation.

Kernels:
  * ``checksum_kernel``       — digest of an int32 word stream.
  * ``checksum_copy_kernel``  — data mover: copies the stream AND digests it in
    the same HBM pass (the paper's "checksum while first reading the file",
    Fig. 4 caption) — one read instead of two.

Tiling: the grid walks (ROWS, 128)-word tiles; TPU grids execute sequentially
on a core, so the running digest accumulates in the output ref across steps
(init at step 0). Per-tile weight tables live in VMEM and are reused every
step (index_map pins them to block 0). The byte-plane factorization keeps the
table at (NBASES, ROWS, 128) int32 — ~128 KiB at ROWS=64 — instead of 4x that:
byte k of word m sits at stream position 4m+k, so its weight is
``W0[m] * r^-k`` with W0[m] = r^(T-1-4m); the three extra scalar multiplies
per plane are free next to the loads.

Numeric safety rails (asserted in tests over full shape/dtype sweeps):
  byte*weight <= 255*46336 = 1.18e7; 128-lane sum <= 1.51e9 < 2^31;
  row-sum of residues <= ROWS*P; residue*residue <= (P-1)^2 = 2.147e9 < 2^31.
"""
from __future__ import annotations

import functools

import numpy as np
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.integrity import BASES, NBASES, P

ROWS = 64           # words per tile row-block: tile = ROWS*128 words = 32 KiB
LANES = 128
TILE_WORDS = ROWS * LANES
TILE_BYTES = 4 * TILE_WORDS


def _pow_mod(base: int, exp: int) -> int:
    return pow(int(base), int(exp), P)


@functools.lru_cache(maxsize=None)
def _tables(rows: int) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """(W0, rinv, rpow): word weights r^(T-1-4m), byte-plane r^-k, tile r^T."""
    tile_words = rows * LANES
    tile_bytes = 4 * tile_words
    w0 = np.empty((NBASES, rows, LANES), np.int32)
    rinv = np.empty((NBASES, 4), np.int32)
    rpow = np.empty((NBASES, 1), np.int32)
    for b, r in enumerate(BASES):
        r4 = _pow_mod(r, 4)
        r4inv = _pow_mod(r4, P - 2)
        acc = _pow_mod(r, tile_bytes - 1)          # weight of word m=0
        flat = np.empty(tile_words, np.int64)
        for m in range(tile_words):
            flat[m] = acc
            acc = (acc * r4inv) % P
        w0[b] = flat.reshape(rows, LANES)
        rinvk = _pow_mod(r, P - 2)
        rinv[b] = [1, rinvk, (rinvk * rinvk) % P, (rinvk * rinvk % P) * rinvk % P]
        rpow[b, 0] = _pow_mod(r, tile_bytes)
    return w0, rinv, rpow


def _plane_hash(words: jax.Array, w0: jax.Array, rinv_row: jax.Array) -> jax.Array:
    """Tile hash for one base given its weight table. words: (R,128) int32."""
    th = jnp.int32(0)
    for k in range(4):
        plane = jnp.bitwise_and(jax.lax.shift_right_logical(words, 8 * k), 255)
        s = jnp.sum(plane * w0, axis=1) % P        # (R,) — lane fold, <2^31
        s = jnp.sum(s) % P                          # row fold, R*P < 2^31
        th = (th + s * rinv_row[k]) % P             # plane shift by r^-k
    return th


def _checksum_kernel(words_ref, w0_ref, rinv_ref, rpow_ref, out_ref):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        out_ref[...] = jnp.zeros((1, NBASES), jnp.int32)

    words = words_ref[...]
    acc = out_ref[...]
    new = []
    for b in range(NBASES):
        th = _plane_hash(words, w0_ref[b], rinv_ref[b])
        new.append((acc[0, b] * rpow_ref[b, 0] + th) % P)  # H <- H*r^T + h_tile
    out_ref[...] = jnp.stack(new)[None, :]


def _checksum_copy_kernel(words_ref, w0_ref, rinv_ref, rpow_ref, out_ref, copy_ref):
    copy_ref[...] = words_ref[...]                 # the ESTO write ...
    _checksum_kernel(words_ref, w0_ref, rinv_ref, rpow_ref, out_ref)  # ... + inline digest


def _common_specs(rows: int):
    return [
        pl.BlockSpec((rows, LANES), lambda i: (i, 0)),          # data tile
        pl.BlockSpec((NBASES, rows, LANES), lambda i: (0, 0, 0)),  # weights (pinned)
        pl.BlockSpec((NBASES, 4), lambda i: (0, 0)),            # r^-k scalars
        pl.BlockSpec((NBASES, 1), lambda i: (0, 0)),            # r^T scalar
    ]


def checksum_words(words: jax.Array, *, rows: int = ROWS, interpret: bool = True) -> jax.Array:
    """Digest residues (NBASES,) int32 of an int32 word stream.

    ``words`` must be 1-D int32 with size % (rows*128) == 0 (the ops.py wrapper
    handles padding + pad correction). ``interpret=True`` runs the kernel body
    on CPU — this container's validation mode; on TPU pass False.
    """
    assert words.ndim == 1 and words.dtype == jnp.int32, (words.shape, words.dtype)
    tile = rows * LANES
    assert words.size % tile == 0 and words.size > 0, words.size
    w0, rinv, rpow = _tables(rows)
    grid = (words.size // tile,)
    out = pl.pallas_call(
        _checksum_kernel,
        grid=grid,
        in_specs=_common_specs(rows),
        out_specs=pl.BlockSpec((1, NBASES), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, NBASES), jnp.int32),
        interpret=interpret,
        name="chunk_checksum",
    )(words.reshape(-1, LANES), jnp.asarray(w0), jnp.asarray(rinv), jnp.asarray(rpow))
    return out[0]


def _checksum_many_kernel(words_ref, w0_ref, rinv_ref, rpow_ref, out_ref):
    @pl.when(pl.program_id(1) == 0)
    def _init():
        out_ref[...] = jnp.zeros((1, NBASES), jnp.int32)

    words = words_ref[0]                           # (rows, LANES)
    acc = out_ref[...]
    new = []
    for b in range(NBASES):
        th = _plane_hash(words, w0_ref[b], rinv_ref[b])
        new.append((acc[0, b] * rpow_ref[b, 0] + th) % P)
    out_ref[...] = jnp.stack(new)[None, :]


def checksum_many_words(
    words2d: jax.Array, *, rows: int = ROWS, interpret: bool = True
) -> jax.Array:
    """Digests of k equal-length int32 word streams in ONE kernel dispatch.

    ``words2d`` is (k, n_words) with n_words % (rows*128) == 0. The grid is
    (k, tiles): the row axis is the batch, the tile axis walks each stream
    sequentially (TPU grids execute in row-major order, so the per-stream
    running digest accumulates in its output row, re-initialized whenever the
    tile index wraps to 0). This is the accelerator side of the fused
    IntegrityEngine drain: one dispatch per drain batch instead of one per
    chunk — the same per-call amortization ``fingerprint_rows`` does for the
    host GEMM path, with the weight tables pinned in VMEM across the whole
    batch. Returns (k, NBASES) int32 residues.
    """
    assert words2d.ndim == 2 and words2d.dtype == jnp.int32, (words2d.shape, words2d.dtype)
    k, n = words2d.shape
    tile = rows * LANES
    assert n % tile == 0 and n > 0 and k > 0, (k, n)
    w0, rinv, rpow = _tables(rows)
    grid = (k, n // tile)
    out = pl.pallas_call(
        _checksum_many_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, rows, LANES), lambda i, j: (i, j, 0)),     # stream tile
            pl.BlockSpec((NBASES, rows, LANES), lambda i, j: (0, 0, 0)),  # weights (pinned)
            pl.BlockSpec((NBASES, 4), lambda i, j: (0, 0)),             # r^-k scalars
            pl.BlockSpec((NBASES, 1), lambda i, j: (0, 0)),             # r^T scalar
        ],
        out_specs=pl.BlockSpec((1, NBASES), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((k, NBASES), jnp.int32),
        interpret=interpret,
        name="chunk_checksum_many",
    )(words2d.reshape(k, -1, LANES), jnp.asarray(w0), jnp.asarray(rinv), jnp.asarray(rpow))
    return out


def checksum_copy_words(
    words: jax.Array, *, rows: int = ROWS, interpret: bool = True
) -> tuple[jax.Array, jax.Array]:
    """Copy an int32 word stream while digesting it (one pass over HBM).

    Returns (digest_residues (NBASES,), copy). The copy output aliases nothing:
    this is the chunk landing in its destination buffer with the integrity
    check folded into the same data movement, paper Fig. 4's overlap taken to
    its limit (zero extra read).
    """
    assert words.ndim == 1 and words.dtype == jnp.int32
    tile = rows * LANES
    assert words.size % tile == 0 and words.size > 0
    w0, rinv, rpow = _tables(rows)
    grid = (words.size // tile,)
    digest, copy = pl.pallas_call(
        _checksum_copy_kernel,
        grid=grid,
        in_specs=_common_specs(rows),
        out_specs=[
            pl.BlockSpec((1, NBASES), lambda i: (0, 0)),
            pl.BlockSpec((rows, LANES), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, NBASES), jnp.int32),
            jax.ShapeDtypeStruct((words.size // LANES, LANES), jnp.int32),
        ],
        interpret=interpret,
        name="chunk_checksum_copy",
    )(words.reshape(-1, LANES), jnp.asarray(w0), jnp.asarray(rinv), jnp.asarray(rpow))
    return digest[0], copy.reshape(-1)
