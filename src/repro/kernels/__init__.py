"""Pallas TPU kernels (validated in interpret mode on CPU).

  checksum.py      — chunk fingerprint kernel + single-pass checksum-copy
  matmul_digest.py — fused matmul + operand digest (consume-and-verify)
  ops.py           — jit'd public wrappers
  ref.py           — pure-jnp oracles (cross-checked vs host numpy oracle)
"""
from repro.kernels.ops import (
    digest_of,
    fingerprint_and_copy,
    fingerprint_array,
    matmul_with_digest,
)

__all__ = ["digest_of", "fingerprint_and_copy", "fingerprint_array", "matmul_with_digest"]
