"""Per-endpoint chunk index: merge-law digest -> landed byte regions.

One ``ChunkIndex`` describes what content ONE endpoint (one filesystem /
staging volume) already holds, keyed by ``(digest_hex, length)`` of the
merge-law chunk fingerprint. Values are the landed locations —
``(path, offset)`` pairs — because the same content may sit in several
files (repeated checkpoint saves, replica staging dirs).

Persistence follows ``core.journal`` exactly: an append-only JSONL log
where every record is self-checksummed, replay keeps every verified
record, the torn tail after the last verified record is truncated before
reopening for append, and ``compact()`` rewrites live records with an
atomic rename. Unlike the chunk journal the index is a CACHE, not a
custody record: appends flush but do not fsync by default (losing a tail
entry across a crash costs a dedup miss, never correctness), and every
lookup hit is re-verified by a read-back fingerprint before it is
trusted — ``read_region`` + the caller's ``verify`` are the contract
that makes a stale or corrupted entry harmless.
"""
from __future__ import annotations

import dataclasses
import os
import threading

from repro.core.integrity import Digest, fingerprint_bytes, verify
from repro.core.journal import checked_line, replay_checked_lines
from repro.obs import metrics as obsmetrics

_M_HITS = obsmetrics.REGISTRY.counter(
    "cas_index_hits_total", "dedup probes satisfied by the index", ("index",))
_M_MISSES = obsmetrics.REGISTRY.counter(
    "cas_index_misses_total", "dedup probes the index could not satisfy",
    ("index",))
_M_STALE = obsmetrics.REGISTRY.counter(
    "cas_index_stale_total",
    "index entries whose backing bytes failed re-verification", ("index",))


@dataclasses.dataclass(frozen=True)
class IndexEntry:
    """One landed location of one content chunk."""

    digest_hex: str
    length: int
    path: str
    offset: int


@dataclasses.dataclass
class DedupStats:
    """Aggregated outcome of one dedup negotiation phase."""

    probed: int = 0
    hits: int = 0              # chunks satisfied without a wire move
    bytes_saved: int = 0       # wire bytes those chunks would have cost
    demoted: int = 0           # stale/corrupt entries demoted to wire moves
    aliases: int = 0           # same-target hits (pure index insert, no copy)


class ChunkIndex:
    """Crash-safe, compactable content index for one endpoint.

    ``scope`` labels this index's metric series (defaults to the log's
    directory name). ``fsync=True`` upgrades appends to full durability —
    unnecessary for a cache, available for tests that assert replay.
    """

    def __init__(self, path: str | os.PathLike, *, scope: str | None = None,
                 fsync: bool = False):
        self.path = str(path)
        self.scope = scope or os.path.basename(os.path.dirname(self.path)) or "cas"
        self.fsync = fsync
        self._lock = threading.Lock()
        self._fh = None
        # (digest_hex, length) -> {(path, offset): None}  (ordered set)
        self._entries: dict[tuple[str, int], dict[tuple[str, int], None]] = {}
        self.torn_tail_bytes = 0
        parent = os.path.dirname(self.path)
        if parent:
            os.makedirs(parent, exist_ok=True)
        if os.path.exists(self.path):
            self._replay()
        self._fh = open(self.path, "a", encoding="utf-8")

    # -- replay ------------------------------------------------------------
    def _replay(self) -> None:
        data, valid_end = replay_checked_lines(self.path, self._apply)
        self.torn_tail_bytes = len(data) - valid_end
        if self.torn_tail_bytes:
            with open(self.path, "r+b") as fh:
                fh.truncate(valid_end)

    def _apply(self, body: dict) -> None:
        key = (body["digest"], int(body["length"]))
        loc = (body["path"], int(body["offset"]))
        if body["op"] == "put":
            self._entries.setdefault(key, {})[loc] = None
        else:  # "del"
            locs = self._entries.get(key)
            if locs is not None:
                locs.pop(loc, None)
                if not locs:
                    self._entries.pop(key, None)

    # -- appends -----------------------------------------------------------
    def _append(self, body: dict) -> None:
        line = checked_line(body)
        assert self._fh is not None
        self._fh.write(line + "\n")
        self._fh.flush()
        if self.fsync:
            os.fsync(self._fh.fileno())

    def put(self, digest_hex: str, length: int, path: str, offset: int) -> bool:
        """Record a landed region; returns False if already indexed."""
        key = (digest_hex, int(length))
        loc = (str(path), int(offset))
        with self._lock:
            locs = self._entries.setdefault(key, {})
            if loc in locs:
                return False
            locs[loc] = None
            self._append({"op": "put", "digest": digest_hex,
                          "length": int(length), "path": loc[0],
                          "offset": loc[1]})
        return True

    def discard(self, digest_hex: str, length: int, path: str, offset: int) -> bool:
        """Drop one location (stale entry, deleted file); returns found."""
        key = (digest_hex, int(length))
        loc = (str(path), int(offset))
        with self._lock:
            locs = self._entries.get(key)
            if locs is None or loc not in locs:
                return False
            locs.pop(loc)
            if not locs:
                self._entries.pop(key)
            self._append({"op": "del", "digest": digest_hex,
                          "length": int(length), "path": loc[0],
                          "offset": loc[1]})
        return True

    # -- probes ------------------------------------------------------------
    def lookup(self, digest_hex: str, length: int) -> tuple[IndexEntry, ...]:
        """Every indexed location of this content (may be stale — verify!)."""
        with self._lock:
            locs = self._entries.get((digest_hex, int(length)), {})
            out = tuple(IndexEntry(digest_hex, int(length), p, o)
                        for p, o in locs)
        if out:
            _M_HITS.inc(1, index=self.scope)
        else:
            _M_MISSES.inc(1, index=self.scope)
        return out

    def note_stale(self, n: int = 1) -> None:
        """Metric hook: a hit's backing bytes failed re-verification."""
        _M_STALE.inc(n, index=self.scope)

    @staticmethod
    def read_region(entry: IndexEntry) -> bytes:
        """Read an entry's backing bytes (pread; raises OSError when gone)."""
        with open(entry.path, "rb") as fh:
            data = os.pread(fh.fileno(), entry.length, entry.offset)
        if len(data) != entry.length:
            raise OSError(
                f"indexed region truncated: {entry.path} @ {entry.offset} "
                f"has {len(data)}/{entry.length} bytes"
            )
        return data

    def verify_entry(self, entry: IndexEntry) -> bytes | None:
        """Read-back fingerprint an entry; bytes when genuine, None when
        stale (missing/truncated/corrupted backing). Never raises — a stale
        entry is an expected condition, not an error."""
        try:
            data = self.read_region(entry)
        except OSError:
            return None
        expected = Digest.from_bytes(bytes.fromhex(entry.digest_hex))
        if not verify(expected, fingerprint_bytes(data)):
            return None
        return data

    # -- maintenance -------------------------------------------------------
    def compact(self) -> dict:
        """Rewrite live entries only; atomic replace (same discipline as
        ``ChunkJournal.compact``). Returns before/after byte counts."""
        with self._lock:
            before = os.path.getsize(self.path) if os.path.exists(self.path) else 0
            tmp = self.path + ".compact.tmp"
            n = 0
            with open(tmp, "w", encoding="utf-8") as fh:
                for (digest_hex, length), locs in sorted(self._entries.items()):
                    for p, o in locs:
                        fh.write(checked_line(
                            {"op": "put", "digest": digest_hex,
                             "length": length, "path": p, "offset": o}) + "\n")
                        n += 1
                fh.flush()
                os.fsync(fh.fileno())
            if self._fh is not None:
                self._fh.close()
            os.replace(tmp, self.path)
            self._fh = open(self.path, "a", encoding="utf-8")
            self.torn_tail_bytes = 0
            after = os.path.getsize(self.path)
        return {"records": n, "bytes_before": before, "bytes_after": after}

    def stats(self) -> dict:
        with self._lock:
            n_locs = sum(len(v) for v in self._entries.values())
            indexed = sum(k[1] * len(v) for k, v in self._entries.items())
            return {
                "digests": len(self._entries),
                "locations": n_locs,
                "indexed_bytes": indexed,
                "log_bytes": os.path.getsize(self.path)
                if os.path.exists(self.path) else 0,
                "hits": _M_HITS.value(index=self.scope),
                "misses": _M_MISSES.value(index=self.scope),
                "stale": _M_STALE.value(index=self.scope),
            }

    @property
    def n_digests(self) -> int:
        with self._lock:
            return len(self._entries)

    @property
    def n_locations(self) -> int:
        with self._lock:
            return sum(len(v) for v in self._entries.values())

    def entries(self) -> tuple[IndexEntry, ...]:
        """Every live entry (deterministic order; tests + gc tooling)."""
        with self._lock:
            return tuple(
                IndexEntry(d, ln, p, o)
                for (d, ln), locs in sorted(self._entries.items())
                for p, o in locs
            )

    def close(self) -> None:
        with self._lock:
            if self._fh is not None:
                self._fh.close()
                self._fh = None

    def __enter__(self) -> "ChunkIndex":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def seed_index_from_manifest(index: ChunkIndex, manifest: dict,
                             save_dir: str | os.PathLike) -> int:
    """Register a previous checkpoint save's chunks in the index.

    A checkpoint MANIFEST.json already catalogs every leaf's chunks with
    their merge-law digests — it IS a content index of the save directory.
    Seeding the destination's ChunkIndex from it turns the next save into a
    delta: the dedup negotiation satisfies every unchanged chunk by a local
    copy from the previous save's files and only changed chunks ride the
    wire. Returns the number of entries registered.
    """
    n = 0
    for leaf in manifest.get("leaves", {}).values():
        path = os.path.abspath(os.path.join(str(save_dir), leaf["file"]))
        for c in leaf.get("chunks", ()):
            if index.put(c["digest"], int(c["length"]), path, int(c["offset"])):
                n += 1
    return n
