"""Content-addressed chunk store — the content plane.

The merge-law digest algebra (``core.integrity``) gives every chunk a
stable fingerprint, yet the data plane re-moves every byte of every
repeated dataset: evolving climate archives are re-published with most
bytes unchanged, and repeated checkpoint saves differ by a few percent.
This package closes that gap with the replica-catalog idea from the
classic Globus replica-management work, rebuilt on the repo's own digest
algebra:

  * ``ChunkIndex`` — a per-endpoint map from merge-law chunk digests to
    landed byte regions, persisted in a self-checksummed append log with
    crash-safe replay and compaction (the same torn-tail discipline as
    ``core.journal``), populated automatically as verified chunks commit;
  * dedup negotiation lives in ``core.transfer`` (engine) and
    ``repro.service`` (tasks): before movers start, the plan's chunk
    digests are probed against the destination's index and already-present
    chunks are satisfied by a destination-local copy (or a pure index
    insert for same-target aliases) instead of wire moves. Every hit is
    re-verified by a read-back fingerprint first — a stale entry demotes
    to a normal wire move with a quarantine record, so the 0-escape
    guarantee is unconditional;
  * ``seed_index_from_manifest`` — delta checkpoints: a previous save's
    MANIFEST.json is itself a chunk catalog; seeding the index from it
    makes the next save move only changed chunks.

Skipped chunks still fold into the whole-file digest chain
(``combine_at_offsets``), so end-to-end integrity verification is
unchanged whether a chunk arrived over the wire or from the index.
"""
from repro.cas.index import (
    ChunkIndex,
    DedupStats,
    IndexEntry,
    seed_index_from_manifest,
)

__all__ = ["ChunkIndex", "DedupStats", "IndexEntry", "seed_index_from_manifest"]
