"""Multi-endpoint WAN fabric topology: endpoints, links, and route planning.

The paper's workloads move data "to, from, and among leadership computing
facilities, as well as other scientific facilities and the home institutions
of facility users" — a *graph* of endpoints, not a single pipe. This module
is the fabric's control-plane map:

  * ``Endpoint`` — one facility DTN pool: mover caps, staging-storage and
    checksum rates, whether it may act as a store-and-forward relay, and a
    scheduled-outage calendar (``core.vclock.Window``);
  * ``Link`` — one directed WAN edge with bandwidth, RTT, and packet loss.
    Loss degrades achievable bandwidth via the Mathis throughput bound
    applied to the paper's 64 movers x 4 TCP streams;
  * ``Topology`` — the registry + adjacency, with JSON round-tripping for
    the CLI (``transferd fabric --topology fabric.json``);
  * ``RoutePlanner`` — congestion-aware route planning: Dijkstra on per-link
    traversal seconds (RTT + bytes over the *residual* capacity after
    already-committed flows), Yen's algorithm for k-shortest simple paths,
    and a multi-source variant used by the campaign distribution-tree
    builder. Only ``relay``-capable endpoints may appear as intermediate
    store-and-forward hops.

Canonical shapes used by benchmarks and tests (``star_topology``,
``shared_trunk_topology``, ``fat_tree_topology``) are built here too, so the
"1 -> N over a shared trunk" wire-byte experiments are reproducible from a
single seed.
"""
from __future__ import annotations

import dataclasses
import heapq
import json
import math
import os
from typing import Iterable, Sequence

from repro.core.simulator import SiteConfig
from repro.core.vclock import Window

Gb = 1e9 / 8.0                     # bytes per Gigabit

# Mathis et al. TCP throughput bound, applied per stream with the paper's
# transfer shape (64 movers x 4 TCP streams): achievable <= C * MSS / (RTT *
# sqrt(loss)) per stream. Zero loss leaves the link at its configured rate.
MATHIS_C = 1.22
MSS_BYTES = 1460
DEFAULT_STREAMS = 64 * 4


class NoRouteError(RuntimeError):
    """No usable path between two endpoints (partition, outage, or caps)."""


@dataclasses.dataclass(frozen=True)
class Endpoint:
    """One facility's DTN pool as seen by the fabric control plane."""

    name: str
    movers: int = 64                 # concurrent data movers at this endpoint
    mover_gbps: float = 3.2          # per-mover network ceiling (paper §4)
    storage_gbps: float = 100.0      # staging-store ingest/egress ceiling
    cksum_gbps: float = 5.2          # per-mover re-read + checksum rate
    relay: bool = True               # may stage chunks as an intermediate hop
    outages: tuple[Window, ...] = () # scheduled maintenance windows

    def available(self, t: float) -> bool:
        return not any(w.contains(t) for w in self.outages)

    @property
    def net_gbps(self) -> float:
        """Aggregate mover-pool network ceiling."""
        return self.movers * self.mover_gbps

    def to_site(self) -> SiteConfig:
        """Project onto the calibrated simulator's site model.

        ``ost_gbps = storage_gbps`` makes the file-level stripe cap saturate
        at the staging-store ceiling, which is the right single-file model
        for a DTN staging area (no Lustre stripe sweep inside the fabric).
        """
        return SiteConfig(
            name=self.name, movers=self.movers, mover_gbps=self.mover_gbps,
            site_io_gbps=self.storage_gbps, ost_gbps=self.storage_gbps,
            cksum_gbps=self.cksum_gbps,
        )

    def to_json(self) -> dict:
        return {
            "name": self.name, "movers": self.movers,
            "mover_gbps": self.mover_gbps, "storage_gbps": self.storage_gbps,
            "cksum_gbps": self.cksum_gbps, "relay": self.relay,
            "outages": [[w.start, w.duration] for w in self.outages],
        }

    @staticmethod
    def from_json(obj: dict) -> "Endpoint":
        return Endpoint(
            name=obj["name"], movers=int(obj.get("movers", 64)),
            mover_gbps=float(obj.get("mover_gbps", 3.2)),
            storage_gbps=float(obj.get("storage_gbps", 100.0)),
            cksum_gbps=float(obj.get("cksum_gbps", 5.2)),
            relay=bool(obj.get("relay", True)),
            outages=tuple(Window(float(s), float(d))
                          for s, d in obj.get("outages", ())),
        )


@dataclasses.dataclass(frozen=True)
class Link:
    """One directed WAN edge."""

    src: str
    dst: str
    gbps: float = 100.0
    rtt_ms: float = 20.0
    loss: float = 0.0                # packet-loss fraction in [0, 1)

    def __post_init__(self):
        if self.gbps <= 0:
            raise ValueError(f"link {self.src}->{self.dst}: gbps must be > 0")
        if not (0.0 <= self.loss < 1.0):
            raise ValueError(f"link {self.src}->{self.dst}: loss must be in [0, 1)")

    @property
    def effective_gbps(self) -> float:
        """Loss-degraded achievable bandwidth (Mathis bound, 256 streams)."""
        if self.loss <= 0.0:
            return self.gbps
        per_stream_bps = (
            MATHIS_C * MSS_BYTES * 8 / ((self.rtt_ms / 1e3) * math.sqrt(self.loss))
        )
        return min(self.gbps, DEFAULT_STREAMS * per_stream_bps / 1e9)

    @property
    def rtt_s(self) -> float:
        return self.rtt_ms / 1e3

    def to_json(self) -> dict:
        return {"src": self.src, "dst": self.dst, "gbps": self.gbps,
                "rtt_ms": self.rtt_ms, "loss": self.loss}


@dataclasses.dataclass(frozen=True)
class Route:
    """One simple path through the fabric, with the planner's cost estimate."""

    nodes: tuple[str, ...]
    seconds: float = 0.0             # planner traversal estimate (not a sim)

    def __post_init__(self):
        if len(self.nodes) < 2:
            raise ValueError("a route needs at least two endpoints")
        if len(set(self.nodes)) != len(self.nodes):
            raise ValueError(f"route revisits an endpoint: {self.nodes}")

    @property
    def hops(self) -> tuple[tuple[str, str], ...]:
        return tuple(zip(self.nodes[:-1], self.nodes[1:]))

    @property
    def n_hops(self) -> int:
        return len(self.nodes) - 1

    @property
    def src(self) -> str:
        return self.nodes[0]

    @property
    def dst(self) -> str:
        return self.nodes[-1]


class Topology:
    """Endpoint registry + directed link graph."""

    def __init__(self):
        self._endpoints: dict[str, Endpoint] = {}
        self._links: dict[tuple[str, str], Link] = {}
        self._adj: dict[str, list[str]] = {}

    # -- construction -------------------------------------------------------
    def add_endpoint(self, ep: Endpoint | str, **kw) -> Endpoint:
        if isinstance(ep, str):
            ep = Endpoint(name=ep, **kw)
        elif kw:
            ep = dataclasses.replace(ep, **kw)
        if ep.name in self._endpoints:
            raise ValueError(f"duplicate endpoint {ep.name!r}")
        self._endpoints[ep.name] = ep
        self._adj.setdefault(ep.name, [])
        return ep

    def add_link(self, src: str, dst: str, *, gbps: float = 100.0,
                 rtt_ms: float = 20.0, loss: float = 0.0,
                 bidirectional: bool = True) -> None:
        for name in (src, dst):
            if name not in self._endpoints:
                raise ValueError(f"link references unknown endpoint {name!r}")
        pairs = [(src, dst)] + ([(dst, src)] if bidirectional else [])
        for u, v in pairs:
            if (u, v) in self._links:
                raise ValueError(f"duplicate link {u}->{v}")
            self._links[(u, v)] = Link(u, v, gbps=gbps, rtt_ms=rtt_ms, loss=loss)
            self._adj[u].append(v)

    # -- queries ------------------------------------------------------------
    @property
    def endpoints(self) -> dict[str, Endpoint]:
        return dict(self._endpoints)

    @property
    def links(self) -> dict[tuple[str, str], Link]:
        return dict(self._links)

    def endpoint(self, name: str) -> Endpoint:
        try:
            return self._endpoints[name]
        except KeyError:
            raise KeyError(f"unknown endpoint {name!r}") from None

    def link(self, u: str, v: str) -> Link:
        try:
            return self._links[(u, v)]
        except KeyError:
            raise KeyError(f"no link {u}->{v}") from None

    def neighbors(self, u: str) -> tuple[str, ...]:
        return tuple(self._adj.get(u, ()))

    # -- serialization (CLI topology files) ---------------------------------
    def to_json(self) -> dict:
        # a symmetric pair is stored once (bidirectional: true); an
        # asymmetric reverse link keeps its own directed entry
        emitted: set[tuple[str, str]] = set()
        links = []
        for (u, v), ln in sorted(self._links.items()):
            if (u, v) in emitted:
                continue
            rev = self._links.get((v, u))
            bidi = rev is not None and rev == Link(
                v, u, gbps=ln.gbps, rtt_ms=ln.rtt_ms, loss=ln.loss)
            links.append({**ln.to_json(), "bidirectional": bidi})
            emitted.add((u, v))
            if bidi:
                emitted.add((v, u))
        return {
            "endpoints": [ep.to_json() for _, ep in sorted(self._endpoints.items())],
            "links": links,
        }

    @staticmethod
    def from_json(obj: dict) -> "Topology":
        topo = Topology()
        for e in obj.get("endpoints", ()):
            topo.add_endpoint(Endpoint.from_json(e))
        for ln in obj.get("links", ()):
            topo.add_link(
                ln["src"], ln["dst"], gbps=float(ln.get("gbps", 100.0)),
                rtt_ms=float(ln.get("rtt_ms", 20.0)),
                loss=float(ln.get("loss", 0.0)),
                bidirectional=bool(ln.get("bidirectional", True)),
            )
        return topo

    @staticmethod
    def load(path: str | os.PathLike) -> "Topology":
        with open(path, "r", encoding="utf-8") as fh:
            return Topology.from_json(json.load(fh))

    def save(self, path: str | os.PathLike) -> None:
        with open(path, "w", encoding="utf-8") as fh:
            json.dump(self.to_json(), fh, indent=2, sort_keys=True)


# ---------------------------------------------------------------------------
# route planning
# ---------------------------------------------------------------------------
class RoutePlanner:
    """Congestion-aware shortest / k-shortest route planning.

    Per-link traversal cost for a payload of ``nbytes``:

        rtt + nbytes / min(residual link bandwidth, endpoint ceilings)

    where residual bandwidth is the link's loss-degraded capacity minus the
    Gb/s already committed through it (``commit``/``release``), floored at
    ``min_residual_frac`` of capacity so a saturated link stays *expensive*
    rather than unreachable. Endpoint ceilings are the mover-pool and
    staging-store rates of both ends, so a slow DTN penalizes every route
    through it. Non-``relay`` endpoints are never used as intermediate hops,
    and endpoints inside a scheduled outage window at ``now`` are skipped.
    """

    def __init__(self, topo: Topology, *, min_residual_frac: float = 0.02):
        self.topo = topo
        self.min_residual_frac = min_residual_frac
        self._load: dict[tuple[str, str], float] = {}

    # -- congestion bookkeeping ---------------------------------------------
    def committed_gbps(self, u: str, v: str) -> float:
        return self._load.get((u, v), 0.0)

    def commit(self, route: Route, gbps: float) -> None:
        for u, v in route.hops:
            self._load[(u, v)] = self._load.get((u, v), 0.0) + gbps

    def release(self, route: Route, gbps: float) -> None:
        for u, v in route.hops:
            left = self._load.get((u, v), 0.0) - gbps
            if left <= 1e-12:
                self._load.pop((u, v), None)
            else:
                self._load[(u, v)] = left

    # -- cost model ---------------------------------------------------------
    def hop_gbps(self, u: str, v: str) -> float:
        """Residual end-to-end capacity of one hop (link + both endpoints)."""
        link = self.topo.link(u, v)
        residual = max(
            link.effective_gbps - self.committed_gbps(u, v),
            link.effective_gbps * self.min_residual_frac,
        )
        a, b = self.topo.endpoint(u), self.topo.endpoint(v)
        return min(residual, a.net_gbps, a.storage_gbps, b.net_gbps, b.storage_gbps)

    def hop_seconds(self, u: str, v: str, nbytes: int) -> float:
        link = self.topo.link(u, v)
        return link.rtt_s + nbytes / (self.hop_gbps(u, v) * Gb)

    def route_seconds(self, nodes: Sequence[str], nbytes: int) -> float:
        return sum(self.hop_seconds(u, v, nbytes) for u, v in zip(nodes, nodes[1:]))

    # -- shortest path ------------------------------------------------------
    def _usable(self, name: str, *, now: float, terminals: frozenset[str]) -> bool:
        ep = self.topo.endpoint(name)
        if not ep.available(now):
            return False
        return ep.relay or name in terminals

    def shortest_from_set(
        self, sources: Iterable[str], dst: str, nbytes: int, *,
        now: float = 0.0, banned_links: frozenset[tuple[str, str]] = frozenset(),
        banned_nodes: frozenset[str] = frozenset(),
    ) -> Route:
        """Multi-source Dijkstra: cheapest route from ANY source to ``dst``.

        The campaign tree builder grows a Steiner-ish tree with this: every
        node already in the tree is a zero-cost source, so a new destination
        attaches at the cheapest grafting point and shared trunk links are
        paid for exactly once.
        """
        sources = [s for s in sources if s not in banned_nodes]
        if not sources:
            raise NoRouteError(f"no usable source for {dst!r}")
        terminals = frozenset(sources) | {dst}
        dist: dict[str, float] = {s: 0.0 for s in sources}
        prev: dict[str, str | None] = {s: None for s in sources}
        heap: list[tuple[float, str]] = [(0.0, s) for s in sources]
        heapq.heapify(heap)
        settled: set[str] = set()
        while heap:
            d, u = heapq.heappop(heap)
            if u in settled:
                continue
            settled.add(u)
            if u == dst:
                nodes = [u]
                while prev[nodes[-1]] is not None:
                    nodes.append(prev[nodes[-1]])
                nodes.reverse()
                return Route(tuple(nodes), seconds=d)
            # only relay-capable (or terminal) nodes may be expanded through
            if u != dst and not self._usable(u, now=now, terminals=terminals):
                continue
            for v in self.topo.neighbors(u):
                if v in settled or v in banned_nodes or (u, v) in banned_links:
                    continue
                if not self._usable(v, now=now, terminals=terminals):
                    continue
                nd = d + self.hop_seconds(u, v, nbytes)
                if nd < dist.get(v, math.inf):
                    dist[v] = nd
                    prev[v] = u
                    heapq.heappush(heap, (nd, v))
        raise NoRouteError(f"no route to {dst!r} (from {sorted(sources)})")

    def best_route(self, src: str, dst: str, nbytes: int, *, now: float = 0.0) -> Route:
        if src == dst:
            raise ValueError("source and destination endpoints are identical")
        return self.shortest_from_set([src], dst, nbytes, now=now)

    def k_shortest(self, src: str, dst: str, nbytes: int, k: int, *,
                   now: float = 0.0) -> list[Route]:
        """Yen's algorithm: the k cheapest loop-free routes, cost-ordered."""
        if k < 1:
            raise ValueError("k must be >= 1")
        best = [self.best_route(src, dst, nbytes, now=now)]
        candidates: list[tuple[float, tuple[str, ...]]] = []
        seen: set[tuple[str, ...]] = {best[0].nodes}
        while len(best) < k:
            last = best[-1].nodes
            for i in range(len(last) - 1):
                spur, root = last[i], last[: i + 1]
                banned_links = {
                    (p[i], p[i + 1]) for p in (r.nodes for r in best)
                    if len(p) > i + 1 and p[: i + 1] == root
                }
                banned_nodes = frozenset(root[:-1])
                try:
                    tail = self.shortest_from_set(
                        [spur], dst, nbytes, now=now,
                        banned_links=frozenset(banned_links),
                        banned_nodes=banned_nodes,
                    )
                except NoRouteError:
                    continue
                nodes = root[:-1] + tail.nodes
                if nodes in seen:
                    continue
                seen.add(nodes)
                heapq.heappush(
                    candidates, (self.route_seconds(nodes, nbytes), nodes))
            if not candidates:
                break
            cost, nodes = heapq.heappop(candidates)
            best.append(Route(nodes, seconds=cost))
        return best


# ---------------------------------------------------------------------------
# canonical topologies (benchmarks + tests)
# ---------------------------------------------------------------------------
def star_topology(n_dests: int, *, trunk_gbps: float = 100.0,
                  leaf_gbps: float = 100.0, rtt_ms: float = 20.0,
                  relay_storage_gbps: float = 400.0) -> Topology:
    """``src -- hub -- {d0..dN-1}``: one shared first hop, N leaf links.

    Relay DTNs get ``relay_storage_gbps`` staging stores: a fan-out node
    re-reads the staged payload once per downstream branch.
    """
    topo = Topology()
    topo.add_endpoint("src")
    topo.add_endpoint("hub", storage_gbps=relay_storage_gbps)
    topo.add_link("src", "hub", gbps=trunk_gbps, rtt_ms=rtt_ms)
    for i in range(n_dests):
        topo.add_endpoint(f"d{i}")
        topo.add_link("hub", f"d{i}", gbps=leaf_gbps, rtt_ms=rtt_ms)
    return topo


def shared_trunk_topology(n_dests: int, *, trunk_hops: int = 3,
                          trunk_gbps: float = 100.0, leaf_gbps: float = 100.0,
                          rtt_ms: float = 20.0,
                          relay_storage_gbps: float = 400.0) -> Topology:
    """``src -- r1 -- ... -- r<trunk_hops> -- {d0..dN-1}``.

    The continental-trunk shape of the climate-replication case study: every
    replica shares ``trunk_hops`` WAN links before fanning out, so naive
    per-destination transfers pay the trunk N times while a campaign
    distribution tree pays it once.
    """
    if trunk_hops < 1:
        raise ValueError("trunk_hops must be >= 1")
    topo = Topology()
    topo.add_endpoint("src")
    prev = "src"
    for h in range(1, trunk_hops + 1):
        topo.add_endpoint(f"r{h}", storage_gbps=relay_storage_gbps)
        topo.add_link(prev, f"r{h}", gbps=trunk_gbps, rtt_ms=rtt_ms)
        prev = f"r{h}"
    for i in range(n_dests):
        topo.add_endpoint(f"d{i}")
        topo.add_link(prev, f"d{i}", gbps=leaf_gbps, rtt_ms=rtt_ms)
    return topo


def fat_tree_topology(n_dests: int, *, core_gbps: float = 400.0,
                      agg_gbps: float = 200.0, leaf_gbps: float = 100.0,
                      rtt_ms: float = 10.0, aggs: int = 2) -> Topology:
    """``src -- core -- {agg_j} -- {d_i}``: two-level distribution tree."""
    if aggs < 1:
        raise ValueError("aggs must be >= 1")
    topo = Topology()
    topo.add_endpoint("src")
    topo.add_endpoint("core", storage_gbps=4 * leaf_gbps)
    topo.add_link("src", "core", gbps=core_gbps, rtt_ms=rtt_ms)
    for j in range(aggs):
        topo.add_endpoint(f"agg{j}", storage_gbps=2 * leaf_gbps)
        topo.add_link("core", f"agg{j}", gbps=agg_gbps, rtt_ms=rtt_ms)
    for i in range(n_dests):
        topo.add_endpoint(f"d{i}")
        topo.add_link(f"agg{i % aggs}", f"d{i}", gbps=leaf_gbps, rtt_ms=rtt_ms)
    return topo


# One canonical fan-out factory map (name -> fn(n_dests) -> Topology) shared
# by the CLI (``transferd fabric --topology``) and ``benchmarks/fabric.py``,
# so the shape users reproduce is exactly the shape the CI wire-byte gate
# measures. "chain" is the shared-trunk case-study shape (3 WAN trunk hops).
BUILTIN_TOPOLOGIES = {
    "chain": lambda n: shared_trunk_topology(n, trunk_hops=3),
    "star": star_topology,
    "fat_tree": fat_tree_topology,
}
