"""Multi-hop store-and-forward relay transfers with per-hop chunk custody.

A relay moves one payload along a fabric ``Route`` (origin -> intermediate
DTNs -> destination). The chunk plan is computed ONCE and shared by every
hop, so a chunk is the unit of *custody*: hop ``h`` journals chunk ``c`` the
moment it has landed (and been read-back verified) at stage ``h+1``, in a
per-hop ``core.journal.ChunkJournal``. That gives the fabric the paper's
partial-restart guarantee at every hop:

  * a chunk that reached an intermediate DTN is NEVER re-pulled from the
    origin after a crash — the restarted relay replays each hop's journal
    and resumes exactly the chunks still missing at that hop;
  * hops are pipelined chunk-wise: chunk ``c`` starts crossing hop ``h+1``
    as soon as hop ``h`` lands it, so relay makespan approaches the slowest
    hop, not the sum of hops;
  * integrity composes along the chain: each hop fingerprints what it read,
    verifies it against the upstream hop's journaled custody digest (staging
    bit-rot detection), write-verifies by destination read-back (in-flight
    corruption detection + re-fetch healing), and the final replica's
    merge-law digest must equal the origin digest.

Chaos hooks mirror the service: per-hop source/dest wrappers let
``repro.faults`` campaigns corrupt, outage, stall, or kill each hop's data
path independently; ``realize_hop_campaigns`` maps the scenario DSL's fabric
faults (``link_outage_at_50pct``, ``degrade_hop``) onto seeded victim hops.
"""
from __future__ import annotations

import dataclasses
import os
import queue
import random
import threading
from typing import Callable

from repro.core.backoff import Backoff
from repro.core.chunker import Chunk, ChunkPlan, plan_chunks
from repro.core.integrity import (
    Digest,
    combine_at_offsets,
    fingerprint_bytes,
    fingerprint_many,
    merge_all,
    verify,
)
from repro.core.journal import ChunkJournal, JournalRecord
from repro.core.transfer import (
    ByteDest,
    ByteSource,
    EndpointOutage,
    FileDest,
    FileSource,
    IntegrityError,
    MoverCrash,
)
from repro.faults.injectors import FaultCampaign, _seed_int
from repro.faults.scenarios import Scenario
from repro.fabric.topology import NoRouteError, Route
from repro.obs.clock import mono_s
from repro.obs.trace import NULL as _NULL_TRACER
from repro.tune.controller import ChunkController
from repro.tune.probe import ChunkSample


# ---------------------------------------------------------------------------
# reports
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class HopReport:
    """Per-hop outcome of one relay incarnation."""

    hop: int
    src: str
    dst: str
    moved_chunks: int = 0        # chunks this incarnation landed at this hop
    resumed_chunks: int = 0      # custody restored from the hop journal
    moved_bytes: int = 0         # custody bytes moved by this incarnation
    retries: int = 0
    refetches: int = 0           # corrupt landings healed by hop-local re-read
    outage_retries: int = 0
    mover_deaths: int = 0
    # per-hop autotuning: the transfer granule this hop settled on (chunks
    # stay the custody unit; a degraded hop only shrinks its own I/O units)
    granule_bytes: int = 0
    granule_replans: int = 0


@dataclasses.dataclass
class RelayReport:
    route: Route
    total_bytes: int
    n_chunks: int
    hops: list[HopReport]
    seconds: float
    file_digest: Digest          # merge-law combine of the final hop's custody
    # -- resilience: route failovers this incarnation performed
    retired_hops: list[HopReport] = dataclasses.field(default_factory=list)
    failovers: int = 0
    re_moved_journaled: int = 0  # invariant: stays 0 (custody handoff works)
    failover_events: list[dict] = dataclasses.field(default_factory=list)

    @property
    def wire_bytes(self) -> int:
        """Custody bytes moved across all hops by THIS incarnation."""
        return sum(h.moved_bytes for h in self.hops + self.retired_hops)

    @property
    def resumed_chunks(self) -> int:
        return sum(h.resumed_chunks for h in self.hops)

    @property
    def mover_deaths(self) -> int:
        return sum(h.mover_deaths for h in self.hops + self.retired_hops)

    @property
    def refetches(self) -> int:
        return sum(h.refetches for h in self.hops + self.retired_hops)


# ---------------------------------------------------------------------------
# relay engine
# ---------------------------------------------------------------------------
class _Hop:
    """Mutable per-hop execution state."""

    __slots__ = ("idx", "u", "v", "source", "dest", "journal", "ready",
                 "done", "digests", "report", "workers", "granule",
                 "controller", "dead", "inflight", "upstream")

    def __init__(self, idx: int, u: str, v: str, source: ByteSource,
                 dest: ByteDest, journal: ChunkJournal,
                 upstream: "_Hop | None" = None):
        self.idx, self.u, self.v = idx, u, v
        self.source, self.dest, self.journal = source, dest, journal
        self.ready: "queue.Queue[Chunk | None]" = queue.Queue()
        self.done: set[int] = set(journal.records)
        self.digests: dict[int, Digest] = {
            i: rec.digest() for i, rec in journal.records.items()
        }
        self.report = HopReport(idx, u, v, resumed_chunks=len(self.done))
        self.workers = 0
        self.granule = 0                  # 0 = whole-chunk moves (untuned)
        self.controller: ChunkController | None = None
        self.dead = False                 # retired by a route failover
        self.inflight: set[int] = set()   # chunks claimed by a mover
        self.upstream = upstream          # None = reads the origin source


class _FailoverSignal(Exception):
    """Internal: a hop wants the remaining route re-planned around its
    sick link (never escapes ``RelayTransfer``)."""


class RelayTransfer:
    """Executes one route-pipelined, custody-journaled relay transfer.

    ``workdir`` holds the per-hop journals and intermediate staging files;
    re-running with the same workdir resumes: every hop skips its journaled
    chunks, so a crash costs only the chunks in flight at crash time — at
    the hop they were crossing, never upstream.
    """

    def __init__(
        self,
        route: Route,
        source: ByteSource,
        dest: ByteDest,
        *,
        workdir: str | os.PathLike,
        chunk_bytes: int | None = None,
        plan: ChunkPlan | None = None,
        movers: int = 4,
        integrity: bool = True,
        max_retries: int = 3,
        max_refetches: int = 3,
        outage_retries: int = 64,
        outage_backoff_s: float = 0.002,
        max_mover_deaths: int = 16,
        retry_backoff_s: float = 0.002,
        source_wrapper: Callable[[int, ByteSource], ByteSource] | None = None,
        dest_wrapper: Callable[[int, ByteDest], ByteDest] | None = None,
        fault_injector: Callable[[int, Chunk, int], None] | None = None,
        tuning: bool = False,              # per-hop transfer-granule control
        granule_min: int = 64 * 1024,
        tune_epoch_chunks: int = 3,
        tune_hops: "set[int] | frozenset[int] | None" = None,  # None = all hops
        tracer=None,                       # obs.trace.Tracer; spans carry hop=
        task: str = "",
        backoff_seed: int = 0,             # de-correlates mover retry instants
        planner=None,                      # fabric.topology.RoutePlanner
        failover: bool = False,            # re-plan around dead links mid-flight
        failover_outage_threshold: int = 8,
        health=None,                       # resil.health.HealthTracker (shared)
        link_source_wrapper: Callable[[str, str, ByteSource], ByteSource] | None = None,
        link_dest_wrapper: Callable[[str, str, ByteDest], ByteDest] | None = None,
    ):
        if movers < 1:
            raise ValueError("movers must be >= 1")
        if failover and planner is None:
            raise ValueError("failover requires a planner to re-plan routes")
        if failover_outage_threshold < 1:
            raise ValueError("failover_outage_threshold must be >= 1")
        self.tracer = tracer if tracer is not None else _NULL_TRACER
        self.task = task or f"relay:{'-'.join(route.nodes)}"
        self.route = route
        self.workdir = str(workdir)
        os.makedirs(self.workdir, exist_ok=True)
        self.total_bytes = source.nbytes
        self.plan = plan or plan_chunks(
            self.total_bytes, movers, chunk_bytes=chunk_bytes,
            min_chunk=1, max_chunk=1 << 62, alignment=1,
        )
        if self.plan.total_bytes != self.total_bytes:
            raise ValueError("chunk plan does not cover the source")
        self.movers = movers
        self.integrity = integrity
        self.max_retries = max_retries
        self.max_refetches = max_refetches
        self.outage_retries = outage_retries
        self.outage_backoff_s = outage_backoff_s
        self.max_mover_deaths = max_mover_deaths
        self.retry_backoff_s = retry_backoff_s
        self.backoff_seed = backoff_seed
        self._fault_injector = fault_injector
        self._lock = threading.Lock()
        self._cond = threading.Condition(self._lock)
        self._errors: list[BaseException] = []
        self._mover_deaths = 0
        self._threads: list[threading.Thread] = []

        # ---- resilience plane state
        self.planner = planner
        self.failover = failover
        self.failover_outage_threshold = failover_outage_threshold
        self.health = health
        self.failover_events: list[dict] = []
        self._fo_gen = 0
        self._re_moved = 0
        self._retired: list[_Hop] = []
        self._banned_links: set[tuple[str, str]] = set()
        self._banned_nodes: set[str] = set()
        # per-NODE custody: every chunk journaled as landed at that node.
        # Failover pre-populates replacement hops from this map, which is
        # what makes "re-move zero journaled chunks" structural rather than
        # best-effort.
        self._custody: dict[str, dict[int, Digest]] = {}

        # ---- per-hop endpoints: origin -> staging files -> final dest
        self._wrap_s = source_wrapper or (lambda _h, s: s)
        self._wrap_d = dest_wrapper or (lambda _h, d: d)
        self._link_wrap_s = link_source_wrapper
        self._link_wrap_d = link_dest_wrapper
        self._orig_source = source
        self._orig_dest = dest
        self._origin_node = route.nodes[0]
        self._final_node = route.nodes[-1]
        self.hops: list[_Hop] = []
        for h, (u, v) in enumerate(route.hops):
            self.hops.append(self._make_hop(
                h, u, v, self._journal_path(h, u, v),
                self.hops[h - 1] if h > 0 else None))
        for hop in self.hops:
            if hop.digests:
                self._custody.setdefault(hop.v, {}).update(hop.digests)
        # per-hop granule controllers: each hop adapts its own I/O unit
        # within [granule_min, chunk_bytes] — custody chunks are untouched,
        # so a degraded middle hop shrinks its own granule without forcing
        # the rest of the path (or the journals) to change
        nominal = self.plan.chunk_bytes
        if tuning and nominal > 0:
            lo = min(granule_min, nominal)
            for hop in self.hops:
                if tune_hops is not None and hop.idx not in tune_hops:
                    continue       # operator scoped tuning to specific hops
                hop.granule = nominal
                # noise-hardened thresholds: hop rates are wall-clock local
                # measurements, so only a halving reads as degradation and
                # probes need a 25% win to stick
                hop.controller = ChunkController(
                    chunk_bytes=nominal, min_chunk=lo, max_chunk=nominal,
                    epoch_chunks=tune_epoch_chunks,
                    degrade_threshold=0.5, hysteresis=0.25,
                    fast_md_streak=3,
                )
                hop.report.granule_bytes = nominal

    # -- paths ---------------------------------------------------------------
    def _stage(self, node: str) -> str:
        path = os.path.join(self.workdir, f"stage-{node}.bin")
        if not os.path.exists(path):
            # staging area preallocation (FileDest keeps a partial file, so a
            # crashed relay's journaled chunks stay on the intermediate DTN)
            with open(path, "wb") as fh:
                if self.total_bytes:
                    fh.truncate(self.total_bytes)
        return path

    def _journal_path(self, h: int, u: str, v: str) -> str:
        return os.path.join(self.workdir, f"hop{h:02d}-{u}--{v}.journal")

    @staticmethod
    def journal_paths(workdir: str | os.PathLike, route: Route) -> list[str]:
        """The custody journal path of every hop (for probes/tests)."""
        return [
            os.path.join(str(workdir), f"hop{h:02d}-{u}--{v}.journal")
            for h, (u, v) in enumerate(route.hops)
        ]

    # -- hop construction (shared by __init__ and failover re-plans) ---------
    def _make_hop(self, idx: int, u: str, v: str, journal_path: str,
                  upstream: "_Hop | None") -> _Hop:
        hop_src: ByteSource = (
            self._orig_source if u == self._origin_node
            else FileSource(self._stage(u)))
        hop_dst: ByteDest = (
            self._orig_dest if v == self._final_node
            else FileDest(self._stage(v), self.total_bytes))
        # node-keyed wrappers survive failover (a fault lives at an endpoint
        # or link, not at a position in whatever route happens to cross it)
        if self._link_wrap_s is not None:
            hop_src = self._link_wrap_s(u, v, hop_src)
        else:
            hop_src = self._wrap_s(idx, hop_src)
        if self._link_wrap_d is not None:
            hop_dst = self._link_wrap_d(u, v, hop_dst)
        else:
            hop_dst = self._wrap_d(idx, hop_dst)
        return _Hop(idx, u, v, hop_src, hop_dst,
                    ChunkJournal(journal_path), upstream)

    # -- worker wakeups (lock held by caller) --------------------------------
    def _wake_hop_locked(self, hop: _Hop) -> None:
        for _ in range(max(1, hop.workers)):
            hop.ready.put(None)

    def _wake_all_locked(self) -> None:
        for hop in self.hops:
            self._wake_hop_locked(hop)

    def _fail_locked(self, e: BaseException) -> None:
        self._errors.append(e)
        self._wake_all_locked()
        self._cond.notify_all()

    def _spawn_workers_locked(self, hop: _Hop) -> None:
        for m in range(self.movers):
            th = threading.Thread(
                target=self._worker, args=(hop,),
                name=f"relay-h{hop.idx}g{self._fo_gen}-m{m}", daemon=True,
            )
            hop.workers += 1
            th.start()
            self._threads.append(th)

    # -- execution -----------------------------------------------------------
    def run(self) -> RelayReport:
        t0 = mono_s()
        n = self.plan.n_chunks
        try:
            # seed each hop's ready queue: upstream custody present, own absent
            with self._lock:
                for hop in self.hops:
                    upstream = (
                        set(range(n)) if hop.upstream is None
                        else hop.upstream.done
                    )
                    for c in self.plan.chunks:
                        if c.index in upstream and c.index not in hop.done:
                            hop.ready.put(c)
                for hop in self.hops:
                    self._spawn_workers_locked(hop)
            with self._cond:
                while not self._finished_locked() and not self._errors:
                    self._cond.wait(0.05)
                self._wake_all_locked()
            # failover spawns replacement workers mid-run: join until the
            # thread list is quiescent, not just the initial snapshot
            while True:
                with self._lock:
                    threads = list(self._threads)
                for th in threads:
                    th.join()
                with self._lock:
                    if len(self._threads) == len(threads):
                        break
            if self._errors:
                raise self._errors[0]
            last = self.hops[-1]
            parts = [(self.plan.chunks[i].offset, d) for i, d in last.digests.items()]
            file_digest = combine_at_offsets(parts, self.total_bytes)
            origin = self.hops[0]
            origin_digest = combine_at_offsets(
                [(self.plan.chunks[i].offset, d) for i, d in origin.digests.items()],
                self.total_bytes,
            )
            if not verify(origin_digest, file_digest):
                raise IntegrityError(
                    f"relay end-to-end digest mismatch along {self.route.nodes}: "
                    f"origin {origin_digest.hexdigest()} != replica "
                    f"{file_digest.hexdigest()}"
                )
            return RelayReport(
                route=self.route, total_bytes=self.total_bytes, n_chunks=n,
                hops=[h.report for h in self.hops],
                seconds=mono_s() - t0, file_digest=file_digest,
                retired_hops=[h.report for h in self._retired],
                failovers=self._fo_gen,
                re_moved_journaled=self._re_moved,
                failover_events=list(self.failover_events),
            )
        finally:
            for hop in self.hops + self._retired:
                hop.journal.close()
            # root span covers the relay makespan even on a faulted exit, so
            # post-mortem attribution still sees the full window
            self.tracer.add(
                "relay", "task", t0, mono_s(), task=self.task,
                route="-".join(self.route.nodes), bytes=self.total_bytes,
                hops=self.route.n_hops,
            )

    def _finished_locked(self) -> bool:
        n = self.plan.n_chunks
        return all(len(h.done) >= n for h in self.hops)

    def _worker(self, hop: _Hop) -> None:
        try:
            while True:
                with self._lock:
                    if (self._errors or hop.dead
                            or len(hop.done) >= self.plan.n_chunks):
                        return
                # blocking get: the queue carries chunks and None sentinels
                # (error, hop completion, failover retirement) — no spin
                chunk = hop.ready.get()
                if chunk is None:
                    continue             # wakeup: re-check the exit conditions
                with self._lock:
                    if (hop.dead or chunk.index in hop.done
                            or chunk.index in hop.inflight):
                        continue
                    hop.inflight.add(chunk.index)
                try:
                    digest = self._move_chunk(hop, chunk)
                except _FailoverSignal:
                    with self._lock:
                        hop.inflight.discard(chunk.index)
                    self._failover(hop)
                    continue             # loop top sees hop.dead and exits
                except MoverCrash:
                    # the mover dies mid-write; the chunk survives it. The
                    # pool respawns in place (this thread carries on as the
                    # replacement) unless the relay-wide death budget is out.
                    with self._lock:
                        hop.inflight.discard(chunk.index)
                        self._mover_deaths += 1
                        hop.report.mover_deaths += 1
                        if self._mover_deaths > self.max_mover_deaths:
                            self._fail_locked(RuntimeError(
                                f"relay mover-death budget exhausted "
                                f"({self._mover_deaths} > {self.max_mover_deaths})"
                            ))
                            return
                    hop.ready.put(chunk)
                    continue
                except BaseException as e:  # noqa: BLE001 — fatal for the relay
                    with self._lock:
                        hop.inflight.discard(chunk.index)
                        if hop.dead:
                            continue     # retired mid-move: its faults are moot
                        self._fail_locked(e)
                    return
                with self._lock:
                    if hop.dead:         # retired while the move was in flight
                        hop.inflight.discard(chunk.index)
                        continue
                try:
                    t_j = mono_s()
                    hop.journal.append(JournalRecord(
                        chunk.index, chunk.offset, chunk.length, digest.hexdigest()
                    ))
                    self.tracer.add(
                        "custody_commit", "journal", t_j, mono_s(),
                        task=self.task, lane=f"hop{hop.idx}:journal",
                        offset=chunk.offset, index=chunk.index, hop=hop.idx,
                    )
                except Exception as e:  # noqa: BLE001 — dead journal: fail fast
                    with self._lock:
                        self._fail_locked(RuntimeError(
                            f"hop {hop.idx} journal append failed for chunk "
                            f"{chunk.index}: {e}"
                        ))
                    return
                nxt = None
                with self._lock:
                    hop.inflight.discard(chunk.index)
                    if hop.dead:
                        # retired while journaling: the replacement path was
                        # seeded without this landing, so it owns the chunk
                        # now — a dead hop's landing must not count as
                        # custody (or the replacement's move would read as a
                        # re-move of a journaled chunk)
                        continue
                    if chunk.index in self._custody.get(hop.v, ()):
                        # a journaled chunk crossed the wire again — the
                        # custody-handoff invariant the failover gate checks
                        self._re_moved += 1
                    self._custody.setdefault(hop.v, {})[chunk.index] = digest
                    hop.done.add(chunk.index)
                    hop.digests[chunk.index] = digest
                    hop.report.moved_chunks += 1
                    hop.report.moved_bytes += chunk.length
                    if len(hop.done) >= self.plan.n_chunks:
                        self._wake_hop_locked(hop)
                    if self._finished_locked():
                        self._cond.notify_all()
                    # hand custody downstream (store-and-forward pipelining);
                    # the CURRENT next hop — failover may have replaced it
                    if hop.idx + 1 < len(self.hops):
                        cand = self.hops[hop.idx + 1]
                        if chunk.index not in cand.done:
                            nxt = cand
                if nxt is not None:
                    nxt.ready.put(chunk)
        finally:
            with self._cond:
                hop.workers -= 1
                self._cond.notify_all()

    def _move_chunk(self, hop: _Hop, chunk: Chunk) -> Digest:
        """One chunk across one hop, with per-failure-class recovery budgets
        (the same taxonomy as the engine/service):

        * digest mismatch -> hop-local re-fetch (the staged upstream copy is
          intact, vouched for by the upstream custody digest), up to
          ``max_refetches``;
        * endpoint outage -> wait out the window on its own larger budget;
        * mover crash -> propagates; the worker re-queues the chunk;
        * anything else -> bounded in-place retries with backoff.
        """
        attempts = generic = refetches = outages = 0
        signal_s = 0.0   # fault-excluded work time: generic retries count
        # (congestion), corruption re-fetches and outage waits do not
        lane = f"hop{hop.idx}:{threading.current_thread().name}"
        while True:
            attempts += 1
            t_att = mono_s()
            try:
                if self._fault_injector is not None:
                    self._fault_injector(hop.idx, chunk, attempts)
                with self._lock:
                    granule = hop.granule
                if granule <= 0 or granule >= chunk.length:
                    # whole-chunk move (the untuned path, byte-identical)
                    data = hop.source.read(chunk.offset, chunk.length)
                    if len(data) != chunk.length:
                        raise IOError(
                            f"short read at {chunk.offset}: {len(data)}/{chunk.length}")
                    digest = fingerprint_bytes(data)
                    if hop.upstream is not None:
                        upstream = hop.upstream.digests.get(chunk.index)
                        if upstream is not None and not verify(upstream, digest):
                            raise IntegrityError(
                                f"hop {hop.idx} staging read of chunk {chunk.index} "
                                f"does not match upstream custody digest"
                            )
                    hop.dest.write(chunk.offset, data)
                    if self.integrity:
                        back = hop.dest.read_back(chunk.offset, chunk.length)
                        if not verify(digest, fingerprint_bytes(back)):
                            raise IntegrityError(
                                f"hop {hop.idx} read-back digest mismatch "
                                f"({hop.u}->{hop.v} @ {chunk.offset})"
                            )
                else:
                    # granular move: the custody chunk crosses this hop in
                    # sub-moves of the hop's tuned granule. Sub-digests fold
                    # into the chunk digest by the merge law, so custody
                    # verification is unchanged — the granule is purely this
                    # hop's I/O unit, invisible to its neighbours. Generic
                    # I/O failures retry the GRANULE in place (that is the
                    # point of shrinking it on a lossy hop: a lost granule
                    # costs one granule, not the whole chunk); corruption,
                    # outages and mover crashes keep chunk-level semantics.
                    parts: list[Digest] = []
                    pos = chunk.offset
                    while pos < chunk.end:
                        take = min(granule, chunk.end - pos)
                        sub_generic = 0
                        while True:
                            try:
                                data = hop.source.read(pos, take)
                                if len(data) != take:
                                    raise IOError(
                                        f"short read at {pos}: {len(data)}/{take}")
                                break
                            except (MoverCrash, EndpointOutage, IntegrityError):
                                raise
                            except Exception:
                                sub_generic += 1
                                if sub_generic > self.max_retries:
                                    raise
                                with self._lock:
                                    hop.report.retries += 1
                                Backoff(self.retry_backoff_s,
                                        seed=self.backoff_seed,
                                        lane=f"{lane}:g{pos}",
                                        ).sleep(sub_generic)
                        hop.dest.write(pos, data)
                        if self.integrity:
                            # batched digest path: the granule and its
                            # read-back are fingerprinted in ONE numpy
                            # dispatch (equal lengths share a GEMM) — the
                            # small-granule regime a degraded hop shrinks
                            # into is exactly where per-call overhead bites
                            back = hop.dest.read_back(pos, take)
                            if len(back) != take:
                                # diagnose the short read-back HERE: fed to
                                # the batched digest it would surface as a
                                # baffling length-mismatch (or worse, a
                                # digest mismatch) far from the cause
                                raise IOError(
                                    f"hop {hop.idx} short read-back at {pos}: "
                                    f"{len(back)}/{take} bytes"
                                )
                            d, d_back = fingerprint_many(
                                [data, back], expect_equal=True)
                            if not verify(d, d_back):
                                raise IntegrityError(
                                    f"hop {hop.idx} read-back digest mismatch "
                                    f"({hop.u}->{hop.v} @ {pos})"
                                )
                        else:
                            d = fingerprint_bytes(data)
                        parts.append(d)
                        pos += take
                    digest = merge_all(parts)
                    if hop.upstream is not None:
                        upstream = hop.upstream.digests.get(chunk.index)
                        if upstream is not None and not verify(upstream, digest):
                            raise IntegrityError(
                                f"hop {hop.idx} staging read of chunk {chunk.index} "
                                f"does not match upstream custody digest"
                            )
                now = mono_s()
                # custody span: this chunk crossing this hop (the attempt
                # that landed it) — checksum work is inline with the move on
                # a relay hop, so the whole attempt is wire custody time
                self.tracer.add(
                    "hop_move", "wire", t_att, now, task=self.task, lane=lane,
                    offset=chunk.offset, index=chunk.index, hop=hop.idx,
                    attempt=attempts,
                )
                if hop.controller is not None:
                    self._observe_hop(
                        hop, chunk, signal_s + (now - t_att),
                        attempts, refetches)
                self._note_health(hop, ok=True)
                return digest
            except MoverCrash:
                raise
            except IntegrityError:
                refetches += 1
                self.tracer.add(
                    "refetch", "stall", t_att, mono_s(), task=self.task,
                    lane=lane, offset=chunk.offset, hop=hop.idx, kind="corruption",
                )
                with self._lock:
                    hop.report.retries += 1
                    hop.report.refetches += 1
                if refetches > self.max_refetches:
                    raise
            except EndpointOutage:
                outages += 1
                with self._lock:
                    hop.report.outage_retries += 1
                self._note_health(hop, ok=False)
                if self._should_failover(hop, outages):
                    self.tracer.add(
                        "outage_wait", "stall", t_att, mono_s(), task=self.task,
                        lane=lane, offset=chunk.offset, hop=hop.idx, kind="outage",
                    )
                    raise _FailoverSignal()
                if outages > self.outage_retries:
                    self.tracer.add(
                        "outage_wait", "stall", t_att, mono_s(), task=self.task,
                        lane=lane, offset=chunk.offset, hop=hop.idx, kind="outage",
                    )
                    raise
                Backoff(self.outage_backoff_s, mode="linear",
                        seed=self.backoff_seed,
                        lane=f"{lane}:c{chunk.index}").sleep(outages)
                # stall span covers the rejected attempt AND the backoff wait
                self.tracer.add(
                    "outage_wait", "stall", t_att, mono_s(), task=self.task,
                    lane=lane, offset=chunk.offset, hop=hop.idx, kind="outage",
                )
            except Exception:
                generic += 1
                signal_s += mono_s() - t_att   # congestion-like
                self.tracer.add(
                    "move_retry", "wire", t_att, mono_s(), task=self.task,
                    lane=lane, offset=chunk.offset, hop=hop.idx, kind="generic",
                )
                if generic > self.max_retries:
                    raise
                with self._lock:
                    hop.report.retries += 1
                Backoff(self.retry_backoff_s, seed=self.backoff_seed,
                        lane=f"{lane}:c{chunk.index}").sleep(generic)


    # -- resilience plane ----------------------------------------------------
    def _note_health(self, hop: _Hop, ok: bool) -> None:
        """Feed the shared tracker: a hop verdict scores its link AND the
        endpoint it was writing toward."""
        if self.health is None:
            return
        self.health.record(f"link:{hop.u}->{hop.v}", ok)
        self.health.record(f"ep:{hop.v}", ok)

    def _should_failover(self, hop: _Hop, outages: int) -> bool:
        if not self.failover or self.planner is None or hop.dead:
            return False
        if outages >= self.failover_outage_threshold:
            return True
        h = self.health
        return h is not None and (
            not h.healthy(f"ep:{hop.v}")
            or not h.healthy(f"link:{hop.u}->{hop.v}"))

    def _failover(self, sick: _Hop) -> None:
        """Re-plan the remaining path around the sick hop's link and hand
        custody forward.

        The sick link's tail node ``u`` is the last healthy custody holder
        on the dead segment, so it becomes the new source; every replacement
        hop is pre-populated with the chunks its own node already journaled
        (including the final destination's), so a failover re-moves ZERO
        journaled chunks — only custody that died with the banned node
        crosses a wire again. The live upstream pipeline keeps feeding ``u``
        untouched; upstream nodes are excluded from the re-plan so the new
        path cannot loop back through it.
        """
        t0 = mono_s()
        with self._lock:
            if sick.dead or self._errors:
                return                       # someone already handled it
            if len(sick.done) >= self.plan.n_chunks:
                return                       # raced with its own completion
            gen = self._fo_gen = self._fo_gen + 1
            u, v = sick.u, sick.v
            self._banned_links.add((u, v))
            if v != self._final_node:
                self._banned_nodes.add(v)
            base = sick.idx
            plan_banned_nodes = set(self._banned_nodes)
            for h in self.hops[:base]:       # no looping back through the
                plan_banned_nodes.add(h.u)   # live upstream pipeline
                plan_banned_nodes.add(h.v)
            plan_banned_nodes.discard(u)
            try:
                route = self.planner.shortest_from_set(
                    [u], self._final_node, self.total_bytes,
                    banned_links=frozenset(self._banned_links),
                    banned_nodes=frozenset(plan_banned_nodes),
                )
            except NoRouteError as e:
                self._fail_locked(RuntimeError(
                    f"failover {gen}: no surviving route {u} -> "
                    f"{self._final_node} (banned links "
                    f"{sorted(self._banned_links)}, nodes "
                    f"{sorted(self._banned_nodes)}): {e}"))
                return
            # retire the dead tail: the sick hop and everything past it
            for hop in self.hops[base:]:
                hop.dead = True
                self._retired.append(hop)
                self._wake_hop_locked(hop)
            new_hops: list[_Hop] = []
            for j, (a, b) in enumerate(route.hops):
                jp = os.path.join(
                    self.workdir,
                    f"fo{gen:02d}-hop{base + j:02d}-{a}--{b}.journal")
                upstream = (new_hops[-1] if new_hops
                            else (self.hops[base - 1] if base > 0 else None))
                hop = self._make_hop(base + j, a, b, jp, upstream)
                # custody handoff: chunks already journaled at this node
                # survived the failure — restore them, never re-move them
                for idx, digest in self._custody.get(b, {}).items():
                    if idx in hop.done:
                        continue
                    c = self.plan.chunks[idx]
                    hop.journal.append(JournalRecord(
                        idx, c.offset, c.length, digest.hexdigest()))
                    hop.done.add(idx)
                    hop.digests[idx] = digest
                    hop.report.resumed_chunks += 1
                new_hops.append(hop)
            self.hops = self.hops[:base] + new_hops
            # seed replacement hops with upstream custody they still miss,
            # then staff them — the relay carries on without a restart
            for hop in new_hops:
                upstream_done = (
                    set(range(self.plan.n_chunks)) if hop.upstream is None
                    else hop.upstream.done)
                for c in self.plan.chunks:
                    if c.index in upstream_done and c.index not in hop.done:
                        hop.ready.put(c)
                self._spawn_workers_locked(hop)
            self.failover_events.append({
                "gen": gen,
                "sick_link": (u, v),
                "banned_nodes": sorted(self._banned_nodes),
                "new_path": list(route.nodes),
                "resumed_chunks": sum(
                    h.report.resumed_chunks for h in new_hops),
            })
            self._cond.notify_all()
        self.tracer.add(
            "failover", "failover", t0, mono_s(), task=self.task,
            lane=f"fo{gen}", hop=sick.idx, sick=f"{u}->{v}",
            path="-".join(route.nodes),
        )

    def _observe_hop(self, hop: _Hop, chunk: Chunk, attempt_seconds: float,
                     attempts: int, refetches: int) -> None:
        """Feed one landed chunk's telemetry to the hop's granule controller
        (the per-hop closed loop; other hops never see this decision)."""
        with self._lock:
            new = hop.controller.observe(ChunkSample(
                offset=chunk.offset, length=chunk.length,
                seconds=attempt_seconds, attempt_seconds=attempt_seconds,
                attempts=attempts, refetches=refetches, mover=hop.idx,
            ))
            if new is not None and new != hop.granule:
                hop.granule = new
                hop.report.granule_replans += 1
                hop.report.granule_bytes = new


def run_relay(
    route: Route,
    source: ByteSource,
    dest: ByteDest,
    *,
    workdir: str | os.PathLike,
    **kw,
) -> RelayReport:
    """One-shot helper mirroring ``core.transfer.transfer_verified``."""
    return RelayTransfer(route, source, dest, workdir=workdir, **kw).run()


# ---------------------------------------------------------------------------
# scenario DSL -> per-hop fault campaigns
# ---------------------------------------------------------------------------
def realize_hop_campaigns(
    scenario: Scenario,
    route: Route,
    *,
    total_bytes: int,
    seed: int = 0,
    movers: int = 4,
) -> tuple[dict[int, FaultCampaign], dict[str, int]]:
    """Bind a (possibly fabric-flavoured) Scenario to a relay route.

    Returns ``(campaigns, victims)``: one ``FaultCampaign`` per hop index,
    plus the seeded victim assignment. Mapping of the scenario DSL onto the
    multi-hop shape:

    * base faults (``bytes_per_error`` corruption) strike EVERY hop's write
      path — any WAN link can flip bits; base endpoint outages and mover
      kills strike hop 0 (the origin pull, matching single-pipe semantics);
    * ``link_outage_at_*`` picks one seeded victim hop whose endpoints
      reject the next ``link_outage_ops`` operations once that hop has moved
      ``link_outage_at_frac`` of its bytes;
    * ``degrade_hop`` picks ``degrade_hops`` seeded victim *intermediate*
      hops (the last hop when the route has no intermediates) whose writes
      all stall — persistently slow DTNs rather than dead ones.
    """
    rng = random.Random(_seed_int(seed, "fabric", route.nodes, scenario.name))
    n_hops = route.n_hops
    victims: dict = {}
    if scenario.link_outage_at_frac is not None:
        victims["link_outage"] = rng.randrange(n_hops)
    if scenario.degrade_hops > 0:
        inner = list(range(1, n_hops)) or [n_hops - 1]
        count = min(scenario.degrade_hops, len(inner))
        victims["degrade"] = tuple(sorted(rng.sample(inner, count)))
    # resilience-plane faults pick one seeded victim hop each (drawn after
    # the legacy victims so old scenarios keep their exact realisations)
    if scenario.down_at_frac is not None:
        victims["down"] = rng.randrange(n_hops)
    if scenario.link_flaps > 0:
        victims["flap"] = rng.randrange(n_hops)
    if scenario.brownout_events > 0:
        victims["brownout"] = rng.randrange(n_hops)

    campaigns: dict[int, FaultCampaign] = {}
    for h in range(n_hops):
        per_hop = Scenario(
            name=f"{scenario.name}@hop{h}",
            bytes_per_error=scenario.bytes_per_error,
            kill_movers=scenario.kill_movers if h == 0 else 0,
            kill_at_frac=scenario.kill_at_frac,
            outage_at_frac=scenario.outage_at_frac if h == 0 else None,
            outage_ops=scenario.outage_ops,
            stall_movers=scenario.stall_movers if h == 0 else 0,
            stall_s=scenario.stall_s,
        )
        if victims.get("link_outage") == h:
            per_hop = per_hop.replace(
                outage_at_frac=scenario.link_outage_at_frac,
                outage_ops=scenario.link_outage_ops,
            )
        if victims.get("down") == h:
            per_hop = per_hop.replace(down_at_frac=scenario.down_at_frac,
                                      down_ops=scenario.down_ops)
        if victims.get("flap") == h:
            per_hop = per_hop.replace(link_flaps=scenario.link_flaps,
                                      flap_ops=scenario.flap_ops)
        if victims.get("brownout") == h:
            per_hop = per_hop.replace(
                brownout_events=scenario.brownout_events)
        if h in victims.get("degrade", ()):
            # a degraded DTN stalls every write (bounded by the chunk count)
            per_hop = per_hop.replace(stall_movers=1 << 16, stall_s=0.001)
        campaigns[h] = FaultCampaign(
            per_hop, total_bytes=total_bytes, seed=_seed_int(seed, h), movers=movers,
        )
    return campaigns, victims
