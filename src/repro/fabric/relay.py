"""Multi-hop store-and-forward relay transfers with per-hop chunk custody.

A relay moves one payload along a fabric ``Route`` (origin -> intermediate
DTNs -> destination). The chunk plan is computed ONCE and shared by every
hop, so a chunk is the unit of *custody*: hop ``h`` journals chunk ``c`` the
moment it has landed (and been read-back verified) at stage ``h+1``, in a
per-hop ``core.journal.ChunkJournal``. That gives the fabric the paper's
partial-restart guarantee at every hop:

  * a chunk that reached an intermediate DTN is NEVER re-pulled from the
    origin after a crash — the restarted relay replays each hop's journal
    and resumes exactly the chunks still missing at that hop;
  * hops are pipelined chunk-wise: chunk ``c`` starts crossing hop ``h+1``
    as soon as hop ``h`` lands it, so relay makespan approaches the slowest
    hop, not the sum of hops;
  * integrity composes along the chain: each hop fingerprints what it read,
    verifies it against the upstream hop's journaled custody digest (staging
    bit-rot detection), write-verifies by destination read-back (in-flight
    corruption detection + re-fetch healing), and the final replica's
    merge-law digest must equal the origin digest.

Chaos hooks mirror the service: per-hop source/dest wrappers let
``repro.faults`` campaigns corrupt, outage, stall, or kill each hop's data
path independently; ``realize_hop_campaigns`` maps the scenario DSL's fabric
faults (``link_outage_at_50pct``, ``degrade_hop``) onto seeded victim hops.
"""
from __future__ import annotations

import dataclasses
import os
import queue
import random
import threading
import time
from typing import Callable

from repro.core.chunker import Chunk, ChunkPlan, plan_chunks
from repro.core.integrity import (
    Digest,
    combine_at_offsets,
    fingerprint_bytes,
    fingerprint_many,
    merge_all,
    verify,
)
from repro.core.journal import ChunkJournal, JournalRecord
from repro.core.transfer import (
    ByteDest,
    ByteSource,
    EndpointOutage,
    FileDest,
    FileSource,
    IntegrityError,
    MoverCrash,
)
from repro.faults.injectors import FaultCampaign, _seed_int
from repro.faults.scenarios import Scenario
from repro.fabric.topology import Route
from repro.obs.clock import mono_s
from repro.obs.trace import NULL as _NULL_TRACER
from repro.tune.controller import ChunkController
from repro.tune.probe import ChunkSample


# ---------------------------------------------------------------------------
# reports
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class HopReport:
    """Per-hop outcome of one relay incarnation."""

    hop: int
    src: str
    dst: str
    moved_chunks: int = 0        # chunks this incarnation landed at this hop
    resumed_chunks: int = 0      # custody restored from the hop journal
    moved_bytes: int = 0         # custody bytes moved by this incarnation
    retries: int = 0
    refetches: int = 0           # corrupt landings healed by hop-local re-read
    outage_retries: int = 0
    mover_deaths: int = 0
    # per-hop autotuning: the transfer granule this hop settled on (chunks
    # stay the custody unit; a degraded hop only shrinks its own I/O units)
    granule_bytes: int = 0
    granule_replans: int = 0


@dataclasses.dataclass
class RelayReport:
    route: Route
    total_bytes: int
    n_chunks: int
    hops: list[HopReport]
    seconds: float
    file_digest: Digest          # merge-law combine of the final hop's custody

    @property
    def wire_bytes(self) -> int:
        """Custody bytes moved across all hops by THIS incarnation."""
        return sum(h.moved_bytes for h in self.hops)

    @property
    def resumed_chunks(self) -> int:
        return sum(h.resumed_chunks for h in self.hops)

    @property
    def mover_deaths(self) -> int:
        return sum(h.mover_deaths for h in self.hops)

    @property
    def refetches(self) -> int:
        return sum(h.refetches for h in self.hops)


# ---------------------------------------------------------------------------
# relay engine
# ---------------------------------------------------------------------------
class _Hop:
    """Mutable per-hop execution state."""

    __slots__ = ("idx", "u", "v", "source", "dest", "journal", "ready",
                 "done", "digests", "report", "workers", "granule", "controller")

    def __init__(self, idx: int, u: str, v: str, source: ByteSource,
                 dest: ByteDest, journal: ChunkJournal):
        self.idx, self.u, self.v = idx, u, v
        self.source, self.dest, self.journal = source, dest, journal
        self.ready: "queue.Queue[Chunk]" = queue.Queue()
        self.done: set[int] = set(journal.records)
        self.digests: dict[int, Digest] = {
            i: rec.digest() for i, rec in journal.records.items()
        }
        self.report = HopReport(idx, u, v, resumed_chunks=len(self.done))
        self.workers = 0
        self.granule = 0                  # 0 = whole-chunk moves (untuned)
        self.controller: ChunkController | None = None


class RelayTransfer:
    """Executes one route-pipelined, custody-journaled relay transfer.

    ``workdir`` holds the per-hop journals and intermediate staging files;
    re-running with the same workdir resumes: every hop skips its journaled
    chunks, so a crash costs only the chunks in flight at crash time — at
    the hop they were crossing, never upstream.
    """

    def __init__(
        self,
        route: Route,
        source: ByteSource,
        dest: ByteDest,
        *,
        workdir: str | os.PathLike,
        chunk_bytes: int | None = None,
        plan: ChunkPlan | None = None,
        movers: int = 4,
        integrity: bool = True,
        max_retries: int = 3,
        max_refetches: int = 3,
        outage_retries: int = 64,
        outage_backoff_s: float = 0.002,
        max_mover_deaths: int = 16,
        retry_backoff_s: float = 0.002,
        source_wrapper: Callable[[int, ByteSource], ByteSource] | None = None,
        dest_wrapper: Callable[[int, ByteDest], ByteDest] | None = None,
        fault_injector: Callable[[int, Chunk, int], None] | None = None,
        tuning: bool = False,              # per-hop transfer-granule control
        granule_min: int = 64 * 1024,
        tune_epoch_chunks: int = 3,
        tune_hops: "set[int] | frozenset[int] | None" = None,  # None = all hops
        tracer=None,                       # obs.trace.Tracer; spans carry hop=
        task: str = "",
    ):
        if movers < 1:
            raise ValueError("movers must be >= 1")
        self.tracer = tracer if tracer is not None else _NULL_TRACER
        self.task = task or f"relay:{'-'.join(route.nodes)}"
        self.route = route
        self.workdir = str(workdir)
        os.makedirs(self.workdir, exist_ok=True)
        self.total_bytes = source.nbytes
        self.plan = plan or plan_chunks(
            self.total_bytes, movers, chunk_bytes=chunk_bytes,
            min_chunk=1, max_chunk=1 << 62, alignment=1,
        )
        if self.plan.total_bytes != self.total_bytes:
            raise ValueError("chunk plan does not cover the source")
        self.movers = movers
        self.integrity = integrity
        self.max_retries = max_retries
        self.max_refetches = max_refetches
        self.outage_retries = outage_retries
        self.outage_backoff_s = outage_backoff_s
        self.max_mover_deaths = max_mover_deaths
        self.retry_backoff_s = retry_backoff_s
        self._fault_injector = fault_injector
        self._lock = threading.Lock()
        self._cond = threading.Condition(self._lock)
        self._errors: list[BaseException] = []
        self._mover_deaths = 0

        # ---- per-hop endpoints: origin -> staging files -> final dest
        wrap_s = source_wrapper or (lambda _h, s: s)
        wrap_d = dest_wrapper or (lambda _h, d: d)
        self.hops: list[_Hop] = []
        n_hops = route.n_hops
        for h, (u, v) in enumerate(route.hops):
            hop_src: ByteSource = source if h == 0 else FileSource(self._stage(u))
            hop_dst: ByteDest = dest if h == n_hops - 1 else FileDest(
                self._stage(v), self.total_bytes)
            journal = ChunkJournal(self._journal_path(h, u, v))
            self.hops.append(_Hop(
                h, u, v, wrap_s(h, hop_src), wrap_d(h, hop_dst), journal))
        # per-hop granule controllers: each hop adapts its own I/O unit
        # within [granule_min, chunk_bytes] — custody chunks are untouched,
        # so a degraded middle hop shrinks its own granule without forcing
        # the rest of the path (or the journals) to change
        nominal = self.plan.chunk_bytes
        if tuning and nominal > 0:
            lo = min(granule_min, nominal)
            for hop in self.hops:
                if tune_hops is not None and hop.idx not in tune_hops:
                    continue       # operator scoped tuning to specific hops
                hop.granule = nominal
                # noise-hardened thresholds: hop rates are wall-clock local
                # measurements, so only a halving reads as degradation and
                # probes need a 25% win to stick
                hop.controller = ChunkController(
                    chunk_bytes=nominal, min_chunk=lo, max_chunk=nominal,
                    epoch_chunks=tune_epoch_chunks,
                    degrade_threshold=0.5, hysteresis=0.25,
                    fast_md_streak=3,
                )
                hop.report.granule_bytes = nominal

    # -- paths ---------------------------------------------------------------
    def _stage(self, node: str) -> str:
        path = os.path.join(self.workdir, f"stage-{node}.bin")
        if not os.path.exists(path):
            # staging area preallocation (FileDest keeps a partial file, so a
            # crashed relay's journaled chunks stay on the intermediate DTN)
            with open(path, "wb") as fh:
                if self.total_bytes:
                    fh.truncate(self.total_bytes)
        return path

    def _journal_path(self, h: int, u: str, v: str) -> str:
        return os.path.join(self.workdir, f"hop{h:02d}-{u}--{v}.journal")

    @staticmethod
    def journal_paths(workdir: str | os.PathLike, route: Route) -> list[str]:
        """The custody journal path of every hop (for probes/tests)."""
        return [
            os.path.join(str(workdir), f"hop{h:02d}-{u}--{v}.journal")
            for h, (u, v) in enumerate(route.hops)
        ]

    # -- execution -----------------------------------------------------------
    def run(self) -> RelayReport:
        t0 = mono_s()
        n = self.plan.n_chunks
        try:
            # seed each hop's ready queue: upstream custody present, own absent
            for hop in self.hops:
                upstream = (
                    set(range(n)) if hop.idx == 0 else self.hops[hop.idx - 1].done
                )
                for c in self.plan.chunks:
                    if c.index in upstream and c.index not in hop.done:
                        hop.ready.put(c)

            threads: list[threading.Thread] = []
            for hop in self.hops:
                for m in range(self.movers):
                    th = threading.Thread(
                        target=self._worker, args=(hop,),
                        name=f"relay-h{hop.idx}-m{m}", daemon=True,
                    )
                    hop.workers += 1
                    th.start()
                    threads.append(th)
            with self._cond:
                while not self._finished_locked() and not self._errors:
                    self._cond.wait(0.05)
            for th in threads:
                th.join()
            if self._errors:
                raise self._errors[0]
            last = self.hops[-1]
            parts = [(self.plan.chunks[i].offset, d) for i, d in last.digests.items()]
            file_digest = combine_at_offsets(parts, self.total_bytes)
            origin = self.hops[0]
            origin_digest = combine_at_offsets(
                [(self.plan.chunks[i].offset, d) for i, d in origin.digests.items()],
                self.total_bytes,
            )
            if not verify(origin_digest, file_digest):
                raise IntegrityError(
                    f"relay end-to-end digest mismatch along {self.route.nodes}: "
                    f"origin {origin_digest.hexdigest()} != replica "
                    f"{file_digest.hexdigest()}"
                )
            return RelayReport(
                route=self.route, total_bytes=self.total_bytes, n_chunks=n,
                hops=[h.report for h in self.hops],
                seconds=mono_s() - t0, file_digest=file_digest,
            )
        finally:
            for hop in self.hops:
                hop.journal.close()
            # root span covers the relay makespan even on a faulted exit, so
            # post-mortem attribution still sees the full window
            self.tracer.add(
                "relay", "task", t0, mono_s(), task=self.task,
                route="-".join(self.route.nodes), bytes=self.total_bytes,
                hops=self.route.n_hops,
            )

    def _finished_locked(self) -> bool:
        n = self.plan.n_chunks
        return all(len(h.done) >= n for h in self.hops)

    def _worker(self, hop: _Hop) -> None:
        try:
            while True:
                with self._lock:
                    if self._errors or len(hop.done) >= self.plan.n_chunks:
                        return
                try:
                    chunk = hop.ready.get(timeout=0.02)
                except queue.Empty:
                    continue             # upstream custody may still arrive
                with self._lock:
                    if chunk.index in hop.done:
                        continue
                try:
                    digest = self._move_chunk(hop, chunk)
                except MoverCrash:
                    # the mover dies mid-write; the chunk survives it. The
                    # pool respawns in place (this thread carries on as the
                    # replacement) unless the relay-wide death budget is out.
                    with self._lock:
                        self._mover_deaths += 1
                        hop.report.mover_deaths += 1
                        if self._mover_deaths > self.max_mover_deaths:
                            self._errors.append(RuntimeError(
                                f"relay mover-death budget exhausted "
                                f"({self._mover_deaths} > {self.max_mover_deaths})"
                            ))
                            self._cond.notify_all()
                            return
                    hop.ready.put(chunk)
                    continue
                except BaseException as e:  # noqa: BLE001 — fatal for the relay
                    with self._lock:
                        self._errors.append(e)
                        self._cond.notify_all()
                    return
                try:
                    t_j = mono_s()
                    hop.journal.append(JournalRecord(
                        chunk.index, chunk.offset, chunk.length, digest.hexdigest()
                    ))
                    self.tracer.add(
                        "custody_commit", "journal", t_j, mono_s(),
                        task=self.task, lane=f"hop{hop.idx}:journal",
                        offset=chunk.offset, index=chunk.index, hop=hop.idx,
                    )
                except Exception as e:  # noqa: BLE001 — dead journal: fail fast
                    with self._lock:
                        self._errors.append(RuntimeError(
                            f"hop {hop.idx} journal append failed for chunk "
                            f"{chunk.index}: {e}"
                        ))
                        self._cond.notify_all()
                    return
                with self._lock:
                    hop.done.add(chunk.index)
                    hop.digests[chunk.index] = digest
                    hop.report.moved_chunks += 1
                    hop.report.moved_bytes += chunk.length
                    finished = self._finished_locked()
                    if finished:
                        self._cond.notify_all()
                # hand custody downstream (store-and-forward pipelining)
                if hop.idx + 1 < len(self.hops):
                    nxt = self.hops[hop.idx + 1]
                    with self._lock:
                        fresh = chunk.index not in nxt.done
                    if fresh:
                        nxt.ready.put(chunk)
        finally:
            with self._cond:
                hop.workers -= 1
                self._cond.notify_all()

    def _move_chunk(self, hop: _Hop, chunk: Chunk) -> Digest:
        """One chunk across one hop, with per-failure-class recovery budgets
        (the same taxonomy as the engine/service):

        * digest mismatch -> hop-local re-fetch (the staged upstream copy is
          intact, vouched for by the upstream custody digest), up to
          ``max_refetches``;
        * endpoint outage -> wait out the window on its own larger budget;
        * mover crash -> propagates; the worker re-queues the chunk;
        * anything else -> bounded in-place retries with backoff.
        """
        attempts = generic = refetches = outages = 0
        signal_s = 0.0   # fault-excluded work time: generic retries count
        # (congestion), corruption re-fetches and outage waits do not
        lane = f"hop{hop.idx}:{threading.current_thread().name}"
        while True:
            attempts += 1
            t_att = mono_s()
            try:
                if self._fault_injector is not None:
                    self._fault_injector(hop.idx, chunk, attempts)
                with self._lock:
                    granule = hop.granule
                if granule <= 0 or granule >= chunk.length:
                    # whole-chunk move (the untuned path, byte-identical)
                    data = hop.source.read(chunk.offset, chunk.length)
                    if len(data) != chunk.length:
                        raise IOError(
                            f"short read at {chunk.offset}: {len(data)}/{chunk.length}")
                    digest = fingerprint_bytes(data)
                    if hop.idx > 0:
                        upstream = self.hops[hop.idx - 1].digests.get(chunk.index)
                        if upstream is not None and not verify(upstream, digest):
                            raise IntegrityError(
                                f"hop {hop.idx} staging read of chunk {chunk.index} "
                                f"does not match upstream custody digest"
                            )
                    hop.dest.write(chunk.offset, data)
                    if self.integrity:
                        back = hop.dest.read_back(chunk.offset, chunk.length)
                        if not verify(digest, fingerprint_bytes(back)):
                            raise IntegrityError(
                                f"hop {hop.idx} read-back digest mismatch "
                                f"({hop.u}->{hop.v} @ {chunk.offset})"
                            )
                else:
                    # granular move: the custody chunk crosses this hop in
                    # sub-moves of the hop's tuned granule. Sub-digests fold
                    # into the chunk digest by the merge law, so custody
                    # verification is unchanged — the granule is purely this
                    # hop's I/O unit, invisible to its neighbours. Generic
                    # I/O failures retry the GRANULE in place (that is the
                    # point of shrinking it on a lossy hop: a lost granule
                    # costs one granule, not the whole chunk); corruption,
                    # outages and mover crashes keep chunk-level semantics.
                    parts: list[Digest] = []
                    pos = chunk.offset
                    while pos < chunk.end:
                        take = min(granule, chunk.end - pos)
                        sub_generic = 0
                        while True:
                            try:
                                data = hop.source.read(pos, take)
                                if len(data) != take:
                                    raise IOError(
                                        f"short read at {pos}: {len(data)}/{take}")
                                break
                            except (MoverCrash, EndpointOutage, IntegrityError):
                                raise
                            except Exception:
                                sub_generic += 1
                                if sub_generic > self.max_retries:
                                    raise
                                with self._lock:
                                    hop.report.retries += 1
                                time.sleep(self.retry_backoff_s
                                           * (2 ** min(sub_generic - 1, 6)))
                        hop.dest.write(pos, data)
                        if self.integrity:
                            # batched digest path: the granule and its
                            # read-back are fingerprinted in ONE numpy
                            # dispatch (equal lengths share a GEMM) — the
                            # small-granule regime a degraded hop shrinks
                            # into is exactly where per-call overhead bites
                            back = hop.dest.read_back(pos, take)
                            if len(back) != take:
                                # diagnose the short read-back HERE: fed to
                                # the batched digest it would surface as a
                                # baffling length-mismatch (or worse, a
                                # digest mismatch) far from the cause
                                raise IOError(
                                    f"hop {hop.idx} short read-back at {pos}: "
                                    f"{len(back)}/{take} bytes"
                                )
                            d, d_back = fingerprint_many(
                                [data, back], expect_equal=True)
                            if not verify(d, d_back):
                                raise IntegrityError(
                                    f"hop {hop.idx} read-back digest mismatch "
                                    f"({hop.u}->{hop.v} @ {pos})"
                                )
                        else:
                            d = fingerprint_bytes(data)
                        parts.append(d)
                        pos += take
                    digest = merge_all(parts)
                    if hop.idx > 0:
                        upstream = self.hops[hop.idx - 1].digests.get(chunk.index)
                        if upstream is not None and not verify(upstream, digest):
                            raise IntegrityError(
                                f"hop {hop.idx} staging read of chunk {chunk.index} "
                                f"does not match upstream custody digest"
                            )
                now = mono_s()
                # custody span: this chunk crossing this hop (the attempt
                # that landed it) — checksum work is inline with the move on
                # a relay hop, so the whole attempt is wire custody time
                self.tracer.add(
                    "hop_move", "wire", t_att, now, task=self.task, lane=lane,
                    offset=chunk.offset, index=chunk.index, hop=hop.idx,
                    attempt=attempts,
                )
                if hop.controller is not None:
                    self._observe_hop(
                        hop, chunk, signal_s + (now - t_att),
                        attempts, refetches)
                return digest
            except MoverCrash:
                raise
            except IntegrityError:
                refetches += 1
                self.tracer.add(
                    "refetch", "stall", t_att, mono_s(), task=self.task,
                    lane=lane, offset=chunk.offset, hop=hop.idx, kind="corruption",
                )
                with self._lock:
                    hop.report.retries += 1
                    hop.report.refetches += 1
                if refetches > self.max_refetches:
                    raise
            except EndpointOutage:
                outages += 1
                with self._lock:
                    hop.report.outage_retries += 1
                if outages > self.outage_retries:
                    self.tracer.add(
                        "outage_wait", "stall", t_att, mono_s(), task=self.task,
                        lane=lane, offset=chunk.offset, hop=hop.idx, kind="outage",
                    )
                    raise
                time.sleep(self.outage_backoff_s * min(outages, 8))
                # stall span covers the rejected attempt AND the backoff wait
                self.tracer.add(
                    "outage_wait", "stall", t_att, mono_s(), task=self.task,
                    lane=lane, offset=chunk.offset, hop=hop.idx, kind="outage",
                )
            except Exception:
                generic += 1
                signal_s += mono_s() - t_att   # congestion-like
                self.tracer.add(
                    "move_retry", "wire", t_att, mono_s(), task=self.task,
                    lane=lane, offset=chunk.offset, hop=hop.idx, kind="generic",
                )
                if generic > self.max_retries:
                    raise
                with self._lock:
                    hop.report.retries += 1
                time.sleep(self.retry_backoff_s * (2 ** (generic - 1)))


    def _observe_hop(self, hop: _Hop, chunk: Chunk, attempt_seconds: float,
                     attempts: int, refetches: int) -> None:
        """Feed one landed chunk's telemetry to the hop's granule controller
        (the per-hop closed loop; other hops never see this decision)."""
        with self._lock:
            new = hop.controller.observe(ChunkSample(
                offset=chunk.offset, length=chunk.length,
                seconds=attempt_seconds, attempt_seconds=attempt_seconds,
                attempts=attempts, refetches=refetches, mover=hop.idx,
            ))
            if new is not None and new != hop.granule:
                hop.granule = new
                hop.report.granule_replans += 1
                hop.report.granule_bytes = new


def run_relay(
    route: Route,
    source: ByteSource,
    dest: ByteDest,
    *,
    workdir: str | os.PathLike,
    **kw,
) -> RelayReport:
    """One-shot helper mirroring ``core.transfer.transfer_verified``."""
    return RelayTransfer(route, source, dest, workdir=workdir, **kw).run()


# ---------------------------------------------------------------------------
# scenario DSL -> per-hop fault campaigns
# ---------------------------------------------------------------------------
def realize_hop_campaigns(
    scenario: Scenario,
    route: Route,
    *,
    total_bytes: int,
    seed: int = 0,
    movers: int = 4,
) -> tuple[dict[int, FaultCampaign], dict[str, int]]:
    """Bind a (possibly fabric-flavoured) Scenario to a relay route.

    Returns ``(campaigns, victims)``: one ``FaultCampaign`` per hop index,
    plus the seeded victim assignment. Mapping of the scenario DSL onto the
    multi-hop shape:

    * base faults (``bytes_per_error`` corruption) strike EVERY hop's write
      path — any WAN link can flip bits; base endpoint outages and mover
      kills strike hop 0 (the origin pull, matching single-pipe semantics);
    * ``link_outage_at_*`` picks one seeded victim hop whose endpoints
      reject the next ``link_outage_ops`` operations once that hop has moved
      ``link_outage_at_frac`` of its bytes;
    * ``degrade_hop`` picks ``degrade_hops`` seeded victim *intermediate*
      hops (the last hop when the route has no intermediates) whose writes
      all stall — persistently slow DTNs rather than dead ones.
    """
    rng = random.Random(_seed_int(seed, "fabric", route.nodes, scenario.name))
    n_hops = route.n_hops
    victims: dict = {}
    if scenario.link_outage_at_frac is not None:
        victims["link_outage"] = rng.randrange(n_hops)
    if scenario.degrade_hops > 0:
        inner = list(range(1, n_hops)) or [n_hops - 1]
        count = min(scenario.degrade_hops, len(inner))
        victims["degrade"] = tuple(sorted(rng.sample(inner, count)))

    campaigns: dict[int, FaultCampaign] = {}
    for h in range(n_hops):
        per_hop = Scenario(
            name=f"{scenario.name}@hop{h}",
            bytes_per_error=scenario.bytes_per_error,
            kill_movers=scenario.kill_movers if h == 0 else 0,
            kill_at_frac=scenario.kill_at_frac,
            outage_at_frac=scenario.outage_at_frac if h == 0 else None,
            outage_ops=scenario.outage_ops,
            stall_movers=scenario.stall_movers if h == 0 else 0,
            stall_s=scenario.stall_s,
        )
        if victims.get("link_outage") == h:
            per_hop = per_hop.replace(
                outage_at_frac=scenario.link_outage_at_frac,
                outage_ops=scenario.link_outage_ops,
            )
        if h in victims.get("degrade", ()):
            # a degraded DTN stalls every write (bounded by the chunk count)
            per_hop = per_hop.replace(stall_movers=1 << 16, stall_s=0.001)
        campaigns[h] = FaultCampaign(
            per_hop, total_bytes=total_bytes, seed=_seed_int(seed, h), movers=movers,
        )
    return campaigns, victims
