"""Fan-out 1 -> N replication campaigns over the fabric.

The climate-replication case study (7.3 PB to multiple sites) is the shape
this module executes: one dataset, many destinations, heterogeneous links.
Two pieces:

  * ``build_distribution_tree`` — grows a Steiner-ish distribution tree over
    the topology with cheapest-attachment: each destination is grafted onto
    the existing tree at its cheapest attachment point (multi-source
    Dijkstra, tree nodes are free sources), so shared first hops are paid
    for ONCE. Every chunk crosses a shared trunk link exactly once and
    branches at the split point — that is the wire-byte win over naive
    per-destination transfers, which pay the trunk N times.

  * ``CampaignRunner`` — executes a campaign against a REAL
    ``TransferService`` by decomposing the tree into one service task per
    tree edge, submitted event-driven as custody becomes available at each
    node (an edge's task is submitted the moment its parent edge SUCCEEDED).
    Because edges are ordinary service tasks, tenant quotas, mover
    allocation, the event stream, pause/resume/cancel and crash recovery
    all apply unchanged. Integrity is verified at every replica with the
    merge-law digests: each edge task's item digest (the commutative combine
    of its chunk fingerprints) must equal its parent edge's — the chain
    anchors at the origin read, so a matching leaf digest proves the replica
    is byte-identical to the origin without re-hashing anything.

Virtual-time execution of the same trees lives in ``fabric.virtual``.
"""
from __future__ import annotations

import dataclasses
import os
import threading
from typing import Any, Sequence

from repro.cas import ChunkIndex
from repro.core.integrity import combine_at_offsets, fingerprint_bytes, verify
from repro.fabric.topology import NoRouteError, RoutePlanner, Topology
from repro.obs.clock import mono_s
from repro.service import events as ev
from repro.service import task as tk
from repro.service.service import TransferService
from repro.service.task import TaskStatus, TransferItem

# edge_states value for a tree edge satisfied from the replica's chunk index
# (no service task was submitted; custody came from verified local bytes)
DEDUPED = "DEDUPED"


# ---------------------------------------------------------------------------
# distribution trees
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class DistributionTree:
    """A replication tree: edges in topological (parent-before-child) order."""

    source: str
    dests: tuple[str, ...]
    edges: tuple[tuple[str, str], ...]

    def __post_init__(self):
        seen = {self.source}
        for u, v in self.edges:
            if u not in seen:
                raise ValueError(f"edge {u}->{v} precedes custody at {u}")
            if v in seen:
                raise ValueError(f"node {v} grafted twice (not a tree)")
            seen.add(v)
        missing = set(self.dests) - seen
        if missing:
            raise ValueError(f"destinations unreachable in tree: {sorted(missing)}")

    @property
    def nodes(self) -> tuple[str, ...]:
        out = [self.source]
        out += [v for _u, v in self.edges]
        return tuple(out)

    @property
    def wire_hops(self) -> int:
        """Links a byte crosses in total — each edge carries the payload once."""
        return len(self.edges)

    def parent(self, v: str) -> str:
        for u, w in self.edges:
            if w == v:
                return u
        raise KeyError(f"{v!r} has no parent (root or unknown)")

    def children(self, u: str) -> tuple[str, ...]:
        return tuple(w for p, w in self.edges if p == u)

    def path(self, dest: str) -> tuple[str, ...]:
        """Source -> dest node path inside the tree."""
        nodes = [dest]
        while nodes[-1] != self.source:
            nodes.append(self.parent(nodes[-1]))
        nodes.reverse()
        return tuple(nodes)

    def wire_bytes(self, nbytes: int) -> int:
        return nbytes * self.wire_hops


def build_distribution_tree(
    planner: RoutePlanner,
    source: str,
    dests: Sequence[str],
    nbytes: int,
    *,
    now: float = 0.0,
) -> DistributionTree:
    """Cheapest-attachment tree construction (shared first hops dedup'd).

    Destinations are attached nearest-first (deterministic: ties broken by
    name); each attachment is a multi-source Dijkstra from every node already
    holding custody, so an added route pays only for links the tree does not
    already cross.
    """
    dests = list(dict.fromkeys(dests))           # dedupe, keep order
    if not dests:
        raise ValueError("campaign needs at least one destination")
    if source in dests:
        raise ValueError("source endpoint cannot also be a destination")
    order = sorted(
        dests,
        key=lambda d: (planner.best_route(source, d, nbytes, now=now).seconds, d),
    )
    tree_nodes: list[str] = [source]
    edges: list[tuple[str, str]] = []
    for dest in order:
        if dest in tree_nodes:
            continue                             # already grafted en route
        # only relay-capable tree nodes (and the origin) may forward custody:
        # a relay=False destination holds a replica but never re-serves it
        grafts = [
            n for n in tree_nodes
            if n == source or planner.topo.endpoint(n).relay
        ]
        route = planner.shortest_from_set(grafts, dest, nbytes, now=now)
        for u, v in route.hops:
            if v not in tree_nodes:
                edges.append((u, v))
                tree_nodes.append(v)
    return DistributionTree(source=source, dests=tuple(dests), edges=tuple(edges))


def naive_wire_hops(
    planner: RoutePlanner, source: str, dests: Sequence[str], nbytes: int, *,
    now: float = 0.0,
) -> int:
    """Total link crossings for N independent per-destination transfers."""
    return sum(
        planner.best_route(source, d, nbytes, now=now).n_hops for d in dests
    )


# ---------------------------------------------------------------------------
# real-service campaign execution
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class CampaignReport:
    """Outcome of one replication campaign run."""

    tree: DistributionTree
    relpath: str
    total_bytes: int
    state: str                               # SUCCEEDED | FAILED | CANCELED
    edge_tasks: dict[tuple[str, str], str]   # tree edge -> service task id
    edge_states: dict[tuple[str, str], str]
    replica_digests: dict[str, str]          # endpoint -> merge-law digest hex
    origin_digest: str
    replicas_verified: int
    integrity_escapes: int
    wire_bytes: int                          # custody bytes over tree edges
    naive_wire_bytes: int                    # what N independent routes cost
    resumed_chunks: int
    seconds: float
    # replica-aware dedup: edges whose destination replica already held the
    # content (per-replica chunk index) and were satisfied without a task
    edges_deduped: int = 0
    dedup_wire_bytes_saved: int = 0
    # resilience plane: orphaned subtrees re-parented onto surviving replicas
    # after an edge task failed (the effective edge set diverges from the
    # planned tree; verification still chains every replica to the origin)
    failovers: int = 0
    failover_events: list[dict] = dataclasses.field(default_factory=list)
    error: str | None = None

    @property
    def wire_reduction(self) -> float:
        return self.naive_wire_bytes / self.wire_bytes if self.wire_bytes else 1.0


class CampaignError(RuntimeError):
    pass


class CampaignRunner:
    """Decomposes distribution trees into service tasks, edge by edge.

    ``endpoint_dirs`` maps every fabric endpoint to its staging directory
    (the DTN's filesystem); edge ``(u, v)`` becomes one service task moving
    ``<dir(u)>/<relpath>`` to ``<dir(v)>/<relpath>``.
    """

    def __init__(
        self,
        service: TransferService,
        topo: Topology,
        endpoint_dirs: dict[str, str | os.PathLike],
        *,
        planner: RoutePlanner | None = None,
        indexes: dict[str, ChunkIndex] | None = None,
    ):
        self.service = service
        self.topo = topo
        self.planner = planner or RoutePlanner(topo)
        self.dirs = {name: str(p) for name, p in endpoint_dirs.items()}
        for name in self.dirs:
            topo.endpoint(name)              # validate against the registry
        # per-replica chunk indexes: an edge whose destination endpoint has
        # one is probed before submission — if the replica already holds every
        # chunk, the edge is satisfied by verified local copies (no task)
        self.indexes: dict[str, ChunkIndex] = dict(indexes or {})
        for name in self.indexes:
            topo.endpoint(name)

    def _path(self, endpoint: str, relpath: str) -> str:
        try:
            return os.path.join(self.dirs[endpoint], relpath)
        except KeyError:
            raise CampaignError(
                f"endpoint {endpoint!r} has no staging directory") from None

    def _dedup_edge(
        self, u: str, v: str, relpath: str, nbytes: int,
        chunk_bytes: int | None,
    ) -> str | None:
        """Try to satisfy edge ``(u, v)`` entirely from ``v``'s chunk index.

        Probes each chunk of the custody file at ``u`` against the replica's
        index; a hit is satisfied by a verified local copy at ``v`` (or pure
        verification when the index already points at the destination path).
        All-or-nothing: any miss, stale entry, or verify failure demotes the
        whole edge to an ordinary wire task. Returns the merge-law whole-file
        digest hex on success (folded from the freshly fingerprinted source
        bytes, so the campaign's custody chain still anchors at the origin),
        or None to demote.
        """
        index = self.indexes.get(v)
        if index is None or nbytes == 0:
            return None
        cb = chunk_bytes or self.service.config.chunk_bytes
        src_path = self._path(u, relpath)
        dst_path = os.path.abspath(self._path(v, relpath))
        parts: list[tuple[int, Any]] = []
        pending_puts: list[tuple[str, int, int]] = []
        out = None
        try:
            with open(src_path, "rb") as fh:
                offset = 0
                while offset < nbytes:
                    length = min(cb, nbytes - offset)
                    data = fh.read(length)
                    if len(data) != length:
                        return None
                    want = fingerprint_bytes(data)
                    satisfied = False
                    for e in index.lookup(want.hexdigest(), length):
                        aliased = (os.path.abspath(e.path) == dst_path
                                   and e.offset == offset)
                        backing = index.verify_entry(e)
                        if backing is None:
                            # stale entry: the bytes behind it changed — drop
                            # it so no later probe trusts it again
                            index.discard(e.digest_hex, e.length, e.path, e.offset)
                            index.note_stale()
                            continue
                        if not aliased:
                            if out is None:
                                mode = "r+b" if os.path.exists(dst_path) else "w+b"
                                os.makedirs(os.path.dirname(dst_path) or ".",
                                            exist_ok=True)
                                out = open(dst_path, mode)
                            out.seek(offset)
                            out.write(backing)
                            out.flush()
                        with open(dst_path, "rb") as back_fh:
                            back_fh.seek(offset)
                            back = back_fh.read(length)
                        if len(back) == length and verify(want, fingerprint_bytes(back)):
                            satisfied = True
                            if not aliased:
                                pending_puts.append(
                                    (want.hexdigest(), length, offset))
                            break
                    if not satisfied:
                        return None
                    parts.append((offset, want))
                    offset += length
        except OSError:
            return None
        finally:
            if out is not None:
                out.close()
        for hexd, length, off in pending_puts:
            try:
                index.put(hexd, length, dst_path, off)
            except Exception:
                pass
        return combine_at_offsets(parts, nbytes).hexdigest()

    def _index_landed(self, v: str, relpath: str, st: TaskStatus) -> None:
        """Register a succeeded edge's verified chunks in ``v``'s index."""
        index = self.indexes.get(v)
        if index is None or not st.item_reports:
            return
        dst_path = os.path.abspath(self._path(v, relpath))
        for c in st.item_reports[0].chunks:
            if not c.get("digest"):
                continue
            try:
                index.put(c["digest"], int(c["length"]), dst_path,
                          int(c["offset"]))
            except Exception:
                pass

    def _replan_edge(
        self,
        tree: DistributionTree,
        edge: tuple[str, str],
        nbytes: int,
        *,
        custody: set[str],
        banned_links: set[tuple[str, str]],
        occupied: set[str],
    ):
        """Re-parent the orphaned subtree below ``edge[1]`` onto a replica.

        The failed link is banned (bans accumulate across failovers, so the
        same link is never retried and the re-plan loop terminates); the new
        route may start at ANY custody-holding relay (a surviving replica is
        as good a parent as the origin) but may not pass through nodes that
        already hold or are already promised custody, nor through endpoints
        without staging directories. Returns the re-planned route or raises
        NoRouteError.
        """
        u, v = edge
        banned_links.add((u, v))
        sources = [
            n for n in custody
            if (n == tree.source or self.topo.endpoint(n).relay)
            and n in self.dirs
        ]
        no_dir = {n for n in self.topo.endpoints if n not in self.dirs}
        banned_nodes = (occupied | no_dir) - set(sources) - {v}
        return self.planner.shortest_from_set(
            sources, v, nbytes,
            banned_links=frozenset(banned_links),
            banned_nodes=frozenset(banned_nodes),
        )

    def replicate(
        self,
        relpath: str,
        source: str,
        dests: Sequence[str],
        *,
        tenant: str = "default",
        label: str = "campaign",
        chunk_bytes: int | None = None,
        tree: DistributionTree | None = None,
        timeout: float | None = 300.0,
        failover: str | None = None,
    ) -> CampaignReport:
        """Replicate ``<dir(source)>/<relpath>`` to every destination.

        Synchronous: drives the schedule to a terminal state. Submission is
        event-driven — an edge's task is submitted the moment its parent
        edge SUCCEEDED, so a fast subtree never waits for a slow sibling;
        the wait itself is event-driven too (the service event stream wakes
        the scheduler — no status polling). ``timeout`` is per-edge-task.

        ``failover="auto"`` re-parents instead of failing: when an edge task
        fails (or times out and is canceled), the orphaned subtree is grafted
        onto a surviving replica via a fresh route that bans the failed link,
        and the replacement hops run as ordinary edge tasks. Bans accumulate,
        so a genuinely partitioned destination still fails the campaign
        (NoRouteError) after every alternative is exhausted. ``failover=None``
        defers to ``ServiceConfig.failover``; ``"off"`` pins the tree — a
        failed edge fails the campaign and its downstream edges are never
        submitted, while unrelated subtrees still finish in flight.
        """
        t0 = mono_s()
        fo = failover if failover is not None else self.service.config.failover
        if fo not in ("off", "auto"):
            raise ValueError(f"failover must be 'off' or 'auto', got {fo!r}")
        src_path = self._path(source, relpath)
        nbytes = os.path.getsize(src_path)
        if tree is None:
            tree = build_distribution_tree(self.planner, source, list(dests), nbytes)
        naive = naive_wire_hops(self.planner, source, tree.dests, nbytes)

        edge_tasks: dict[tuple[str, str], str] = {}
        statuses: dict[tuple[str, str], TaskStatus] = {}
        dedup_digests: dict[tuple[str, str], str] = {}
        final_edges = list(tree.edges)       # effective set; failover splices
        ready = [e for e in tree.edges if e[0] == source]
        blocked = [e for e in tree.edges if e[0] != source]
        inflight: dict[tuple[str, str], tuple[str, float | None]] = {}
        custody: set[str] = {source}
        banned_links: set[tuple[str, str]] = set()
        failover_events: list[dict] = []
        failed: str | None = None

        # the scheduler sleeps on this and the event stream wakes it: any
        # terminal task event may be one of ours. The subscription is live
        # BEFORE the first submit, so a fast task cannot finish unseen.
        wake = threading.Event()
        _TERMINAL_KINDS = (ev.SUCCEEDED, ev.FAILED, ev.CANCELED)
        unsubscribe = self.service.subscribe(
            lambda e: wake.set() if e.kind in _TERMINAL_KINDS else None)

        def fail_edge(edge: tuple[str, str], tid: str, reason: str) -> None:
            """Re-parent the orphan (failover=auto) or fail the campaign."""
            nonlocal failed
            u, v = edge
            if fo == "auto":
                occupied = set(custody)
                for coll in (ready, blocked, inflight):
                    occupied.update(e[1] for e in coll)
                try:
                    route = self._replan_edge(
                        tree, edge, nbytes, custody=custody,
                        banned_links=banned_links, occupied=occupied)
                except NoRouteError as exc:
                    if v not in tree.dests:
                        # the orphan is a pure relay with no surviving route
                        # to it — nothing is *delivered* there, so drop it
                        # and re-parent each child subtree directly (they may
                        # reach their nodes through paths that bypass v)
                        if edge in final_edges:
                            final_edges.remove(edge)
                        children = [e for e in blocked if e[0] == v]
                        for child in children:
                            blocked.remove(child)
                        for child in children:
                            fail_edge(child, tid,
                                      f"{reason}; relay {v} unreachable")
                        return
                    if failed is None:
                        failed = (f"edge {u}->{v}: {reason}; no surviving "
                                  f"re-parent route: {exc}")
                    return
                final_edges.remove(edge)
                final_edges.extend(route.hops)
                # first replacement hop leaves a custody holder: runs now;
                # the rest chain behind it through the normal unlock path
                ready.append(route.hops[0])
                blocked.extend(route.hops[1:])
                evd = {
                    "edge": f"{u}->{v}", "reason": reason,
                    "new_parent": route.src, "new_path": list(route.nodes),
                    "banned_links": sorted(f"{a}->{b}" for a, b in banned_links),
                }
                failover_events.append(evd)
                self.service.record_failover(
                    tid, sick_link=f"{u}->{v}", new_path=list(route.nodes),
                    resumed_chunks=0, reason=reason)
            elif failed is None:
                failed = f"edge {u}->{v} task {tid} {reason}"

        try:
            while ready or inflight:
                for u, v in ready:
                    # replica-aware dedup: probe v's chunk index before
                    # paying for the wire — a full hit grants custody
                    # immediately and unlocks the subtree below v in the
                    # same scheduling pass
                    digest_hex = self._dedup_edge(u, v, relpath, nbytes,
                                                  chunk_bytes)
                    if digest_hex is not None:
                        dedup_digests[(u, v)] = digest_hex
                        custody.add(v)
                        unlocked = [e for e in blocked if e[0] == v]
                        blocked = [e for e in blocked if e[0] != v]
                        ready.extend(unlocked)
                        continue
                    item = TransferItem(
                        self._path(u, relpath), self._path(v, relpath), nbytes)
                    [tid] = self.service.submit(
                        [item], tenant=tenant, chunk_bytes=chunk_bytes,
                        label=f"{label}/{u}->{v}", batch=False, failover=fo,
                    )
                    edge_tasks[(u, v)] = tid
                    deadline = None if timeout is None else mono_s() + timeout
                    inflight[(u, v)] = (tid, deadline)
                ready = []
                if not inflight:
                    continue
                wake.clear()     # before the scan: a terminal event landing
                #                  mid-scan re-sets it and the wait falls through
                for edge, (tid, deadline) in list(inflight.items()):
                    st = self.service.status(tid)
                    if st.state in tk.TERMINAL:
                        inflight.pop(edge)
                        statuses[edge] = st
                        if st.state == tk.SUCCEEDED:
                            custody.add(edge[1])
                            self._index_landed(edge[1], relpath, st)
                            unlocked = [e for e in blocked if e[0] == edge[1]]
                            blocked = [e for e in blocked if e[0] != edge[1]]
                            ready.extend(unlocked)
                        elif st.state == tk.FAILED:
                            fail_edge(edge, tid, f"FAILED: {st.error}")
                        elif failed is None:
                            failed = (f"edge {edge[0]}->{edge[1]} task {tid} "
                                      f"{st.state}: {st.error}")
                    elif deadline is not None and mono_s() > deadline:
                        # don't leave a hung task writing into the staging
                        # dirs after the edge has been given up on
                        inflight.pop(edge)
                        self.service.cancel(tid)
                        try:
                            # drain before re-parenting: the dying task must
                            # stop writing into v's staging file before a
                            # replacement edge starts writing the same file
                            self.service.wait(tid, timeout=30.0)
                        except TimeoutError:
                            pass
                        fail_edge(edge, tid,
                                  f"timed out after {timeout}s (canceled)")
                if ready or not inflight:
                    continue
                # sleep until a terminal event or the nearest deadline; the
                # 0.5 s cap is a lost-wakeup backstop, not a poll interval
                rem = None
                for _tid, dl in inflight.values():
                    if dl is not None:
                        r = dl - mono_s()
                        rem = r if rem is None else min(rem, r)
                wake.wait(0.5 if rem is None else max(0.0, min(rem, 0.5)))
        finally:
            unsubscribe()

        # ---- merge-law verification chain: child digest == parent digest.
        # Failover makes the effective edge list non-topological (replacement
        # hops append at the tail), so the chain resolves to a fixpoint:
        # an edge is checked once its parent's digest is known.
        edge_digest: dict[tuple[str, str], str] = {}
        for e in final_edges:
            if e in dedup_digests:
                edge_digest[e] = dedup_digests[e]
            else:
                st = statuses.get(e)
                if st is not None and st.state == tk.SUCCEEDED and st.item_reports:
                    edge_digest[e] = st.item_reports[0].digest_hex
        origin_digest = ""
        replica_digests: dict[str, str] = {}
        escapes = 0
        verified = 0
        pending = [e for e in final_edges if e in edge_digest]
        progress = True
        while pending and progress:
            progress = False
            for e in list(pending):
                u, v = e
                digest = edge_digest[e]
                if u == tree.source:
                    if not origin_digest:
                        origin_digest = digest
                    parent_digest = origin_digest
                else:
                    parent_digest = replica_digests.get(u, "")
                    if not parent_digest:
                        continue        # parent unresolved: try next round
                pending.remove(e)
                progress = True
                replica_digests[v] = digest
                if digest == parent_digest:
                    if v in tree.dests:
                        verified += 1
                else:
                    escapes += 1
        state = tk.SUCCEEDED
        if failed or blocked or pending or verified < len(tree.dests):
            state = tk.FAILED
        if escapes:
            state = tk.FAILED
        edge_states = {e: s.state for e, s in statuses.items()}
        edge_states.update({e: DEDUPED for e in dedup_digests})
        wire_edges = sum(1 for e in final_edges
                         if e in statuses and statuses[e].state == tk.SUCCEEDED)
        return CampaignReport(
            tree=tree,
            relpath=relpath,
            total_bytes=nbytes,
            state=state,
            edge_tasks=edge_tasks,
            edge_states=edge_states,
            replica_digests=replica_digests,
            origin_digest=origin_digest,
            replicas_verified=verified,
            integrity_escapes=escapes,
            wire_bytes=nbytes * wire_edges,
            naive_wire_bytes=nbytes * naive,
            resumed_chunks=sum(s.resumed_chunks for s in statuses.values()),
            seconds=mono_s() - t0,
            edges_deduped=len(dedup_digests),
            dedup_wire_bytes_saved=nbytes * len(dedup_digests),
            failovers=len(failover_events),
            failover_events=failover_events,
            error=failed,
        )
