"""Virtual-time fabric execution — campaigns at testbed scale, no testbed.

The fluid-model executor for distribution trees, built from the same parts
as the service testbed and sharing its virtual clock:

  * per-edge steady-state rate ceilings come from the CALIBRATED per-chunk
    simulator (``core.simulator.simulate_transfer``) run on the two
    endpoints' site projections and the link's loss-degraded bandwidth — so
    checksum pipelining, mover caps and chunk-control overheads are folded
    into every hop exactly as they are for single-pipe predictions;
  * shared links and endpoints are arbitrated max-min fair across all
    concurrently-flowing tree edges (``core.simulator._maxmin_rates`` — the
    same progressive-filling allocator the WAN model uses internally);
  * store-and-forward coupling: an edge can forward no faster than custody
    arrives at its tail (cut-through at chunk granularity), so relay
    makespan approaches the slowest hop instead of the sum of hops;
  * campaign arrivals are activated tenant-fair under a concurrency cap via
    ``service.scheduler.select_activations`` — the same activation policy
    the real service and the testbed run;
  * the fault-scenario DSL applies: ``link_outage_at_50pct`` drops a seeded
    victim link to zero bandwidth for ``link_outage_s`` virtual seconds once
    the campaign set crosses the progress fraction; ``degrade_hop`` scales a
    seeded victim relay endpoint's rates by ``degrade_factor``; corruption
    at ``bytes_per_error`` costs chunk-granular re-moves per edge; endpoint
    *scheduled* outages (``Endpoint.outages`` windows) zero every edge
    touching the endpoint for the window.

Event stepping runs on ``core.vclock.VirtualClock`` like every other
virtual backend in the repo.
"""
from __future__ import annotations

import dataclasses
import math
import random
from typing import Sequence

import numpy as np

from repro.core.simulator import (
    Gb,
    LinkConfig,
    TransferSpec,
    _maxmin_rates,
    simulate_transfer,
)
from repro.core.vclock import VirtualClock, Window
from repro.fabric.campaign import DistributionTree
from repro.fabric.topology import RoutePlanner, Topology
from repro.faults.injectors import _seed_int
from repro.faults.scenarios import Scenario
from repro.service.scheduler import DEFAULT_QUOTA, TenantQuota, select_activations


# ---------------------------------------------------------------------------
# submissions / reports
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class CampaignSubmission:
    """One replication campaign entering the fabric at ``time_s``."""

    time_s: float
    tenant: str
    tree: DistributionTree
    nbytes: int
    label: str = ""


@dataclasses.dataclass
class FlowResult:
    campaign_id: str
    tenant: str
    label: str
    nbytes: int
    dests: tuple[str, ...]
    submit_s: float
    start_s: float | None = None
    done_s: float | None = None
    dest_done_s: dict[str, float] = dataclasses.field(default_factory=dict)

    @property
    def latency_s(self) -> float:
        assert self.done_s is not None
        return self.done_s - self.submit_s


@dataclasses.dataclass
class FabricFaultLog:
    corruptions: int = 0
    re_moved_bytes: float = 0.0
    link_outage_s: float = 0.0
    degraded_endpoints: tuple[str, ...] = ()


@dataclasses.dataclass
class FabricLoadReport:
    flows: list[FlowResult]
    makespan_s: float
    wire_bytes: float                # bytes that crossed WAN links (with re-moves)
    goodput_bytes: float             # replica bytes delivered (nbytes * n_dests)
    scenario: str = "clean"
    faults: FabricFaultLog = dataclasses.field(default_factory=FabricFaultLog)
    victims: dict[str, str] = dataclasses.field(default_factory=dict)

    @property
    def aggregate_gbps(self) -> float:
        return (
            self.goodput_bytes * 8 / 1e9 / self.makespan_s
            if self.makespan_s > 0 else 0.0
        )

    @property
    def all_done(self) -> bool:
        return all(f.done_s is not None for f in self.flows)


# ---------------------------------------------------------------------------
# per-edge steady-state rate prediction (core.simulator)
# ---------------------------------------------------------------------------
class EdgeRatePredictor:
    """Memoized per-hop rate ceilings from the calibrated per-chunk model."""

    def __init__(self, topo: Topology, *, chunk_bytes: int | None,
                 integrity: bool = True):
        self.topo = topo
        self.chunk_bytes = chunk_bytes
        self.integrity = integrity
        self._cache: dict[tuple, float] = {}

    def cap_gbps(self, u: str, v: str, nbytes: int) -> float:
        key = (u, v, nbytes)
        hit = self._cache.get(key)
        if hit is not None:
            return hit
        link = self.topo.link(u, v)
        a, b = self.topo.endpoint(u), self.topo.endpoint(v)
        spec = TransferSpec(
            file_bytes=(nbytes,),
            chunk_bytes=min(self.chunk_bytes, nbytes) if self.chunk_bytes else None,
            integrity=self.integrity,
            concurrency=min(a.movers, b.movers),
        )
        lnk = LinkConfig(
            wan_gbps=link.effective_gbps,
            chunk_latency_s=max(0.02, 2.0 * link.rtt_s),
        )
        secs = simulate_transfer(a.to_site(), b.to_site(), spec, lnk).seconds
        cap = nbytes * 8 / 1e9 / secs if secs > 0 else float("inf")
        self._cache[key] = cap
        return cap


# ---------------------------------------------------------------------------
# fluid engine internals
# ---------------------------------------------------------------------------
class _EdgeFlow:
    __slots__ = ("flow", "u", "v", "parent", "delivered", "cap_gbps",
                 "corrupt_slowdown", "rate")

    def __init__(self, flow: "_Flow", u: str, v: str,
                 parent: "_EdgeFlow | None", cap_gbps: float):
        self.flow, self.u, self.v, self.parent = flow, u, v, parent
        self.delivered = 0.0
        self.cap_gbps = cap_gbps
        self.corrupt_slowdown = 1.0   # goodput fraction after re-moved chunks
        self.rate = 0.0               # effective Gb/s this step

    @property
    def done(self) -> bool:
        return self.delivered >= self.flow.nbytes - 1e-6


class _Flow:
    def __init__(self, seq: int, sub: CampaignSubmission,
                 predictor: EdgeRatePredictor):
        self.seq = seq
        self.sub = sub
        self.nbytes = float(sub.nbytes)
        self.result = FlowResult(
            campaign_id=f"campaign-{seq:04d}-{sub.tenant}",
            tenant=sub.tenant, label=sub.label, nbytes=sub.nbytes,
            dests=sub.tree.dests, submit_s=sub.time_s,
        )
        by_node: dict[str, _EdgeFlow] = {}
        self.edges: list[_EdgeFlow] = []
        for u, v in sub.tree.edges:          # topo order: parent before child
            ef = _EdgeFlow(self, u, v, by_node.get(u),
                           predictor.cap_gbps(u, v, sub.nbytes))
            by_node[v] = ef
            self.edges.append(ef)

    @property
    def done(self) -> bool:
        return all(e.done for e in self.edges)


def run_fabric_load(
    topo: Topology,
    submissions: Sequence[CampaignSubmission],
    *,
    chunk_bytes: int | None = 500 * 1000 * 1000,
    integrity: bool = True,
    max_concurrent: int = 8,
    scenario: Scenario | None = None,
    seed: int = 0,
    quotas: dict[str, TenantQuota] | None = None,
    default_quota: TenantQuota = DEFAULT_QUOTA,
) -> FabricLoadReport:
    """Drive a set of replication campaigns through the fabric in virtual time."""
    predictor = EdgeRatePredictor(topo, chunk_bytes=chunk_bytes, integrity=integrity)
    flows = [
        _Flow(i, sub, predictor)
        for i, sub in enumerate(sorted(submissions, key=lambda s: (s.time_s,)))
    ]
    flog = FabricFaultLog()
    victims: dict[str, str] = {}

    # ---- seeded fault realisation over the whole campaign set
    used_links: list[tuple[str, str]] = []
    link_count: dict[tuple[str, str], int] = {}
    for f in flows:
        for e in f.edges:
            key = (e.u, e.v)
            if key not in link_count:
                used_links.append(key)
            link_count[key] = link_count.get(key, 0) + 1
    rng = random.Random(_seed_int(seed, "fabric-virtual",
                                  scenario.name if scenario else "clean"))
    victim_link: tuple[str, str] | None = None
    degraded: set[str] = set()
    if scenario is not None and scenario.link_outage_at_frac is not None and used_links:
        shared = [l for l in used_links if link_count[l] > 1]
        victim_link = rng.choice(sorted(shared or used_links))
        victims["link_outage"] = f"{victim_link[0]}->{victim_link[1]}"
    if scenario is not None and scenario.degrade_hops > 0:
        inner = sorted({
            e.u for f in flows for e in f.edges if e.parent is not None
        })
        if not inner:
            inner = sorted({e.v for f in flows for e in f.edges})
        for name in rng.sample(inner, min(scenario.degrade_hops, len(inner))):
            degraded.add(name)
        victims["degrade"] = ",".join(sorted(degraded))
        flog.degraded_endpoints = tuple(sorted(degraded))
    if scenario is not None and scenario.bytes_per_error is not None:
        crng = np.random.default_rng(_seed_int(seed, "corrupt"))
        eff_chunk = float(chunk_bytes or 500 * 1000 * 1000)
        for f in flows:
            for e in f.edges:
                n = int(crng.poisson(f.nbytes / scenario.bytes_per_error))
                if n:
                    extra = float(min(n * min(eff_chunk, f.nbytes), 4 * f.nbytes))
                    # a corrupt landing costs one chunk re-move on THIS hop:
                    # model as a goodput-rate haircut on the edge
                    e.corrupt_slowdown = f.nbytes / (f.nbytes + extra)
                    flog.corruptions += n
                    flog.re_moved_bytes += extra

    planned_wire = sum(f.nbytes * len(f.edges) for f in flows)
    outage_trigger = (
        scenario.link_outage_at_frac * planned_wire
        if scenario is not None and scenario.link_outage_at_frac is not None
        else None
    )
    link_outage_win: Window | None = None

    pending: list[_Flow] = []
    active: list[_Flow] = []
    finished: list[_Flow] = []
    served: dict[str, int] = {}
    ai = 0
    moved_wire = 0.0
    n_edges_total = sum(len(f.edges) for f in flows) or 1
    clock = VirtualClock(guard=200 * n_edges_total + 2000, label="fabric")

    def degrade_factor(name: str) -> float:
        return scenario.degrade_factor if name in degraded else 1.0

    def endpoint_dark(name: str, t: float) -> bool:
        return not topo.endpoint(name).available(t)

    def link_dark(u: str, v: str, t: float) -> bool:
        if link_outage_win is None or victim_link is None:
            return False
        if not link_outage_win.contains(t):
            return False
        return (u, v) == victim_link or (v, u) == victim_link

    def compute_rates(t: float) -> list[_EdgeFlow]:
        for f in active:
            for e in f.edges:
                e.rate = 0.0          # incl. done parents: no stale coupling
        live = [e for f in active for e in f.edges if not e.done]
        flowing = [
            e for e in live
            if not link_dark(e.u, e.v, t)
            and not endpoint_dark(e.u, t) and not endpoint_dark(e.v, t)
        ]
        if flowing:
            idx = {id(e): i for i, e in enumerate(flowing)}
            res: dict[str, tuple[float, list[int]]] = {}

            def add(name: str, cap_gbps: float, member: _EdgeFlow):
                cap = cap_gbps * Gb
                if name not in res:
                    res[name] = (cap, [])
                res[name][1].append(idx[id(member)])

            for e in flowing:
                link = topo.link(e.u, e.v)
                a, b = topo.endpoint(e.u), topo.endpoint(e.v)
                add(f"link:{e.u}->{e.v}", link.effective_gbps, e)
                add(f"out:{e.u}",
                    min(a.net_gbps, a.storage_gbps) * degrade_factor(e.u), e)
                add(f"in:{e.v}",
                    min(b.net_gbps, b.storage_gbps) * degrade_factor(e.v), e)
                ceiling = (
                    e.cap_gbps * e.corrupt_slowdown
                    * degrade_factor(e.u) * degrade_factor(e.v)
                )
                add(f"edge:{id(e)}", ceiling, e)
            _maxmin_rates(flowing, res)
            for e in flowing:
                e.rate = e.rate / Gb          # _maxmin_rates works in bytes/s
        # store-and-forward coupling, in topo order (parents precede children)
        for e in live:
            par = e.parent
            avail = e.flow.nbytes if par is None else par.delivered
            backlog = avail - e.delivered
            if backlog <= 1e-6:
                e.rate = min(e.rate, par.rate if par is not None else e.rate)
        return live

    def reschedule(t: float) -> None:
        free = max_concurrent - len(active)
        if free <= 0 or not pending:
            return
        by_tenant: dict[str, int] = {}
        for a in active:
            by_tenant[a.sub.tenant] = by_tenant.get(a.sub.tenant, 0) + 1
        chosen = select_activations(
            [(p.seq, p.result.campaign_id, p.sub.tenant) for p in pending],
            by_tenant, free_slots=free,
            quotas=quotas, default_quota=default_quota,
            served_by_tenant=served,
        )
        lut = {p.result.campaign_id: p for p in pending}
        for cid in chosen:
            f = lut[cid]
            pending.remove(f)
            f.result.start_s = t
            served[f.sub.tenant] = served.get(f.sub.tenant, 0) + 1
            active.append(f)

    while ai < len(flows) or pending or active:
        # admissions
        while ai < len(flows) and flows[ai].sub.time_s <= clock.now + 1e-12:
            pending.append(flows[ai])
            ai += 1
        reschedule(clock.now)
        live = compute_rates(clock.now)
        # wire traffic includes the re-moved chunks corruption costs: an edge
        # delivering goodput at rate r crosses the link at r / slowdown
        wire_Bps = sum(e.rate / e.corrupt_slowdown for e in live) * Gb

        cands: list[float] = []
        if ai < len(flows):
            cands.append(flows[ai].sub.time_s - clock.now)
        for e in live:
            if e.rate > 1e-12:
                cands.append((e.flow.nbytes - e.delivered) / (e.rate * Gb))
                par = e.parent
                if par is not None:
                    backlog = par.delivered - e.delivered
                    gap = (e.rate - par.rate) * Gb
                    if backlog > 1e-6 and gap > 1e-9:
                        cands.append(backlog / gap)   # catch-up: coupling binds
        if outage_trigger is not None and wire_Bps > 0 and moved_wire < outage_trigger:
            cands.append((outage_trigger - moved_wire) / wire_Bps)
        if link_outage_win is not None:
            b = link_outage_win.next_boundary(clock.now)
            if math.isfinite(b):
                cands.append(b)
        for f in active:                     # endpoint maintenance calendars
            for e in f.edges:
                if e.done:
                    continue
                for name in (e.u, e.v):
                    for w in topo.endpoint(name).outages:
                        b = w.next_boundary(clock.now)
                        if math.isfinite(b):
                            cands.append(b)
        dt = clock.tick(*cands)

        for e in live:
            if e.rate > 0:
                e.delivered += e.rate * Gb * dt
                par = e.parent
                ceiling = e.flow.nbytes if par is None else par.delivered
                e.delivered = min(e.delivered, ceiling)
        moved_wire += wire_Bps * dt

        if (outage_trigger is not None and moved_wire >= outage_trigger - 1e-6
                and link_outage_win is None and victim_link is not None):
            link_outage_win = Window(clock.now, scenario.link_outage_s)
            flog.link_outage_s = scenario.link_outage_s
            outage_trigger = None
        if link_outage_win is not None and clock.now >= link_outage_win.end - 1e-12:
            link_outage_win = None

        # completions: record dest arrival times, retire finished campaigns
        for f in list(active):
            for e in f.edges:
                if e.done and e.v in f.sub.tree.dests:
                    f.result.dest_done_s.setdefault(e.v, clock.now)
            if f.done:
                f.result.done_s = clock.now
                active.remove(f)
                finished.append(f)

    goodput = sum(float(f.nbytes) * len(f.sub.tree.dests) for f in flows)
    t0 = min((f.sub.time_s for f in flows), default=0.0)
    makespan = max((f.result.done_s or 0.0 for f in flows), default=0.0) - t0
    return FabricLoadReport(
        flows=[f.result for f in flows],
        makespan_s=makespan,
        wire_bytes=moved_wire,
        goodput_bytes=goodput,
        scenario=scenario.name if scenario is not None else "clean",
        faults=flog,
        victims=victims,
    )


# ---------------------------------------------------------------------------
# convenience entry points
# ---------------------------------------------------------------------------
def simulate_campaign(
    topo: Topology,
    tree: DistributionTree,
    nbytes: int,
    *,
    tenant: str = "default",
    **kw,
) -> FabricLoadReport:
    """One fan-out campaign, submitted at t=0."""
    return run_fabric_load(
        topo, [CampaignSubmission(0.0, tenant, tree, nbytes)], **kw)


def simulate_naive(
    topo: Topology,
    source: str,
    dests: Sequence[str],
    nbytes: int,
    *,
    planner: RoutePlanner | None = None,
    tenant: str = "default",
    **kw,
) -> FabricLoadReport:
    """N independent per-destination transfers (the pre-fabric baseline).

    Each destination gets its own best route executed as a degenerate
    single-branch tree; all N run concurrently and contend max-min fair for
    the shared trunk links a campaign tree would have crossed once.
    """
    planner = planner or RoutePlanner(topo)
    subs = []
    for d in dests:
        route = planner.best_route(source, d, nbytes)
        tree = DistributionTree(source=source, dests=(d,), edges=route.hops)
        subs.append(CampaignSubmission(0.0, tenant, tree, nbytes, label=f"naive:{d}"))
    return run_fabric_load(topo, subs, **kw)
