"""Multi-endpoint WAN fabric: routed relays + fan-out replication campaigns.

The paper's production context moves data "to, from, and among" many
facilities; this package lifts the repo's single-pipe transfer stack onto a
fabric of endpoints:

  * ``topology``  — endpoint registry (mover caps, storage/checksum rates,
    outage calendars), link graph (bandwidth/RTT/loss), congestion-aware
    k-shortest-path route planning;
  * ``relay``     — multi-hop store-and-forward transfers with per-hop chunk
    custody journals (a chunk that reached an intermediate DTN is never
    re-pulled from the origin after a crash);
  * ``campaign``  — 1 -> N replication campaigns: cheapest-attachment
    distribution trees that pay shared trunk links once, decomposed into
    ordinary ``repro.service`` tasks (tenants/quotas/events/pause-resume
    apply), with merge-law digest verification at every replica;
  * ``virtual``   — virtual-time fluid execution of the same trees on the
    calibrated simulator, with the fault-scenario DSL
    (``link_outage_at_50pct+degrade_hop``) applied to links and relay DTNs.
"""
from repro.fabric.campaign import (
    CampaignError,
    CampaignReport,
    CampaignRunner,
    DistributionTree,
    build_distribution_tree,
    naive_wire_hops,
)
from repro.fabric.relay import (
    HopReport,
    RelayReport,
    RelayTransfer,
    realize_hop_campaigns,
    run_relay,
)
from repro.fabric.topology import (
    BUILTIN_TOPOLOGIES,
    Endpoint,
    Link,
    NoRouteError,
    Route,
    RoutePlanner,
    Topology,
    fat_tree_topology,
    shared_trunk_topology,
    star_topology,
)
from repro.fabric.virtual import (
    CampaignSubmission,
    EdgeRatePredictor,
    FabricFaultLog,
    FabricLoadReport,
    FlowResult,
    run_fabric_load,
    simulate_campaign,
    simulate_naive,
)

__all__ = [
    "BUILTIN_TOPOLOGIES",
    "CampaignError", "CampaignReport", "CampaignRunner", "CampaignSubmission",
    "DistributionTree", "EdgeRatePredictor", "Endpoint", "FabricFaultLog",
    "FabricLoadReport", "FlowResult", "HopReport", "Link", "NoRouteError",
    "RelayReport", "RelayTransfer", "Route", "RoutePlanner", "Topology",
    "build_distribution_tree", "fat_tree_topology", "naive_wire_hops",
    "realize_hop_campaigns", "run_fabric_load", "run_relay",
    "shared_trunk_topology", "simulate_campaign", "simulate_naive",
    "star_topology",
]
