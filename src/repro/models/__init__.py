"""Model zoo: dense, MoE, SSM, hybrid, enc-dec, VLM families."""
from repro.models.common import ModelConfig
from repro.models.transformer import DenseLM
from repro.models.moe import MoELM
from repro.models.ssm import Mamba2LM
from repro.models.hybrid import RecurrentGemmaLM
from repro.models.encdec import WhisperLM
from repro.models.vlm import InternVLM

__all__ = ["ModelConfig", "DenseLM", "MoELM", "Mamba2LM",
           "RecurrentGemmaLM", "WhisperLM", "InternVLM"]
