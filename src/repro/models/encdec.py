"""Whisper-family encoder-decoder (audio backbone; conv frontend stubbed).

Per the assignment brief the modality frontend is a stub: ``input_specs``
provides precomputed frame embeddings (B, enc_positions, d_model) — the
log-mel + 2xConv1d stem's output — and this module implements the transformer
backbone faithfully: sinusoidal encoder positions, learned decoder positions,
MHA (kv_heads == heads), plain 2-layer GELU MLPs, pre-LayerNorm with biases,
causal decoder self-attention + cross-attention to the encoder output.

Decoder positional table is sized to the requested sequence length (beyond
Whisper's native 448) so the decode_32k/prefill cells are well-defined;
noted in DESIGN.md §6.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.models import common as cm
from repro.models.common import ModelConfig
from repro.distributed.mesh import MODEL


def layer_norm(x, scale, bias, eps=1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


def sinusoids(length: int, channels: int) -> jnp.ndarray:
    t = jnp.arange(length, dtype=jnp.float32)[:, None]
    inv = jnp.exp(-math.log(10000.0) * jnp.arange(channels // 2, dtype=jnp.float32)
                  / (channels // 2 - 1))
    ang = t * inv[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=1)


class WhisperLM(cm.ShardingMixin):
    def __init__(self, cfg: ModelConfig, mesh: Mesh | None = None, *, max_target: int = 448):
        self.cfg = cfg
        self.mesh = mesh
        self.max_target = max_target

    # -- params ---------------------------------------------------------------
    def _attn_p(self, ini, n, tag, cross=False):
        cfg, D = self.cfg, self.cfg.d_model
        H, hd = cfg.n_heads, cfg.hd
        return {
            "ln_s": ini.ones((n, D)), "ln_b": ini.zeros((n, D)),
            "wq": ini(f"{tag}.wq", (n, D, H, hd)),
            "wk": ini(f"{tag}.wk", (n, D, H, hd)),
            "wv": ini(f"{tag}.wv", (n, D, H, hd)),
            "wo": ini(f"{tag}.wo", (n, H, hd, D), scale=1.0 / math.sqrt(H * hd)),
        }

    def _mlp_p(self, ini, n, tag):
        cfg, D = self.cfg, self.cfg.d_model
        return {
            "ln_s": ini.ones((n, D)), "ln_b": ini.zeros((n, D)),
            "w1": ini(f"{tag}.w1", (n, D, cfg.d_ff)),
            "b1": ini.zeros((n, cfg.d_ff)),
            "w2": ini(f"{tag}.w2", (n, cfg.d_ff, D), scale=1.0 / math.sqrt(cfg.d_ff)),
            "b2": ini.zeros((n, D)),
        }

    def init_params(self, seed: int = 0) -> Any:
        cfg = self.cfg
        ini = cm.Initializer(seed, cfg.dtype)
        ne, nd, D = cfg.n_enc_layers, cfg.n_layers, cfg.d_model
        return {
            "embed": ini("embed", (cfg.vocab, D), scale=1.0),
            "pos_dec": ini("pos_dec", (self.max_target, D), scale=0.02),
            "enc": {"self": self._attn_p(ini, ne, "enc.self"),
                    "mlp": self._mlp_p(ini, ne, "enc.mlp")},
            "enc_norm_s": ini.ones((D,)), "enc_norm_b": ini.zeros((D,)),
            "dec": {"self": self._attn_p(ini, nd, "dec.self"),
                    "cross": self._attn_p(ini, nd, "dec.cross", cross=True),
                    "mlp": self._mlp_p(ini, nd, "dec.mlp")},
            "dec_norm_s": ini.ones((D,)), "dec_norm_b": ini.zeros((D,)),
        }

    def param_specs(self, mesh: Mesh) -> Any:
        cfg = self.cfg
        d_dat = cm.shardable(cfg.d_model, "data", mesh)
        h_m = cm.shardable(cfg.n_heads, MODEL, mesh)
        f_m = cm.shardable(cfg.d_ff, MODEL, mesh)
        attn = {"ln_s": P(None, None), "ln_b": P(None, None),
                "wq": P(None, d_dat, h_m, None), "wk": P(None, d_dat, h_m, None),
                "wv": P(None, d_dat, h_m, None), "wo": P(None, h_m, None, d_dat)}
        mlp = {"ln_s": P(None, None), "ln_b": P(None, None),
               "w1": P(None, d_dat, f_m), "b1": P(None, f_m),
               "w2": P(None, f_m, d_dat), "b2": P(None, None)}
        return {
            "embed": P(cm.shardable(cfg.vocab, MODEL, mesh), d_dat),
            "pos_dec": P(None, None),
            "enc": {"self": dict(attn), "mlp": dict(mlp)},
            "enc_norm_s": P(None), "enc_norm_b": P(None),
            "dec": {"self": dict(attn), "cross": dict(attn), "mlp": dict(mlp)},
            "dec_norm_s": P(None), "dec_norm_b": P(None),
        }

    # -- sub-layers --------------------------------------------------------------
    def _qspec(self, S):
        """Whisper's 20 heads don't divide a 16-wide model axis: use
        context-parallel attention (q seq-sharded, full KV) instead."""
        return P(self._batch(), self._seq(S), None, None)

    def _sa(self, x, lp, *, causal, q_pos, kv=None, kv_pos=None):
        h = layer_norm(x, lp["ln_s"], lp["ln_b"])
        q = jnp.einsum("bsd,dnh->bsnh", h, lp["wq"])
        q = self._constrain(q, self._qspec(q.shape[1]))
        if kv is None:
            k = jnp.einsum("bsd,dnh->bsnh", h, lp["wk"])
            v = jnp.einsum("bsd,dnh->bsnh", h, lp["wv"])
            k = self._constrain(k, P(self._batch(), None, None, None))
            v = self._constrain(v, P(self._batch(), None, None, None))
            kp = q_pos
        else:
            k, v, kp = kv
        o = cm.attention(q, k, v, causal=causal, q_positions=q_pos, kv_positions=kp)
        return self._res(x + jnp.einsum("bsnh,nhd->bsd", o, lp["wo"])), (k, v)

    def _cross(self, x, lp, enc_k, enc_v, enc_pos, q_pos):
        h = layer_norm(x, lp["ln_s"], lp["ln_b"])
        q = jnp.einsum("bsd,dnh->bsnh", h, lp["wq"])
        q = self._constrain(q, self._qspec(q.shape[1]))
        o = cm.attention(q, enc_k, enc_v, causal=False,
                         q_positions=q_pos, kv_positions=enc_pos)
        return self._res(x + jnp.einsum("bsnh,nhd->bsd", o, lp["wo"]))

    def _mlp(self, x, lp):
        h = layer_norm(x, lp["ln_s"], lp["ln_b"])
        h = cm.act_fn("gelu")(jnp.einsum("bsd,df->bsf", h, lp["w1"]) + lp["b1"])
        h = self._constrain(h, P(self._batch(), None,
                                 cm.shardable(self.cfg.d_ff, MODEL, self.mesh)
                                 if self.mesh else None))
        return self._res(x + jnp.einsum("bsf,fd->bsd", h, lp["w2"]) + lp["b2"])

    # -- encoder -------------------------------------------------------------------
    def encode(self, params, audio_embed):
        cfg = self.cfg
        B, T, D = audio_embed.shape
        x = audio_embed.astype(cfg.dtype) + sinusoids(T, D).astype(cfg.dtype)[None]
        x = self._res(x)
        pos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None], (B, T))

        def body(carry, blk):
            x = carry
            x, _ = self._sa(x, blk["self"], causal=False, q_pos=pos)
            x = self._mlp(x, blk["mlp"])
            return x, None

        x, _ = cm.scan(cm.maybe_remat(body, cfg), x, params["enc"])
        return layer_norm(x, params["enc_norm_s"], params["enc_norm_b"])

    # -- decoder (train) -------------------------------------------------------------
    def dec_hidden(self, params, tokens, enc_out):
        cfg = self.cfg
        B, S = tokens.shape
        x = self._lookup(params["embed"], tokens).astype(cfg.dtype)
        x = self._res(x + params["pos_dec"][:S][None].astype(cfg.dtype))
        q_pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
        enc_pos = jnp.broadcast_to(
            jnp.arange(enc_out.shape[1], dtype=jnp.int32)[None], (B, enc_out.shape[1]))

        def body(carry, blk):
            x = carry
            x, _ = self._sa(x, blk["self"], causal=True, q_pos=q_pos)
            ek = jnp.einsum("btd,dnh->btnh", enc_out, blk["cross"]["wk"])
            ev = jnp.einsum("btd,dnh->btnh", enc_out, blk["cross"]["wv"])
            x = self._cross(x, blk["cross"], ek, ev, enc_pos, q_pos)
            x = self._mlp(x, blk["mlp"])
            return x, None

        x, _ = cm.scan(cm.maybe_remat(body, cfg), x, params["dec"])
        return layer_norm(x, params["dec_norm_s"], params["dec_norm_b"])

    def dec_logits(self, params, tokens, enc_out):
        x = self.dec_hidden(params, tokens, enc_out)
        return jnp.einsum("bsd,vd->bsv", x, params["embed"].astype(self.cfg.dtype))

    def loss(self, params, batch):
        enc = self.encode(params, batch["audio_embed"])
        h = self.dec_hidden(params, batch["tokens"][:, :-1], enc)
        return cm.chunked_xent(h, self._out_w(params), batch["tokens"][:, 1:])

    def _out_w(self, params):
        w = params["embed"].T.astype(self.cfg.dtype)
        if self.mesh is not None:
            w = cm.constrain(w, self.mesh,
                             P(None, cm.shardable(self.cfg.vocab, MODEL, self.mesh)))
        return w

    # -- decode -----------------------------------------------------------------------
    def init_cache(self, batch: int, max_len: int) -> Any:
        """Decoder self-attn KV ring + cross-attn KV (filled by prefill)."""
        cfg = self.cfg
        nd, H, hd, Te = cfg.n_layers, cfg.n_heads, cfg.hd, cfg.enc_positions
        return {
            "k": jnp.zeros((nd, batch, max_len, H, hd), cfg.dtype),
            "v": jnp.zeros((nd, batch, max_len, H, hd), cfg.dtype),
            "p": jnp.full((nd, batch, max_len), -1, jnp.int32),
            "ek": jnp.zeros((nd, batch, Te, H, hd), cfg.dtype),
            "ev": jnp.zeros((nd, batch, Te, H, hd), cfg.dtype),
        }

    def cache_specs(self, mesh: Mesh, batch: int, max_len: int) -> Any:
        kv = cm.kv_cache_spec(mesh, batch, max_len, extra=(None, None))
        ekv = cm.kv_cache_spec(mesh, batch, self.cfg.enc_positions, extra=(None, None))
        return {"k": kv, "v": kv, "p": cm.kv_cache_spec(mesh, batch, max_len),
                "ek": ekv, "ev": ekv}

    def prefill_cross(self, params, cache, audio_embed):
        """Compute encoder output and fill per-layer cross-attn K/V."""
        enc = self.encode(params, audio_embed)
        ek = jnp.einsum("btd,ldnh->lbtnh", enc, params["dec"]["cross"]["wk"])
        ev = jnp.einsum("btd,ldnh->lbtnh", enc, params["dec"]["cross"]["wv"])
        return {**cache, "ek": ek, "ev": ev}

    def decode_step(self, params, cache, tokens, pos):
        cfg = self.cfg
        B = tokens.shape[0]
        x = self._lookup(params["embed"], tokens).astype(cfg.dtype)
        pos_emb = jnp.take(params["pos_dec"], jnp.minimum(pos, self.max_target - 1), axis=0)
        x = x + pos_emb[:, None].astype(cfg.dtype)
        q_pos = pos[:, None]
        Te = cfg.enc_positions
        enc_pos = jnp.broadcast_to(jnp.arange(Te, dtype=jnp.int32)[None], (B, Te))

        from repro.models.transformer import DenseLM

        def body(carry, xs):
            x = carry
            blk = xs["blk"]
            T = xs["k"].shape[1]
            slot = pos % T
            h = layer_norm(x, blk["self"]["ln_s"], blk["self"]["ln_b"])
            q = jnp.einsum("bsd,dnh->bsnh", h, blk["self"]["wq"])
            k = jnp.einsum("bsd,dnh->bsnh", h, blk["self"]["wk"])
            v = jnp.einsum("bsd,dnh->bsnh", h, blk["self"]["wv"])
            ck, cv, cp = DenseLM._cache_write(xs["k"], xs["v"], xs["p"], k, v, pos, slot)
            o = cm.attention(q, ck, cv, causal=True, q_positions=q_pos, kv_positions=cp)
            x = x + jnp.einsum("bsnh,nhd->bsd", o, blk["self"]["wo"])
            x = self._cross(x, blk["cross"], xs["ek"], xs["ev"], enc_pos, q_pos)
            x = self._mlp(x, blk["mlp"])
            return x, {"k": ck, "v": cv, "p": cp}

        xs = {"blk": params["dec"], "k": cache["k"], "v": cache["v"], "p": cache["p"],
              "ek": cache["ek"], "ev": cache["ev"]}
        x, new = cm.scan(body, x, xs)
        x = layer_norm(x, params["dec_norm_s"], params["dec_norm_b"])
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"].astype(cfg.dtype))
        return logits, {**cache, "k": new["k"], "v": new["v"], "p": new["p"]}
