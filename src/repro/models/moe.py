"""Mixture-of-Experts LM with static-capacity all-to-all expert parallelism.

Token-choice top-k routing; dispatch/combine are the GShard/Switch-style
static-shape all-to-alls, executed inside a shard_map that is manual over the
whole mesh for the MoE block only (attention stays GSPMD-auto):

  1. each model column takes a 1/tp slice of the data-shard's tokens,
  2. routes them into a (tp, E_loc, C, D) send buffer (capacity-dropped,
     rank-in-bucket via one-hot cumsum),
  3. all-to-all over the model axis delivers each column its experts' tokens,
  4. batched expert FFN (E_loc experts per column),
  5. reverse all-to-all + weighted combine, then all-gather restores the
     model-replicated activation layout.

Expert placement generalizes over the fixed 16-column model axis:
  * E >= tp (qwen3: 128/16): E_loc = E/tp experts per column, full FFN width.
  * E <  tp (grok-1: 8/16):  SPLIT = tp/E columns per expert, each holding an
    F/SPLIT slice; tokens fan out to all SPLIT slices and the slices' partial
    outputs are summed in combine — tensor parallelism *inside* expert
    parallelism, so the 16-wide axis is always fully used.

Weights are stored pre-sliced as (tp, E_loc, D, F/SPLIT) so a per-column slice
is a plain PartitionSpec('model', ...) — total element count = E*D*F exactly.

The all-to-alls are the model-axis analogue of the paper's chunked transfers:
they are the single largest routed data movement in the framework, and the
hillclimb chunks them (see EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import functools
import math
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.models import common as cm
from repro.models.common import ModelConfig
from repro.models.transformer import DenseLM
from repro.distributed.mesh import MODEL, POD, DATA, shard_map


def expert_layout(cfg: ModelConfig, tp: int) -> tuple[int, int, int]:
    """(E_loc, SPLIT, C_factor-less layout) for a model axis of size tp."""
    E = cfg.n_experts
    if E >= tp:
        assert E % tp == 0, (E, tp)
        return E // tp, 1, tp
    assert tp % E == 0, (E, tp)
    return 1, tp // E, E


def capacity(t_sub: int, cfg: ModelConfig, tp: int, cf: float = 2.0) -> int:
    """Per-(dest-column, local-expert) receive capacity from one sender."""
    e_loc, split, _ = expert_layout(cfg, tp)
    per_bucket = t_sub * cfg.top_k * split / (tp * e_loc)
    return max(4, int(math.ceil(per_bucket * cf)))


def _moe_local(x_my, wr, wg, wi, wo, *, cfg: ModelConfig, tp: int,
               axis_name: str | None, cf: float):
    """MoE over this column's token slice. x_my: (T_sub, D).

    wg/wi/wo: (E_loc, D, Fs) / (E_loc, D, Fs) / (E_loc, Fs, D) local slices.
    Returns (T_sub, D).
    """
    T_sub, D = x_my.shape
    E = cfg.n_experts
    k = cfg.top_k
    e_loc, split, _ = expert_layout(cfg, tp)
    C = capacity(T_sub, cfg, tp, cf)

    # ---- routing (f32 for stability)
    logits = (x_my.astype(jnp.float32) @ wr.astype(jnp.float32))      # (T_sub, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)                            # (T_sub, k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # ---- bucket ranks: bucket = (expert-group g, local expert e_loc)
    flat_e = top_e.reshape(-1)                                        # (T_sub*k,)
    g = flat_e // e_loc                                               # column group
    el = flat_e % e_loc
    bucket = g * e_loc + el                                           # (T_sub*k,) in [0, E)
    onehot = jax.nn.one_hot(bucket, E, dtype=jnp.int32)               # (T*k, E)
    rank = jnp.cumsum(onehot, axis=0) * onehot                        # 1-indexed
    slot = jnp.sum(rank, axis=1) - 1                                  # (T*k,)
    keep = slot < C
    slot_c = jnp.where(keep, slot, C)                                 # C => dropped

    tok_idx = jnp.repeat(jnp.arange(T_sub), k)

    # ---- scatter into send buffer (tp, E_loc, C, D); h-splits duplicate rows
    send = jnp.zeros((tp, e_loc, C, D), cfg.dtype)
    vals = x_my[tok_idx].astype(cfg.dtype)
    for h in range(split):
        dest = g * split + h
        send = send.at[dest, el, slot_c].add(vals, mode="drop")

    # ---- a2a to expert owners
    if axis_name is not None and tp > 1:
        recv = jax.lax.all_to_all(send, axis_name, split_axis=0, concat_axis=0)
    else:
        recv = send                                                    # tp == 1

    # ---- expert FFN (E_loc experts, rows = tp*C each)
    xe = recv.transpose(1, 0, 2, 3).reshape(e_loc, tp * C, D)
    hg = cm.act_fn(cfg.act)(jnp.einsum("etd,edf->etf", xe, wg))
    hi = jnp.einsum("etd,edf->etf", xe, wi)
    out = jnp.einsum("etf,efd->etd", hg * hi, wo)                      # (E_loc, tp*C, D)
    out = out.reshape(e_loc, tp, C, D).transpose(1, 0, 2, 3)           # (tp, E_loc, C, D)

    # ---- return trip + combine
    if axis_name is not None and tp > 1:
        back = jax.lax.all_to_all(out, axis_name, split_axis=0, concat_axis=0)
    else:
        back = out
    # gather per (token, choice): sum F-splits, weight by router prob
    y = jnp.zeros((T_sub, D), jnp.float32)
    flat_back = back.reshape(tp * e_loc * C, D)
    for h in range(split):
        dest = g * split + h
        lin = (dest * e_loc + el) * C + jnp.where(keep, slot, tp * e_loc * C)
        picked = jnp.take(flat_back, jnp.clip(lin, 0, flat_back.shape[0] - 1), axis=0)
        picked = jnp.where(keep[:, None], picked.astype(jnp.float32), 0.0)
        y = y.at[tok_idx].add(picked * top_p.reshape(-1)[:, None])
    return y.astype(cfg.dtype)


class MoELM(DenseLM):
    """DenseLM attention + EP MoE FFN."""

    def __init__(self, cfg: ModelConfig, mesh: Mesh | None = None, *, cf: float = 2.0):
        super().__init__(cfg, mesh)
        self.cf = cf
        self.tp = mesh.shape[MODEL] if (mesh is not None and MODEL in mesh.axis_names) else 1

    # -- params --------------------------------------------------------------
    def init_params(self, seed: int = 0) -> Any:
        params = super().init_params(seed)
        cfg = self.cfg
        ini = cm.Initializer(seed + 1, cfg.dtype)
        nb, D, F, E = self.n_blocks, cfg.d_model, cfg.d_ff, cfg.n_experts
        e_loc, split, _ = expert_layout(cfg, self.tp)
        fs = F // split
        for i in range(len(self.pattern)):
            lp = params["blocks"][str(i)]
            for key in ("wi", "wg", "wmo"):
                del lp[key]
            lp["router"] = ini(f"b{i}.router", (nb, D, E), scale=1.0 / math.sqrt(D))
            lp["we_g"] = ini(f"b{i}.we_g", (nb, self.tp, e_loc, D, fs))
            lp["we_i"] = ini(f"b{i}.we_i", (nb, self.tp, e_loc, D, fs))
            lp["we_o"] = ini(f"b{i}.we_o", (nb, self.tp, e_loc, fs, D),
                             scale=1.0 / math.sqrt(F))
        return params

    def param_specs(self, mesh: Mesh) -> Any:
        specs = super().param_specs(mesh)
        d_dat = cm.shardable(self.cfg.d_model, DATA, mesh)
        for i in range(len(self.pattern)):
            lp = specs["blocks"][str(i)]
            for key in ("wi", "wg", "wmo"):
                del lp[key]
            lp["router"] = P(None, d_dat, None)
            lp["we_g"] = P(None, MODEL, None, d_dat, None)
            lp["we_i"] = P(None, MODEL, None, d_dat, None)
            lp["we_o"] = P(None, MODEL, None, None, d_dat)
        return specs

    # -- the MoE FFN replaces the dense MLP ----------------------------------
    def _mlp(self, x, lp):
        cfg = self.cfg
        B, S, D = x.shape
        h = cm.rms_norm(x, lp["ln2"])
        tp = self.tp
        # Fast path: with the residual already sequence-sharded over MODEL
        # (Megatron-SP), each column's seq shard IS its token slice — no
        # slice/all-gather bracket around the dispatch.
        seq_sharded = self.mesh is not None and self._seq(S) is not None

        def block(h_loc, wr, wg, wi, wo):
            Bl, Sl, _ = h_loc.shape
            t_loc = Bl * Sl
            flat = h_loc.reshape(t_loc, D)
            if tp > 1 and seq_sharded:
                y = _moe_local(flat, wr, wg[0], wi[0], wo[0], cfg=cfg, tp=tp,
                               axis_name=MODEL, cf=self.cf)
            elif tp > 1:
                col = jax.lax.axis_index(MODEL)
                pad = (-t_loc) % tp          # decode batches can be < tp
                if pad:
                    flat = jnp.pad(flat, ((0, pad), (0, 0)))
                sliced = flat.reshape(-1, tp, D)
                x_my = jax.lax.dynamic_slice_in_dim(sliced, col, 1, axis=1)[:, 0]
                y_my = _moe_local(x_my, wr, wg[0], wi[0], wo[0], cfg=cfg, tp=tp,
                                  axis_name=MODEL, cf=self.cf)
                g = jax.lax.all_gather(y_my, MODEL, axis=0)           # (tp, T_sub, D)
                y = g.transpose(1, 0, 2).reshape(-1, D)[:t_loc]
            else:
                y = _moe_local(flat, wr, wg[0], wi[0], wo[0], cfg=cfg, tp=1,
                               axis_name=None, cf=self.cf)
            return y.reshape(Bl, Sl, D)

        if self.mesh is not None and self.mesh.size > 1:
            b_axes = self._batch()
            manual = {a for a in (POD, DATA, MODEL) if a in self.mesh.axis_names}
            if self.pod_manual:
                manual.discard(POD)   # already manual in the enclosing region
            seq_ax = MODEL if seq_sharded else None
            y = shard_map(
                block, mesh=self.mesh,
                in_specs=(P(b_axes, seq_ax, None), P(None, None),
                          P(MODEL, None, None, None), P(MODEL, None, None, None),
                          P(MODEL, None, None, None)),
                out_specs=P(b_axes, seq_ax, None),
                axis_names=manual, check_vma=False,
            )(h, lp["router"], lp["we_g"], lp["we_i"], lp["we_o"])
        else:
            y = block(h, lp["router"], lp["we_g"], lp["we_i"], lp["we_o"])
        return self._res(x + y)
