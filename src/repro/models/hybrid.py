"""RecurrentGemma (Griffin): RG-LRU recurrent blocks + local MQA, 2:1 pattern.

Layer layout (26 layers): repeating (recurrent, recurrent, local-attention)
blocks — 8 full blocks — plus a 2-layer recurrent tail. The main stack scans
over the 8 blocks; the tail is a second scan over its own stacked params.

RG-LRU recurrence (trained with an associative scan — parallel over sequence):
    r_t = sigmoid(x_t W_a + b_a)          (recurrence gate)
    i_t = sigmoid(x_t W_x + b_x)          (input gate)
    log a_t = -c * softplus(Lambda) * r_t (c = 8)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Decode carries (recurrent state, conv window, local-attn KV ring) — constant
memory in sequence length, which is why this arch runs the long_500k cell.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.models import common as cm
from repro.models.common import ModelConfig
from repro.models.ssm import _causal_conv
from repro.distributed.mesh import MODEL

_C = 8.0  # RG-LRU gate sharpness constant


def rg_lru(x, gates_a, gates_x, lam, h0=None):
    """x: (b,l,w). gates: pre-activations (b,l,w). lam: (w,). Returns (y, h_last)."""
    r = jax.nn.sigmoid(gates_a.astype(jnp.float32))
    i = jax.nn.sigmoid(gates_x.astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(lam.astype(jnp.float32))[None, None, :] * r
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * i * x.astype(jnp.float32)
    if h0 is not None:
        # fold the carried state into the first step: h_1 = a_1 h0 + b_1
        b = b.at[:, 0].add(a[:, 0] * h0.astype(jnp.float32))

    def combine(left, right):
        al, bl = left
        ar, br = right
        return al * ar, ar * bl + br

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h.astype(x.dtype), h[:, -1]


def rg_lru_step(h, x_t, ga_t, gx_t, lam):
    """One decode step. h: (b,w); x_t/gates: (b,w)."""
    r = jax.nn.sigmoid(ga_t.astype(jnp.float32))
    i = jax.nn.sigmoid(gx_t.astype(jnp.float32))
    a = jnp.exp(-_C * jax.nn.softplus(lam.astype(jnp.float32))[None, :] * r)
    h = a * h.astype(jnp.float32) + jnp.sqrt(jnp.maximum(1 - a * a, 1e-12)) * i * x_t.astype(jnp.float32)
    return h, h


class RecurrentGemmaLM(cm.ShardingMixin):
    PATTERN = ("r", "r", "a")
    SEQ_SHARD = False   # RG-LRU associative scan runs over the seq dim

    def __init__(self, cfg: ModelConfig, mesh: Mesh | None = None):
        self.cfg = cfg
        self.mesh = mesh
        self.w = cfg.lru_width or cfg.d_model
        kinds = []
        while len(kinds) < cfg.n_layers:
            kinds.extend(self.PATTERN)
        self.kinds = tuple(kinds[: cfg.n_layers])
        self.n_blocks = cfg.n_layers // len(self.PATTERN)
        self.n_tail = cfg.n_layers - self.n_blocks * len(self.PATTERN)
        assert all(k == "r" for k in self.kinds[self.n_blocks * 3:]), self.kinds

    # -- params ----------------------------------------------------------------
    def _rec_params(self, ini, n, tag):
        cfg, D, w = self.cfg, self.cfg.d_model, self.w
        return {
            "ln": ini.zeros((n, D)),
            "wx": ini(f"{tag}.wx", (n, D, w)),
            "wy": ini(f"{tag}.wy", (n, D, w)),
            "conv_w": ini(f"{tag}.conv", (n, w, cfg.conv1d_size), scale=0.5),
            "wa": ini(f"{tag}.wa", (n, w, w), scale=1.0 / math.sqrt(w)),
            "ba": ini.zeros((n, w)),
            "wxg": ini(f"{tag}.wxg", (n, w, w), scale=1.0 / math.sqrt(w)),
            "bxg": ini.zeros((n, w)),
            "lam": ini.ones((n, w)),
            "wo": ini(f"{tag}.wo", (n, w, D), scale=1.0 / math.sqrt(w)),
            "ln2": ini.zeros((n, D)),
            "mi": ini(f"{tag}.mi", (n, D, cfg.d_ff)),
            "mg": ini(f"{tag}.mg", (n, D, cfg.d_ff)),
            "mo": ini(f"{tag}.mo", (n, cfg.d_ff, D), scale=1.0 / math.sqrt(cfg.d_ff)),
        }

    def _attn_params(self, ini, n, tag):
        cfg, D = self.cfg, self.cfg.d_model
        H, KVH, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
        return {
            "ln": ini.zeros((n, D)),
            "wq": ini(f"{tag}.wq", (n, D, H, hd)),
            "wk": ini(f"{tag}.wk", (n, D, KVH, hd)),
            "wv": ini(f"{tag}.wv", (n, D, KVH, hd)),
            "wo": ini(f"{tag}.wo", (n, H, hd, D), scale=1.0 / math.sqrt(H * hd)),
            "ln2": ini.zeros((n, D)),
            "mi": ini(f"{tag}.mi", (n, D, cfg.d_ff)),
            "mg": ini(f"{tag}.mg", (n, D, cfg.d_ff)),
            "mo": ini(f"{tag}.mo", (n, cfg.d_ff, D), scale=1.0 / math.sqrt(cfg.d_ff)),
        }

    def init_params(self, seed: int = 0) -> Any:
        cfg = self.cfg
        ini = cm.Initializer(seed, cfg.dtype)
        params = {
            "embed": ini("embed", (cfg.vocab, cfg.d_model), scale=1.0),
            "final_norm": ini.zeros((cfg.d_model,)),
            "rec0": self._rec_params(ini, self.n_blocks, "rec0"),
            "rec1": self._rec_params(ini, self.n_blocks, "rec1"),
            "attn": self._attn_params(ini, self.n_blocks, "attn"),
        }
        if self.n_tail:
            params["tail"] = self._rec_params(ini, self.n_tail, "tail")
        return params

    def _rec_specs(self, mesh):
        cfg = self.cfg
        d_dat = cm.shardable(cfg.d_model, "data", mesh)
        w_m = cm.shardable(self.w, MODEL, mesh)
        f_m = cm.shardable(cfg.d_ff, MODEL, mesh)
        return {
            "ln": P(None, None), "ln2": P(None, None),
            "wx": P(None, d_dat, w_m), "wy": P(None, d_dat, w_m),
            "conv_w": P(None, w_m, None),
            "wa": P(None, None, w_m), "ba": P(None, w_m),
            "wxg": P(None, None, w_m), "bxg": P(None, w_m),
            "lam": P(None, w_m),
            "wo": P(None, w_m, d_dat),
            "mi": P(None, d_dat, f_m), "mg": P(None, d_dat, f_m),
            "mo": P(None, f_m, d_dat),
        }

    def param_specs(self, mesh: Mesh) -> Any:
        cfg = self.cfg
        d_dat = cm.shardable(cfg.d_model, "data", mesh)
        attn = {
            "ln": P(None, None), "ln2": P(None, None),
            "wq": P(None, d_dat, cm.shardable(cfg.n_heads, MODEL, mesh), None),
            "wk": P(None, d_dat, cm.shardable(cfg.n_kv_heads, MODEL, mesh), None),
            "wv": P(None, d_dat, cm.shardable(cfg.n_kv_heads, MODEL, mesh), None),
            "wo": P(None, cm.shardable(cfg.n_heads, MODEL, mesh), None, d_dat),
            "mi": P(None, d_dat, cm.shardable(cfg.d_ff, MODEL, mesh)),
            "mg": P(None, d_dat, cm.shardable(cfg.d_ff, MODEL, mesh)),
            "mo": P(None, cm.shardable(cfg.d_ff, MODEL, mesh), d_dat),
        }
        specs = {
            "embed": P(cm.shardable(cfg.vocab, MODEL, mesh), d_dat),
            "final_norm": P(None),
            "rec0": self._rec_specs(mesh),
            "rec1": self._rec_specs(mesh),
            "attn": attn,
        }
        if self.n_tail:
            specs["tail"] = self._rec_specs(mesh)
        return specs

    # -- sub-layer applications ---------------------------------------------
    def _mlp(self, x, lp):
        h = cm.rms_norm(x, lp["ln2"])
        g = cm.act_fn("gelu")(jnp.einsum("bld,df->blf", h, lp["mg"]))
        u = jnp.einsum("bld,df->blf", h, lp["mi"])
        return x + jnp.einsum("blf,fd->bld", g * u, lp["mo"])

    def _rec_layer(self, x, lp, conv_cache=None, h0=None):
        """Returns (x_out, new_conv_cache, h_last)."""
        h = cm.rms_norm(x, lp["ln"])
        xb = jnp.einsum("bld,dw->blw", h, lp["wx"])
        yb = cm.act_fn("gelu")(jnp.einsum("bld,dw->blw", h, lp["wy"]))
        xb, new_conv = _causal_conv(xb, lp["conv_w"], cache=conv_cache)
        ga = jnp.einsum("blw,wu->blu", xb, lp["wa"]) + lp["ba"]
        gx = jnp.einsum("blw,wu->blu", xb, lp["wxg"]) + lp["bxg"]
        hseq, h_last = rg_lru(xb, ga, gx, lp["lam"], h0=h0)
        out = jnp.einsum("blw,wd->bld", hseq.astype(x.dtype) * yb, lp["wo"])
        return self._res(self._mlp(x + out, lp)), new_conv, h_last

    def _attn_layer(self, x, lp, q_pos, kv=None, kv_pos=None):
        cfg = self.cfg
        h = cm.rms_norm(x, lp["ln"])
        q = jnp.einsum("bsd,dnh->bsnh", h, lp["wq"])
        k = jnp.einsum("bsd,dkh->bskh", h, lp["wk"])
        v = jnp.einsum("bsd,dkh->bskh", h, lp["wv"])
        q = cm.rope(q, q_pos, cfg.rope_theta)
        k = cm.rope(k, q_pos, cfg.rope_theta)
        if kv is None:
            kk, vv, kpos = k, v, q_pos
        else:
            kk, vv, kpos = kv
        o = cm.attention(q, kk, vv, causal=True, q_positions=q_pos,
                         kv_positions=kpos, window=cfg.window)
        o = jnp.einsum("bsnh,nhd->bsd", o, lp["wo"])
        return self._res(self._mlp(x + o, lp)), (k, v)

    # -- train ------------------------------------------------------------------
    def hidden(self, params, tokens):
        cfg = self.cfg
        B, S = tokens.shape
        x = self._lookup(params["embed"], tokens).astype(cfg.dtype)
        x = self._res(x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype))
        pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

        def body(carry, blk):
            x = carry
            x, _, _ = self._rec_layer(x, blk["rec0"])
            x, _, _ = self._rec_layer(x, blk["rec1"])
            x, _ = self._attn_layer(x, blk["attn"], pos)
            return x, None

        blocks = {"rec0": params["rec0"], "rec1": params["rec1"], "attn": params["attn"]}
        x, _ = cm.scan(cm.maybe_remat(body, cfg), x, blocks)
        if self.n_tail:
            def tail_body(carry, lp):
                y, _, _ = self._rec_layer(carry, lp)
                return y, None
            x, _ = cm.scan(cm.maybe_remat(tail_body, cfg), x, params["tail"])
        return cm.rms_norm(x, params["final_norm"])

    def logits(self, params, tokens):
        x = self.hidden(params, tokens)
        return jnp.einsum("bld,vd->blv", x, params["embed"].astype(self.cfg.dtype))

    def loss(self, params, batch):
        tokens = batch["tokens"]
        h = self.hidden(params, tokens[:, :-1])
        return cm.chunked_xent(h, self._out_w(params),
                               tokens[:, 1:], final_cap=self.cfg.final_softcap)

    def _out_w(self, params):
        w = params["embed"].T.astype(self.cfg.dtype)
        if self.mesh is not None:
            w = cm.constrain(w, self.mesh,
                             P(None, cm.shardable(self.cfg.vocab, MODEL, self.mesh)))
        return w

    # -- decode -------------------------------------------------------------------
    def init_cache(self, batch: int, max_len: int) -> Any:
        cfg = self.cfg
        nb, w, k = self.n_blocks, self.w, cfg.conv1d_size
        T = min(cfg.window, max_len)
        cache = {
            "h0": jnp.zeros((nb, batch, w), jnp.float32),
            "c0": jnp.zeros((nb, batch, k - 1, w), cfg.dtype),
            "h1": jnp.zeros((nb, batch, w), jnp.float32),
            "c1": jnp.zeros((nb, batch, k - 1, w), cfg.dtype),
            "ak": jnp.zeros((nb, batch, T, cfg.n_kv_heads, cfg.hd), cfg.dtype),
            "av": jnp.zeros((nb, batch, T, cfg.n_kv_heads, cfg.hd), cfg.dtype),
            "ap": jnp.full((nb, batch, T), -1, jnp.int32),
        }
        if self.n_tail:
            cache["ht"] = jnp.zeros((self.n_tail, batch, w), jnp.float32)
            cache["ct"] = jnp.zeros((self.n_tail, batch, k - 1, w), cfg.dtype)
        return cache

    def cache_specs(self, mesh: Mesh, batch: int, max_len: int) -> Any:
        cfg = self.cfg
        import math as _m
        b_axes = cm.batch_axes(mesh)
        bs = b_axes if isinstance(b_axes, tuple) else ((b_axes,) if b_axes else ())
        sizes = {a: mesh.shape[a] for a in mesh.axis_names}
        b = b_axes if batch % max(1, _m.prod(sizes[a] for a in bs)) == 0 else None
        w_m = cm.shardable(self.w, MODEL, mesh)
        T = min(cfg.window, max_len)
        kv = cm.kv_cache_spec(mesh, batch, T, extra=(None, None))
        specs = {
            "h0": P(None, b, w_m), "c0": P(None, b, None, w_m),
            "h1": P(None, b, w_m), "c1": P(None, b, None, w_m),
            "ak": kv, "av": kv, "ap": cm.kv_cache_spec(mesh, batch, T),
        }
        if self.n_tail:
            specs["ht"] = P(None, b, w_m)
            specs["ct"] = P(None, b, None, w_m)
        return specs

    def _rec_step(self, x, lp, h0, conv):
        """x: (B,1,D). Returns (x_out, h_new, conv_new)."""
        h = cm.rms_norm(x, lp["ln"])
        xb = jnp.einsum("bld,dw->blw", h, lp["wx"])
        yb = cm.act_fn("gelu")(jnp.einsum("bld,dw->blw", h, lp["wy"]))
        xb, new_conv = _causal_conv(xb, lp["conv_w"], cache=conv)
        ga = jnp.einsum("blw,wu->blu", xb, lp["wa"]) + lp["ba"]
        gx = jnp.einsum("blw,wu->blu", xb, lp["wxg"]) + lp["bxg"]
        h_new, hs = rg_lru_step(h0, xb[:, 0], ga[:, 0], gx[:, 0], lp["lam"])
        out = jnp.einsum("blw,wd->bld", hs[:, None].astype(x.dtype) * yb, lp["wo"])
        return self._mlp(x + out, lp), h_new, new_conv

    def decode_step(self, params, cache, tokens, pos):
        cfg = self.cfg
        B = tokens.shape[0]
        x = self._lookup(params["embed"], tokens).astype(cfg.dtype)
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
        q_pos = pos[:, None]

        from repro.models.transformer import DenseLM  # cache-write helper

        def body(carry, xs):
            x = carry
            new = {}
            x, new["h0"], new["c0"] = self._rec_step(x, xs["rec0"], xs["h0"], xs["c0"])
            x, new["h1"], new["c1"] = self._rec_step(x, xs["rec1"], xs["h1"], xs["c1"])
            lp = xs["attn"]
            T = xs["ak"].shape[1]
            slot = pos % T
            h = cm.rms_norm(x, lp["ln"])
            q = jnp.einsum("bsd,dnh->bsnh", h, lp["wq"])
            k = jnp.einsum("bsd,dkh->bskh", h, lp["wk"])
            v = jnp.einsum("bsd,dkh->bskh", h, lp["wv"])
            q = cm.rope(q, q_pos, cfg.rope_theta)
            k = cm.rope(k, q_pos, cfg.rope_theta)
            ck, cv, cp = DenseLM._cache_write(xs["ak"], xs["av"], xs["ap"], k, v, pos, slot)
            o = cm.attention(q, ck, cv, causal=True, q_positions=q_pos,
                             kv_positions=cp, window=cfg.window)
            o = jnp.einsum("bsnh,nhd->bsd", o, lp["wo"])
            x = self._mlp(x + o, lp)
            new["ak"], new["av"], new["ap"] = ck, cv, cp
            return x, new

        xs = {"rec0": params["rec0"], "rec1": params["rec1"], "attn": params["attn"],
              "h0": cache["h0"], "c0": cache["c0"], "h1": cache["h1"], "c1": cache["c1"],
              "ak": cache["ak"], "av": cache["av"], "ap": cache["ap"]}
        x, new_cache = cm.scan(body, x, xs)
        if self.n_tail:
            def tail_body(carry, xs):
                x = carry
                x, hn, cn = self._rec_step(x, xs["lp"], xs["h"], xs["c"])
                return x, {"h": hn, "c": cn}
            x, tail_new = cm.scan(
                tail_body, x, {"lp": params["tail"], "h": cache["ht"], "c": cache["ct"]})
            new_cache = dict(new_cache)
            new_cache["ht"], new_cache["ct"] = tail_new["h"], tail_new["c"]
        x = cm.rms_norm(x, params["final_norm"])
        logits = jnp.einsum("bld,vd->blv", x, params["embed"].astype(cfg.dtype))
        return cm.softcap(logits, cfg.final_softcap), new_cache
