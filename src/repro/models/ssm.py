"""Mamba-2 (state-space duality / SSD) language model.

Structural rhyme with the paper (DESIGN.md §6): SSD *is* a chunking
algorithm — the sequence is cut into chunks; intra-chunk work becomes dense
matmuls (MXU-friendly), inter-chunk work reduces to a tiny state recurrence —
the same "cut a long transfer into chunks to fill parallel units" move Globus
makes for files. The chunk length trades MXU utilization (bigger chunks)
against the O(Q^2) intra-chunk term, mirroring Fig. 6's chunk-size sweet spot.

Faithful to the minimal-SSD reference: inputs folded as (x*dt, A*dt, B, C);
depthwise causal conv over (x, B, C); gated RMSNorm before out-projection;
D skip connection. Decode carries (conv window, SSM state) per layer.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.models import common as cm
from repro.models.common import ModelConfig
from repro.distributed.mesh import MODEL


def _segsum(a: jax.Array) -> jax.Array:
    """log-decay matrix: out[..., i, j] = sum_{k=j+1..i} a[..., k], -inf for j>i."""
    Q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]          # (..., i, j) = cs_i - cs_j
    mask = jnp.tril(jnp.ones((Q, Q), bool), 0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x, a, B, C, chunk: int, h0=None):
    """SSD dual form. x:(b,l,h,p)  a:(b,l,h) log-decay  B,C:(b,l,n).

    Returns (y (b,l,h,p), final_state (b,h,p,n)). Single B/C group
    (mamba2 ngroups=1) broadcast over heads.
    """
    b, l, h, p = x.shape
    n = B.shape[-1]
    pad = (-l) % chunk
    if pad:  # causal: zero-pad the tail, outputs for real positions unchanged
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
        out, last = ssd_chunked(x, a, B, C, chunk, h0)
        return out[:, :l], last
    c = l // chunk
    xq = x.reshape(b, c, chunk, h, p)
    aq = a.reshape(b, c, chunk, h)
    Bq = B.reshape(b, c, chunk, n)
    Cq = C.reshape(b, c, chunk, n)

    acs = jnp.cumsum(aq.astype(jnp.float32), axis=2)     # (b,c,q,h) f32 decays
    # 1) intra-chunk (dense, MXU): Y_diag[q] = sum_{s<=q} C_q.B_s L[q,s] x_s
    L = jnp.exp(_segsum(aq.astype(jnp.float32).transpose(0, 1, 3, 2)))
    G = jnp.einsum("bcqn,bcsn->bcqs", Cq, Bq)            # (b,c,q,s)
    M = (G[:, :, None] * L.astype(G.dtype))              # (b,c,h,q,s)
    y_diag = jnp.einsum("bchqs,bcshp->bcqhp", M, xq).astype(jnp.float32)

    # 2) per-chunk end states
    decay_tail = jnp.exp(acs[:, :, -1:, :] - acs)        # (b,c,q,h)
    states = jnp.einsum("bcqn,bcqh,bcqhp->bchpn",
                        Bq.astype(jnp.float32), decay_tail, xq.astype(jnp.float32))

    # 3) inter-chunk recurrence (tiny scan over chunk states)
    chunk_decay = jnp.exp(acs[:, :, -1, :])              # (b,c,h)
    if h0 is None:
        h0 = jnp.zeros((b, h, p, n), states.dtype)

    def step(carry, inp):
        st, dec = inp                                    # (b,h,p,n), (b,h)
        new = st + dec[..., None, None] * carry
        return new, carry                                # emit state BEFORE chunk

    last, state_in = cm.scan(
        step, h0, (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    state_in = state_in.transpose(1, 0, 2, 3, 4)         # (b,c,h,p,n)

    # 4) inter-chunk contribution
    y_off = jnp.einsum("bcqn,bchpn,bcqh->bcqhp",
                       Cq.astype(jnp.float32), state_in, jnp.exp(acs))
    y = (y_diag + y_off).reshape(b, l, h, p)
    return y, last


def ssd_step(state, x_t, a_t, B_t, C_t):
    """One decode step. state:(b,h,p,n) x_t:(b,h,p) a_t:(b,h) B_t,C_t:(b,n)."""
    decay = jnp.exp(a_t)[..., None, None]
    state = decay * state + jnp.einsum("bhp,bn->bhpn", x_t, B_t)
    y = jnp.einsum("bhpn,bn->bhp", state, C_t)
    return state, y


def _causal_conv(x, w, cache=None):
    """Depthwise causal conv. x:(b,l,d) w:(d,k). cache:(b,k-1,d) prev inputs."""
    k = w.shape[1]
    if cache is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        pad = cache
    xp = jnp.concatenate([pad, x], axis=1)               # (b, l+k-1, d)
    out = sum(xp[:, i : i + x.shape[1]] * w[:, i] for i in range(k))
    new_cache = xp[:, -(k - 1):, :] if k > 1 else pad
    return out, new_cache


class Mamba2LM(cm.ShardingMixin):
    SEQ_SHARD = False   # SSD scans over seq; shard batch + inner dims instead

    def __init__(self, cfg: ModelConfig, mesh: Mesh | None = None):
        self.cfg = cfg
        self.mesh = mesh
        self.d_inner = cfg.d_model * cfg.ssm_expand
        self.nheads = self.d_inner // cfg.ssm_head_dim
        self.n_state = cfg.ssm_state

    # -- params ---------------------------------------------------------------
    def init_params(self, seed: int = 0) -> Any:
        cfg = self.cfg
        ini = cm.Initializer(seed, cfg.dtype)
        L, D, di, nh, ns = cfg.n_layers, cfg.d_model, self.d_inner, self.nheads, self.n_state
        conv_d = di + 2 * ns
        blocks = {
            "ln": ini.zeros((L, D)),
            "w_in": ini("w_in", (L, D, 2 * di + 2 * ns + nh)),
            "conv_w": ini("conv_w", (L, conv_d, cfg.ssm_conv), scale=0.5),
            "A_log": jnp.zeros((L, nh), cfg.dtype) + jnp.log(
                jnp.linspace(1.0, 16.0, nh, dtype=jnp.float32)).astype(cfg.dtype)[None],
            "D": ini.ones((L, nh)),
            "dt_bias": ini.zeros((L, nh)),
            "norm_scale": ini.zeros((L, di)),
            "w_out": ini("w_out", (L, di, D), scale=1.0 / math.sqrt(di)),
        }
        return {
            "embed": ini("embed", (cfg.vocab, D), scale=1.0),
            "final_norm": ini.zeros((D,)),
            "blocks": blocks,
        }

    def param_specs(self, mesh: Mesh) -> Any:
        cfg = self.cfg
        d_dat = cm.shardable(cfg.d_model, "data", mesh)
        di_m = cm.shardable(self.d_inner, MODEL, mesh)
        return {
            "embed": P(cm.shardable(cfg.vocab, MODEL, mesh), d_dat),
            "final_norm": P(None),
            "blocks": {
                "ln": P(None, None),
                "w_in": P(None, d_dat, None),
                "conv_w": P(None, None, None),
                "A_log": P(None, None),
                "D": P(None, None),
                "dt_bias": P(None, None),
                "norm_scale": P(None, di_m),
                "w_out": P(None, di_m, d_dat),
            },
        }

    # -- shared projections ----------------------------------------------------
    def _split_proj(self, h, lp):
        cfg = self.cfg
        di, nh, ns = self.d_inner, self.nheads, self.n_state
        zxbcdt = jnp.einsum("bld,de->ble", h, lp["w_in"])
        z, xin, Bc, Cc, dt = jnp.split(
            zxbcdt, [di, 2 * di, 2 * di + ns, 2 * di + 2 * ns], axis=-1)
        dt = jax.nn.softplus(dt.astype(jnp.float32) + lp["dt_bias"].astype(jnp.float32))
        return z, xin, Bc, Cc, dt

    def _finish(self, y, z, x_res, dt, lp):
        """Gated norm + D-skip + out projection. y:(b,l,h,p)."""
        cfg = self.cfg
        nh, hd = self.nheads, cfg.ssm_head_dim
        b, l = y.shape[0], y.shape[1]
        xh = x_res.reshape(b, l, nh, hd)
        y = y + lp["D"].astype(jnp.float32)[None, None, :, None] * xh.astype(jnp.float32)
        y = y.reshape(b, l, self.d_inner).astype(cfg.dtype)
        y = cm.rms_norm(y * jax.nn.silu(z), lp["norm_scale"])
        return jnp.einsum("ble,ed->bld", y, lp["w_out"])

    # -- train forward -----------------------------------------------------------
    def hidden(self, params, tokens):
        cfg = self.cfg
        B, S = tokens.shape
        x = self._res(self._lookup(params["embed"], tokens).astype(cfg.dtype))
        nh, hd, ns = self.nheads, cfg.ssm_head_dim, self.n_state

        def body(carry, lp):
            x = carry
            h = cm.rms_norm(x, lp["ln"])
            z, xin, Bc, Cc, dt = self._split_proj(h, lp)
            conv_in = jnp.concatenate([xin, Bc, Cc], axis=-1)
            conv_out, _ = _causal_conv(conv_in, lp["conv_w"])
            conv_out = jax.nn.silu(conv_out)
            xc, Bc, Cc = jnp.split(conv_out, [self.d_inner, self.d_inner + ns], axis=-1)
            A = -jnp.exp(lp["A_log"].astype(jnp.float32))           # (nh,)
            a = dt * A[None, None, :]                                # (b,l,nh)
            ssd_dt = jnp.bfloat16 if cfg.ssm_bf16 else jnp.float32
            xh = xc.reshape(B, -1, nh, hd).astype(jnp.float32)
            xdt = (xh * dt[..., None]).astype(ssd_dt)
            y, _ = ssd_chunked(xdt, a, Bc.astype(ssd_dt), Cc.astype(ssd_dt),
                               chunk=min(cfg.ssm_chunk, xh.shape[1]))
            out = self._finish(y, z, xc, dt, lp)
            return self._res(x + out), None

        x, _ = cm.scan(cm.maybe_remat(body, cfg), x, params["blocks"])
        return cm.rms_norm(x, params["final_norm"])

    def logits(self, params, tokens):
        x = self.hidden(params, tokens)
        return jnp.einsum("bld,vd->blv", x, params["embed"].astype(self.cfg.dtype))

    def loss(self, params, batch):
        tokens = batch["tokens"]
        h = self.hidden(params, tokens[:, :-1])
        return cm.chunked_xent(h, self._out_w(params), tokens[:, 1:])

    def _out_w(self, params):
        w = params["embed"].T.astype(self.cfg.dtype)
        if self.mesh is not None:
            w = cm.constrain(w, self.mesh,
                             P(None, cm.shardable(self.cfg.vocab, MODEL, self.mesh)))
        return w

    # -- decode ---------------------------------------------------------------
    def init_cache(self, batch: int, max_len: int) -> Any:
        cfg = self.cfg
        conv_d = self.d_inner + 2 * self.n_state
        return {
            "ssm": jnp.zeros((cfg.n_layers, batch, self.nheads,
                              cfg.ssm_head_dim, self.n_state), jnp.float32),
            "conv": jnp.zeros((cfg.n_layers, batch, cfg.ssm_conv - 1, conv_d), cfg.dtype),
        }

    def cache_specs(self, mesh: Mesh, batch: int, max_len: int) -> Any:
        b_axes = cm.batch_axes(mesh)
        sizes = {a: mesh.shape[a] for a in mesh.axis_names}
        import math as _m
        bs = b_axes if isinstance(b_axes, tuple) else ((b_axes,) if b_axes else ())
        b = b_axes if batch % max(1, _m.prod(sizes[a] for a in bs)) == 0 else None
        nh_m = cm.shardable(self.nheads, MODEL, mesh)
        di_m = cm.shardable(self.d_inner + 2 * self.n_state, MODEL, mesh)
        return {"ssm": P(None, b, nh_m, None, None), "conv": P(None, b, None, di_m)}

    def decode_step(self, params, cache, tokens, pos):
        cfg = self.cfg
        B = tokens.shape[0]
        x = self._lookup(params["embed"], tokens).astype(cfg.dtype)  # (B,1,D)
        nh, hd, ns = self.nheads, cfg.ssm_head_dim, self.n_state

        def body(carry, xs):
            x = carry
            lp, ssm, conv = xs["blk"], xs["ssm"], xs["conv"]
            h = cm.rms_norm(x, lp["ln"])
            z, xin, Bc, Cc, dt = self._split_proj(h, lp)
            conv_in = jnp.concatenate([xin, Bc, Cc], axis=-1)        # (B,1,conv_d)
            conv_out, new_conv = _causal_conv(conv_in, lp["conv_w"], cache=conv)
            conv_out = jax.nn.silu(conv_out)
            xc, Bc, Cc = jnp.split(conv_out, [self.d_inner, self.d_inner + ns], axis=-1)
            A = -jnp.exp(lp["A_log"].astype(jnp.float32))
            a = (dt * A[None, None, :])[:, 0]                        # (B,nh)
            xh = xc.reshape(B, nh, hd).astype(jnp.float32)
            xdt = xh * dt[:, 0, :, None]
            new_ssm, y = ssd_step(ssm, xdt, a, Bc[:, 0].astype(jnp.float32),
                                  Cc[:, 0].astype(jnp.float32))
            out = self._finish(y[:, None], z, xc, dt, lp)
            return x + out, {"ssm": new_ssm, "conv": new_conv}

        xs = {"blk": params["blocks"], **cache}
        x, new_cache = cm.scan(body, x, xs)
        x = cm.rms_norm(x, params["final_norm"])
        logits = jnp.einsum("bld,vd->blv", x, params["embed"].astype(cfg.dtype))
        return logits, new_cache
