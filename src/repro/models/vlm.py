"""InternVL2-style VLM: stubbed ViT frontend + InternLM2 (llama-arch) backbone.

Per the assignment brief the vision tower is a stub: ``input_specs`` provides
already-projected patch embeddings (B, n_vis_tokens, d_model) — InternViT +
the MLP projector's output. They are prepended to the text embeddings as a
causal prefix; the loss is masked to text positions. Decode is inherited
unchanged from DenseLM (the visual prefix simply occupies the first
n_vis_tokens KV-cache slots after prefill).
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import common as cm
from repro.models.transformer import DenseLM


class InternVLM(DenseLM):
    def hidden_mm(self, params, tokens, vis_embed):
        cfg = self.cfg
        B, S = tokens.shape
        Nv = vis_embed.shape[1]
        xt = self._lookup(params["embed"], tokens)
        x = jnp.concatenate([vis_embed.astype(cfg.dtype), xt.astype(cfg.dtype)], axis=1)
        x = self._res(x)
        T = Nv + S
        pos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None], (B, T))

        def body(carry, blk):
            x = carry
            for i, kind in enumerate(self.pattern):
                x, _ = self._attn(x, blk[str(i)], kind, pos, None, None)
                x = self._mlp(x, blk[str(i)])
            return x, None

        x, _ = cm.scan(cm.maybe_remat(body, cfg), x, params["blocks"])
        return cm.rms_norm(x, params["final_norm"])

    def logits_mm(self, params, tokens, vis_embed):
        x = self.hidden_mm(params, tokens, vis_embed)
        return jnp.einsum("bsd,dv->bsv", x, self._out_w(params))

    def loss(self, params, batch):
        tokens = batch["tokens"]
        vis = batch["vis_embed"]
        Nv = vis.shape[1]
        h = self.hidden_mm(params, tokens[:, :-1], vis)
        # text-only loss: positions [Nv-1, Nv+S-2) predict tokens[:, 1:]
        h_text = h[:, Nv - 1 : -1] if Nv > 0 else h
        return cm.chunked_xent(h_text[:, : tokens.shape[1] - 1], self._out_w(params),
                               tokens[:, 1:])
