"""Shared model components: configs, norms, RoPE, GQA attention, MLPs.

Pure-functional JAX (params are nested dicts of arrays). Every assigned
architecture is expressed as a ModelConfig; layers are stacked on a leading
axis and executed with jax.lax.scan (+ remat) so that a 64-layer model
compiles one layer body — essential for dry-run compile times and for HLO
compactness at 512 devices.

Sharding: ``param_specs``-style functions return a PartitionSpec pytree that
mirrors the param pytree. Dims shard on a mesh axis only when divisible;
otherwise they stay replicated (e.g. MQA's single KV head).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.distributed.mesh import DATA, MODEL, POD

Params = Any
DType = Any

# ---------------------------------------------------------------------------
# scan wrapper: XLA's cost analysis counts while-loop bodies ONCE, so the
# dry-run's reduced-layer FLOPs probes trace with every scan fully unrolled
# (see launch/dryrun.py). Production/full-size compiles keep the loops.
# ---------------------------------------------------------------------------
import contextlib

_UNROLL_SCANS = False


@contextlib.contextmanager
def unroll_scans():
    global _UNROLL_SCANS
    prev = _UNROLL_SCANS
    _UNROLL_SCANS = True
    try:
        yield
    finally:
        _UNROLL_SCANS = prev


def scan(body, init, xs, **kw):
    if _UNROLL_SCANS:
        kw["unroll"] = True
    return jax.lax.scan(body, init, xs, **kw)


# ---------------------------------------------------------------------------
# config
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int | None = None     # default d_model // n_heads
    act: str = "silu"               # silu (SwiGLU) | gelu (GeGLU)
    rope_theta: float = 10000.0
    # attention pattern, repeated to cover n_layers: "g"=global, "l"=local
    attn_pattern: str = "g"
    window: int = 4096              # local-attention window
    attn_softcap: float | None = None
    final_softcap: float | None = None
    post_norms: bool = False        # gemma2-style post-attn/post-mlp norms
    tie_embeddings: bool = True
    embed_scale: bool = False       # gemma: scale embeddings by sqrt(d)
    # MoE
    n_experts: int = 0
    top_k: int = 0
    # SSM (mamba2)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv: int = 4
    ssm_chunk: int = 256
    # hybrid (recurrentgemma)
    lru_width: int | None = None
    conv1d_size: int = 4
    # enc-dec (whisper)
    n_enc_layers: int = 0
    enc_positions: int = 1500
    # vlm
    n_vis_tokens: int = 0
    # numerics / memory
    dtype: Any = jnp.bfloat16
    remat: str = "full"             # full | dots | none
    ssm_bf16: bool = False          # SSD intra-chunk matmuls in bf16 (§Perf)
    # applicability notes (long_500k etc.)
    subquadratic: bool = False

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    def layer_kinds(self) -> tuple[str, ...]:
        pat = self.attn_pattern
        reps = -(-self.n_layers // len(pat))
        return tuple((pat * reps)[: self.n_layers])

    def param_count(self) -> int:
        """Total parameters (embedding included once when tied)."""
        d, f, v, hd = self.d_model, self.d_ff, self.vocab, self.hd
        attn = d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd + self.n_heads * hd * d
        if self.family == "ssm":
            d_in = d * self.ssm_expand
            nh = d_in // self.ssm_head_dim
            per = (d * (2 * d_in + 2 * self.ssm_state + nh)   # in_proj (z,x,B,C,dt)
                   + (d_in + 2 * self.ssm_state) * self.ssm_conv
                   + nh * 2                                    # A_log, D
                   + d_in * d + 2 * d)                         # out_proj + norms
            body = self.n_layers * per
        elif self.family == "moe":
            mlp = self.n_experts * 3 * d * f + d * self.n_experts
            body = self.n_layers * (attn + mlp + 2 * d)
        elif self.family == "hybrid":
            kinds = self.layer_kinds()
            n_rec = sum(1 for k in kinds if k == "r")
            n_att = self.n_layers - n_rec
            w = self.lru_width or d
            rec = d * w * 2 + w * self.conv1d_size + w * 4 + w * d  # in/out + conv + gates
            mlp = 3 * d * f
            body = n_rec * (rec + mlp + 2 * d) + n_att * (attn + mlp + 2 * d)
        elif self.family == "encdec":
            mlp = 2 * d * f  # whisper uses plain GELU MLP (no gating)
            enc = self.n_enc_layers * (attn + mlp + 2 * d)
            dec = self.n_layers * (2 * attn + mlp + 3 * d)
            body = enc + dec + self.enc_positions * d
        else:
            mlp = 3 * d * f
            body = self.n_layers * (attn + mlp + 2 * d)
        embed = v * d * (1 if self.tie_embeddings else 2)
        return body + embed + d

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k of n_experts)."""
        if self.family != "moe":
            return self.param_count()
        d, f = self.d_model, self.d_ff
        dense = self.param_count() - self.n_layers * self.n_experts * 3 * d * f
        return dense + self.n_layers * self.top_k * 3 * d * f


# ---------------------------------------------------------------------------
# sharding mixin: every model family uses these helpers so activations are
# consistently batch+seq (Megatron-SP) constrained. Families whose sequence
# math cannot shard (scans over seq) set SEQ_SHARD = False.
# ---------------------------------------------------------------------------
class ShardingMixin:
    mesh: Mesh | None = None
    pod_manual: bool = False
    SEQ_SHARD: bool = True

    def _constrain(self, x, spec):
        if self.mesh is None:
            return x
        return constrain(x, self.mesh, spec)

    def _batch(self):
        if self.mesh is None:
            return None
        return batch_axes(self.mesh, exclude_pod=self.pod_manual)

    def _seq(self, s: int):
        if self.mesh is None or not self.SEQ_SHARD:
            return None
        return shardable(s, MODEL, self.mesh)

    def _res(self, x):
        """Constrain a (B, S, D) residual to batch(+seq) sharding."""
        return self._constrain(x, P(self._batch(), self._seq(x.shape[1]), None))

    def _lookup(self, table, tokens):
        """Embedding gather. Inside a pod-manual region XLA's partitioner
        cannot gather from a 2D-sharded table (upstream CHECK failure, see
        DESIGN.md §5) — constrain to vocab-only sharding first."""
        if self.mesh is not None and self.pod_manual:
            table = self._constrain(
                table, P(shardable(table.shape[0], MODEL, self.mesh), None))
        return jnp.take(table, tokens, axis=0)


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------
def trunc_normal(key, shape, scale, dtype) -> jax.Array:
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * scale).astype(dtype)


class Initializer:
    """Deterministic per-leaf init from a path-derived key (cheap, reproducible)."""

    def __init__(self, seed: int, dtype):
        self.root = jax.random.PRNGKey(seed)
        self.dtype = dtype

    def __call__(self, path: str, shape: Sequence[int], scale: float | None = None):
        key = jax.random.fold_in(self.root, hash(path) % (2**31))
        if scale is None:
            scale = 1.0 / math.sqrt(shape[-2] if len(shape) >= 2 else shape[-1])
        return trunc_normal(key, tuple(shape), scale, self.dtype)

    def zeros(self, shape):
        return jnp.zeros(tuple(shape), self.dtype)

    def ones(self, shape):
        return jnp.ones(tuple(shape), self.dtype)


# ---------------------------------------------------------------------------
# building blocks
# ---------------------------------------------------------------------------
def rms_norm(x: jax.Array, scale: jax.Array, *, eps: float = 1e-6,
             unit_offset: bool = True) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    w = (1.0 + scale.astype(jnp.float32)) if unit_offset else scale.astype(jnp.float32)
    return (x * w).astype(dt)


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding. x: (..., seq, heads, head_dim); positions: (..., seq)."""
    hd = x.shape[-1]
    half = hd // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., :, None].astype(jnp.float32) * freq  # (..., seq, half)
    sin, cos = jnp.sin(ang)[..., None, :], jnp.cos(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    ).astype(x.dtype)


def softcap(logits: jax.Array, cap: float | None) -> jax.Array:
    if cap is None:
        return logits
    return jnp.tanh(logits / cap) * cap


ATTN_BLOCK_KV = 512   # KV chunk for the online-softmax (flash-style) path
ATTN_DENSE_MAX = 1024  # use the dense path when S_q <= this (decode, smoke)


def _attn_mask(q_pos, kv_pos, causal, window):
    """(B, Sq, Skv) bool mask from absolute positions (-1 kv = invalid slot)."""
    mask = kv_pos[:, None, :] >= 0
    if causal:
        mask = mask & (q_pos[:, :, None] >= kv_pos[:, None, :])
    if window is not None:
        mask = mask & (q_pos[:, :, None] - kv_pos[:, None, :] < window)
    return mask


def attention(
    q: jax.Array,          # (B, S, H, hd)
    k: jax.Array,          # (B, T, KVH, hd)
    v: jax.Array,          # (B, T, KVH, hd)
    *,
    causal: bool,
    q_positions: jax.Array,     # (B, S) absolute positions of queries
    kv_positions: jax.Array,    # (B, T) absolute positions of keys (-1 = invalid)
    window: int | None = None,  # local attention window (None = global)
    logit_cap: float | None = None,
    block_kv: int = ATTN_BLOCK_KV,
) -> jax.Array:
    """GQA attention with sliding-window and soft-cap support.

    Long sequences use an online-softmax scan over KV chunks (flash-style in
    pure JAX): peak logits memory drops from O(S*T) to O(S*block_kv) — without
    this the S^2 f32 logits of a 4k-train cell alone exceed a v5e's HBM.
    Short-q (decode) and smoke shapes take the dense path.
    """
    B, S, H, hd = q.shape
    KVH = k.shape[2]
    assert H % KVH == 0
    G = H // KVH
    qf = q.astype(jnp.float32).reshape(B, S, KVH, G, hd)
    T = k.shape[1]

    if S <= ATTN_DENSE_MAX or T <= block_kv:
        kf, vf = k.astype(jnp.float32), v.astype(jnp.float32)
        logits = jnp.einsum("bskgh,btkh->bkgst", qf, kf) / math.sqrt(hd)
        logits = softcap(logits, logit_cap)
        mask = _attn_mask(q_positions, kv_positions, causal, window)
        logits = jnp.where(mask[:, None, None, :, :], logits, -1e30)
        probs = jax.nn.softmax(logits, axis=-1)
        out = jnp.einsum("bkgst,btkh->bskgh", probs, vf)
        return out.reshape(B, S, H, hd).astype(q.dtype)

    # ---- blocked online-softmax path
    pad = (-T) % block_kv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_positions = jnp.pad(kv_positions, ((0, 0), (0, pad)), constant_values=-1)
    nblk = k.shape[1] // block_kv
    kb = k.astype(jnp.float32).reshape(B, nblk, block_kv, KVH, hd).transpose(1, 0, 2, 3, 4)
    vb = v.astype(jnp.float32).reshape(B, nblk, block_kv, KVH, hd).transpose(1, 0, 2, 3, 4)
    pb = kv_positions.reshape(B, nblk, block_kv).transpose(1, 0, 2)

    def step(carry, blk):
        m, l, acc = carry                     # (B,KVH,G,S), (B,KVH,G,S), (..., hd)
        kc, vc, pc = blk
        logits = jnp.einsum("bskgh,btkh->bkgst", qf, kc) / math.sqrt(hd)
        logits = softcap(logits, logit_cap)
        mask = _attn_mask(q_positions, pc, causal, window)
        logits = jnp.where(mask[:, None, None, :, :], logits, -1e30)
        m_new = jnp.maximum(m, jnp.max(logits, axis=-1))
        p = jnp.exp(logits - m_new[..., None])
        scale = jnp.exp(m - m_new)
        l_new = l * scale + jnp.sum(p, axis=-1)
        acc_new = acc * scale[..., None] + jnp.einsum("bkgst,btkh->bkgsh", p, vc)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, KVH, G, S), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, KVH, G, S), jnp.float32)
    a0 = jnp.zeros((B, KVH, G, S, hd), jnp.float32)
    (m, l, acc), _ = scan(step, (m0, l0, a0), (kb, vb, pb))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = out.transpose(0, 3, 1, 2, 4).reshape(B, S, H, hd)
    return out.astype(q.dtype)


def act_fn(name: str) -> Callable[[jax.Array], jax.Array]:
    if name == "silu":
        return jax.nn.silu
    if name == "gelu":
        return lambda x: jax.nn.gelu(x, approximate=True)
    raise ValueError(name)


def gated_mlp(x: jax.Array, wi: jax.Array, wg: jax.Array, wo: jax.Array, act: str) -> jax.Array:
    h = act_fn(act)(x @ wg) * (x @ wi)
    return h @ wo


def cross_entropy(logits: jax.Array, labels: jax.Array, *, mask: jax.Array | None = None,
                  final_cap: float | None = None) -> jax.Array:
    logits = softcap(logits.astype(jnp.float32), final_cap)
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    if mask is None:
        return -jnp.mean(ll)
    return -jnp.sum(ll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def chunked_xent(
    h: jax.Array,            # (B, S, D) final hidden states
    w: jax.Array,            # (D, V) unembedding
    labels: jax.Array,       # (B, S)
    *,
    final_cap: float | None = None,
    mask: jax.Array | None = None,
    seq_chunk: int = 512,
) -> jax.Array:
    """Cross-entropy without materializing (B, S, V) f32 logits.

    The unembed matmul + log-softmax run per seq-chunk under remat: peak
    logits memory falls from O(S*V) to O(seq_chunk*V), which at 256k vocabs
    is the difference between fitting a v5e or not.
    """
    B, S, D = h.shape
    if S <= seq_chunk:
        logits = jnp.einsum("bsd,dv->bsv", h, w)
        return cross_entropy(logits, labels, mask=mask, final_cap=final_cap)
    if mask is None:
        mask = jnp.ones((B, S), jnp.float32)
    pad = (-S) % seq_chunk
    if pad:
        h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
    n = h.shape[1] // seq_chunk
    hc = h.reshape(B, n, seq_chunk, D).transpose(1, 0, 2, 3)
    lc = labels.reshape(B, n, seq_chunk).transpose(1, 0, 2)
    mc = mask.reshape(B, n, seq_chunk).transpose(1, 0, 2).astype(jnp.float32)

    @jax.checkpoint
    def body(carry, inp):
        hh, ll, mm = inp
        logits = softcap(jnp.einsum("bsd,dv->bsv", hh, w).astype(jnp.float32), final_cap)
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, ll[..., None], axis=-1)[..., 0]
        return carry + jnp.sum(nll * mm), None

    total, _ = scan(body, jnp.float32(0.0), (hc, lc, mc))
    return total / jnp.maximum(jnp.sum(mc), 1.0)


# ---------------------------------------------------------------------------
# sharding helpers
# ---------------------------------------------------------------------------
def shardable(size: int, axis: str, mesh: Mesh) -> str | None:
    """Use `axis` only when the dim divides evenly on this mesh."""
    if axis in mesh.axis_names and size % mesh.shape[axis] == 0:
        return axis
    return None


def batch_axes(mesh: Mesh, exclude_pod: bool = False):
    """Mesh axes carrying the batch dim; pod excluded inside manual-pod regions."""
    cand = (DATA,) if exclude_pod else (POD, DATA)
    axes = tuple(a for a in cand if a in mesh.axis_names)
    if not axes:
        return None
    return axes if len(axes) > 1 else axes[0]


def constrain(x: jax.Array, mesh: Mesh, spec: P) -> jax.Array:
    """Sharding constraint resolved against the ambient mesh when one is set.

    Inside a manual-pod shard_map the ambient (abstract) mesh carries Manual
    axis types — a NamedSharding built from the original all-Auto mesh would
    be rejected there, so prefer the bare-PartitionSpec form.
    """
    try:
        cur = jax.sharding.get_abstract_mesh()
        has_ctx = cur is not None and not cur.empty
    except Exception:  # noqa: BLE001 — conservative fallback
        has_ctx = False
    if has_ctx:
        return jax.lax.with_sharding_constraint(x, spec)
    return jax.lax.with_sharding_constraint(x, jax.sharding.NamedSharding(mesh, spec))


def kv_cache_spec(mesh: Mesh, batch: int, time: int, extra: tuple = ()) -> P:
    """Sharding for a (layers, B, T, ...) decode cache.

    Batch shards over (pod, data) when divisible; the TIME dim soaks up every
    remaining mesh axis it divides by — long-context decode (B=1, T=524288)
    ends up fully context-sharded, which is what makes the long_500k cells
    fit (DESIGN.md §5 SP/CP).
    """
    b_axes = tuple(a for a in (POD, DATA) if a in mesh.axis_names)
    import math as _m
    if batch % max(1, _m.prod(mesh.shape[a] for a in b_axes)) != 0:
        b_axes = ()
    b = b_axes if len(b_axes) > 1 else (b_axes[0] if b_axes else None)
    t_axes = []
    rem = time
    for a in (MODEL, DATA, POD):
        if a in mesh.axis_names and a not in b_axes and rem % mesh.shape[a] == 0:
            t_axes.append(a)
            rem //= mesh.shape[a]
    t = tuple(t_axes) if len(t_axes) > 1 else (t_axes[0] if t_axes else None)
    return P(None, b, t, *extra)


def remat_policy(name: str):
    if name == "none":
        return None
    if name == "dots":
        return jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    return jax.checkpoint_policies.nothing_saveable


def maybe_remat(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    return jax.checkpoint(fn, policy=remat_policy(cfg.remat))
