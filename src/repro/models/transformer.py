"""Dense decoder-only transformer LM (gemma, gemma2, yi, mistral-nemo, ...).

Layers are grouped into blocks of ``len(attn_pattern)`` (gemma2's "lg" ->
13 blocks of local+global) and executed with jax.lax.scan over stacked block
params; the scan body is remat'ed. Decode keeps per-kind KV caches: local
layers get a ring buffer of ``window`` slots, global layers a full-length
cache — each slot also records its absolute position, so masking (validity,
causality, window) is uniform for both.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.models import common as cm
from repro.models.common import ModelConfig
from repro.distributed.mesh import MODEL


class DenseLM(cm.ShardingMixin):
    def __init__(self, cfg: ModelConfig, mesh: Mesh | None = None):
        self.cfg = cfg
        self.mesh = mesh
        self.pod_manual = False   # set by launch.steps for chunked-pod training
        pat = cfg.attn_pattern
        assert cfg.n_layers % len(pat) == 0, (cfg.name, cfg.n_layers, pat)
        self.n_blocks = cfg.n_layers // len(pat)
        self.pattern = pat

    # -- params ------------------------------------------------------------
    def init_params(self, seed: int = 0) -> Any:
        cfg = self.cfg
        ini = cm.Initializer(seed, cfg.dtype)
        nb, D, H, KVH, hd, F = (self.n_blocks, cfg.d_model, cfg.n_heads,
                                cfg.n_kv_heads, cfg.hd, cfg.d_ff)
        blocks: dict[str, Any] = {}
        for i in range(len(self.pattern)):
            lp = {
                "ln1": ini.zeros((nb, D)),
                "ln2": ini.zeros((nb, D)),
                "wq": ini(f"b{i}.wq", (nb, D, H, hd)),
                "wk": ini(f"b{i}.wk", (nb, D, KVH, hd)),
                "wv": ini(f"b{i}.wv", (nb, D, KVH, hd)),
                "wo": ini(f"b{i}.wo", (nb, H, hd, D), scale=1 / math.sqrt(H * hd)),
                "wi": ini(f"b{i}.wi", (nb, D, F)),
                "wg": ini(f"b{i}.wg", (nb, D, F)),
                "wmo": ini(f"b{i}.wmo", (nb, F, D), scale=1 / math.sqrt(F)),
            }
            if cfg.post_norms:
                lp["post_ln1"] = ini.zeros((nb, D))
                lp["post_ln2"] = ini.zeros((nb, D))
            blocks[str(i)] = lp
        params = {
            "embed": ini("embed", (cfg.vocab, D), scale=1.0),
            "final_norm": ini.zeros((D,)),
            "blocks": blocks,
        }
        if not cfg.tie_embeddings:
            params["unembed"] = ini("unembed", (D, cfg.vocab))
        return params

    def param_specs(self, mesh: Mesh, *, serve: bool = False) -> Any:
        cfg = self.cfg
        sh = lambda n, ax: cm.shardable(n, ax, mesh)  # noqa: E731
        if serve:
            return self._serve_param_specs(mesh)
        m_head = sh(cfg.n_heads, MODEL)
        m_kv = sh(cfg.n_kv_heads, MODEL)
        m_ff = sh(cfg.d_ff, MODEL)
        m_voc = sh(cfg.vocab, MODEL)
        d_dat = cm.shardable(cfg.d_model, "data", mesh)
        lp = {
            "ln1": P(None, None),
            "ln2": P(None, None),
            "wq": P(None, d_dat, m_head, None),
            "wk": P(None, d_dat, m_kv, None),
            "wv": P(None, d_dat, m_kv, None),
            "wo": P(None, m_head, None, d_dat),
            "wi": P(None, d_dat, m_ff),
            "wg": P(None, d_dat, m_ff),
            "wmo": P(None, m_ff, d_dat),
        }
        if cfg.post_norms:
            lp["post_ln1"] = P(None, None)
            lp["post_ln2"] = P(None, None)
        specs = {
            "embed": P(m_voc, d_dat),
            "final_norm": P(None),
            "blocks": {str(i): dict(lp) for i in range(len(self.pattern))},
        }
        if not cfg.tie_embeddings:
            specs["unembed"] = P(d_dat, m_voc)
        return specs

    def _serve_param_specs(self, mesh: Mesh) -> Any:
        """Weight-stationary decode sharding (§Perf hillclimb, yi-34b cell).

        Training uses ZeRO-3: every step re-gathers FSDP-sharded weights —
        fine when amortized over 1M-token batches, ruinous for one-token
        decode steps (yi-34b: ~4 GB gathered per step). For serving, weights
        shard only along *non-contracted* dims (head_dim / ffn / vocab) on
        MODEL: matmuls then need no weight gathers at all; the partial-sum
        all-reduces they emit are activation-sized (KBs at S=1).
        """
        cfg = self.cfg
        hd_m = cm.shardable(cfg.hd, MODEL, mesh)
        m_ff = cm.shardable(cfg.d_ff, MODEL, mesh)
        m_voc = cm.shardable(cfg.vocab, MODEL, mesh)
        lp = {
            "ln1": P(None, None), "ln2": P(None, None),
            "wq": P(None, None, None, hd_m),
            "wk": P(None, None, None, hd_m),
            "wv": P(None, None, None, hd_m),
            "wo": P(None, None, hd_m, None),
            "wi": P(None, None, m_ff),
            "wg": P(None, None, m_ff),
            "wmo": P(None, m_ff, None),
        }
        if cfg.post_norms:
            lp["post_ln1"] = P(None, None)
            lp["post_ln2"] = P(None, None)
        specs = {
            "embed": P(m_voc, None),
            "final_norm": P(None),
            "blocks": {str(i): dict(lp) for i in range(len(self.pattern))},
        }
        if not cfg.tie_embeddings:
            specs["unembed"] = P(None, m_voc)
        return specs

    # -- shared layer application -------------------------------------------
    def _attn(self, x, lp, kind, q_pos, kv, kv_pos):
        """One attention sub-layer. kv: (k, v) override for decode (cached)."""
        cfg = self.cfg
        b = self._batch()
        h = cm.rms_norm(x, lp["ln1"])
        q = jnp.einsum("bsd,dnh->bsnh", h, lp["wq"])
        k_new = jnp.einsum("bsd,dkh->bskh", h, lp["wk"])
        v_new = jnp.einsum("bsd,dkh->bskh", h, lp["wv"])
        q = cm.rope(q, q_pos, cfg.rope_theta)
        k_new = cm.rope(k_new, q_pos, cfg.rope_theta)
        # Context-parallel attention: q seq-sharded over MODEL, K/V full-seq.
        # Head sharding would have to survive the (H) -> (KVH, G) GQA reshape,
        # which requires tp | KVH — never true for the assigned archs on a
        # 16-wide model axis; GSPMD then replicates full-seq q/logits, which
        # the dry-run showed costs 30x in gathered bytes. CP splits attention
        # FLOPs and logits across the axis for every head count. (Head
        # sharding of the *projections* is unchanged — it lives in the weight
        # specs.)
        if self.mesh is not None:
            q = self._constrain(q, P(b, self._seq(q.shape[1]), None, None))
            k_new = self._constrain(k_new, P(b, None, None, None))
            v_new = self._constrain(v_new, P(b, None, None, None))
        if kv is None:
            k, v, kv_positions = k_new, v_new, q_pos
        else:
            k, v, kv_positions = kv
        o = cm.attention(
            q, k, v, causal=True, q_positions=q_pos, kv_positions=kv_positions,
            window=cfg.window if kind == "l" else None,
            logit_cap=cfg.attn_softcap,
        )
        o = jnp.einsum("bsnh,nhd->bsd", o, lp["wo"])
        if cfg.post_norms:
            o = cm.rms_norm(o, lp["post_ln1"])
        return self._res(x + o), (k_new, v_new)

    def _mlp(self, x, lp):
        cfg = self.cfg
        b = self._batch()
        h = cm.rms_norm(x, lp["ln2"])
        g = cm.act_fn(cfg.act)(jnp.einsum("bsd,df->bsf", h, lp["wg"]))
        u = jnp.einsum("bsd,df->bsf", h, lp["wi"])
        hh = self._constrain(g * u, P(b, None, cm.shardable(cfg.d_ff, MODEL, self.mesh)
                                      if self.mesh else None))
        m = jnp.einsum("bsf,fd->bsd", hh, lp["wmo"])
        if cfg.post_norms:
            m = cm.rms_norm(m, lp["post_ln2"])
        return self._res(x + m)

    # -- train forward -------------------------------------------------------
    def hidden(self, params, tokens):
        """Backbone: final-normed hidden states (B, S, D)."""
        cfg = self.cfg
        B, S = tokens.shape
        x = self._lookup(params["embed"], tokens)
        if cfg.embed_scale:
            x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
        x = self._res(x.astype(cfg.dtype))
        pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

        def body(carry, blk):
            x = carry
            for i, kind in enumerate(self.pattern):
                x, _ = self._attn(x, blk[str(i)], kind, pos, None, None)
                x = self._mlp(x, blk[str(i)])
            return x, None

        x, _ = cm.scan(cm.maybe_remat(body, cfg), x, params["blocks"])
        return cm.rms_norm(x, params["final_norm"])

    def _out_w(self, params):
        cfg = self.cfg
        w = params["embed"].T if cfg.tie_embeddings else params["unembed"]
        w = w.astype(cfg.dtype)
        if self.mesh is not None:
            # vocab-sharded, d gathered ONCE — otherwise the chunked-xent scan
            # re-gathers the d dim of a ~GB unembedding every seq chunk.
            w = cm.constrain(w, self.mesh,
                             P(None, cm.shardable(cfg.vocab, MODEL, self.mesh)))
        return w

    def logits(self, params, tokens):
        x = self.hidden(params, tokens)
        logits = jnp.einsum("bsd,dv->bsv", x, self._out_w(params))
        return self._constrain(logits, P(self._batch(), None, MODEL))

    def loss(self, params, batch):
        tokens = batch["tokens"]
        h = self.hidden(params, tokens[:, :-1])
        return cm.chunked_xent(h, self._out_w(params), tokens[:, 1:],
                               final_cap=self.cfg.final_softcap)

    # -- decode ----------------------------------------------------------------
    def cache_len(self, kind: str, max_len: int) -> int:
        return min(self.cfg.window, max_len) if kind == "l" else max_len

    def init_cache(self, batch: int, max_len: int) -> Any:
        cfg = self.cfg
        nb, KVH, hd = self.n_blocks, cfg.n_kv_heads, cfg.hd
        cache = {}
        for i, kind in enumerate(self.pattern):
            T = self.cache_len(kind, max_len)
            cache[f"k{i}"] = jnp.zeros((nb, batch, T, KVH, hd), cfg.dtype)
            cache[f"v{i}"] = jnp.zeros((nb, batch, T, KVH, hd), cfg.dtype)
            cache[f"p{i}"] = jnp.full((nb, batch, T), -1, jnp.int32)
        return cache

    def cache_specs(self, mesh: Mesh, batch: int, max_len: int) -> Any:
        specs = {}
        for i, kind in enumerate(self.pattern):
            T = self.cache_len(kind, max_len)
            kv = cm.kv_cache_spec(mesh, batch, T, extra=(None, None))
            specs[f"k{i}"] = kv
            specs[f"v{i}"] = kv
            specs[f"p{i}"] = cm.kv_cache_spec(mesh, batch, T)
        return specs

    @staticmethod
    def _cache_write(cache_k, cache_v, cache_p, k_new, v_new, pos, slot):
        """Write one token's K/V at per-batch slot. shapes: cache (B,T,KVH,hd)."""
        def upd(c, n, s):
            return jax.lax.dynamic_update_slice_in_dim(c, n, s, axis=0)
        ck = jax.vmap(upd)(cache_k, k_new, slot)
        cv = jax.vmap(upd)(cache_v, v_new, slot)
        cp = jax.vmap(upd)(cache_p, pos[:, None], slot)
        return ck, cv, cp

    def decode_step(self, params, cache, tokens, pos):
        """tokens: (B, 1) int32, pos: (B,) current absolute position.

        Returns (logits (B,1,V), new_cache)."""
        cfg = self.cfg
        B = tokens.shape[0]
        x = self._lookup(params["embed"], tokens)
        if cfg.embed_scale:
            x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
        x = x.astype(cfg.dtype)
        q_pos = pos[:, None]

        def body(carry, xs):
            x = carry
            blk = xs["blk"]
            new_cache = {}
            for i, kind in enumerate(self.pattern):
                T = xs[f"k{i}"].shape[1]
                slot = pos % T   # ring slot for local windows; == pos for global
                lp = blk[str(i)]
                h = cm.rms_norm(x, lp["ln1"])
                q = jnp.einsum("bsd,dnh->bsnh", h, lp["wq"])
                k_new = jnp.einsum("bsd,dkh->bskh", h, lp["wk"])
                v_new = jnp.einsum("bsd,dkh->bskh", h, lp["wv"])
                q = cm.rope(q, q_pos, cfg.rope_theta)
                k_new = cm.rope(k_new, q_pos, cfg.rope_theta)
                ck, cv, cp = self._cache_write(
                    xs[f"k{i}"], xs[f"v{i}"], xs[f"p{i}"], k_new, v_new, pos, slot
                )
                o = cm.attention(
                    q, ck, cv, causal=True, q_positions=q_pos, kv_positions=cp,
                    window=cfg.window if kind == "l" else None,
                    logit_cap=cfg.attn_softcap,
                )
                o = jnp.einsum("bsnh,nhd->bsd", o, lp["wo"])
                if cfg.post_norms:
                    o = cm.rms_norm(o, lp["post_ln1"])
                x = x + o
                x = self._mlp(x, lp)
                new_cache[f"k{i}"], new_cache[f"v{i}"], new_cache[f"p{i}"] = ck, cv, cp
            return x, new_cache

        xs = {"blk": params["blocks"], **cache}
        x, new_cache = cm.scan(body, x, xs)
        x = cm.rms_norm(x, params["final_norm"])
        out_w = params["embed"].T if cfg.tie_embeddings else params["unembed"]
        logits = jnp.einsum("bsd,dv->bsv", x, out_w.astype(cfg.dtype))
        logits = cm.softcap(logits, cfg.final_softcap)
        return logits, new_cache
