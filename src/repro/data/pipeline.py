"""Deterministic synthetic token pipeline, sharded at ingest.

Host-side batches are generated per process, double-buffered on a background
thread, and placed directly into their (pod, data)-sharded device layout —
the ingest path never materializes a replicated global batch. Determinism is
(seed, step)-keyed, so elastic restarts resume the exact data order from the
checkpointed step (fault-tolerance requirement: data and model state restart
together). A Zipf-ish marginal over the vocab gives the loss curve a
non-degenerate learnable structure for the e2e example.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator

import numpy as np
import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed.mesh import DATA, POD


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    # learnable structure: token t+1 = (a * t + noise) % vocab on a zipf base
    structured: bool = True


def _batch_at(cfg: DataConfig, step: int) -> np.ndarray:
    rng = np.random.default_rng(np.random.SeedSequence([cfg.seed, step]))
    B, S, V = cfg.global_batch, cfg.seq_len + 1, cfg.vocab
    if not cfg.structured:
        return rng.integers(0, V, (B, S), dtype=np.int32)
    base = rng.zipf(1.3, size=(B, 1)).astype(np.int64) % V
    mult = rng.integers(1, 17, (B, 1))
    pos = np.arange(S, dtype=np.int64)[None, :]
    noise = rng.integers(0, 3, (B, S))
    return ((base + mult * pos + noise) % V).astype(np.int32)


class TokenPipeline:
    """Iterator of device-sharded {'tokens': (B, S+1)} batches."""

    def __init__(self, cfg: DataConfig, mesh: Mesh | None = None,
                 start_step: int = 0, prefetch: int = 2):
        self.cfg = cfg
        self.mesh = mesh
        self.step = start_step
        self._next_produce = start_step
        self._q: "queue.Queue[tuple[int, np.ndarray]]" = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._producer, daemon=True)
        self._thread.start()

    def _producer(self) -> None:
        while not self._stop.is_set():
            s = self._next_produce
            batch = _batch_at(self.cfg, s)
            try:
                self._q.put((s, batch), timeout=0.5)
            except queue.Full:
                continue
            if s == self._next_produce:
                self._next_produce = s + 1

    def _sharding(self):
        if self.mesh is None:
            return None
        axes = tuple(a for a in (POD, DATA) if a in self.mesh.axis_names)
        b = axes if len(axes) > 1 else (axes[0] if axes else None)
        return NamedSharding(self.mesh, P(b, None))

    def __iter__(self) -> Iterator[dict]:
        return self

    def __next__(self) -> dict:
        batch = None
        for _ in range(self._q.maxsize + 1):   # drop stale prefetches after a seek
            try:
                s, b = self._q.get_nowait()
            except queue.Empty:
                break
            if s == self.step:
                batch = b
                break
        if batch is None:                      # cold start / post-seek miss
            batch = _batch_at(self.cfg, self.step)
        self.step += 1
        sh = self._sharding()
        tokens = jax.device_put(batch, sh) if sh is not None else jax.numpy.asarray(batch)
        return {"tokens": tokens}

    def seek(self, step: int) -> None:
        self.step = step
        self._next_produce = step
        while not self._q.empty():
            try:
                self._q.get_nowait()
            except queue.Empty:
                break

    def close(self) -> None:
        self._stop.set()
