"""Load-aware allocation of mover concurrency across concurrent transfers.

Kettimuthu et al. [2015] (cited in paper §2.3) showed that "a sufficient, but
not excessive, allocation of concurrency to the right transfers" improves
aggregate resource performance. With client-driven chunking in the picture the
allocator has a new degree of freedom: a single-large-file transfer can now
*use* more than one mover, so concurrency is allocated by marginal benefit
rather than by file count.

Policies:
  * "fair"        — equal movers per transfer (classic Globus behaviour).
  * "file_bound"  — movers = min(files, share): the pre-chunking allocator;
                    single-file transfers get 1 mover (the paper's baseline).
  * "marginal"    — greedy water-filling by simulated marginal throughput
                    gain, chunk-aware (the paper-enabled allocator).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

from repro.core.simulator import (
    DEFAULT_LINK,
    LinkConfig,
    SiteConfig,
    TransferSpec,
    simulate_transfer,
)


@dataclasses.dataclass(frozen=True)
class TransferRequest:
    name: str
    src: SiteConfig
    dst: SiteConfig
    file_bytes: tuple[int, ...]
    chunk_bytes: int | None = 200 * 1024 * 1024
    integrity: bool = True
    stripe_count: int = 16


@dataclasses.dataclass(frozen=True)
class Allocation:
    request: TransferRequest
    movers: int
    predicted_seconds: float
    predicted_gbps: float


def _predict(req: TransferRequest, movers: int, link: LinkConfig) -> float:
    if movers <= 0:
        return float("inf")
    spec = TransferSpec(
        file_bytes=req.file_bytes,
        chunk_bytes=req.chunk_bytes,
        integrity=req.integrity,
        stripe_count=req.stripe_count,
        concurrency=movers,
    )
    return simulate_transfer(req.src, req.dst, spec, link).seconds


def allocate(
    requests: Sequence[TransferRequest],
    total_movers: int = 64,
    policy: str = "marginal",
    link: LinkConfig = DEFAULT_LINK,
    step: int = 4,
    predict: Callable[[TransferRequest, int], float] | None = None,
) -> list[Allocation]:
    """Split a mover budget across transfers; returns per-transfer allocations.

    ``predict(request, movers) -> seconds`` overrides the built-in simulator
    cost model; the service layer passes a memoizing wrapper so repeated
    reallocation over a stable active set stays cheap.
    """
    if not requests:
        return []
    if predict is None:
        predict = lambda r, m: _predict(r, m, link)  # noqa: E731
    n = len(requests)
    if total_movers < n:
        raise ValueError(f"need >= 1 mover per transfer ({n} transfers, {total_movers} movers)")

    if policy == "fair":
        alloc = [total_movers // n] * n
        for i in range(total_movers - sum(alloc)):
            alloc[i] += 1
    elif policy == "file_bound":
        # Pre-chunking behaviour: a transfer can't use more movers than files.
        alloc = [0] * n
        budget = total_movers
        for i, r in enumerate(requests):
            alloc[i] = 1
            budget -= 1
        for i, r in enumerate(requests):
            extra = min(len(r.file_bytes) - 1, budget)
            alloc[i] += extra
            budget -= extra
    elif policy == "marginal":
        # Greedy water-filling on simulated completion-time reduction per mover.
        alloc = [1] * n
        budget = total_movers - n
        cur = [predict(r, 1) for r in requests]
        while budget >= step:
            best_i, best_gain, best_t = -1, 0.0, 0.0
            for i, r in enumerate(requests):
                t = predict(r, alloc[i] + step)
                gain = cur[i] - t
                if gain > best_gain:
                    best_i, best_gain, best_t = i, gain, t
            if best_i < 0:
                break
            alloc[best_i] += step
            cur[best_i] = best_t
            budget -= step
    else:
        raise ValueError(f"unknown policy {policy!r}")

    out = []
    for r, m in zip(requests, alloc):
        secs = predict(r, m)
        total = sum(r.file_bytes)
        out.append(Allocation(r, m, secs, total * 8 / 1e9 / secs if secs > 0 else 0.0))
    return out
