"""Mergeable integrity fingerprints — the TPU-native replacement for MD5.

The paper (§3.2) overlaps per-chunk MD5 checksums with data movement. MD5 is a
strictly sequential 64-byte block chain: the worst possible fit for a TPU's
8x128-lane vector units. What the Globus protocol actually *needs* from the
checksum is

  (1) corruption detection for random bit/byte flips, and
  (2) per-chunk digests that *merge* into a whole-file verdict
      (the ERET/ESTO partial-transfer checksums of §3.2).

We therefore use a degree-weighted polynomial fingerprint over the prime field
GF(p), p = 46337 (the largest prime with (p-1)^2 < 2^31, so every product of
two residues fits in signed int32 — native TPU arithmetic). Four independent
evaluation points r_1..r_4 give a 4x~15.5 = 62-bit digest, stronger than the
32-bit checksum value Globus transmits (paper §3.2).

Definition, over the byte stream b_0..b_{n-1} (each byte is one coefficient):

    H_r(b) = sum_k b_k * r^(n-1-k)  mod p          (degree-descending)

which satisfies the *merge law* used throughout this framework:

    H_r(A || B) = H_r(A) * r^len(B) + H_r(B)   (mod p)

so chunk digests computed independently — in any order, by any mover — combine
associatively into the stream digest. Out-of-order completion (movers finish
chunks at different times; paper §3.1) is supported by `combine_at_offset`,
because chunk C at byte offset o of an n-byte file contributes exactly
H_r(C) * r^(n - o - len(C)) to the file digest, a commutative sum.

Detection strength: two distinct equal-length streams collide at evaluation
point r iff r is a root of their (degree < n) difference polynomial; for the
four fixed points the miss probability for a random corruption is ~(1/p)^4
~= 2.2e-19 per point-set, far below the one-error-per-1.26 TB corruption rate
observed in the Globus logs (paper §2.3). Unequal lengths never collide: the
digest carries the exact byte length.

Three implementations, one algebra:
  * this module      — exact host/numpy version over raw bytes (checkpoint path)
  * kernels/ref.py   — pure-jnp oracle over int32-packed words
  * kernels/checksum — Pallas TPU kernel (BlockSpec VMEM tiling), validated
                       against ref.py in interpret mode.
"""
from __future__ import annotations

import dataclasses
import functools
import threading
from typing import Iterable, Sequence

import numpy as np

P = 46337                        # largest prime with (p-1)^2 < 2^31
BASES = (10007, 20011, 31337, 40009)   # four fixed evaluation points
NBASES = len(BASES)
_BLOCK = 1 << 16                 # host-side processing block (bytes)

# Bigint-pow accounting: `Digest.merge`/`shifted`/`combine_at_offsets` run
# O(chunks x hops) in fabric relays and service digest chains, and every one
# of them needs r^len for the four bases. The LRU below makes repeated
# same-length merges hit a table instead of calling CPython's bigint pow();
# the counter exists so benchmarks/overlap.py can *gate* that (pow calls per
# merge chain must stay >= 5x below the uncached 4-per-merge cost).
_POW_STATS = {"bigint_pow_calls": 0}


@functools.lru_cache(maxsize=1 << 16)
def _pow_mod_cached(base: int, exp: int, mod: int) -> int:
    _POW_STATS["bigint_pow_calls"] += 1
    return pow(base, exp, mod)


def _pow_mod(base: int, exp: int, mod: int = P) -> int:
    return _pow_mod_cached(int(base), int(exp), mod)


@functools.lru_cache(maxsize=1 << 14)
def _shift_vector(exp: int) -> tuple[int, ...]:
    """(r^exp mod P for r in BASES) — the per-merge weight vector, cached so
    a chain of equal-length merges costs four pow() calls total, not 4/merge."""
    return tuple(_pow_mod_cached(r, int(exp), P) for r in BASES)


def pow_call_count() -> int:
    """Cumulative bigint pow() invocations (cache misses) this process."""
    return _POW_STATS["bigint_pow_calls"]


def clear_pow_caches() -> None:
    """Drop the pow/shift LRUs (microbenchmarks measure from a cold start)."""
    _pow_mod_cached.cache_clear()
    _shift_vector.cache_clear()


@dataclasses.dataclass(frozen=True)
class Digest:
    """A mergeable fingerprint: four GF(p) residues plus the exact byte length."""

    h: tuple[int, int, int, int]
    length: int

    def __post_init__(self):
        if len(self.h) != NBASES:
            raise ValueError(f"digest must carry {NBASES} residues, got {len(self.h)}")
        if any(not (0 <= v < P) for v in self.h):
            raise ValueError(f"residues out of field range: {self.h}")
        if self.length < 0:
            raise ValueError("negative length")

    # -- algebra ------------------------------------------------------------
    def merge(self, right: "Digest") -> "Digest":
        """Digest of the concatenation self || right."""
        sv = _shift_vector(right.length)
        h = tuple(
            (hl * s + hr) % P for hl, hr, s in zip(self.h, right.h, sv)
        )
        return Digest(h, self.length + right.length)

    def shifted(self, tail_bytes: int) -> tuple[int, ...]:
        """Contribution of this chunk when `tail_bytes` bytes follow it."""
        sv = _shift_vector(tail_bytes)
        return tuple((hv * s) % P for hv, s in zip(self.h, sv))

    def to_bytes(self) -> bytes:
        out = bytearray()
        for v in self.h:
            out += int(v).to_bytes(4, "little")
        out += int(self.length).to_bytes(8, "little")
        return bytes(out)

    @staticmethod
    def from_bytes(raw: bytes) -> "Digest":
        if len(raw) != 4 * NBASES + 8:
            raise ValueError(f"bad digest encoding length {len(raw)}")
        h = tuple(int.from_bytes(raw[4 * i : 4 * i + 4], "little") for i in range(NBASES))
        length = int.from_bytes(raw[4 * NBASES :], "little")
        return Digest(h, length)

    def hexdigest(self) -> str:
        return self.to_bytes().hex()


EMPTY_DIGEST = Digest((0, 0, 0, 0), 0)


def fingerprint_bytes(
    data: bytes | bytearray | memoryview | np.ndarray,
    *,
    state: "Digest | None" = None,
) -> Digest:
    """Exact digest of a raw byte stream (vectorized numpy host path).

    This is the checkpoint-path implementation: it must digest arbitrary-length
    byte strings at (multi-)100 MB/s so that per-chunk checksumming can overlap
    chunk I/O (paper Fig. 4) without itself becoming the bottleneck.

    ``state`` is a running digest of everything streamed so far: passing it
    returns ``state || data`` by the merge law, which is the single-pass data
    plane's primitive — the source fingerprint accumulates granule-by-granule
    *while* the chunk streams into the destination, instead of in a second
    full pass over the chunk (``core.dataplane.stream_chunk``).
    """
    if state is not None:
        return state.merge(fingerprint_bytes(data))
    buf = np.frombuffer(data, dtype=np.uint8) if not isinstance(data, np.ndarray) else data
    if buf.dtype != np.uint8:
        buf = buf.view(np.uint8)
    buf = buf.reshape(-1)
    n = buf.size
    h = np.zeros(NBASES, dtype=np.int64)
    if n == 0:
        return EMPTY_DIGEST
    # Weight tables as float64: every product (<= 255 * 46336) and every
    # 64 KiB block sum (<= 7.7e11) is exactly representable in f64 (< 2^53),
    # so we get BLAS-speed GEMMs with exact integer results.
    weights = _host_weight_table_f64(_BLOCK)                 # (NBASES, _BLOCK)
    full, rem = divmod(n, _BLOCK)
    SUPER = 128  # blocks per GEMM: 8 MiB of input per call
    # per-thread reusable conversion buffer: a fresh np.empty here would cost
    # a 64 MB mmap + page-fault storm PER CALL, halving the digest rate in
    # the small-chunk regime the data plane streams through
    conv = _conv_buffer(min(SUPER, full) or 1)
    for s in range(0, full, SUPER):
        e = min(s + SUPER, full)
        m = e - s
        x = conv[:m]
        np.copyto(x, buf[s * _BLOCK : e * _BLOCK].reshape(m, _BLOCK))
        blks = (x @ weights.T).astype(np.int64) % P  # (m, NBASES)
        # fold the m block digests in ONE reduction instead of a python
        # recurrence: H = sum_j blks[j] * r^(B*(m-1-j)), terms < P^2 * m
        # stay exact in int64 for m <= 128
        h_super = (blks * _block_fold_powers(m)).sum(axis=0) % P
        h = (h * np.asarray(_shift_vector(m * _BLOCK), dtype=np.int64)
             + h_super) % P
    if rem:
        tail = buf[full * _BLOCK :].astype(np.float64)
        # weights[:, B-rem:] = [r^(rem-1) ... r^0] — descending weights for `rem` coeffs.
        blk = (weights[:, _BLOCK - rem :] @ tail).astype(np.int64) % P
        h = (h * np.asarray(_shift_vector(rem), dtype=np.int64) + blk) % P
    return Digest(tuple(int(v) for v in h), n)


@functools.lru_cache(maxsize=256)
def _block_fold_powers(m: int) -> np.ndarray:
    """(m, NBASES) table: [r^(_BLOCK*(m-1-j))]_j — the block-fold weights."""
    out = np.empty((m, NBASES), dtype=np.int64)
    for j in range(m):
        out[j] = _shift_vector((m - 1 - j) * _BLOCK)
    return out


_WEIGHT_CACHE: dict[int, np.ndarray] = {}
_WEIGHT_CACHE_F64: dict[int, np.ndarray] = {}
_TLS = threading.local()


def _conv_buffer(blocks: int) -> np.ndarray:
    """Thread-local (blocks, _BLOCK) float64 conversion scratch, grown on
    demand and reused across calls (page faults paid once per thread)."""
    buf = getattr(_TLS, "conv", None)
    if buf is None or buf.shape[0] < blocks:
        buf = np.empty((blocks, _BLOCK), dtype=np.float64)
        _TLS.conv = buf
    return buf


def _host_weight_table_f64(block: int) -> np.ndarray:
    """float64 view of the weight table, cached (the GEMM operand)."""
    tbl = _WEIGHT_CACHE_F64.get(block)
    if tbl is None:
        tbl = _host_weight_table(block).astype(np.float64)
        _WEIGHT_CACHE_F64[block] = tbl
    return tbl


def _host_weight_table(block: int) -> np.ndarray:
    """weights[b, k] = BASES[b] ^ (block-1-k) mod P, shape (NBASES, block)."""
    tbl = _WEIGHT_CACHE.get(block)
    if tbl is None:
        tbl = np.empty((NBASES, block), dtype=np.int64)
        for b, r in enumerate(BASES):
            w = np.empty(block, dtype=np.int64)
            acc = 1
            for k in range(block - 1, -1, -1):
                w[k] = acc
                acc = (acc * r) % P
            tbl[b] = w
        _WEIGHT_CACHE[block] = tbl
    return tbl


class RunningFingerprint:
    """Incremental fingerprint accumulator (the merge law as a stream API).

    ``update()`` folds the next granule into the running digest while the
    granule is still cache-hot from the copy that produced it — this is how
    the zero-copy data plane computes the source digest during streaming
    instead of in a separate full pass. Merge cost is four table lookups per
    granule (the ``_shift_vector`` LRU), so granule size can be small.
    """

    __slots__ = ("_digest",)

    def __init__(self, start: Digest = EMPTY_DIGEST):
        self._digest = start

    def update(self, data: bytes | bytearray | memoryview | np.ndarray) -> None:
        self._digest = self._digest.merge(fingerprint_bytes(data))

    @property
    def length(self) -> int:
        return self._digest.length

    def digest(self) -> Digest:
        return self._digest


# rows per conversion slab: the f64 slab (rows x 512 KiB) must stay
# cache-resident — at 128 rows the 64 MiB working set spills to DRAM and the
# "fused" path measures slower than per-chunk; 16 rows (8 MiB) is the sweet
# spot measured across the 64 KiB..1 MiB granule range
_ROW_SLAB = 16


def _wT_f64() -> np.ndarray:
    """Contiguous (_BLOCK, NBASES) GEMM operand — ``weights.T`` as a view is
    non-contiguous, and BLAS re-copies the 2 MiB table on EVERY call; cached
    contiguous it is read once per slab and stays in LLC across the batch."""
    tbl = _WEIGHT_CACHE_F64.get(-_BLOCK)
    if tbl is None:
        tbl = np.ascontiguousarray(_host_weight_table_f64(_BLOCK).T)
        _WEIGHT_CACHE_F64[-_BLOCK] = tbl
    return tbl


@functools.lru_cache(maxsize=64)
def _tail_weight_f64(rem: int) -> np.ndarray:
    """Contiguous (rem, NBASES) tail-weight operand for partial blocks."""
    return np.ascontiguousarray(_host_weight_table_f64(_BLOCK)[:, _BLOCK - rem :].T)


def fingerprint_rows(rows: Sequence[np.ndarray]) -> list[Digest]:
    """Digests of k equal-length uint8 rows — one fused GEMM per block column.

    This is the batched-dispatch primitive under ``fingerprint_many`` and the
    ``IntegrityEngine`` fused drain. The old implementation stacked the rows
    into one matrix and ran a full-width ``astype(np.float64)``: two fresh
    multi-MB allocations per call, which page-fault so hard the "fused" path
    measured *slower* than per-chunk calls. Here every 64 KiB block column is
    converted row-by-row straight into the same thread-local float64 scratch
    ``fingerprint_bytes`` reuses, so the only large memory traffic is the one
    unavoidable uint8→f64 spread, and the GEMM amortizes across all k rows.

    Rows may be arbitrary 1-D uint8 views (rows of a staging buffer, pooled
    granules) — no copy-stacking. Raises ``ValueError`` naming the offending
    row on ragged input; callers that may be ragged use ``fingerprint_many``.
    """
    k = len(rows)
    if k == 0:
        return []
    n = int(rows[0].size)
    for j, r in enumerate(rows):
        if int(r.size) != n:
            raise ValueError(
                f"fingerprint_rows requires equal lengths: row {j} has "
                f"{int(r.size)} bytes, row 0 has {n}"
            )
    if n == 0:
        return [EMPTY_DIGEST] * k
    wT = _wT_f64()                                           # (_BLOCK, NBASES)
    full, rem = divmod(n, _BLOCK)
    h = np.zeros((k, NBASES), dtype=np.int64)
    r_blk = np.asarray(_shift_vector(_BLOCK), dtype=np.int64)
    for s0 in range(0, k, _ROW_SLAB):
        s1 = min(s0 + _ROW_SLAB, k)
        m = s1 - s0
        conv = _conv_buffer(m)
        for s in range(full):
            lo = s * _BLOCK
            x = conv[:m]
            for j in range(m):
                np.copyto(x[j], rows[s0 + j][lo : lo + _BLOCK])
            blks = (x @ wT).astype(np.int64) % P             # (m, NBASES)
            h[s0:s1] = (h[s0:s1] * r_blk[None, :] + blks) % P
        if rem:
            lo = full * _BLOCK
            if full == 0:
                # sub-block rows: pack contiguously into the flat scratch —
                # conv[:m, :rem] has strided rows, which forces BLAS to
                # re-copy the whole operand on every GEMM call
                x = conv.reshape(-1)[: m * rem].reshape(m, rem)
            else:
                x = conv[:m, :rem]
            for j in range(m):
                np.copyto(x[j], rows[s0 + j][lo:])
            r_tail = np.asarray(_shift_vector(rem), dtype=np.int64)
            blk = (x @ _tail_weight_f64(rem)).astype(np.int64) % P
            h[s0:s1] = (h[s0:s1] * r_tail[None, :] + blk) % P
    return [Digest(tuple(int(v) for v in h[i]), n) for i in range(k)]


def fingerprint_many(
    chunks: Sequence[bytes | bytearray | memoryview | np.ndarray],
    *,
    expect_equal: bool = False,
) -> list[Digest]:
    """Digests of many chunks in one numpy dispatch per equal-length group.

    ``fingerprint_bytes`` pays fixed numpy dispatch + conversion overhead per
    call, which dominates in the small-chunk regime (fabric relay granules,
    engine drain batches, re-planned tails at the tuner's floor). Lengths are
    validated up front: equal-length groups of two or more go through the
    fused ``fingerprint_rows`` GEMM stack, while ragged leftovers fall back
    to per-item ``fingerprint_bytes`` — so mixed-length input degrades
    gracefully instead of raising deep inside the GEMM stacking. Equal
    results to the per-chunk path, bit for bit.

    ``expect_equal=True`` makes ragged input an error, reported in the
    ``describe_mismatch`` style (which items, which lengths) — for callers
    like the relay's read-back comparison where a length spread is itself
    the fault being detected (a short read-back), not a batching choice.
    """
    bufs: list[np.ndarray] = []
    for data in chunks:
        b = np.frombuffer(data, dtype=np.uint8) if not isinstance(data, np.ndarray) else data
        if b.dtype != np.uint8:
            b = b.view(np.uint8)
        bufs.append(b.reshape(-1))
    groups: dict[int, list[int]] = {}
    for i, b in enumerate(bufs):
        groups.setdefault(int(b.size), []).append(i)
    if expect_equal and len(groups) > 1:
        sizes = sorted(groups)
        raise ValueError(
            "length mismatch across batch: "
            + ", ".join(f"items {groups[n]} have {n} bytes" for n in sizes)
            + " — short read/over read upstream of the digest"
        )
    out: list[Digest | None] = [None] * len(bufs)
    for n, idxs in groups.items():
        if n == 0:
            for i in idxs:
                out[i] = EMPTY_DIGEST
        elif len(idxs) == 1:
            # singleton group: the fused path has nothing to amortize over
            out[idxs[0]] = fingerprint_bytes(bufs[idxs[0]])
        else:
            digs = fingerprint_rows([bufs[i] for i in idxs])
            for row, i in enumerate(idxs):
                out[i] = digs[row]
    return out                                            # type: ignore[return-value]


def fingerprint_ndarray(arr: np.ndarray) -> Digest:
    """Digest of an ndarray's in-memory byte image (C-order)."""
    return fingerprint_bytes(np.ascontiguousarray(arr).view(np.uint8))


def merge_all(digests: Iterable[Digest]) -> Digest:
    """Fold an in-order sequence of chunk digests into the stream digest."""
    out = EMPTY_DIGEST
    for d in digests:
        out = out.merge(d)
    return out


def combine_at_offsets(
    parts: Sequence[tuple[int, Digest]], total_length: int
) -> Digest:
    """Commutative combination of (byte_offset, digest) chunk parts.

    Chunks may be supplied in ANY order (movers complete out of order,
    paper §3.1); offsets must tile [0, total_length) exactly.
    """
    cover = sorted((off, d.length) for off, d in parts)
    pos = 0
    for off, ln in cover:
        if off != pos:
            raise ValueError(f"chunk coverage gap/overlap at byte {pos} (next chunk at {off})")
        pos += ln
    if pos != total_length:
        raise ValueError(f"chunks cover {pos} bytes, expected {total_length}")
    acc = [0] * NBASES
    for off, d in parts:
        tail = total_length - off - d.length
        contrib = d.shifted(tail)
        for b in range(NBASES):
            acc[b] = (acc[b] + contrib[b]) % P
    return Digest(tuple(acc), total_length)


def verify(expected: Digest, actual: Digest) -> bool:
    return expected.h == actual.h and expected.length == actual.length


def describe_mismatch(expected: Digest, actual: Digest) -> str:
    """Human-readable diagnosis of a failed ``verify`` (for fault reports).

    Distinguishes a length mismatch (short/over read — an I/O fault) from a
    residue mismatch (content corruption) and names the evaluation points
    that disagree: a single disagreeing base on equal lengths is the
    signature of in-flight bit corruption rather than a framing error.
    """
    if expected.length != actual.length:
        return f"length mismatch ({expected.length} vs {actual.length} bytes)"
    bad = [i for i in range(NBASES) if expected.h[i] != actual.h[i]]
    if not bad:
        return "digests match"
    return (
        f"content corruption: {len(bad)}/{NBASES} residues disagree "
        f"(bases {tuple(BASES[i] for i in bad)})"
    )
