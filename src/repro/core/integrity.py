"""Mergeable integrity fingerprints — the TPU-native replacement for MD5.

The paper (§3.2) overlaps per-chunk MD5 checksums with data movement. MD5 is a
strictly sequential 64-byte block chain: the worst possible fit for a TPU's
8x128-lane vector units. What the Globus protocol actually *needs* from the
checksum is

  (1) corruption detection for random bit/byte flips, and
  (2) per-chunk digests that *merge* into a whole-file verdict
      (the ERET/ESTO partial-transfer checksums of §3.2).

We therefore use a degree-weighted polynomial fingerprint over the prime field
GF(p), p = 46337 (the largest prime with (p-1)^2 < 2^31, so every product of
two residues fits in signed int32 — native TPU arithmetic). Four independent
evaluation points r_1..r_4 give a 4x~15.5 = 62-bit digest, stronger than the
32-bit checksum value Globus transmits (paper §3.2).

Definition, over the byte stream b_0..b_{n-1} (each byte is one coefficient):

    H_r(b) = sum_k b_k * r^(n-1-k)  mod p          (degree-descending)

which satisfies the *merge law* used throughout this framework:

    H_r(A || B) = H_r(A) * r^len(B) + H_r(B)   (mod p)

so chunk digests computed independently — in any order, by any mover — combine
associatively into the stream digest. Out-of-order completion (movers finish
chunks at different times; paper §3.1) is supported by `combine_at_offset`,
because chunk C at byte offset o of an n-byte file contributes exactly
H_r(C) * r^(n - o - len(C)) to the file digest, a commutative sum.

Detection strength: two distinct equal-length streams collide at evaluation
point r iff r is a root of their (degree < n) difference polynomial; for the
four fixed points the miss probability for a random corruption is ~(1/p)^4
~= 2.2e-19 per point-set, far below the one-error-per-1.26 TB corruption rate
observed in the Globus logs (paper §2.3). Unequal lengths never collide: the
digest carries the exact byte length.

Three implementations, one algebra:
  * this module      — exact host/numpy version over raw bytes (checkpoint path)
  * kernels/ref.py   — pure-jnp oracle over int32-packed words
  * kernels/checksum — Pallas TPU kernel (BlockSpec VMEM tiling), validated
                       against ref.py in interpret mode.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence

import numpy as np

P = 46337                        # largest prime with (p-1)^2 < 2^31
BASES = (10007, 20011, 31337, 40009)   # four fixed evaluation points
NBASES = len(BASES)
_BLOCK = 1 << 16                 # host-side processing block (bytes)


def _pow_mod(base: int, exp: int, mod: int = P) -> int:
    return pow(int(base), int(exp), mod)


@dataclasses.dataclass(frozen=True)
class Digest:
    """A mergeable fingerprint: four GF(p) residues plus the exact byte length."""

    h: tuple[int, int, int, int]
    length: int

    def __post_init__(self):
        if len(self.h) != NBASES:
            raise ValueError(f"digest must carry {NBASES} residues, got {len(self.h)}")
        if any(not (0 <= v < P) for v in self.h):
            raise ValueError(f"residues out of field range: {self.h}")
        if self.length < 0:
            raise ValueError("negative length")

    # -- algebra ------------------------------------------------------------
    def merge(self, right: "Digest") -> "Digest":
        """Digest of the concatenation self || right."""
        h = tuple(
            (hl * _pow_mod(r, right.length) + hr) % P
            for hl, hr, r in zip(self.h, right.h, BASES)
        )
        return Digest(h, self.length + right.length)

    def shifted(self, tail_bytes: int) -> tuple[int, ...]:
        """Contribution of this chunk when `tail_bytes` bytes follow it."""
        return tuple((hv * _pow_mod(r, tail_bytes)) % P for hv, r in zip(self.h, BASES))

    def to_bytes(self) -> bytes:
        out = bytearray()
        for v in self.h:
            out += int(v).to_bytes(4, "little")
        out += int(self.length).to_bytes(8, "little")
        return bytes(out)

    @staticmethod
    def from_bytes(raw: bytes) -> "Digest":
        if len(raw) != 4 * NBASES + 8:
            raise ValueError(f"bad digest encoding length {len(raw)}")
        h = tuple(int.from_bytes(raw[4 * i : 4 * i + 4], "little") for i in range(NBASES))
        length = int.from_bytes(raw[4 * NBASES :], "little")
        return Digest(h, length)

    def hexdigest(self) -> str:
        return self.to_bytes().hex()


EMPTY_DIGEST = Digest((0, 0, 0, 0), 0)


def fingerprint_bytes(data: bytes | bytearray | memoryview | np.ndarray) -> Digest:
    """Exact digest of a raw byte stream (vectorized numpy host path).

    This is the checkpoint-path implementation: it must digest arbitrary-length
    byte strings at (multi-)100 MB/s so that per-chunk checksumming can overlap
    chunk I/O (paper Fig. 4) without itself becoming the bottleneck.
    """
    buf = np.frombuffer(data, dtype=np.uint8) if not isinstance(data, np.ndarray) else data
    if buf.dtype != np.uint8:
        buf = buf.view(np.uint8)
    buf = buf.reshape(-1)
    n = buf.size
    h = np.zeros(NBASES, dtype=np.int64)
    if n == 0:
        return EMPTY_DIGEST
    # Weight tables as float64: every product (<= 255 * 46336) and every
    # 64 KiB block sum (<= 7.7e11) is exactly representable in f64 (< 2^53),
    # so we get BLAS-speed GEMMs with exact integer results.
    weights = _host_weight_table(_BLOCK).astype(np.float64)  # (NBASES, _BLOCK)
    r_blk = np.array([_pow_mod(r, _BLOCK) for r in BASES], dtype=np.int64)
    full, rem = divmod(n, _BLOCK)
    SUPER = 128  # blocks per GEMM: 8 MiB of input per call
    conv = np.empty((SUPER, _BLOCK), dtype=np.float64)  # reused conversion buffer
    for s in range(0, full, SUPER):
        e = min(s + SUPER, full)
        x = conv[: e - s]
        np.copyto(x, buf[s * _BLOCK : e * _BLOCK].reshape(e - s, _BLOCK))
        blks = (x @ weights.T).astype(np.int64) % P  # (e-s, NBASES)
        for i in range(e - s):
            h = (h * r_blk + blks[i]) % P
    if rem:
        tail = buf[full * _BLOCK :].astype(np.float64)
        r_tail = np.array([_pow_mod(r, rem) for r in BASES], dtype=np.int64)
        # weights[:, B-rem:] = [r^(rem-1) ... r^0] — descending weights for `rem` coeffs.
        blk = (weights[:, _BLOCK - rem :] @ tail).astype(np.int64) % P
        h = (h * r_tail + blk) % P
    return Digest(tuple(int(v) for v in h), n)


_WEIGHT_CACHE: dict[int, np.ndarray] = {}


def _host_weight_table(block: int) -> np.ndarray:
    """weights[b, k] = BASES[b] ^ (block-1-k) mod P, shape (NBASES, block)."""
    tbl = _WEIGHT_CACHE.get(block)
    if tbl is None:
        tbl = np.empty((NBASES, block), dtype=np.int64)
        for b, r in enumerate(BASES):
            w = np.empty(block, dtype=np.int64)
            acc = 1
            for k in range(block - 1, -1, -1):
                w[k] = acc
                acc = (acc * r) % P
            tbl[b] = w
        _WEIGHT_CACHE[block] = tbl
    return tbl


def fingerprint_ndarray(arr: np.ndarray) -> Digest:
    """Digest of an ndarray's in-memory byte image (C-order)."""
    return fingerprint_bytes(np.ascontiguousarray(arr).view(np.uint8))


def merge_all(digests: Iterable[Digest]) -> Digest:
    """Fold an in-order sequence of chunk digests into the stream digest."""
    out = EMPTY_DIGEST
    for d in digests:
        out = out.merge(d)
    return out


def combine_at_offsets(
    parts: Sequence[tuple[int, Digest]], total_length: int
) -> Digest:
    """Commutative combination of (byte_offset, digest) chunk parts.

    Chunks may be supplied in ANY order (movers complete out of order,
    paper §3.1); offsets must tile [0, total_length) exactly.
    """
    cover = sorted((off, d.length) for off, d in parts)
    pos = 0
    for off, ln in cover:
        if off != pos:
            raise ValueError(f"chunk coverage gap/overlap at byte {pos} (next chunk at {off})")
        pos += ln
    if pos != total_length:
        raise ValueError(f"chunks cover {pos} bytes, expected {total_length}")
    acc = [0] * NBASES
    for off, d in parts:
        tail = total_length - off - d.length
        contrib = d.shifted(tail)
        for b in range(NBASES):
            acc[b] = (acc[b] + contrib[b]) % P
    return Digest(tuple(acc), total_length)


def verify(expected: Digest, actual: Digest) -> bool:
    return expected.h == actual.h and expected.length == actual.length


def describe_mismatch(expected: Digest, actual: Digest) -> str:
    """Human-readable diagnosis of a failed ``verify`` (for fault reports).

    Distinguishes a length mismatch (short/over read — an I/O fault) from a
    residue mismatch (content corruption) and names the evaluation points
    that disagree: a single disagreeing base on equal lengths is the
    signature of in-flight bit corruption rather than a framing error.
    """
    if expected.length != actual.length:
        return f"length mismatch ({expected.length} vs {actual.length} bytes)"
    bad = [i for i in range(NBASES) if expected.h[i] != actual.h[i]]
    if not bad:
        return "digests match"
    return (
        f"content corruption: {len(bad)}/{NBASES} residues disagree "
        f"(bases {tuple(BASES[i] for i in bad)})"
    )
