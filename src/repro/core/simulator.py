"""Calibrated discrete-event model of chunked wide-area transfers.

This is the quantitative stand-in for the ALCF/NERSC/OLCF testbed of paper §4:
a max-min-fair, event-stepped simulation of data movers, WAN capacity, parallel
file-system (OST) contention, per-chunk control overheads, and dest-side
re-read checksumming. It serves two roles:

  1. *Claim validation* — benchmarks/fig5..fig10 run this model in the paper's
     experimental configurations and check the headline observations
     (9.5x single-file chunking speedup, the 200-500 MB chunk-size sweet spot,
     integrity checking ~halving un-chunked throughput, the 8.1x Lustre-stripe
     effect, multi-file vs single-file scaling).
  2. *Cost model* — `core.chunker.plan_auto` consults it to pick chunk sizes,
     implementing the automation the paper's §6 calls for.

Calibration (documented in EXPERIMENTS.md §Claims): per-mover network rate
3.2 Gb/s (64 movers x 4 TCP streams, paper §4), per-mover checksum rate
5.2 Gb/s (500 GB re-read+MD5 in 773 s, paper Fig. 8), OST file-level ceiling
`ost_gbps * stripes^0.755` (the 8.1x gain from stripes 1->16, paper Fig. 5,
with a mild decline past 16 stripes as the paper observed at 64).

The model's serial transfer->checksum pipeline then *predicts* the paper's
1.98 Gb/s for an un-chunked 500 GB integrity-checked transfer:
1/(1/3.2 + 1/5.2) = 1.98 Gb/s — an independent check of the calibration.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Iterable

from repro.core.chunker import MiB, GiB, plan_chunks
from repro.core.vclock import VirtualClock

Gb = 1e9 / 8.0  # bytes per Gigabit


@dataclasses.dataclass(frozen=True)
class SiteConfig:
    """One facility's DTN + file-system configuration."""

    name: str
    movers: int = 64                 # GridFTP concurrency (paper: 64)
    parallelism: int = 4             # TCP streams per mover (paper: 4)
    mover_gbps: float = 3.2          # per-mover network ceiling
    site_io_gbps: float = 100.0      # aggregate PFS<->DTN bandwidth
    ost_gbps: float = 2.4            # single-OST streaming *read* bandwidth
    ost_write_factor: float = 2.0    # writes land in OST caches/buffers faster
    stripe_eff: float = 0.755        # sublinear OST scaling exponent
    cksum_gbps: float = 5.2          # per-mover re-read + checksum rate

    def file_io_cap_gbps(self, stripes: int, *, write: bool = False) -> float:
        """File-level I/O ceiling vs Lustre stripe count (calibrated, Fig. 5)."""
        stripes = max(1, stripes)
        if stripes <= 16:
            eff = stripes ** self.stripe_eff
        else:
            # Paper observed decline from 16 -> 64 stripes (§4.1): server
            # competition + metadata overheads; modeled as a slow rolloff.
            eff = (16 ** self.stripe_eff) * (16 / stripes) ** 0.25
        base = self.ost_gbps * (self.ost_write_factor if write else 1.0)
        return min(base * eff, self.site_io_gbps)


ALCF = SiteConfig("ALCF", ost_gbps=2.4)
NERSC = SiteConfig("NERSC", ost_gbps=3.92)
OLCF = SiteConfig("OLCF", ost_gbps=3.0, site_io_gbps=90.0)
SITES = {s.name: s for s in (ALCF, NERSC, OLCF)}


@dataclasses.dataclass(frozen=True)
class LinkConfig:
    wan_gbps: float = 100.0
    chunk_latency_s: float = 0.10    # per-request control-channel turnaround


DEFAULT_LINK = LinkConfig()


@dataclasses.dataclass(frozen=True)
class TransferSpec:
    file_bytes: tuple[int, ...]
    chunk_bytes: int | None = None   # None => no chunking (paper baseline)
    integrity: bool = True
    stripe_count: int = 16
    pipeline_depth: int = 4
    concurrency: int | None = None   # movers engaged; default min(site movers)


@dataclasses.dataclass
class SimResult:
    seconds: float
    gbps: float
    n_items: int
    transfer_done_s: float           # when the last byte landed
    checksum_tail_s: float           # extra time spent finishing checksums


class _Stage:
    """One pipelined stage (network move or checksum re-read) of one item."""

    __slots__ = ("kind", "file", "bytes_left", "setup_left", "mover", "rate", "nbytes")

    def __init__(self, kind: str, file: int, nbytes: int | float, setup: float, mover: int):
        self.kind = kind             # "net" | "hash"
        self.file = file
        self.nbytes = float(nbytes)  # original item size
        self.bytes_left = float(nbytes)
        self.setup_left = setup
        self.mover = mover
        self.rate = 0.0              # bytes/s, assigned each event step


def _maxmin_rates(stages: list[_Stage], resources: dict[str, tuple[float, list[int]]]):
    """Progressive-filling max-min fair allocation.

    resources: name -> (capacity_bytes_per_s, member stage indices).
    Stage rates start at 0 and rise together; when a resource saturates its
    members freeze. Per-stage ceilings are expressed as 1-member resources.
    """
    n = len(stages)
    rate = [0.0] * n
    frozen = [False] * n
    member_any: set[int] = set()
    for _cap, mem in resources.values():
        member_any.update(mem)
    while True:
        best_key, best_target = None, math.inf
        for key, (cap, mem) in resources.items():
            un = sum(1 for i in mem if not frozen[i])
            if un == 0:
                continue
            used = sum(rate[i] for i in mem if frozen[i])
            target = max(0.0, cap - used) / un
            if target < best_target:
                best_key, best_target = key, target
        if best_key is None:
            break
        # Raise every still-unfrozen flow to the common rate at which the
        # bottleneck resource saturates, then freeze that resource's members.
        # Monotonicity: for any other resource, headroom/target can only be
        # >= the bottleneck's, so rates never need to decrease.
        for i in member_any:
            if not frozen[i]:
                rate[i] = best_target
        for i in resources[best_key][1]:
            frozen[i] = True
    for i, s in enumerate(stages):
        s.rate = rate[i]


def simulate_transfer(
    src: SiteConfig,
    dst: SiteConfig,
    spec: TransferSpec,
    link: LinkConfig = DEFAULT_LINK,
) -> SimResult:
    """Run one transfer task set to completion; returns makespan + throughput."""
    movers = spec.concurrency or min(src.movers, dst.movers)
    total_bytes = sum(spec.file_bytes)
    if total_bytes == 0:
        return SimResult(0.0, 0.0, 0, 0.0, 0.0)

    # ---- work items: (file, nbytes); chunked files are split by the planner.
    per_file: list[list[tuple[int, int]]] = []
    for f, size in enumerate(spec.file_bytes):
        if spec.chunk_bytes and size > spec.chunk_bytes:
            plan = plan_chunks(
                size, movers, chunk_bytes=spec.chunk_bytes,
                pipeline_depth=spec.pipeline_depth, min_chunk=1, max_chunk=size,
            )
            per_file.append([(f, c.length) for c in plan.chunks])
        else:
            per_file.append([(f, size)])
    # Globus drives files concurrently: interleave chunks round-robin across
    # files so movers spread over files instead of draining them in sequence.
    items: list[tuple[int, int]] = []
    idx = [0] * len(per_file)
    remaining = sum(len(p) for p in per_file)
    while remaining:
        for f, lst in enumerate(per_file):
            if idx[f] < len(lst):
                items.append(lst[idx[f]])
                idx[f] += 1
                remaining -= 1
    queue = list(reversed(items))  # pop() from the end == FIFO

    # Pipelining amortizes the control-channel turnaround (paper Fig. 3).
    setup_s = link.chunk_latency_s / max(1, spec.pipeline_depth)

    net_busy: list[_Stage | None] = [None] * movers
    hash_busy: list[_Stage | None] = [None] * movers
    hash_q: list[list[_Stage]] = [[] for _ in range(movers)]

    def pull(m: int):
        if queue and net_busy[m] is None:
            f, nb = queue.pop()
            net_busy[m] = _Stage("net", f, nb, setup_s, m)

    for m in range(movers):
        pull(m)

    clock = VirtualClock(guard=20 * len(items) + 1000, label="simulator")
    transfer_done = 0.0
    eps = 1e-12
    while True:
        stages = [s for s in net_busy if s] + [s for s in hash_busy if s]
        if not stages:
            break

        # ---- build resource graph over *flowing* stages (setup done)
        idx = {id(s): i for i, s in enumerate(stages)}
        flowing = [s for s in stages if s.setup_left <= eps]
        res: dict[str, tuple[float, list[int]]] = {}

        def add(name: str, cap_gbps: float, member: _Stage):
            cap = cap_gbps * Gb
            if name not in res:
                res[name] = (cap, [])
            res[name][1].append(idx[id(member)])

        for s in flowing:
            if s.kind == "net":
                add(f"mover_net:{s.mover}", min(src.mover_gbps, dst.mover_gbps), s)
                add("wan", link.wan_gbps, s)
                add("src_io", src.site_io_gbps, s)
                add("dst_io", dst.site_io_gbps, s)
                add(f"src_file:{s.file}", src.file_io_cap_gbps(spec.stripe_count), s)
                add(f"dst_file_w:{s.file}", dst.file_io_cap_gbps(spec.stripe_count, write=True), s)
            else:  # hash: dest-side re-read + checksum (paper §3.2)
                add(f"mover_hash:{s.mover}", dst.cksum_gbps, s)
                add("dst_io", dst.site_io_gbps, s)
                add(f"dst_file_r:{s.file}", dst.file_io_cap_gbps(spec.stripe_count), s)

        for s in stages:
            s.rate = 0.0
        if flowing:
            _maxmin_rates(stages, res)

        # ---- next event (clock enforces the guard + deadlock detection)
        cands = []
        for s in stages:
            if s.setup_left > eps:
                cands.append(s.setup_left)
            elif s.rate > eps:
                cands.append(s.bytes_left / s.rate)
        dt = clock.tick(*cands, floor=eps)

        # ---- advance
        for s in stages:
            if s.setup_left > eps:
                s.setup_left -= dt
            else:
                s.bytes_left -= s.rate * dt

        # ---- completions
        for m in range(movers):
            s = net_busy[m]
            if s and s.setup_left <= eps and s.bytes_left <= eps * max(1.0, s.rate):
                net_busy[m] = None
                transfer_done = clock.now
                if spec.integrity:
                    # dest re-reads + checksums the full item (paper §3.2)
                    hash_q[m].append(_Stage("hash", s.file, s.nbytes, 0.0, m))
                pull(m)
            h = hash_busy[m]
            if h and h.bytes_left <= eps * max(1.0, h.rate):
                hash_busy[m] = None
            if hash_busy[m] is None and hash_q[m]:
                hash_busy[m] = hash_q[m].pop(0)

    t_end = clock.now
    return SimResult(
        seconds=t_end,
        gbps=total_bytes / Gb / t_end if t_end > 0 else 0.0,
        n_items=len(items),
        transfer_done_s=transfer_done,
        checksum_tail_s=max(0.0, t_end - transfer_done),
    )


def predict_transfer_time(
    src: SiteConfig,
    dst: SiteConfig,
    total_bytes: int,
    *,
    n_files: int = 1,
    chunk_bytes: int | None,
    integrity: bool = True,
    stripe_count: int = 16,
    link: LinkConfig = DEFAULT_LINK,
) -> float:
    """Cost-model entry point used by ``chunker.plan_auto``."""
    per = total_bytes // n_files
    sizes = tuple([per] * (n_files - 1) + [total_bytes - per * (n_files - 1)])
    spec = TransferSpec(
        file_bytes=sizes, chunk_bytes=chunk_bytes,
        integrity=integrity, stripe_count=stripe_count,
    )
    return simulate_transfer(src, dst, spec, link).seconds
