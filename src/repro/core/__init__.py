"""Core of the paper's contribution: client-driven chunking of large transfers.

Submodules:
  chunker    — chunk planning heuristics (paper §3.1) + automated sizing (§6)
  integrity  — mergeable fingerprints replacing MD5 (paper §3.2, TPU-adapted)
  dataplane  — zero-copy buffer pool, single-pass streaming, and the
               decoupled integrity engine (checksum workers off the mover
               critical path — the paper's Fig. 4 overlap made structural)
  transfer   — host-side chunked transfer engine with chunk-level FT
  journal    — chunk-completion journal (partial restart)
  simulator  — calibrated model of the paper's ALCF/NERSC/OLCF testbed
  scheduler  — load-aware mover allocation across transfers
  vclock     — shared virtual clock + outage-window arithmetic for every
               event-stepped backend (simulator, testbed, fabric.virtual)
"""
from repro.core.chunker import Chunk, ChunkPlan, plan_auto, plan_chunks, plan_for_array
from repro.core.dataplane import (
    BufferPool,
    ChunkBuffer,
    IntegrityEngine,
    VerifyJob,
    read_back_into,
    read_into,
    stream_chunk,
)
from repro.core.integrity import (
    BASES,
    Digest,
    EMPTY_DIGEST,
    P,
    RunningFingerprint,
    combine_at_offsets,
    describe_mismatch,
    fingerprint_bytes,
    fingerprint_many,
    fingerprint_ndarray,
    fingerprint_rows,
    merge_all,
    verify,
)
from repro.core.journal import ChunkJournal, JournalRecord, replay_checked_lines
from repro.core.transfer import (
    BufferDest,
    BufferSource,
    ChunkedTransfer,
    EndpointOutage,
    FileDest,
    FileSource,
    IntegrityError,
    MoverCrash,
    QuarantineRecord,
    TransferReport,
    transfer_verified,
)
from repro.core.vclock import ConvergenceError, VirtualClock, Window

__all__ = [
    "Chunk", "ChunkPlan", "plan_auto", "plan_chunks", "plan_for_array",
    "BASES", "Digest", "EMPTY_DIGEST", "P", "RunningFingerprint",
    "combine_at_offsets",
    "describe_mismatch", "fingerprint_bytes", "fingerprint_many",
    "fingerprint_ndarray", "fingerprint_rows", "merge_all", "verify",
    "BufferPool", "ChunkBuffer", "IntegrityEngine", "VerifyJob",
    "read_into", "read_back_into", "stream_chunk",
    "ChunkJournal", "JournalRecord", "replay_checked_lines",
    "BufferDest", "BufferSource", "ChunkedTransfer", "EndpointOutage",
    "FileDest", "FileSource", "IntegrityError", "MoverCrash",
    "QuarantineRecord", "TransferReport", "transfer_verified",
    "ConvergenceError", "VirtualClock", "Window",
]
