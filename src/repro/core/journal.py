"""Chunk-completion journal — fault tolerance at chunk granularity.

"The implementation keeps track of which chunks have been transmitted
successfully so as to enable efficient partial restarts upon failures."
(paper §3.1). The journal is an append-only JSON-lines file; every record is
self-checksummed so torn writes (host crash mid-append) are detected on
replay.

Crash-consistency model: every record vouches for itself via its own
checksum, so replay keeps every verified record wherever it sits — damaged
lines in between (bit rot, or the legacy glued-line artifact of appending
onto a torn tail) are skipped without distrusting what follows. Only the
torn tail — the unverified bytes after the LAST verified record, i.e. a
crashed final append — is truncated away before the journal reopens for
appending, so a new record is never glued onto a half-written line.
"""
from __future__ import annotations

import dataclasses
import json
import os
import threading
from typing import IO

from repro.core.integrity import Digest, fingerprint_bytes


@dataclasses.dataclass(frozen=True)
class JournalRecord:
    chunk_index: int
    offset: int
    length: int
    digest_hex: str
    status: str = "done"     # "done" | "failed"

    def digest(self) -> Digest:
        return Digest.from_bytes(bytes.fromhex(self.digest_hex))


def _self_check(payload: str) -> str:
    return fingerprint_bytes(payload.encode()).hexdigest()[:16]


def checked_line(body: dict) -> str:
    """Serialise one self-checksummed JSONL record (no trailing newline).

    Shared by every append-log in the repo (chunk journal, task log, CAS
    chunk index) so compaction and replay agree on the byte format.
    """
    # Serialise the body once: embed the canonical (sort_keys) form directly
    # rather than dumping it a second time inside the wrapper. Replay parses
    # the line and re-canonicalises the body, so the bytes verify either way.
    canon = json.dumps(body, sort_keys=True)
    return '{"body": %s, "check": "%s"}' % (canon, _self_check(canon))


def replay_checked_lines(path: str, apply) -> tuple[bytes, int]:
    """Replay a self-checksummed JSONL file with crash-consistent repair.

    Calls ``apply(body)`` for each verified record, in order. Every record
    carries its own checksum, so each one vouches for itself independently:

    * a DAMAGED line (garbled JSON or failed self-check) is skipped, and
      replay continues — a later record that passes its self-check is
      genuine regardless of earlier damage. This also tolerates the legacy
      glued-line artifact (an appender that wrote a fresh record onto a torn
      partial line) without sacrificing anything that follows it;
    * the TORN TAIL — everything after the last verified record (a crashed
      final append, trailing garbage, or an unterminated line) — is excluded
      from the returned ``valid_end`` so callers may truncate it and new
      appends start on a clean line;
    * a SEMANTIC failure — ``apply`` raises on a record whose self-check
      passed (e.g. a record written by a newer code version) — stops further
      application, but the bytes are intact and stay inside ``valid_end``:
      truncating well-formed records over a schema mismatch would turn an
      upgrade/downgrade into data loss.

    Returns ``(raw_bytes, valid_end)`` where ``valid_end`` is the byte
    offset just past the last verified record. Shared by the chunk journal
    and the service task log (service.store).
    """
    with open(path, "rb") as fh:
        data = fh.read()
    pos = 0
    valid_end = 0
    applying = True
    while True:
        nl = data.find(b"\n", pos)
        if nl < 0:
            break                      # unterminated tail: torn final append
        line = data[pos:nl].strip()
        pos = nl + 1
        if not line:
            continue
        try:
            obj = json.loads(line.decode("utf-8"))
            body = obj["body"]
            verified = obj["check"] == _self_check(json.dumps(body, sort_keys=True))
        except Exception:              # noqa: BLE001 — damaged line
            verified = False
        if not verified:
            continue                   # skip: later records vouch for themselves
        valid_end = pos
        if applying:
            try:
                apply(body)
            except Exception:          # noqa: BLE001 — semantic: stop applying
                applying = False
    return data, valid_end


class ChunkJournal:
    """Append-only, crash-tolerant record of per-chunk completion."""

    def __init__(self, path: str | os.PathLike):
        self.path = str(path)
        self._fh: IO[str] | None = None
        # appends must serialize: concurrent movers writing through one text
        # handle could interleave two records into one garbled line, and the
        # stop-at-first-damage replay would (correctly) distrust everything
        # after it — losing valid fsync'd records.
        self._append_lock = threading.Lock()
        self.records: dict[int, JournalRecord] = {}
        self.torn_tail_bytes = 0     # bytes dropped from a crashed append
        if os.path.exists(self.path):
            self._replay()
        self._fh = open(self.path, "a", encoding="utf-8")

    # ------------------------------------------------------------------
    def _replay(self) -> None:
        data, valid_end = replay_checked_lines(self.path, self._apply)
        self.torn_tail_bytes = len(data) - valid_end
        if self.torn_tail_bytes:
            # repair: drop the torn tail so the next append starts on a
            # clean line instead of gluing onto the half-written record
            with open(self.path, "r+b") as fh:
                fh.truncate(valid_end)

    def _apply(self, body: dict) -> None:
        rec = JournalRecord(**body)
        if rec.status == "done":
            self.records[rec.chunk_index] = rec
        else:
            self.records.pop(rec.chunk_index, None)

    def append(self, rec: JournalRecord) -> None:
        line = checked_line(dataclasses.asdict(rec))
        with self._append_lock:
            assert self._fh is not None
            self._fh.write(line + "\n")
            self._fh.flush()
            os.fsync(self._fh.fileno())
            if rec.status == "done":
                self.records[rec.chunk_index] = rec
            else:
                self.records.pop(rec.chunk_index, None)

    def compact(self) -> dict:
        """Rewrite the log to live records only; atomic replace.

        Journals grow without bound across repeated saves: every "failed"
        record and every superseded append stays on disk forever. Compaction
        rewrites the current live-record set (sorted by chunk id) into a
        temp file, fsyncs it, and atomically renames it over the log, then
        reopens the append handle — a crash at any point leaves either the
        old log or the complete new one, never a mix. Returns
        ``{"records", "bytes_before", "bytes_after"}``.
        """
        with self._append_lock:
            before = os.path.getsize(self.path) if os.path.exists(self.path) else 0
            tmp = self.path + ".compact.tmp"
            with open(tmp, "w", encoding="utf-8") as fh:
                for idx in sorted(self.records):
                    fh.write(checked_line(dataclasses.asdict(self.records[idx])) + "\n")
                fh.flush()
                os.fsync(fh.fileno())
            if self._fh is not None:
                self._fh.close()
            os.replace(tmp, self.path)
            self._fh = open(self.path, "a", encoding="utf-8")
            self.torn_tail_bytes = 0
            after = os.path.getsize(self.path)
        return {"records": len(self.records), "bytes_before": before,
                "bytes_after": after}

    # ------------------------------------------------------------------
    def completed(self) -> set[int]:
        return set(self.records)

    def is_complete(self, n_chunks: int) -> bool:
        return len(self.records) == n_chunks and set(self.records) == set(range(n_chunks))

    def close(self) -> None:
        if self._fh is not None:
            self._fh.close()
            self._fh = None

    def __enter__(self) -> "ChunkJournal":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
