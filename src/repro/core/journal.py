"""Chunk-completion journal — fault tolerance at chunk granularity.

"The implementation keeps track of which chunks have been transmitted
successfully so as to enable efficient partial restarts upon failures."
(paper §3.1). The journal is an append-only JSON-lines file; every record is
self-checksummed so torn writes (host crash mid-append) are detected and
dropped on replay rather than corrupting recovery.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import IO

from repro.core.integrity import Digest, fingerprint_bytes


@dataclasses.dataclass(frozen=True)
class JournalRecord:
    chunk_index: int
    offset: int
    length: int
    digest_hex: str
    status: str = "done"     # "done" | "failed"

    def digest(self) -> Digest:
        return Digest.from_bytes(bytes.fromhex(self.digest_hex))


def _self_check(payload: str) -> str:
    return fingerprint_bytes(payload.encode()).hexdigest()[:16]


class ChunkJournal:
    """Append-only, crash-tolerant record of per-chunk completion."""

    def __init__(self, path: str | os.PathLike):
        self.path = str(path)
        self._fh: IO[str] | None = None
        self.records: dict[int, JournalRecord] = {}
        if os.path.exists(self.path):
            self._replay()
        self._fh = open(self.path, "a", encoding="utf-8")

    # ------------------------------------------------------------------
    def _replay(self) -> None:
        with open(self.path, "r", encoding="utf-8") as fh:
            for line in fh:
                line = line.strip()
                if not line:
                    continue
                try:
                    obj = json.loads(line)
                    body = obj["body"]
                    if obj["check"] != _self_check(json.dumps(body, sort_keys=True)):
                        continue  # torn/corrupt record: ignore
                    rec = JournalRecord(**body)
                except (json.JSONDecodeError, KeyError, TypeError):
                    continue      # truncated tail line: ignore
                if rec.status == "done":
                    self.records[rec.chunk_index] = rec
                else:
                    self.records.pop(rec.chunk_index, None)

    def append(self, rec: JournalRecord) -> None:
        assert self._fh is not None
        body = dataclasses.asdict(rec)
        line = json.dumps(
            {"body": body, "check": _self_check(json.dumps(body, sort_keys=True))}
        )
        self._fh.write(line + "\n")
        self._fh.flush()
        os.fsync(self._fh.fileno())
        if rec.status == "done":
            self.records[rec.chunk_index] = rec
        else:
            self.records.pop(rec.chunk_index, None)

    # ------------------------------------------------------------------
    def completed(self) -> set[int]:
        return set(self.records)

    def is_complete(self, n_chunks: int) -> bool:
        return len(self.records) == n_chunks and set(self.records) == set(range(n_chunks))

    def close(self) -> None:
        if self._fh is not None:
            self._fh.close()
            self._fh = None

    def __enter__(self) -> "ChunkJournal":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
