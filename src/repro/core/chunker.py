"""Client-driven chunk planning (paper §3.1).

The Globus service — the *client* in client-driven chunking — knows the
configuration of both endpoints (number of data movers, pipeline depth,
link characteristics) and can therefore plan chunking globally, which the
older server-side striping (SPAS/SPOR) could not. In this framework the
"client" is the launcher/compiler: it holds the whole mesh/topology and emits
a static chunk plan.

The paper's empirical guidance encoded here:

  * enough chunks to saturate every parallel channel: the paper explains the
    large-chunk falloff by `n_chunks < concurrency x parallelism (64 x 4 = 256)`
    (§4.2) — so we target n_chunks >= movers * pipeline_depth;
  * chunks must not be too small, or per-chunk (control channel / pipelining)
    overheads dominate — the 50 MB side of the Fig. 6 curve;
  * the sweet spot measured was 200-500 MB for 64 movers over a 100 Gb/s WAN
    (§4.3): defaults below reproduce that via the simulator;
  * chunk boundaries are aligned so partial checksums and partial restarts
    compose (alignment also keeps device chunk slices on tile boundaries).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Sequence

MiB = 1024 * 1024
GiB = 1024 * MiB


@dataclasses.dataclass(frozen=True)
class Chunk:
    """One disjoint byte range of a transfer, assigned to a mover."""

    index: int
    offset: int
    length: int
    mover: int

    @property
    def end(self) -> int:
        return self.offset + self.length


@dataclasses.dataclass(frozen=True)
class ChunkPlan:
    total_bytes: int
    chunk_bytes: int           # nominal size (last chunk may be short)
    movers: int
    pipeline_depth: int
    chunks: tuple[Chunk, ...]

    @property
    def n_chunks(self) -> int:
        return len(self.chunks)

    def for_mover(self, mover: int) -> tuple[Chunk, ...]:
        return tuple(c for c in self.chunks if c.mover == mover)

    def validate(self) -> None:
        """Invariants: disjoint, in-order, exact coverage (property-tested)."""
        pos = 0
        for i, c in enumerate(self.chunks):
            if c.index != i:
                raise AssertionError(f"chunk {i} has index {c.index}")
            if c.offset != pos or c.length <= 0:
                raise AssertionError(f"coverage broken at chunk {i}: offset={c.offset} pos={pos}")
            if not (0 <= c.mover < self.movers):
                raise AssertionError(f"chunk {i} assigned to invalid mover {c.mover}")
            pos = c.end
        if pos != self.total_bytes:
            raise AssertionError(f"chunks cover {pos} != total {self.total_bytes}")


def plan_chunks(
    total_bytes: int,
    movers: int,
    *,
    chunk_bytes: int | None = None,
    pipeline_depth: int = 4,
    min_chunk: int = 16 * MiB,
    max_chunk: int = 512 * MiB,
    alignment: int = 4,
    max_chunks: int = 1 << 20,
) -> ChunkPlan:
    """Plan chunks for one transfer using the paper's heuristic.

    With ``chunk_bytes=None`` the size is derived: split so every mover gets
    ~``pipeline_depth`` chunks (keeps pipelining busy, §3.1/Fig. 3), clamped to
    [min_chunk, max_chunk] (Fig. 6 sweet spot). A transfer smaller than
    ``min_chunk * 2`` is not chunked at all — mirroring the paper's finding
    that chunking only pays for large files (§4.5).
    """
    if total_bytes < 0:
        raise ValueError("total_bytes must be >= 0")
    if movers < 1:
        raise ValueError("movers must be >= 1")
    if alignment < 1:
        raise ValueError("alignment must be >= 1")
    if total_bytes == 0:
        return ChunkPlan(0, 0, movers, pipeline_depth, ())

    if chunk_bytes is None:
        target = total_bytes / (movers * pipeline_depth)
        chunk_bytes = int(min(max(target, min_chunk), max_chunk))
        if total_bytes < 2 * min_chunk:
            chunk_bytes = total_bytes  # too small to chunk
    chunk_bytes = max(alignment, _round_up(min(chunk_bytes, total_bytes), alignment))
    # chunk-count ceiling: control-plane state (journal, queue) stays bounded
    # regardless of requested size — the Globus-service-side scalability guard.
    if math.ceil(total_bytes / chunk_bytes) > max_chunks:
        chunk_bytes = _round_up(math.ceil(total_bytes / max_chunks), alignment)

    n = math.ceil(total_bytes / chunk_bytes)
    chunks = []
    pos = 0
    for i in range(n):
        ln = min(chunk_bytes, total_bytes - pos)
        # Round-robin assignment; the transfer engine additionally work-steals,
        # so static assignment only seeds locality (paper movers pull chunks).
        chunks.append(Chunk(index=i, offset=pos, length=ln, mover=i % movers))
        pos += ln
    plan = ChunkPlan(total_bytes, chunk_bytes, movers, pipeline_depth, tuple(chunks))
    plan.validate()
    return plan


def _round_up(x: int, align: int) -> int:
    return ((x + align - 1) // align) * align


# ---------------------------------------------------------------------------
# byte-region algebra — the substrate of mid-flight tail re-planning
# ---------------------------------------------------------------------------
# A "region" is an (offset, length) byte range. The autotuner re-partitions
# the UNTRANSFERRED tail of a transfer by (1) subtracting journaled custody
# regions from the file, then (2) carving fresh chunks out of the gaps — so a
# re-plan can only ever cut at un-journaled boundaries, and the merge-law
# digest chain over the final chunk set still tiles the file exactly.

def merge_regions(regions: Sequence[tuple[int, int]]) -> list[tuple[int, int]]:
    """Sort and coalesce disjoint (offset, length) regions; adjacency merges,
    overlap is a caller bug and raises."""
    out: list[list[int]] = []
    for off, ln in sorted((int(o), int(n)) for o, n in regions):
        if ln < 0:
            raise ValueError(f"negative region length {ln} at offset {off}")
        if ln == 0:
            continue
        if out and off < out[-1][0] + out[-1][1]:
            raise ValueError(
                f"overlapping regions at byte {off} (previous ends at "
                f"{out[-1][0] + out[-1][1]})"
            )
        if out and off == out[-1][0] + out[-1][1]:
            out[-1][1] += ln
        else:
            out.append([off, ln])
    return [(o, n) for o, n in out]


def subtract_regions(
    total_bytes: int, covered: Sequence[tuple[int, int]]
) -> list[tuple[int, int]]:
    """The gaps of [0, total_bytes) not covered by ``covered`` regions."""
    gaps: list[tuple[int, int]] = []
    pos = 0
    for off, ln in merge_regions(covered):
        if off + ln > total_bytes:
            raise ValueError(f"region ({off}, {ln}) exceeds total {total_bytes}")
        if off > pos:
            gaps.append((pos, off - pos))
        pos = off + ln
    if pos < total_bytes:
        gaps.append((pos, total_bytes - pos))
    return gaps


def partition_regions(
    regions: Sequence[tuple[int, int]],
    chunk_bytes: int,
    *,
    start_index: int = 0,
    movers: int = 1,
    alignment: int = 1,
) -> list[Chunk]:
    """Carve ~``chunk_bytes`` chunks out of disjoint byte regions.

    This is the tail re-plan primitive: indices run sequentially from
    ``start_index`` (the caller allocates a band that cannot collide with
    journaled ids), interior cut points land on ``alignment`` multiples
    relative to each region's start, and region boundaries themselves are
    never moved — a journaled chunk's bytes are untouchable by construction
    because they are simply not in ``regions``.
    """
    if chunk_bytes < 1:
        raise ValueError("chunk_bytes must be >= 1")
    if alignment < 1:
        raise ValueError("alignment must be >= 1")
    chunk_bytes = max(alignment, _round_up(chunk_bytes, alignment))
    chunks: list[Chunk] = []
    i = start_index
    for off, ln in merge_regions(regions):
        pos = off
        end = off + ln
        while pos < end:
            take = min(chunk_bytes, end - pos)
            chunks.append(Chunk(index=i, offset=pos, length=take,
                                mover=(i - start_index) % max(1, movers)))
            pos += take
            i += 1
    return chunks


# ---------------------------------------------------------------------------
# intra-chunk striping — split one chunk across N concurrent movers
# ---------------------------------------------------------------------------
# The paper's headline numbers come from concurrency x parallelism streams
# (64 x 4, §4.2); a single huge chunk on one mover is exactly the
# single-stream ceiling the Petascale DTN Project measured. A StripePlan
# splits one chunk's byte range into N disjoint stripes so N movers (each one
# "stream") carry it concurrently. Because the merge-law digest algebra is
# partition-refinement-closed, per-stripe digests fold into the chunk digest
# with combine_at_offsets — no extra hashing pass.

@dataclasses.dataclass(frozen=True)
class Stripe:
    """One disjoint byte sub-range of a parent chunk."""

    seq: int          # 0..n_stripes-1 within the parent
    offset: int       # absolute file offset
    length: int

    @property
    def end(self) -> int:
        return self.offset + self.length


@dataclasses.dataclass(frozen=True)
class StripePlan:
    chunk: Chunk
    stripes: tuple[Stripe, ...]

    @property
    def n_stripes(self) -> int:
        return len(self.stripes)

    def validate(self) -> None:
        """Invariants: stripes tile the parent chunk exactly, in order."""
        pos = self.chunk.offset
        for i, s in enumerate(self.stripes):
            if s.seq != i:
                raise AssertionError(f"stripe {i} has seq {s.seq}")
            if s.offset != pos or s.length <= 0:
                raise AssertionError(
                    f"stripe coverage broken at {i}: offset={s.offset} pos={pos}")
            pos = s.end
        if pos != self.chunk.end:
            raise AssertionError(
                f"stripes cover up to {pos} != chunk end {self.chunk.end}")


def plan_stripes(
    chunk: Chunk,
    stripes: int,
    *,
    stripe_min_bytes: int = 1 * MiB,
    alignment: int = 1,
) -> StripePlan:
    """Split ``chunk`` into up to ``stripes`` disjoint sub-ranges.

    The effective stripe count is capped so every stripe carries at least
    ``stripe_min_bytes`` (striping tiny chunks only adds per-item overhead —
    the same reasoning as the 50 MB side of the Fig. 6 curve, one level
    down). Interior cut points land on ``alignment`` multiples relative to
    the chunk start so partial checksums and device slices stay composable.
    A plan with one stripe is valid and means "do not stripe".
    """
    if stripes < 1:
        raise ValueError("stripes must be >= 1")
    if stripe_min_bytes < 1:
        raise ValueError("stripe_min_bytes must be >= 1")
    if alignment < 1:
        raise ValueError("alignment must be >= 1")
    n = min(stripes, chunk.length // stripe_min_bytes)
    n = max(1, n)
    # Even split, rounded up to alignment; the last stripe absorbs the tail.
    width = _round_up(math.ceil(chunk.length / n), alignment)
    out: list[Stripe] = []
    pos = chunk.offset
    seq = 0
    while pos < chunk.end:
        take = min(width, chunk.end - pos)
        out.append(Stripe(seq=seq, offset=pos, length=take))
        pos += take
        seq += 1
    plan = StripePlan(chunk=chunk, stripes=tuple(out))
    plan.validate()
    return plan


def plan_auto(
    total_bytes: int,
    movers: int,
    cost_model: Callable[[int], float],
    *,
    candidates: Sequence[int] = (
        16 * MiB, 50 * MiB, 100 * MiB, 200 * MiB, 500 * MiB, 1000 * MiB,
        2000 * MiB, 5000 * MiB,
    ),
    pipeline_depth: int = 4,
    alignment: int = 4,
) -> ChunkPlan:
    """Automated chunk-size selection (the paper's §6 'further optimization').

    ``cost_model(chunk_bytes) -> predicted_seconds`` is typically
    ``simulator.predict_transfer_time`` — the same calibrated model used to
    reproduce the paper's figures — evaluated per candidate size.
    """
    if total_bytes <= 0:
        return plan_chunks(total_bytes, movers, pipeline_depth=pipeline_depth)
    best, best_t = None, float("inf")
    for s in candidates:
        if s > total_bytes:
            continue
        t = cost_model(s)
        if t < best_t:
            best, best_t = s, t
    if best is None:
        best = total_bytes
    return plan_chunks(
        total_bytes, movers, chunk_bytes=best,
        pipeline_depth=pipeline_depth, alignment=alignment,
        min_chunk=1, max_chunk=total_bytes,
    )


def plan_for_array(
    shape: Sequence[int],
    dtype_bytes: int,
    movers: int,
    *,
    pipeline_depth: int = 4,
    min_chunk: int = 4 * MiB,
    max_chunk: int = 256 * MiB,
) -> ChunkPlan:
    """Chunk a tensor's byte image; boundaries stay element-aligned so device
    slices, host writes, and per-chunk digests all cut at the same offsets."""
    total = int(math.prod(shape)) * dtype_bytes
    return plan_chunks(
        total, movers, pipeline_depth=pipeline_depth,
        min_chunk=min_chunk, max_chunk=max_chunk, alignment=dtype_bytes,
    )
