"""Seeded-jitter retry backoff — the shared de-correlation policy.

Every retry loop in the repo used to compute its own delay inline, and all
of them were the same two unjittered formulas::

    time.sleep(base * (2 ** (attempt - 1)))      # generic I/O retries
    time.sleep(base * min(attempt, 8))           # outage waits

Unjittered backoff synchronizes: when an endpoint outage rejects a burst of
operations, every mover that was hit computes the *same* delay and the whole
pool re-arrives as one retry storm — exactly the thundering herd a
recovering endpoint cannot absorb ("Reexamining Paradigms of End-to-End
Data Movement": recovery behaviour in the first minutes after a fault is
where transfers are won or lost). ``Backoff`` keeps the familiar shapes
(exponential with a capped exponent, linear with a capped multiplier) but
multiplies each delay by a per-``(seed, lane, attempt)`` jitter factor drawn
through SHA-256 — NOT the process-salted ``hash`` and NOT shared RNG state —
so:

  * two movers (distinct ``lane``) retrying the same attempt number get
    *different* delays — their retry instants de-correlate;
  * the same ``(seed, lane, attempt)`` always gets the *same* delay — a
    failing run replays bit-for-bit, and tests can assert exact schedules;
  * jitter only ever shortens the delay (factor in ``[1 - jitter, 1]``), so
    no caller's worst-case timeout budget grows.
"""
from __future__ import annotations

import dataclasses
import hashlib
import time


def jitter_u(*parts) -> float:
    """Deterministic uniform in [0, 1) keyed by ``parts`` (SHA-256, not the
    process-salted ``hash``)."""
    blob = "|".join(repr(p) for p in parts).encode()
    n = int.from_bytes(hashlib.sha256(blob).digest()[:8], "big")
    return n / float(1 << 64)


@dataclasses.dataclass(frozen=True)
class Backoff:
    """One lane's deterministic retry-delay schedule.

    ``mode="exp"``: ``base_s * factor ** min(attempt - 1, cap_exp)``;
    ``mode="linear"``: ``base_s * min(attempt, cap_mult)`` (the outage-wait
    shape — outages heal on their own clock, so the wait grows gently).
    Either shape is then scaled by the seeded jitter factor. ``attempt``
    starts at 1 (the first retry).
    """

    base_s: float
    mode: str = "exp"                # "exp" | "linear"
    factor: float = 2.0
    cap_exp: int = 6                 # exp: exponent ceiling
    cap_mult: int = 8                # linear: multiplier ceiling
    jitter: float = 0.5              # delay scaled into [1 - jitter, 1]
    seed: int = 0
    lane: str = ""                   # the de-correlation key (mover/hop id)

    def __post_init__(self):
        if self.mode not in ("exp", "linear"):
            raise ValueError(f"unknown backoff mode {self.mode!r}")
        if not (0.0 <= self.jitter < 1.0):
            raise ValueError("jitter must be in [0, 1)")

    def delay(self, attempt: int) -> float:
        """The delay before retry ``attempt`` (>= 1), jittered, in seconds."""
        if attempt < 1:
            raise ValueError("attempt numbering starts at 1")
        if self.mode == "exp":
            d = self.base_s * self.factor ** min(attempt - 1, self.cap_exp)
        else:
            d = self.base_s * min(attempt, self.cap_mult)
        u = jitter_u(self.seed, self.lane, self.mode, attempt)
        return d * (1.0 - self.jitter * u)

    def sleep(self, attempt: int, *, sleep=time.sleep) -> float:
        """Sleep the jittered delay; returns the seconds slept."""
        d = self.delay(attempt)
        if d > 0.0:
            sleep(d)
        return d
