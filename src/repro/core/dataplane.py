"""Zero-copy pipelined data plane — buffers, streaming, and the integrity engine.

The paper's central overlap claim (§3.2, Fig. 4) is that per-chunk integrity
checking must run *concurrently* with data movement, not serialized behind
it. This module is the host-side machinery that makes that true:

  * **BufferPool / ChunkBuffer** — reusable chunk-sized buffers handed out as
    exact-length ``memoryview`` handles, so source read, fingerprint, and
    destination write all touch ONE allocation with zero intermediate
    ``bytes()`` copies. Buffers cycle back to the pool the moment the write
    lands; verification reads back into a *different* pooled buffer, so a
    chunk never pins two buffers at once.
  * **read_into / read_back_into** — zero-copy endpoint adapters: they use an
    endpoint's native ``read_into``/``read_back_into`` (``os.preadv`` on
    files, slice assignment on memory) when present and fall back to the
    classic ``read()``/``read_back()`` + copy otherwise, so chaos wrappers
    and third-party endpoints keep working unchanged.
  * **stream_chunk** — the single-pass move: the chunk streams source->dest
    in ``granule``-byte sub-reads and the source fingerprint accumulates via
    the merge law *while each granule is cache-hot*, eliminating the separate
    full digest pass the serial engine pays.
  * **IntegrityEngine** — the decoupled checksum worker pool. Movers enqueue
    a ``VerifyJob`` (coordinates + expected digest) the moment a chunk's
    write lands and immediately pull the next chunk; integrity workers drain
    the digest queue concurrently — read-back, fingerprint, verdict — and
    fire the caller's callbacks. The custody rule lives in the callbacks: a
    chunk's journal record commits only in ``on_verified``, so a crash with
    verification lagging N chunks behind movement re-moves exactly those N
    unverified chunks and nothing else.
"""
from __future__ import annotations

import dataclasses
import os
import queue
import threading
import time
from typing import Any, Callable

import numpy as np

from repro.core.integrity import (
    Digest,
    RunningFingerprint,
    fingerprint_bytes,
    fingerprint_many,
    verify,
)
from repro.obs import metrics as _metrics
from repro.obs.trace import NULL as _NULL_TRACER

MiB = 1024 * 1024
DEFAULT_STREAM_GRANULE = 1 * MiB


# ---------------------------------------------------------------------------
# buffer pool
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class PoolStats:
    """Reuse accounting (surfaced by benchmarks/overlap.py)."""

    acquires: int = 0
    reuses: int = 0            # served from the free list (no allocation)
    allocations: int = 0       # fresh pooled buffers created
    oversize: int = 0          # requests larger than the pool's buffer size


class ChunkBuffer:
    """One pooled buffer lease: an exact-length writable ``memoryview``.

    ``view`` is the only handle movers/verifiers should touch; ``release()``
    returns the backing buffer to the pool (idempotent — double release is a
    no-op, and the view must not be used afterwards).
    """

    __slots__ = ("view", "_pool", "_raw")

    def __init__(self, pool: "BufferPool | None", raw: bytearray, length: int):
        self._pool = pool
        self._raw = raw
        self.view = memoryview(raw)[:length]

    def release(self) -> None:
        raw, self._raw = self._raw, None
        if raw is None:
            return
        self.view.release()
        self.view = None  # type: ignore[assignment]
        if self._pool is not None:
            self._pool._put_back(raw)

    def __enter__(self) -> "ChunkBuffer":
        return self

    def __exit__(self, *exc) -> None:
        self.release()


class BufferPool:
    """Thread-safe pool of ``buffer_bytes``-sized reusable buffers.

    ``capacity`` bounds how many idle buffers are retained; extra releases
    drop their buffer (GC'd) so a transient burst cannot pin memory forever.
    Requests larger than ``buffer_bytes`` (re-planned jumbo tails) get an
    exact-size one-shot allocation that is never pooled.
    """

    def __init__(self, buffer_bytes: int, *, capacity: int = 8):
        if buffer_bytes < 1:
            raise ValueError("buffer_bytes must be >= 1")
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.buffer_bytes = int(buffer_bytes)
        self.capacity = int(capacity)
        self._free: list[bytearray] = []
        self._lock = threading.Lock()
        self.stats = PoolStats()

    def acquire(self, length: int) -> ChunkBuffer:
        if length < 0:
            # a negative length would silently lease a truncated python-slice
            # view — surface the caller bug instead of corrupting a landing
            raise ValueError(f"acquire length must be >= 0, got {length}")
        if length > self.buffer_bytes:
            with self._lock:
                self.stats.acquires += 1
                self.stats.oversize += 1
            return ChunkBuffer(None, bytearray(length), length)
        with self._lock:
            self.stats.acquires += 1
            if self._free:
                self.stats.reuses += 1
                raw = self._free.pop()
            else:
                self.stats.allocations += 1
                raw = bytearray(self.buffer_bytes)
        return ChunkBuffer(self, raw, length)

    def _put_back(self, raw: bytearray) -> None:
        with self._lock:
            if len(self._free) < self.capacity:
                self._free.append(raw)


# ---------------------------------------------------------------------------
# zero-copy endpoint adapters
# ---------------------------------------------------------------------------
def read_into(source: Any, offset: int, view: memoryview) -> None:
    """Read ``len(view)`` bytes at ``offset`` from ``source`` into ``view``.

    Zero-copy when the source implements ``read_into``; otherwise falls back
    to ``read()`` + one copy (chaos wrappers, legacy endpoints). Short reads
    raise ``IOError`` either way, matching the engine's retry taxonomy.
    """
    n = len(view)
    fn = getattr(source, "read_into", None)
    if fn is not None:
        got = fn(offset, view)
        if got != n:
            raise IOError(f"short read at {offset}: {got}/{n}")
        return
    data = source.read(offset, n)
    if len(data) != n:
        raise IOError(f"short read at {offset}: {len(data)}/{n}")
    view[:] = data


def read_back_into(dest: Any, offset: int, view: memoryview) -> None:
    """Verification read: like ``read_into`` but against a destination."""
    n = len(view)
    fn = getattr(dest, "read_back_into", None)
    if fn is not None:
        got = fn(offset, view)
        if got != n:
            raise IOError(f"short read-back at {offset}: {got}/{n}")
        return
    data = dest.read_back(offset, n)
    if len(data) != n:
        raise IOError(f"short read-back at {offset}: {len(data)}/{n}")
    view[:] = data


def read_into_vec(source: Any, offset: int, views: list[memoryview]) -> None:
    """Vectored read: fill consecutive ``views`` starting at ``offset``.

    One ``os.preadv``-style syscall when the source implements ``readv_into``
    (file endpoints), else a per-view ``read_into`` loop — the same graceful
    degradation as the scalar adapters, so chaos wrappers and third-party
    endpoints keep working unchanged.
    """
    fn = getattr(source, "readv_into", None)
    if fn is not None:
        total = sum(len(v) for v in views)
        got = fn(offset, views)
        if got != total:
            raise IOError(f"short vectored read at {offset}: {got}/{total}")
        return
    pos = offset
    for v in views:
        read_into(source, pos, v)
        pos += len(v)


def write_vec(dest: Any, offset: int, views: list[memoryview]) -> None:
    """Vectored write: land consecutive ``views`` starting at ``offset`` via
    one ``os.pwritev``-style syscall when the destination implements
    ``writev``, else a per-view ``write`` loop."""
    fn = getattr(dest, "writev", None)
    if fn is not None:
        total = sum(len(v) for v in views)
        got = fn(offset, views)
        if got != total:
            raise IOError(f"short vectored write at {offset}: {got}/{total}")
        return
    pos = offset
    for v in views:
        dest.write(pos, v)
        pos += len(v)


def fingerprint_view(mv: memoryview, granule: int = DEFAULT_STREAM_GRANULE) -> Digest:
    """Digest a buffer in cache-sized granule steps (merge law).

    One monolithic ``fingerprint_bytes`` over a large chunk streams its
    float64 conversion scratch through memory; granule-sized batches keep
    the working set cache-resident and run measurably faster. This is the
    read-back path's mirror of ``stream_chunk``'s granule digesting.
    """
    n = len(mv)
    if n <= granule:
        return fingerprint_bytes(mv)
    rf = RunningFingerprint()
    for pos in range(0, n, granule):
        rf.update(mv[pos : pos + granule])
    return rf.digest()


def read_back_fingerprint(
    dest: Any,
    offset: int,
    length: int,
    *,
    pool: "BufferPool | None" = None,
    granule: int = DEFAULT_STREAM_GRANULE,
) -> Digest:
    """Fingerprint the landed bytes, cheapest path first: in place via the
    destination's zero-copy ``read_back_view`` when it has one, else into a
    pooled buffer, else through the classic ``read_back()`` bytes. Shared by
    the integrity engine and the single-pass inline verifier."""
    viewfn = getattr(dest, "read_back_view", None)
    if viewfn is not None:
        mv = viewfn(offset, length)
        try:
            return fingerprint_view(mv, granule)
        finally:
            if isinstance(mv, memoryview):
                mv.release()
    if pool is not None:
        with pool.acquire(length) as buf:
            read_back_into(dest, offset, buf.view)
            return fingerprint_view(buf.view, granule)
    back = dest.read_back(offset, length)
    return fingerprint_view(memoryview(back), granule)


def stream_chunk(
    source: Any,
    dest: Any,
    offset: int,
    length: int,
    *,
    pool: BufferPool,
    granule: int = DEFAULT_STREAM_GRANULE,
    digest: bool = True,
    iov_batch: int = 1,
) -> tuple[Digest | None, float]:
    """Single-pass chunk move: stream source->dest in granules, fingerprinting
    each granule while it is cache-hot from the read that produced it.

    Returns ``(source_digest, cksum_seconds)`` where ``cksum_seconds`` is the
    time spent inside fingerprint math only — the copy itself is mover time.
    The destination sees the same disjoint-offset writes a whole-chunk move
    would produce (granule writes are idempotent re-writes on retry).

    ``digest=False`` skips the fingerprint and returns ``(None, 0.0)`` when
    the source supports stable zero-copy views — the pipelined engine's
    checksum workers re-derive the source digest from the SAME view off the
    mover path (the paper's "source fingerprinting runs concurrently with
    subsequent chunk moves"). Sources without views always digest here: the
    streamed bytes are not reachable afterwards.

    ``iov_batch > 1`` batches that many consecutive granules into ONE vectored
    read and ONE vectored write (``os.preadv``/``os.pwritev`` on file
    endpoints): the syscall count per chunk drops by the batch factor while
    the per-granule cache-hot fingerprinting is unchanged — the stripe movers'
    default, since striping multiplies the number of in-flight sub-ranges.
    """
    granule = max(1, int(granule))
    iov_batch = max(1, int(iov_batch))
    rf = RunningFingerprint()
    ck_s = 0.0
    pos = offset
    end = offset + length
    viewfn = getattr(source, "read_view", None)
    if viewfn is not None:
        # fully zero-copy: digest and write straight out of the source image
        while pos < end:
            take = min(granule * iov_batch, end - pos)
            mv = viewfn(pos, take)
            if len(mv) != take:
                raise IOError(f"short read at {pos}: {len(mv)}/{take}")
            if digest:
                t0 = time.perf_counter()
                for g in range(0, take, granule):
                    rf.update(mv[g : g + granule])
                ck_s += time.perf_counter() - t0
            if iov_batch > 1:
                write_vec(dest, pos, [mv[g : g + granule]
                                      for g in range(0, take, granule)])
            else:
                dest.write(pos, mv)
            pos += take
        return (rf.digest() if digest else None), ck_s
    span = min(granule * iov_batch, length) if length else 0
    buf = pool.acquire(span)
    try:
        while pos < end:
            take = min(span, end - pos)
            views = [buf.view[g : min(g + granule, take)]
                     for g in range(0, take, granule)]
            if len(views) == 1:
                read_into(source, pos, views[0])
            else:
                read_into_vec(source, pos, views)
            for v in views:
                t0 = time.perf_counter()
                rf.update(v)
                ck_s += time.perf_counter() - t0
            if len(views) == 1:
                dest.write(pos, views[0])
            else:
                write_vec(dest, pos, views)
            pos += take
    finally:
        buf.release()
    return rf.digest(), ck_s


def _digest_rows_pallas(rows: list["np.ndarray"]) -> list[Digest]:
    """Batched digests with the accelerator in the loop: equal-length groups
    whose byte length tiles the checksum kernel grid go through ONE
    ``checksum_many_words`` dispatch per group; everything else (ragged
    leftovers, non-tile lengths) falls back to the host GEMM stack. Imports
    lazily so host-only deployments never pay the jax import."""
    from repro.kernels import checksum as _ck
    import jax.numpy as jnp

    out: list[Digest | None] = [None] * len(rows)
    groups: dict[int, list[int]] = {}
    for i, r in enumerate(rows):
        groups.setdefault(int(r.size), []).append(i)
    host_idx: list[int] = []
    for n, idxs in groups.items():
        if n > 0 and n % _ck.TILE_BYTES == 0:
            mat = np.stack([rows[i] for i in idxs]).view(np.int32)
            res = np.asarray(_ck.checksum_many_words(jnp.asarray(mat)))
            for row_j, i in enumerate(idxs):
                out[i] = Digest(tuple(int(v) for v in res[row_j]), n)
        else:
            host_idx.extend(idxs)
    if host_idx:
        digs = fingerprint_many([rows[i] for i in host_idx])
        for i, d in zip(host_idx, digs):
            out[i] = d
    return out                                        # type: ignore[return-value]


# ---------------------------------------------------------------------------
# the decoupled integrity engine
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class VerifyJob:
    """One deferred verification, enqueued by a mover.

    ``key`` is the caller's chunk identity (opaque to the engine), ``dest``
    the endpoint to read back from, ``expected`` the source digest taken
    during streaming. ``expected=None`` defers the SOURCE fingerprint too:
    the worker re-derives it from ``source``'s stable zero-copy view before
    verifying — movers on view-capable sources are pure wire. ``payload``
    rides along to the callbacks (the engine's callers stash their
    outcome/telemetry object there).
    """

    key: Any
    offset: int
    length: int
    expected: Digest | None
    dest: Any
    enqueued_s: float
    payload: Any = None
    source: Any = None           # required when expected is None


@dataclasses.dataclass
class IntegrityStats:
    verified: int = 0
    corrupt: int = 0
    errors: int = 0
    lag_seconds: float = 0.0     # sum of (verdict time - enqueue time)
    max_lag_s: float = 0.0
    cksum_seconds: float = 0.0   # read-back + fingerprint work time
    fused_batches: int = 0       # drain rounds digested as one fused dispatch
    fused_jobs: int = 0          # jobs that rode a fused dispatch


class IntegrityEngine:
    """Checksum worker pool consuming a digest queue off the mover path.

    Workers read the landed bytes back (into pooled buffers), fingerprint
    them, and fire exactly one of the caller's callbacks per job — all from
    worker threads, so callbacks must do their own locking:

      * ``on_verified(job, lag_s, ck_s)``   — digests match; this is where
        the caller journals the chunk (the custody rule);
      * ``on_corrupt(job, actual, lag_s)``  — digest mismatch; the caller
        quarantines and re-queues the chunk within its re-fetch budget;
      * ``on_error(job, exc)``              — the read-back itself failed.

    ``drain()`` blocks until every submitted job has a verdict; ``close()``
    stops the workers (``abandon=True`` skips the join — crash simulation).

    **Fused drain** (``fuse=True``, the default): instead of one read-back +
    one host digest call per job, a worker opportunistically collects up to
    ``batch`` queued jobs, reads all of them back, and digests every row —
    landed bytes plus any deferred source fingerprints — in ONE
    ``fingerprint_many`` dispatch (equal-length granules stack into a single
    GEMM; ragged lengths fall back per-item inside). Jobs larger than
    ``fuse_max_bytes`` keep the per-chunk granule-streaming path, which is
    already bandwidth-bound at that size. ``backend="pallas"`` additionally
    routes tile-aligned equal-length groups through the batched
    ``kernels.checksum.checksum_many_words`` kernel (one accelerator dispatch
    per drain batch); the host GEMM stack handles whatever does not tile.
    """

    _SENTINEL = None

    def __init__(
        self,
        *,
        workers: int = 2,
        pool: BufferPool | None = None,
        on_verified: Callable[[VerifyJob, float, float], None],
        on_corrupt: Callable[[VerifyJob, Digest, float], None],
        on_error: Callable[[VerifyJob, BaseException], None] | None = None,
        tracer=None,                 # obs.Tracer: verify wait/work spans
        task: str = "",              # owning task id for spans + metrics
        fuse: bool = True,
        batch: int = 32,
        fuse_max_bytes: int = 8 * MiB,
        backend: str = "host",
    ):
        if workers < 1:
            raise ValueError("workers must be >= 1")
        if batch < 1:
            raise ValueError("batch must be >= 1")
        if backend not in ("host", "pallas"):
            raise ValueError(f"unknown integrity backend {backend!r}")
        self._pool = pool
        self._fuse = bool(fuse)
        self._batch = int(batch)
        self._fuse_max = int(fuse_max_bytes)
        self._backend = backend
        self._on_verified = on_verified
        self._on_corrupt = on_corrupt
        self._on_error = on_error
        self._tracer = tracer if tracer is not None else _NULL_TRACER
        self._task = task
        # verification lag is the pipelined data plane's health signal: a
        # growing distribution means the checksum pool is falling behind
        # movement (the flip side of the overlap win)
        self._lag_hist = _metrics.REGISTRY.histogram(
            "verify_lag_seconds", "move-landed -> verified delay",
            ("task",), scale=1e-5)
        self._verdicts = _metrics.REGISTRY.counter(
            "verify_verdicts_total", "deferred verification verdicts",
            ("task", "verdict"))
        self._q: "queue.Queue[VerifyJob | None]" = queue.Queue()
        self._lock = threading.Lock()
        self._idle = threading.Condition(self._lock)
        self._pending = 0
        self._closed = False
        self.stats = IntegrityStats()
        self._threads = [
            threading.Thread(target=self._worker, args=(i,),
                             name=f"integrity-{i}", daemon=True)
            for i in range(workers)
        ]
        for th in self._threads:
            th.start()

    # ------------------------------------------------------------------
    @property
    def pending(self) -> int:
        with self._lock:
            return self._pending

    def submit(self, job: VerifyJob) -> bool:
        """Enqueue a job; returns False if the engine is already closed.

        A False return happens only in shutdown/kill races (a mover landing
        its last write while the owner tears the engine down); the chunk
        simply stays unverified and unjournaled — exactly what a crash at
        that instant would leave behind.
        """
        with self._lock:
            if self._closed:
                return False
            self._pending += 1
            # the enqueue must happen under the same lock as the _closed
            # check: otherwise a submit that passed the check can land its
            # job BEHIND close()'s sentinels — the job never gets a verdict,
            # _pending never decrements, and drain() hangs forever
            self._q.put(job)
        return True

    def drain(self, timeout: float | None = None) -> bool:
        """Wait until every submitted job has a verdict. Returns False on
        timeout (pending jobs remain)."""
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._idle:
            while self._pending > 0:
                remaining = None if deadline is None else deadline - time.monotonic()
                if remaining is not None and remaining <= 0:
                    return False
                self._idle.wait(remaining if remaining is not None else 0.5)
        return True

    def close(self, *, abandon: bool = False) -> None:
        """Stop the workers. Queued jobs still get verdicts before the stop
        lands (the sentinel sits behind them) unless ``abandon`` — the crash
        path — which leaves the daemon workers to die with the process."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
            # sentinels go in under the lock too, so every job admitted by
            # submit() is provably ahead of them in the queue
            for _ in self._threads:
                self._q.put(self._SENTINEL)
        if not abandon:
            for th in self._threads:
                th.join()

    # ------------------------------------------------------------------
    def _worker(self, wid: int) -> None:
        while True:
            job = self._q.get()
            if job is self._SENTINEL:
                return
            batch = [job]
            if self._fuse and self._batch > 1:
                # opportunistic batch collection: take whatever is already
                # queued (up to the cap) without blocking — an idle queue
                # degrades to the per-job path, a deep one fuses
                while len(batch) < self._batch:
                    try:
                        nxt = self._q.get_nowait()
                    except queue.Empty:
                        break
                    if nxt is self._SENTINEL:
                        # resurface it: jobs can never be queued behind a
                        # sentinel (submit+close share the lock), so the
                        # tail is all sentinels and re-putting is safe
                        self._q.put(nxt)
                        break
                    batch.append(nxt)
            if len(batch) == 1 or not self._fusable(batch):
                for j in batch:
                    try:
                        self._verify_one(j, wid)
                    finally:
                        with self._idle:
                            self._pending -= 1
                            self._idle.notify_all()
            else:
                self._verify_batch(batch, wid)

    def _fusable(self, batch: list[VerifyJob]) -> bool:
        """A batch fuses when at least two jobs sit in the granule regime the
        GEMM stack amortizes; oversize jobs are better off streaming."""
        return sum(1 for j in batch if j.length <= self._fuse_max) >= 2

    def _verify_batch(self, jobs: list[VerifyJob], wid: int) -> None:
        """Fused verification: gather every row, digest in one dispatch, then
        fire per-job verdicts. Per-job pending decrement happens only after
        that job's callback — drain()'s return stays authoritative."""
        t0 = time.perf_counter()
        small = [j for j in jobs if j.length <= self._fuse_max]
        big = [j for j in jobs if j.length > self._fuse_max]
        entries: list[dict] = []
        for job in small:
            ent: dict = {"job": job, "holders": [], "buf": None, "error": None,
                         "back": None, "src": None,
                         "back_dig": None, "src_dig": None}
            try:
                if job.expected is None:
                    mv = job.source.read_view(job.offset, job.length)
                    ent["holders"].append(mv)
                    ent["src"] = np.frombuffer(mv, dtype=np.uint8)
                viewfn = getattr(job.dest, "read_back_view", None)
                if viewfn is not None:
                    mv = viewfn(job.offset, job.length)
                    if len(mv) != job.length:
                        raise IOError(
                            f"short read-back at {job.offset}: {len(mv)}/{job.length}")
                    ent["holders"].append(mv)
                    ent["back"] = np.frombuffer(mv, dtype=np.uint8)
                elif self._pool is not None:
                    buf = self._pool.acquire(job.length)
                    ent["buf"] = buf
                    read_back_into(job.dest, job.offset, buf.view)
                    ent["back"] = np.frombuffer(buf.view, dtype=np.uint8)
                else:
                    data = job.dest.read_back(job.offset, job.length)
                    if len(data) != job.length:
                        raise IOError(
                            f"short read-back at {job.offset}: {len(data)}/{job.length}")
                    ent["back"] = np.frombuffer(data, dtype=np.uint8)
            except BaseException as e:  # noqa: BLE001 — routed per job
                ent["error"] = e
            entries.append(ent)
        # ONE fused digest dispatch over every gathered row (landed bytes and
        # deferred source fingerprints alike); fingerprint_many groups equal
        # lengths into single GEMM stacks and handles the ragged leftovers
        rows: list[np.ndarray] = []
        slots: list[tuple[dict, str]] = []
        for ent in entries:
            if ent["error"] is None:
                rows.append(ent["back"])
                slots.append((ent, "back_dig"))
                if ent["src"] is not None:
                    rows.append(ent["src"])
                    slots.append((ent, "src_dig"))
        if rows:
            try:
                digs = self._digest_rows(rows)
                for (ent, field), d in zip(slots, digs):
                    ent[field] = d
            except BaseException as e:  # noqa: BLE001 — poison the whole batch
                for ent in entries:
                    if ent["error"] is None:
                        ent["error"] = e
        del rows, slots
        t_dig = time.perf_counter()
        with self._lock:
            self.stats.fused_batches += 1
            self.stats.fused_jobs += len(small)
        # per-job verdicts: sequential sub-windows of the batch interval keep
        # the verifier lane's span timeline non-overlapping for obs.attr
        n = len(entries)
        width = (t_dig - t0) / max(1, n)
        for i, ent in enumerate(entries):
            job = ent["job"]
            try:
                self._finish_fused(ent, wid, t0 + i * width, t0 + (i + 1) * width)
            finally:
                ent["back"] = ent["src"] = None
                for h in ent["holders"]:
                    if isinstance(h, memoryview):
                        h.release()
                if ent["buf"] is not None:
                    ent["buf"].release()
                with self._idle:
                    self._pending -= 1
                    self._idle.notify_all()
        for job in big:
            try:
                self._verify_one(job, wid)
            finally:
                with self._idle:
                    self._pending -= 1
                    self._idle.notify_all()

    def _finish_fused(self, ent: dict, wid: int, t0: float, t1: float) -> None:
        job: VerifyJob = ent["job"]
        self._tracer.add(
            "verify_wait", "cksum_wait", job.enqueued_s, t0,
            task=self._task, lane=f"verifier{wid}", offset=job.offset)
        if ent["error"] is not None:
            with self._lock:
                self.stats.errors += 1
            if self._on_error is not None:
                self._on_error(job, ent["error"])
            return
        expected = job.expected if job.expected is not None else ent["src_dig"]
        job.expected = expected
        actual = ent["back_dig"]
        lag = t1 - job.enqueued_s
        ck = t1 - t0
        ok = verify(expected, actual)
        self._tracer.add(
            "verify", "cksum", t0, t1, task=self._task,
            lane=f"verifier{wid}", offset=job.offset, ok=ok, fused=True)
        self._lag_hist.observe(lag, task=self._task)
        self._verdicts.inc(1, task=self._task,
                           verdict="ok" if ok else "corrupt")
        with self._lock:
            self.stats.cksum_seconds += ck
            self.stats.lag_seconds += lag
            self.stats.max_lag_s = max(self.stats.max_lag_s, lag)
            if ok:
                self.stats.verified += 1
            else:
                self.stats.corrupt += 1
        try:
            if ok:
                self._on_verified(job, lag, ck)
            else:
                self._on_corrupt(job, actual, lag)
        except BaseException as e:  # noqa: BLE001 — a callback bug must not
            with self._lock:        # silently kill a verifier thread
                self.stats.errors += 1
            if self._on_error is not None:
                self._on_error(job, e)

    def _digest_rows(self, rows: list[np.ndarray]) -> list[Digest]:
        if self._backend == "pallas":
            return _digest_rows_pallas(rows)
        return fingerprint_many(rows)

    def _verify_one(self, job: VerifyJob, wid: int = 0) -> None:
        t0 = time.perf_counter()
        # queue-wait is a first-class span: when this interval is non-trivial
        # the verify pool is saturated and the transfer is checksum-BOUND —
        # exactly the condition obs.attr charges segments to "cksum"
        self._tracer.add(
            "verify_wait", "cksum_wait", job.enqueued_s, t0,
            task=self._task, lane=f"verifier{wid}", offset=job.offset)
        try:
            if job.expected is None:
                # deferred source fingerprint: derive it off the mover path
                # from the source's stable view (same bytes the mover wrote)
                src_mv = job.source.read_view(job.offset, job.length)
                try:
                    job.expected = fingerprint_view(src_mv)
                finally:
                    if isinstance(src_mv, memoryview):
                        src_mv.release()
            # true zero-copy verify where the dest allows it: fingerprint
            # the landed bytes in place (in-memory dests expose their image
            # as a view; concurrent movers only touch disjoint offsets)
            actual = read_back_fingerprint(
                job.dest, job.offset, job.length, pool=self._pool)
        except BaseException as e:  # noqa: BLE001 — routed to the caller
            with self._lock:
                self.stats.errors += 1
            if self._on_error is not None:
                self._on_error(job, e)
            return
        now = time.perf_counter()
        lag = now - job.enqueued_s
        ck = now - t0
        ok = verify(job.expected, actual)
        self._tracer.add(
            "verify", "cksum", t0, now, task=self._task,
            lane=f"verifier{wid}", offset=job.offset, ok=ok)
        self._lag_hist.observe(lag, task=self._task)
        self._verdicts.inc(1, task=self._task,
                           verdict="ok" if ok else "corrupt")
        with self._lock:
            self.stats.cksum_seconds += ck
            self.stats.lag_seconds += lag
            self.stats.max_lag_s = max(self.stats.max_lag_s, lag)
            if ok:
                self.stats.verified += 1
            else:
                self.stats.corrupt += 1
        try:
            if ok:
                self._on_verified(job, lag, ck)
            else:
                self._on_corrupt(job, actual, lag)
        except BaseException as e:  # noqa: BLE001 — a callback bug must not
            with self._lock:        # silently kill a verifier thread
                self.stats.errors += 1
            if self._on_error is not None:
                self._on_error(job, e)
