"""Zero-copy pipelined data plane — buffers, streaming, and the integrity engine.

The paper's central overlap claim (§3.2, Fig. 4) is that per-chunk integrity
checking must run *concurrently* with data movement, not serialized behind
it. This module is the host-side machinery that makes that true:

  * **BufferPool / ChunkBuffer** — reusable chunk-sized buffers handed out as
    exact-length ``memoryview`` handles, so source read, fingerprint, and
    destination write all touch ONE allocation with zero intermediate
    ``bytes()`` copies. Buffers cycle back to the pool the moment the write
    lands; verification reads back into a *different* pooled buffer, so a
    chunk never pins two buffers at once.
  * **read_into / read_back_into** — zero-copy endpoint adapters: they use an
    endpoint's native ``read_into``/``read_back_into`` (``os.preadv`` on
    files, slice assignment on memory) when present and fall back to the
    classic ``read()``/``read_back()`` + copy otherwise, so chaos wrappers
    and third-party endpoints keep working unchanged.
  * **stream_chunk** — the single-pass move: the chunk streams source->dest
    in ``granule``-byte sub-reads and the source fingerprint accumulates via
    the merge law *while each granule is cache-hot*, eliminating the separate
    full digest pass the serial engine pays.
  * **IntegrityEngine** — the decoupled checksum worker pool. Movers enqueue
    a ``VerifyJob`` (coordinates + expected digest) the moment a chunk's
    write lands and immediately pull the next chunk; integrity workers drain
    the digest queue concurrently — read-back, fingerprint, verdict — and
    fire the caller's callbacks. The custody rule lives in the callbacks: a
    chunk's journal record commits only in ``on_verified``, so a crash with
    verification lagging N chunks behind movement re-moves exactly those N
    unverified chunks and nothing else.
"""
from __future__ import annotations

import dataclasses
import os
import queue
import threading
import time
from typing import Any, Callable

from repro.core.integrity import (
    Digest,
    RunningFingerprint,
    fingerprint_bytes,
    verify,
)
from repro.obs import metrics as _metrics
from repro.obs.trace import NULL as _NULL_TRACER

MiB = 1024 * 1024
DEFAULT_STREAM_GRANULE = 1 * MiB


# ---------------------------------------------------------------------------
# buffer pool
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class PoolStats:
    """Reuse accounting (surfaced by benchmarks/overlap.py)."""

    acquires: int = 0
    reuses: int = 0            # served from the free list (no allocation)
    allocations: int = 0       # fresh pooled buffers created
    oversize: int = 0          # requests larger than the pool's buffer size


class ChunkBuffer:
    """One pooled buffer lease: an exact-length writable ``memoryview``.

    ``view`` is the only handle movers/verifiers should touch; ``release()``
    returns the backing buffer to the pool (idempotent — double release is a
    no-op, and the view must not be used afterwards).
    """

    __slots__ = ("view", "_pool", "_raw")

    def __init__(self, pool: "BufferPool | None", raw: bytearray, length: int):
        self._pool = pool
        self._raw = raw
        self.view = memoryview(raw)[:length]

    def release(self) -> None:
        raw, self._raw = self._raw, None
        if raw is None:
            return
        self.view.release()
        self.view = None  # type: ignore[assignment]
        if self._pool is not None:
            self._pool._put_back(raw)

    def __enter__(self) -> "ChunkBuffer":
        return self

    def __exit__(self, *exc) -> None:
        self.release()


class BufferPool:
    """Thread-safe pool of ``buffer_bytes``-sized reusable buffers.

    ``capacity`` bounds how many idle buffers are retained; extra releases
    drop their buffer (GC'd) so a transient burst cannot pin memory forever.
    Requests larger than ``buffer_bytes`` (re-planned jumbo tails) get an
    exact-size one-shot allocation that is never pooled.
    """

    def __init__(self, buffer_bytes: int, *, capacity: int = 8):
        if buffer_bytes < 1:
            raise ValueError("buffer_bytes must be >= 1")
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.buffer_bytes = int(buffer_bytes)
        self.capacity = int(capacity)
        self._free: list[bytearray] = []
        self._lock = threading.Lock()
        self.stats = PoolStats()

    def acquire(self, length: int) -> ChunkBuffer:
        if length > self.buffer_bytes:
            with self._lock:
                self.stats.acquires += 1
                self.stats.oversize += 1
            return ChunkBuffer(None, bytearray(length), length)
        with self._lock:
            self.stats.acquires += 1
            if self._free:
                self.stats.reuses += 1
                raw = self._free.pop()
            else:
                self.stats.allocations += 1
                raw = bytearray(self.buffer_bytes)
        return ChunkBuffer(self, raw, length)

    def _put_back(self, raw: bytearray) -> None:
        with self._lock:
            if len(self._free) < self.capacity:
                self._free.append(raw)


# ---------------------------------------------------------------------------
# zero-copy endpoint adapters
# ---------------------------------------------------------------------------
def read_into(source: Any, offset: int, view: memoryview) -> None:
    """Read ``len(view)`` bytes at ``offset`` from ``source`` into ``view``.

    Zero-copy when the source implements ``read_into``; otherwise falls back
    to ``read()`` + one copy (chaos wrappers, legacy endpoints). Short reads
    raise ``IOError`` either way, matching the engine's retry taxonomy.
    """
    n = len(view)
    fn = getattr(source, "read_into", None)
    if fn is not None:
        got = fn(offset, view)
        if got != n:
            raise IOError(f"short read at {offset}: {got}/{n}")
        return
    data = source.read(offset, n)
    if len(data) != n:
        raise IOError(f"short read at {offset}: {len(data)}/{n}")
    view[:] = data


def read_back_into(dest: Any, offset: int, view: memoryview) -> None:
    """Verification read: like ``read_into`` but against a destination."""
    n = len(view)
    fn = getattr(dest, "read_back_into", None)
    if fn is not None:
        got = fn(offset, view)
        if got != n:
            raise IOError(f"short read-back at {offset}: {got}/{n}")
        return
    data = dest.read_back(offset, n)
    if len(data) != n:
        raise IOError(f"short read-back at {offset}: {len(data)}/{n}")
    view[:] = data


def fingerprint_view(mv: memoryview, granule: int = DEFAULT_STREAM_GRANULE) -> Digest:
    """Digest a buffer in cache-sized granule steps (merge law).

    One monolithic ``fingerprint_bytes`` over a large chunk streams its
    float64 conversion scratch through memory; granule-sized batches keep
    the working set cache-resident and run measurably faster. This is the
    read-back path's mirror of ``stream_chunk``'s granule digesting.
    """
    n = len(mv)
    if n <= granule:
        return fingerprint_bytes(mv)
    rf = RunningFingerprint()
    for pos in range(0, n, granule):
        rf.update(mv[pos : pos + granule])
    return rf.digest()


def read_back_fingerprint(
    dest: Any,
    offset: int,
    length: int,
    *,
    pool: "BufferPool | None" = None,
    granule: int = DEFAULT_STREAM_GRANULE,
) -> Digest:
    """Fingerprint the landed bytes, cheapest path first: in place via the
    destination's zero-copy ``read_back_view`` when it has one, else into a
    pooled buffer, else through the classic ``read_back()`` bytes. Shared by
    the integrity engine and the single-pass inline verifier."""
    viewfn = getattr(dest, "read_back_view", None)
    if viewfn is not None:
        mv = viewfn(offset, length)
        try:
            return fingerprint_view(mv, granule)
        finally:
            if isinstance(mv, memoryview):
                mv.release()
    if pool is not None:
        with pool.acquire(length) as buf:
            read_back_into(dest, offset, buf.view)
            return fingerprint_view(buf.view, granule)
    back = dest.read_back(offset, length)
    return fingerprint_view(memoryview(back), granule)


def stream_chunk(
    source: Any,
    dest: Any,
    offset: int,
    length: int,
    *,
    pool: BufferPool,
    granule: int = DEFAULT_STREAM_GRANULE,
    digest: bool = True,
) -> tuple[Digest | None, float]:
    """Single-pass chunk move: stream source->dest in granules, fingerprinting
    each granule while it is cache-hot from the read that produced it.

    Returns ``(source_digest, cksum_seconds)`` where ``cksum_seconds`` is the
    time spent inside fingerprint math only — the copy itself is mover time.
    The destination sees the same disjoint-offset writes a whole-chunk move
    would produce (granule writes are idempotent re-writes on retry).

    ``digest=False`` skips the fingerprint and returns ``(None, 0.0)`` when
    the source supports stable zero-copy views — the pipelined engine's
    checksum workers re-derive the source digest from the SAME view off the
    mover path (the paper's "source fingerprinting runs concurrently with
    subsequent chunk moves"). Sources without views always digest here: the
    streamed bytes are not reachable afterwards.
    """
    granule = max(1, int(granule))
    rf = RunningFingerprint()
    ck_s = 0.0
    pos = offset
    end = offset + length
    viewfn = getattr(source, "read_view", None)
    if viewfn is not None:
        # fully zero-copy: digest and write straight out of the source image
        while pos < end:
            take = min(granule, end - pos)
            mv = viewfn(pos, take)
            if len(mv) != take:
                raise IOError(f"short read at {pos}: {len(mv)}/{take}")
            if digest:
                t0 = time.perf_counter()
                rf.update(mv)
                ck_s += time.perf_counter() - t0
            dest.write(pos, mv)
            pos += take
        return (rf.digest() if digest else None), ck_s
    buf = pool.acquire(min(granule, length) if length else 0)
    try:
        while pos < end:
            take = min(granule, end - pos)
            mv = buf.view[:take]
            read_into(source, pos, mv)
            t0 = time.perf_counter()
            rf.update(mv)
            ck_s += time.perf_counter() - t0
            dest.write(pos, mv)
            pos += take
    finally:
        buf.release()
    return rf.digest(), ck_s


# ---------------------------------------------------------------------------
# the decoupled integrity engine
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class VerifyJob:
    """One deferred verification, enqueued by a mover.

    ``key`` is the caller's chunk identity (opaque to the engine), ``dest``
    the endpoint to read back from, ``expected`` the source digest taken
    during streaming. ``expected=None`` defers the SOURCE fingerprint too:
    the worker re-derives it from ``source``'s stable zero-copy view before
    verifying — movers on view-capable sources are pure wire. ``payload``
    rides along to the callbacks (the engine's callers stash their
    outcome/telemetry object there).
    """

    key: Any
    offset: int
    length: int
    expected: Digest | None
    dest: Any
    enqueued_s: float
    payload: Any = None
    source: Any = None           # required when expected is None


@dataclasses.dataclass
class IntegrityStats:
    verified: int = 0
    corrupt: int = 0
    errors: int = 0
    lag_seconds: float = 0.0     # sum of (verdict time - enqueue time)
    max_lag_s: float = 0.0
    cksum_seconds: float = 0.0   # read-back + fingerprint work time


class IntegrityEngine:
    """Checksum worker pool consuming a digest queue off the mover path.

    Workers read the landed bytes back (into pooled buffers), fingerprint
    them, and fire exactly one of the caller's callbacks per job — all from
    worker threads, so callbacks must do their own locking:

      * ``on_verified(job, lag_s, ck_s)``   — digests match; this is where
        the caller journals the chunk (the custody rule);
      * ``on_corrupt(job, actual, lag_s)``  — digest mismatch; the caller
        quarantines and re-queues the chunk within its re-fetch budget;
      * ``on_error(job, exc)``              — the read-back itself failed.

    ``drain()`` blocks until every submitted job has a verdict; ``close()``
    stops the workers (``abandon=True`` skips the join — crash simulation).
    """

    _SENTINEL = None

    def __init__(
        self,
        *,
        workers: int = 2,
        pool: BufferPool | None = None,
        on_verified: Callable[[VerifyJob, float, float], None],
        on_corrupt: Callable[[VerifyJob, Digest, float], None],
        on_error: Callable[[VerifyJob, BaseException], None] | None = None,
        tracer=None,                 # obs.Tracer: verify wait/work spans
        task: str = "",              # owning task id for spans + metrics
    ):
        if workers < 1:
            raise ValueError("workers must be >= 1")
        self._pool = pool
        self._on_verified = on_verified
        self._on_corrupt = on_corrupt
        self._on_error = on_error
        self._tracer = tracer if tracer is not None else _NULL_TRACER
        self._task = task
        # verification lag is the pipelined data plane's health signal: a
        # growing distribution means the checksum pool is falling behind
        # movement (the flip side of the overlap win)
        self._lag_hist = _metrics.REGISTRY.histogram(
            "verify_lag_seconds", "move-landed -> verified delay",
            ("task",), scale=1e-5)
        self._verdicts = _metrics.REGISTRY.counter(
            "verify_verdicts_total", "deferred verification verdicts",
            ("task", "verdict"))
        self._q: "queue.Queue[VerifyJob | None]" = queue.Queue()
        self._lock = threading.Lock()
        self._idle = threading.Condition(self._lock)
        self._pending = 0
        self._closed = False
        self.stats = IntegrityStats()
        self._threads = [
            threading.Thread(target=self._worker, args=(i,),
                             name=f"integrity-{i}", daemon=True)
            for i in range(workers)
        ]
        for th in self._threads:
            th.start()

    # ------------------------------------------------------------------
    @property
    def pending(self) -> int:
        with self._lock:
            return self._pending

    def submit(self, job: VerifyJob) -> bool:
        """Enqueue a job; returns False if the engine is already closed.

        A False return happens only in shutdown/kill races (a mover landing
        its last write while the owner tears the engine down); the chunk
        simply stays unverified and unjournaled — exactly what a crash at
        that instant would leave behind.
        """
        with self._lock:
            if self._closed:
                return False
            self._pending += 1
        self._q.put(job)
        return True

    def drain(self, timeout: float | None = None) -> bool:
        """Wait until every submitted job has a verdict. Returns False on
        timeout (pending jobs remain)."""
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._idle:
            while self._pending > 0:
                remaining = None if deadline is None else deadline - time.monotonic()
                if remaining is not None and remaining <= 0:
                    return False
                self._idle.wait(remaining if remaining is not None else 0.5)
        return True

    def close(self, *, abandon: bool = False) -> None:
        """Stop the workers. Queued jobs still get verdicts before the stop
        lands (the sentinel sits behind them) unless ``abandon`` — the crash
        path — which leaves the daemon workers to die with the process."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
        for _ in self._threads:
            self._q.put(self._SENTINEL)
        if not abandon:
            for th in self._threads:
                th.join()

    # ------------------------------------------------------------------
    def _worker(self, wid: int) -> None:
        while True:
            job = self._q.get()
            if job is self._SENTINEL:
                return
            try:
                self._verify_one(job, wid)
            finally:
                with self._idle:
                    self._pending -= 1
                    self._idle.notify_all()

    def _verify_one(self, job: VerifyJob, wid: int = 0) -> None:
        t0 = time.perf_counter()
        # queue-wait is a first-class span: when this interval is non-trivial
        # the verify pool is saturated and the transfer is checksum-BOUND —
        # exactly the condition obs.attr charges segments to "cksum"
        self._tracer.add(
            "verify_wait", "cksum_wait", job.enqueued_s, t0,
            task=self._task, lane=f"verifier{wid}", offset=job.offset)
        try:
            if job.expected is None:
                # deferred source fingerprint: derive it off the mover path
                # from the source's stable view (same bytes the mover wrote)
                src_mv = job.source.read_view(job.offset, job.length)
                try:
                    job.expected = fingerprint_view(src_mv)
                finally:
                    if isinstance(src_mv, memoryview):
                        src_mv.release()
            # true zero-copy verify where the dest allows it: fingerprint
            # the landed bytes in place (in-memory dests expose their image
            # as a view; concurrent movers only touch disjoint offsets)
            actual = read_back_fingerprint(
                job.dest, job.offset, job.length, pool=self._pool)
        except BaseException as e:  # noqa: BLE001 — routed to the caller
            with self._lock:
                self.stats.errors += 1
            if self._on_error is not None:
                self._on_error(job, e)
            return
        now = time.perf_counter()
        lag = now - job.enqueued_s
        ck = now - t0
        ok = verify(job.expected, actual)
        self._tracer.add(
            "verify", "cksum", t0, now, task=self._task,
            lane=f"verifier{wid}", offset=job.offset, ok=ok)
        self._lag_hist.observe(lag, task=self._task)
        self._verdicts.inc(1, task=self._task,
                           verdict="ok" if ok else "corrupt")
        with self._lock:
            self.stats.cksum_seconds += ck
            self.stats.lag_seconds += lag
            self.stats.max_lag_s = max(self.stats.max_lag_s, lag)
            if ok:
                self.stats.verified += 1
            else:
                self.stats.corrupt += 1
        try:
            if ok:
                self._on_verified(job, lag, ck)
            else:
                self._on_corrupt(job, actual, lag)
        except BaseException as e:  # noqa: BLE001 — a callback bug must not
            with self._lock:        # silently kill a verifier thread
                self.stats.errors += 1
            if self._on_error is not None:
                self._on_error(job, e)
