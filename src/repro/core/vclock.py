"""Shared virtual-time utilities for the event-stepped backends.

Before this module existed, three event loops each hand-rolled the same
virtual-clock bookkeeping: the calibrated per-chunk WAN simulator
(``core.simulator``), the fluid-model service testbed (``service.testbed``,
including its fault-scenario outage windows), and — with the fabric — the
multi-hop campaign executor (``fabric.virtual``). Each had its own ``t``
accumulator, its own iteration guard with its own error message, its own
"no progressing stage" deadlock check, and its own inline interval
arithmetic for outage windows. They are now all ports of the two primitives
here:

  * ``VirtualClock`` — a monotonically advancing virtual ``now`` with a
    built-in convergence guard. Each loop iteration calls ``tick(*candidate
    event deltas)``; the clock picks the earliest finite candidate, advances,
    and raises ``ConvergenceError`` when nothing can progress or the loop
    exceeds its step budget (a deterministic stand-in for "this model
    diverged", catchable as RuntimeError by older callers).

  * ``Window`` — a half-open ``[start, start+duration)`` virtual-time
    interval used for outage/degradation schedules: scenario outage windows
    in the testbed, per-endpoint maintenance schedules in ``fabric.topology``,
    and link-outage windows in ``fabric.virtual`` all share its
    ``contains``/``until_end`` arithmetic instead of re-deriving it inline.
"""
from __future__ import annotations

import dataclasses
import math


class ConvergenceError(RuntimeError):
    """An event loop stopped progressing (deadlock) or exceeded its budget."""


@dataclasses.dataclass(frozen=True)
class Window:
    """Half-open virtual-time interval ``[start, start + duration)``."""

    start: float
    duration: float

    def __post_init__(self):
        if self.duration < 0:
            raise ValueError("window duration must be >= 0")

    @property
    def end(self) -> float:
        return self.start + self.duration

    def contains(self, t: float, *, eps: float = 1e-12) -> bool:
        return self.start - eps <= t < self.end - eps

    def until_start(self, t: float) -> float:
        """Virtual seconds until the window opens (inf if already open/past)."""
        return self.start - t if t < self.start else math.inf

    def until_end(self, t: float) -> float:
        """Virtual seconds until the window closes (inf once it has)."""
        return self.end - t if t < self.end else math.inf

    def next_boundary(self, t: float) -> float:
        """Virtual seconds to the nearest upcoming edge (start or end)."""
        return min(self.until_start(t), self.until_end(t))


class VirtualClock:
    """Guarded virtual-time stepper shared by the event-stepped backends.

    ``guard`` bounds the number of ``tick`` calls; event loops size it from
    their workload (e.g. ``20 * n_items + 1000``) so a buggy model fails fast
    and deterministically instead of spinning. ``label`` names the backend in
    error messages.
    """

    def __init__(self, *, guard: int, label: str = "event loop"):
        if guard < 1:
            raise ValueError("guard must be >= 1")
        self.now = 0.0
        self.steps = 0
        self.guard = guard
        self.label = label

    def tick(self, *candidates: float, floor: float = 0.0) -> float:
        """Advance to the earliest of the candidate event deltas.

        Ignores non-finite candidates; if none are finite the model is
        deadlocked (nothing progresses) and ``ConvergenceError`` is raised.
        ``floor`` clamps the step from below (the simulator's numeric eps).
        Returns the delta actually applied.
        """
        self.steps += 1
        if self.steps > self.guard:
            raise ConvergenceError(
                f"{self.label} failed to converge (event-loop guard: "
                f"{self.guard} steps)"
            )
        dt = math.inf
        for c in candidates:
            if math.isfinite(c) and c < dt:
                dt = c
        if not math.isfinite(dt):
            raise ConvergenceError(f"{self.label} deadlock: no progressing stage")
        dt = max(dt, floor)
        self.now += dt
        return dt
