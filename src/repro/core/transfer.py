"""Chunked transfer engine — the host-side data movers.

This is the paper-faithful implementation of §3.1/§3.2 on a host: N worker
threads (the "data mover pairs") pull chunks from a shared queue (natural
work-stealing => straggler mitigation), move disjoint byte ranges from a
source to a destination, compute per-chunk fingerprints pipelined with the
movement, verify end-to-end integrity chunk-by-chunk, journal completions for
partial restart, retry failed chunks (chunk-granular fault recovery rather
than whole-transfer restart), and optionally speculate on stragglers.

It backs the checkpoint subsystem (repro.ckpt) — where source = device-host
array bytes and destination = the checkpoint file — and the CPU-measurable
overlap benchmarks (benchmarks/overlap.py).
"""
from __future__ import annotations

import dataclasses
import os
import queue
import threading
import time
from typing import Callable, Protocol

import numpy as np

from repro.core.chunker import Chunk, ChunkPlan
from repro.core.integrity import Digest, combine_at_offsets, fingerprint_bytes, verify
from repro.core.journal import ChunkJournal, JournalRecord


# ---------------------------------------------------------------------------
# Source / destination abstractions
# ---------------------------------------------------------------------------
class ByteSource(Protocol):
    nbytes: int
    def read(self, offset: int, length: int) -> bytes: ...


class ByteDest(Protocol):
    def write(self, offset: int, data: bytes) -> None: ...
    def read_back(self, offset: int, length: int) -> bytes: ...


class BufferSource:
    """Zero-copy view over an in-memory byte image (e.g. a host array)."""

    def __init__(self, data: bytes | bytearray | memoryview | np.ndarray):
        if isinstance(data, np.ndarray):
            data = np.ascontiguousarray(data).view(np.uint8).reshape(-1).data
        self._mv = memoryview(data)
        self.nbytes = self._mv.nbytes

    def read(self, offset: int, length: int) -> bytes:
        return bytes(self._mv[offset : offset + length])


class FileSource:
    def __init__(self, path: str | os.PathLike):
        self.path = str(path)
        self.nbytes = os.path.getsize(self.path)
        self._local = threading.local()

    def _fh(self):
        fh = getattr(self._local, "fh", None)
        if fh is None:
            fh = open(self.path, "rb")
            self._local.fh = fh
        return fh

    def read(self, offset: int, length: int) -> bytes:
        fh = self._fh()
        fh.seek(offset)
        return fh.read(length)


class FileDest:
    """Preallocated file destination; per-thread handles allow concurrent
    positional writes of disjoint ranges (the ESTO analogue)."""

    def __init__(self, path: str | os.PathLike, total_bytes: int):
        self.path = str(path)
        self.total_bytes = total_bytes
        # Preallocate only when absent/mis-sized: a partially-written file from
        # a crashed save must keep its journaled chunks (partial restart).
        if not os.path.exists(self.path) or os.path.getsize(self.path) != total_bytes:
            with open(self.path, "wb") as fh:
                if total_bytes:
                    fh.truncate(total_bytes)
        self._local = threading.local()

    def _fh(self):
        fh = getattr(self._local, "fh", None)
        if fh is None:
            fh = open(self.path, "r+b")
            self._local.fh = fh
        return fh

    def write(self, offset: int, data: bytes) -> None:
        fh = self._fh()
        fh.seek(offset)
        fh.write(data)
        fh.flush()

    def read_back(self, offset: int, length: int) -> bytes:
        fh = self._fh()
        fh.seek(offset)
        return fh.read(length)


class BufferDest:
    def __init__(self, total_bytes: int):
        self.buf = bytearray(total_bytes)

    def write(self, offset: int, data: bytes) -> None:
        self.buf[offset : offset + len(data)] = data

    def read_back(self, offset: int, length: int) -> bytes:
        return bytes(self.buf[offset : offset + length])


# ---------------------------------------------------------------------------
# Transfer engine
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class ChunkOutcome:
    chunk: Chunk
    digest: Digest
    attempts: int
    mover: int
    seconds: float


@dataclasses.dataclass
class TransferReport:
    total_bytes: int
    file_digest: Digest
    outcomes: dict[int, ChunkOutcome]
    seconds: float
    retries: int
    skipped_chunks: int            # restored from journal (partial restart)
    speculated: int

    @property
    def gbps(self) -> float:
        return self.total_bytes * 8 / 1e9 / self.seconds if self.seconds > 0 else 0.0


class IntegrityError(RuntimeError):
    pass


class ChunkedTransfer:
    """Executes a ChunkPlan with integrity checking and chunk-level recovery."""

    def __init__(
        self,
        source: ByteSource,
        dest: ByteDest,
        plan: ChunkPlan,
        *,
        integrity: bool = True,
        journal: ChunkJournal | None = None,
        max_retries: int = 3,
        fault_injector: Callable[[Chunk, int], None] | None = None,
        speculative_factor: float = 0.0,   # >0 enables straggler duplication
    ):
        if source.nbytes != plan.total_bytes:
            raise ValueError(f"source has {source.nbytes} bytes, plan expects {plan.total_bytes}")
        self.source, self.dest, self.plan = source, dest, plan
        self.integrity = integrity
        self.journal = journal
        self.max_retries = max_retries
        self.fault_injector = fault_injector
        self.speculative_factor = speculative_factor
        self._lock = threading.Lock()
        self._outcomes: dict[int, ChunkOutcome] = {}
        self._retries = 0
        self._speculated = 0
        self._errors: list[BaseException] = []

    # -- single chunk (one ERET/ESTO pair) --------------------------------
    def _move_chunk(self, chunk: Chunk, mover: int) -> ChunkOutcome:
        attempts = 0
        t0 = time.perf_counter()
        while True:
            attempts += 1
            try:
                if self.fault_injector is not None:
                    self.fault_injector(chunk, attempts)
                data = self.source.read(chunk.offset, chunk.length)
                if len(data) != chunk.length:
                    raise IOError(f"short read at {chunk.offset}: {len(data)}/{chunk.length}")
                # Source-side fingerprint while the data is in hand (the
                # paper's "modest cost incurred when first reading the file").
                src_digest = fingerprint_bytes(data)
                self.dest.write(chunk.offset, data)
                if self.integrity:
                    back = self.dest.read_back(chunk.offset, chunk.length)
                    dst_digest = fingerprint_bytes(back)
                    if not verify(src_digest, dst_digest):
                        raise IntegrityError(
                            f"chunk {chunk.index} digest mismatch "
                            f"(offset={chunk.offset}, len={chunk.length})"
                        )
                return ChunkOutcome(chunk, src_digest, attempts, mover, time.perf_counter() - t0)
            except Exception:
                if attempts > self.max_retries:
                    raise
                with self._lock:
                    self._retries += 1

    # -- worker loop: pull-from-queue == work stealing ---------------------
    def _worker(self, mover: int, q: "queue.Queue[Chunk | None]") -> None:
        while True:
            chunk = q.get()
            if chunk is None:
                return
            with self._lock:
                if chunk.index in self._outcomes:   # speculated twin already landed
                    continue
            try:
                out = self._move_chunk(chunk, mover)
            except BaseException as e:  # noqa: BLE001 — propagated to caller
                with self._lock:
                    self._errors.append(e)
                return
            with self._lock:
                first = chunk.index not in self._outcomes
                if first:
                    self._outcomes[chunk.index] = out
            if first and self.journal is not None:
                self.journal.append(
                    JournalRecord(chunk.index, chunk.offset, chunk.length, out.digest.hexdigest())
                )

    def run(self) -> TransferReport:
        t0 = time.perf_counter()
        done_before: dict[int, Digest] = {}
        if self.journal is not None:
            for idx, rec in self.journal.records.items():
                done_before[idx] = rec.digest()

        pending = [c for c in self.plan.chunks if c.index not in done_before]
        q: "queue.Queue[Chunk | None]" = queue.Queue()
        for c in pending:
            q.put(c)

        movers = max(1, min(self.plan.movers, len(pending))) if pending else 1
        threads = [
            threading.Thread(target=self._worker, args=(m, q), daemon=True)
            for m in range(movers)
        ]
        # Straggler mitigation: when the queue drains, re-enqueue the oldest
        # in-flight chunks so idle movers can duplicate them (first write wins
        # — writes are idempotent on disjoint ranges).
        if self.speculative_factor > 0 and pending:
            watcher = threading.Thread(target=self._speculate, args=(q, movers), daemon=True)
        else:
            watcher = None
        for th in threads:
            th.start()
        if watcher:
            watcher.start()
        for _ in threads:
            q.put(None)
        for th in threads:
            th.join()
        if self._errors:
            raise self._errors[0]

        parts = [(c.offset, self._outcomes[c.index].digest) for c in self.plan.chunks
                 if c.index in self._outcomes]
        parts += [(self.plan.chunks[i].offset, d) for i, d in done_before.items()]
        file_digest = combine_at_offsets(parts, self.plan.total_bytes)
        return TransferReport(
            total_bytes=self.plan.total_bytes,
            file_digest=file_digest,
            outcomes=self._outcomes,
            seconds=time.perf_counter() - t0,
            retries=self._retries,
            skipped_chunks=len(done_before),
            speculated=self._speculated,
        )

    def _speculate(self, q: "queue.Queue[Chunk | None]", movers: int) -> None:
        while True:
            time.sleep(0.005)
            with self._lock:
                done = len(self._outcomes)
                total = self.plan.n_chunks
                if done >= total or self._errors:
                    return
                if q.qsize() <= movers and total - done <= movers:
                    missing = [c for c in self.plan.chunks if c.index not in self._outcomes]
                    for c in missing[: movers]:
                        q.put(c)
                        self._speculated += 1
                    return


def transfer_verified(
    source: ByteSource,
    dest: ByteDest,
    plan: ChunkPlan,
    expected: Digest | None = None,
    **kw,
) -> TransferReport:
    """One-shot helper: run the transfer; optionally check the end-to-end digest."""
    report = ChunkedTransfer(source, dest, plan, **kw).run()
    if expected is not None and not verify(expected, report.file_digest):
        raise IntegrityError(
            f"end-to-end digest mismatch: expected {expected.hexdigest()}, "
            f"got {report.file_digest.hexdigest()}"
        )
    return report
