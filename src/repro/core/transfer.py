"""Chunked transfer engine — the host-side data movers.

This is the paper-faithful implementation of §3.1/§3.2 on a host: N worker
threads (the "data mover pairs") pull chunks from a shared queue (natural
work-stealing => straggler mitigation), move disjoint byte ranges from a
source to a destination, compute per-chunk fingerprints pipelined with the
movement, verify end-to-end integrity chunk-by-chunk, journal completions for
partial restart, retry failed chunks (chunk-granular fault recovery rather
than whole-transfer restart), and optionally speculate on stragglers.

The data plane has three modes (see ``PIPELINE_MODES`` below and
``core.dataplane``): the classic serial path, a zero-copy single-pass
streaming path, and a fully pipelined path where a decoupled integrity
engine verifies chunks concurrently with subsequent moves — the journal
record commits only after the deferred verification lands.

It backs the checkpoint subsystem (repro.ckpt) — where source = device-host
array bytes and destination = the checkpoint file — and the CPU-measurable
overlap benchmarks (benchmarks/overlap.py).
"""
from __future__ import annotations

import dataclasses
import os
import queue
import threading
import time
from typing import Callable, Protocol

import numpy as np

from repro.core.chunker import (
    Chunk,
    ChunkPlan,
    merge_regions,
    partition_regions,
    plan_stripes,
    subtract_regions,
)
from repro.core.dataplane import (
    DEFAULT_STREAM_GRANULE,
    BufferPool,
    IntegrityEngine,
    VerifyJob,
    read_back_fingerprint,
    stream_chunk,
)
from repro.core.integrity import (
    Digest,
    combine_at_offsets,
    describe_mismatch,
    fingerprint_bytes,
    merge_all,
    verify,
)
from repro.core.backoff import Backoff
from repro.core.journal import ChunkJournal, JournalRecord
from repro.obs import metrics as obsmetrics
from repro.obs.trace import NULL as NULL_TRACER

# data-plane pipeline modes (ChunkedTransfer(pipeline=...)):
#   serial      — read -> digest -> write -> read-back -> digest -> verify,
#                 all on the mover (the original engine, now zero-copy);
#   single_pass — the source digest accumulates WHILE the chunk streams into
#                 the destination (one data pass saved); verify still inline;
#   pipelined   — single-pass streaming + verification deferred to the
#                 integrity engine's checksum workers, off the mover path.
#                 Custody rule: the journal record commits only after the
#                 deferred verification lands.
PIPELINE_MODES = ("serial", "single_pass", "pipelined")

# Work-item index band for intra-chunk stripes. Stripe work items carry
# indices from this base so they can never collide with plan chunk ids,
# re-planned tail ids (which grow upward from plan.n_chunks), or the
# service's tuned band (1 << 40) — and so restart logic can recognize a
# journal record as stripe custody by its index alone.
STRIPE_INDEX_BASE = 1 << 50


# ---------------------------------------------------------------------------
# Source / destination abstractions
# ---------------------------------------------------------------------------
class ByteSource(Protocol):
    nbytes: int
    def read(self, offset: int, length: int) -> bytes: ...
    # optional zero-copy variant (``core.dataplane.read_into`` adapts):
    #   def read_into(self, offset: int, view: memoryview) -> int: ...


class ByteDest(Protocol):
    def write(self, offset: int, data: bytes) -> None: ...
    def read_back(self, offset: int, length: int) -> bytes: ...
    # optional zero-copy variant (``core.dataplane.read_back_into`` adapts):
    #   def read_back_into(self, offset: int, view: memoryview) -> int: ...


_HAS_PREAD = hasattr(os, "pread") and hasattr(os, "pwrite")


class BufferSource:
    """Zero-copy view over an in-memory byte image (e.g. a host array)."""

    def __init__(self, data: bytes | bytearray | memoryview | np.ndarray):
        if isinstance(data, np.ndarray):
            data = np.ascontiguousarray(data).view(np.uint8).reshape(-1).data
        self._mv = memoryview(data)
        self.nbytes = self._mv.nbytes

    def read(self, offset: int, length: int) -> bytes:
        return bytes(self._mv[offset : offset + length])

    def read_into(self, offset: int, view: memoryview) -> int:
        n = min(len(view), self.nbytes - offset)
        view[:n] = self._mv[offset : offset + n]
        return n

    def read_view(self, offset: int, length: int) -> memoryview:
        """Zero-copy window over the source image: streaming movers digest
        and write straight from it — no staging buffer, no copy at all."""
        return self._mv[offset : offset + length]


class _FallbackHandles:
    """Per-thread seekable handles for the off-POSIX path.

    Each mover thread gets its OWN handle (two movers sharing one seekable
    handle can interleave seek+read/seek+write and corrupt landings), and
    every handle ever vended is tracked under a lock so ``close()`` can
    actually close them — the per-thread handles used to leak, one fd per
    mover thread per endpoint, for the lifetime of the process.
    """

    def __init__(self, opener: Callable[[], object]):
        self._opener = opener
        self._local = threading.local()
        self._lock = threading.Lock()
        self._all: list = []

    def get(self):
        fh = getattr(self._local, "fh", None)
        if fh is None or fh.closed:
            fh = self._opener()
            self._local.fh = fh
            with self._lock:
                self._all.append(fh)
        return fh

    def close_all(self) -> None:
        with self._lock:
            handles, self._all = self._all, []
        for fh in handles:
            try:
                fh.close()
            except Exception:  # noqa: BLE001 — already-closed / teardown
                pass


class FileSource:
    """Positional-read file source: one shared fd, ``os.pread`` per read, so
    concurrent movers on the same file never serialize on a seek+read handle
    (non-POSIX platforms fall back to per-thread handles)."""

    def __init__(self, path: str | os.PathLike):
        self.path = str(path)
        self.nbytes = os.path.getsize(self.path)
        self._fd: int | None = None
        if _HAS_PREAD:
            self._fd = os.open(self.path, os.O_RDONLY)
        self._fallback = _FallbackHandles(lambda: open(self.path, "rb"))

    def _fh(self):
        return self._fallback.get()

    def read(self, offset: int, length: int) -> bytes:
        if self._fd is not None:
            return os.pread(self._fd, length, offset)
        fh = self._fh()
        fh.seek(offset)
        return fh.read(length)

    def read_into(self, offset: int, view: memoryview) -> int:
        if self._fd is not None:
            return os.preadv(self._fd, [view], offset)
        fh = self._fh()
        fh.seek(offset)
        return fh.readinto(view)

    def readv_into(self, offset: int, views: list) -> int:
        """Vectored read: one ``os.preadv`` fills every view (the stripe
        movers' iovec batch); the off-POSIX fallback loops on the thread's
        own handle, so concurrency safety matches the scalar path."""
        if self._fd is not None:
            return os.preadv(self._fd, views, offset)
        fh = self._fh()
        fh.seek(offset)
        got = 0
        for v in views:
            n = fh.readinto(v)
            got += n
            if n < len(v):
                break
        return got

    def close(self) -> None:
        fd, self._fd = self._fd, None
        if fd is not None:
            os.close(fd)
        self._fallback.close_all()

    def __del__(self):  # raw fds are not GC-closed like file objects
        try:
            self.close()
        except Exception:  # noqa: BLE001 — interpreter teardown
            pass


class FileDest:
    """Preallocated file destination; positional ``os.pwrite``/``os.pread``
    on one shared fd allow concurrent writes + verification reads of disjoint
    ranges with no per-op locking or seeking (the ESTO analogue)."""

    def __init__(self, path: str | os.PathLike, total_bytes: int):
        self.path = str(path)
        self.total_bytes = total_bytes
        # Preallocate only when absent/mis-sized: a partially-written file from
        # a crashed save must keep its journaled chunks (partial restart).
        if not os.path.exists(self.path) or os.path.getsize(self.path) != total_bytes:
            with open(self.path, "wb") as fh:
                if total_bytes:
                    fh.truncate(total_bytes)
        self._fd: int | None = None
        if _HAS_PREAD:
            self._fd = os.open(self.path, os.O_RDWR)
        self._fallback = _FallbackHandles(lambda: open(self.path, "r+b"))

    def _fh(self):
        return self._fallback.get()

    def write(self, offset: int, data: bytes) -> None:
        if self._fd is not None:
            os.pwrite(self._fd, data, offset)
            return
        fh = self._fh()
        fh.seek(offset)
        fh.write(data)
        fh.flush()

    def writev(self, offset: int, views: list) -> int:
        """Vectored write: one ``os.pwritev`` lands every view (the stripe
        movers' iovec batch); the off-POSIX fallback loops on the thread's
        own handle."""
        if self._fd is not None and hasattr(os, "pwritev"):
            return os.pwritev(self._fd, views, offset)
        if self._fd is not None:
            got = 0
            for v in views:
                got += os.pwrite(self._fd, v, offset + got)
            return got
        fh = self._fh()
        fh.seek(offset)
        got = 0
        for v in views:
            got += fh.write(v)
        fh.flush()
        return got

    def read_back(self, offset: int, length: int) -> bytes:
        if self._fd is not None:
            return os.pread(self._fd, length, offset)
        fh = self._fh()
        fh.seek(offset)
        return fh.read(length)

    def read_back_into(self, offset: int, view: memoryview) -> int:
        if self._fd is not None:
            return os.preadv(self._fd, [view], offset)
        fh = self._fh()
        fh.seek(offset)
        return fh.readinto(view)

    def close(self) -> None:
        fd, self._fd = self._fd, None
        if fd is not None:
            os.close(fd)
        self._fallback.close_all()

    def __del__(self):
        try:
            self.close()
        except Exception:  # noqa: BLE001 — interpreter teardown
            pass


class BufferDest:
    def __init__(self, total_bytes: int):
        self.buf = bytearray(total_bytes)

    def write(self, offset: int, data: bytes) -> None:
        self.buf[offset : offset + len(data)] = data

    def read_back(self, offset: int, length: int) -> bytes:
        return bytes(self.buf[offset : offset + length])

    def read_back_into(self, offset: int, view: memoryview) -> int:
        n = min(len(view), len(self.buf) - offset)
        view[:n] = memoryview(self.buf)[offset : offset + n]
        return n

    def read_back_view(self, offset: int, length: int) -> memoryview:
        """Zero-copy window over the landed bytes (deferred verification
        fingerprints the destination image in place)."""
        return memoryview(self.buf)[offset : offset + length]


# ---------------------------------------------------------------------------
# Fault taxonomy — the failure classes the recovery logic distinguishes
# ---------------------------------------------------------------------------
class IntegrityError(RuntimeError):
    """Per-chunk digest mismatch that survived the re-fetch budget."""


class MoverCrash(RuntimeError):
    """A data mover died mid-chunk. The worker thread that raises (or
    observes) this is gone; the chunk it held is re-queued for surviving
    movers — a dead mover costs one chunk re-move, never the transfer."""


class EndpointOutage(IOError):
    """An endpoint is temporarily unavailable (reads/writes raise for a
    window). Retried on a separate, larger budget than generic I/O errors
    with backoff, because outages heal on their own clock, not the chunk's."""


@dataclasses.dataclass(frozen=True)
class QuarantineRecord:
    """One corrupt chunk landing, caught by the read-back digest and healed
    by a re-fetch from the source (the paper's §3.2 rationale: a bad chunk
    costs one chunk re-read, not a terabyte-file restart)."""

    chunk_index: int
    offset: int
    length: int
    attempt: int
    expected_hex: str
    actual_hex: str
    detail: str


class _ChunkCorruption(Exception):
    """Internal: read-back digest disagreed with the source digest."""

    def __init__(self, expected: Digest, actual: Digest):
        super().__init__(describe_mismatch(expected, actual))
        self.expected, self.actual = expected, actual


# ---------------------------------------------------------------------------
# Transfer engine
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class ChunkOutcome:
    chunk: Chunk
    digest: Digest
    attempts: int
    mover: int
    seconds: float                 # total time on the chunk, retries included
    attempt_seconds: float = 0.0   # fault-excluded MOVER work time (tuner signal)
    cksum_seconds: float = 0.0     # checksum work on the mover path (source
    #                                fingerprint; + read-back verify when inline)
    cksum_lag_s: float = 0.0       # pipelined only: move-landed -> verified delay
    refetches: int = 0             # corruption-healing re-reads of this chunk


@dataclasses.dataclass
class _StripeSet:
    """Aggregation state for one striped chunk: per-stripe digests collect
    here and fold into the parent digest when the last stripe verifies."""

    parent: Chunk
    n: int
    digests: dict[int, Digest] = dataclasses.field(default_factory=dict)
    attempts: int = 0
    refetches: int = 0
    seconds: float = 0.0           # summed stripe mover time (work, not wall)
    attempt_seconds: float = 0.0
    cksum_seconds: float = 0.0
    cksum_lag_s: float = 0.0


@dataclasses.dataclass
class TransferReport:
    total_bytes: int
    file_digest: Digest
    outcomes: dict[int, ChunkOutcome]
    seconds: float
    retries: int
    skipped_chunks: int            # restored from journal (partial restart)
    speculated: int
    refetches: int = 0             # corrupt chunks healed by source re-read
    mover_deaths: int = 0          # worker threads lost mid-chunk, survived
    outage_retries: int = 0        # ops rejected by an endpoint outage window
    quarantined: tuple[QuarantineRecord, ...] = ()
    replans: int = 0               # mid-flight tail re-partitions (autotuner)
    chunk_bytes_final: int = 0     # nominal tail chunk size at completion
    pipeline: str = "serial"       # data-plane mode this transfer ran under
    cksum_lag_s: float = 0.0       # pipelined: total verification lag (sum)
    stripes: int = 1               # stripe fan-out at completion (tuner-led)
    striped_chunks: int = 0        # parent chunks that were striped
    stripe_replans: int = 0        # mid-flight stripe-count changes (tuner)
    deduped_chunks: int = 0        # chunks satisfied from the chunk index
    dedup_bytes_saved: int = 0     # wire bytes those chunks would have cost
    dedup_demoted: int = 0         # stale/corrupt index hits demoted to wire

    @property
    def gbps(self) -> float:
        return self.total_bytes * 8 / 1e9 / self.seconds if self.seconds > 0 else 0.0


class ChunkedTransfer:
    """Executes a ChunkPlan with integrity checking and chunk-level recovery."""

    def __init__(
        self,
        source: ByteSource,
        dest: ByteDest,
        plan: ChunkPlan,
        *,
        integrity: bool = True,
        journal: ChunkJournal | None = None,
        max_retries: int = 3,
        max_refetches: int = 3,            # re-reads per chunk on digest mismatch
        outage_retries: int = 64,          # endpoint-outage budget per chunk
        outage_backoff_s: float = 0.002,
        max_mover_deaths: int | None = None,   # None -> 4*movers + 4
        fault_injector: Callable[[Chunk, int], None] | None = None,
        speculative_factor: float = 0.0,   # >0 enables straggler duplication
        tuner=None,                        # ChunkController-like: observe(sample)
        alignment: int = 1,                # re-plan cut-point alignment
        pipeline: str = "serial",          # serial | single_pass | pipelined
        integrity_workers: int = 2,        # checksum worker pool (pipelined)
        stream_granule: int = DEFAULT_STREAM_GRANULE,
        pool: BufferPool | None = None,    # shared buffer pool (else per-run)
        tracer=None,                       # obs.Tracer: chunk-lifecycle spans
        task: str = "",                    # task id on spans/metrics labels
        stripes: int = 1,                  # >1 splits big chunks across movers
        stripe_min_bytes: int = 4 * 1024 * 1024,
        iov_batch: int = 1,                # granules per vectored I/O syscall
        dedup_index=None,                  # cas.ChunkIndex of the dest endpoint
        dedup_target: str = "",            # dest's canonical path in that index
    ):
        if source.nbytes != plan.total_bytes:
            raise ValueError(f"source has {source.nbytes} bytes, plan expects {plan.total_bytes}")
        if tuner is not None and speculative_factor > 0:
            raise ValueError(
                "speculative duplication and mid-flight re-planning are "
                "mutually exclusive: a speculated twin of a re-partitioned "
                "chunk would overlap the fresh tail chunks"
            )
        if pipeline not in PIPELINE_MODES:
            raise ValueError(f"pipeline must be one of {PIPELINE_MODES}, got {pipeline!r}")
        if pipeline == "pipelined" and speculative_factor > 0:
            raise ValueError(
                "speculative duplication forces serial verification: a "
                "speculated twin racing a deferred verify could journal a "
                "chunk the verifier has not vouched for"
            )
        if pipeline == "pipelined" and not integrity:
            pipeline = "single_pass"    # nothing to defer without read-back
        if integrity_workers < 1:
            raise ValueError("integrity_workers must be >= 1")
        if stripes < 1:
            raise ValueError("stripes must be >= 1")
        if stripes > 1 and speculative_factor > 0:
            raise ValueError(
                "speculative duplication and striping are mutually "
                "exclusive: a speculated twin duplicates whole plan chunks, "
                "but striped chunks land as sub-ranges the speculation "
                "watcher does not know about"
            )
        if stripe_min_bytes < 1:
            raise ValueError("stripe_min_bytes must be >= 1")
        self.source, self.dest, self.plan = source, dest, plan
        self.integrity = integrity
        self.pipeline = pipeline
        self.integrity_workers = integrity_workers
        self.stream_granule = max(1, int(stream_granule))
        self.journal = journal
        self.max_retries = max_retries
        self.max_refetches = max_refetches
        self.outage_retries = outage_retries
        self.outage_backoff_s = outage_backoff_s
        self.max_mover_deaths = max_mover_deaths
        self.fault_injector = fault_injector
        self.speculative_factor = speculative_factor
        self.tuner = tuner
        self.alignment = max(1, alignment)
        # observability: spans are emitted RETROACTIVELY from timestamps the
        # engine takes anyway (tuner telemetry), so the default NullTracer
        # costs one no-op call per phase on the hot path
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.task = task
        self._enq_t: dict[int, float] = {}    # chunk index -> last enqueue time
        self._m_chunks = obsmetrics.REGISTRY.counter(
            "chunks_total", "landed chunks", ("task", "pipeline"))
        self._m_bytes = obsmetrics.REGISTRY.counter(
            "bytes_total", "landed bytes", ("task", "pipeline"))
        self._m_retry = obsmetrics.REGISTRY.counter(
            "chunk_retries_total", "per-class chunk recovery events",
            ("task", "kind"))
        self._m_wire = obsmetrics.REGISTRY.histogram(
            "chunk_wire_seconds", "fault-excluded per-chunk mover time",
            ("task",), scale=1e-4)
        self._m_dedup = obsmetrics.REGISTRY.counter(
            "dedup_chunks_total", "chunks satisfied from the chunk index",
            ("task",))
        self._m_dedup_bytes = obsmetrics.REGISTRY.counter(
            "dedup_bytes_saved_total", "wire bytes saved by dedup hits",
            ("task",))
        self._lock = threading.Lock()
        self._cond = threading.Condition(self._lock)   # completion/error/death
        self._outcomes: dict[int, ChunkOutcome] = {}
        self._retries = 0
        self._refetches = 0
        self._outage_retries_seen = 0
        self._mover_deaths = 0
        self._speculated = 0
        self._quarantined: list[QuarantineRecord] = []
        self._errors: list[BaseException] = []
        self._target = 0           # chunks this run() must land
        self._live_workers = 0
        self._death_budget = 0
        # mid-flight re-plan state: the nominal tail size, a fresh-index
        # allocator that can never collide with journaled ids, and counters.
        # The controller is not thread-safe; movers serialize observe +
        # re-plan under a dedicated lock (separate from self._lock, which
        # _replan_queued itself acquires).
        self._tune_lock = threading.Lock()
        self._chunk_bytes_now = plan.chunk_bytes or plan.total_bytes
        self._next_index = plan.n_chunks
        self._replans = 0
        # striping state: stripe work items carry indices from the stripe
        # band; the parent map routes their commits into the _StripeSet that
        # folds per-stripe digests into the parent chunk digest. The index
        # allocator is bumped past any journaled stripe ids at run() so a
        # restarted incarnation can never re-issue a journaled stripe's id.
        self.stripes = int(stripes)
        self.stripe_min_bytes = int(stripe_min_bytes)
        self.iov_batch = max(1, int(iov_batch))
        self._stripe_parent: dict[int, Chunk] = {}
        self._stripe_sets: dict[int, _StripeSet] = {}
        self._next_stripe_index = STRIPE_INDEX_BASE
        self._striped_chunks = 0
        self._stripe_replans = 0
        # content plane: the destination endpoint's chunk index. Probed
        # before movers start (_negotiate_dedup); populated as verified
        # chunks commit so the NEXT transfer can skip them. Deduped chunks
        # never reach _move_chunk, so they feed neither the tuner's
        # congestion signal nor the wire metrics — by construction.
        self.dedup_index = dedup_index
        self.dedup_target = os.path.abspath(str(dedup_target)) if dedup_target else ""
        self._deduped_parts: list[tuple[int, Digest]] = []
        self._dedup_skip: set[int] = set()   # deduped plan-chunk ids
        self._deduped_chunks = 0
        self._dedup_bytes_saved = 0
        self._dedup_demoted = 0
        # zero-copy buffer pool: movers stream through granule-sized views,
        # serial verification and the integrity engine read back into
        # chunk-sized ones. Oversize requests (jumbo re-planned tails) fall
        # through to one-shot allocations inside the pool.
        if pool is None:
            buffer_bytes = max(
                self.stream_granule, min(self._chunk_bytes_now or 1, 64 * 1024 * 1024)
            )
            pool = BufferPool(
                buffer_bytes, capacity=plan.movers + integrity_workers + 2
            )
        self._pool = pool
        # pipelined state: the engine is armed per run(); movers enqueue
        # VerifyJobs, the callbacks below commit custody / quarantine.
        self._engine: IntegrityEngine | None = None
        self._queue: "queue.Queue[Chunk] | None" = None
        self._verify_refetches: dict[int, int] = {}

    # -- single chunk (one ERET/ESTO pair) --------------------------------
    def _copy_chunk(self, chunk: Chunk) -> tuple[Digest, float]:
        """One read -> fingerprint -> write pass over the chunk.

        Serial mode is the CLASSIC engine byte path, kept verbatim — whole-
        chunk ``bytes()`` read, full digest pass, write — it is the baseline
        the streaming modes are measured against. Streaming modes fingerprint
        granule-by-granule out of zero-copy views (or pooled buffers) while
        each granule is cache-hot, sharing the single pass with the
        destination write. Returns ``(source_digest, cksum_seconds)``.
        """
        if self.pipeline == "serial":
            data = self.source.read(chunk.offset, chunk.length)
            if len(data) != chunk.length:
                raise IOError(f"short read at {chunk.offset}: {len(data)}/{chunk.length}")
            # Source-side fingerprint while the data is in hand (the
            # paper's "modest cost incurred when first reading the file").
            t_ck = time.perf_counter()
            src_digest = fingerprint_bytes(data)
            cksum_s = time.perf_counter() - t_ck
            self.dest.write(chunk.offset, data)
            return src_digest, cksum_s
        # pipelined movers on view-capable sources are pure wire: the
        # integrity engine re-derives the source digest from the same view
        # off the mover path (tentpole rule: source fingerprinting runs
        # concurrently with subsequent chunk moves)
        defer_src = (
            self.pipeline == "pipelined"
            and self._engine is not None
            and hasattr(self.source, "read_view")
        )
        return stream_chunk(
            self.source, self.dest, chunk.offset, chunk.length,
            pool=self._pool, granule=self.stream_granule,
            digest=not defer_src, iov_batch=self.iov_batch,
        )

    # -- dedup negotiation (content plane) ---------------------------------
    def _negotiate_dedup(self, pending: list[Chunk]) -> list[Chunk]:
        """Probe pending chunks against the destination's chunk index and
        satisfy hits locally; returns the chunks that still need the wire.

        Runs once, before movers start. Each pending chunk's source bytes
        are fingerprinted (the source-side read the engine pays anyway for
        end-to-end integrity) and the digest probed against the index. A
        hit is satisfied WITHOUT a wire move: an alias entry (same target
        path + offset — the bytes are already in place) needs only its
        read-back verification; any other entry's backing bytes are
        re-verified, copied locally into the destination, and verified
        again after landing. Every satisfied chunk commits journal custody
        and folds into the whole-file digest chain exactly like a moved
        chunk, so the 0-escape guarantee is unconditional. A stale entry
        (missing, truncated, or rotted backing) is discarded with a
        quarantine record; if no live entry satisfies the chunk it demotes
        to a normal wire move — correctness never rests on the index.
        """
        index = self.dedup_index
        keep: list[Chunk] = []
        for c in pending:
            t_p = time.perf_counter()
            try:
                data = self.source.read(c.offset, c.length)
            except Exception:     # noqa: BLE001 — probe failure = wire move
                keep.append(c)
                continue
            if len(data) != c.length:
                keep.append(c)
                continue
            want = fingerprint_bytes(data)
            del data
            satisfied = False
            demoted_here = False
            aliased = False
            for e in index.lookup(want.hexdigest(), c.length):
                alias = bool(self.dedup_target) \
                    and os.path.abspath(e.path) == self.dedup_target \
                    and e.offset == c.offset
                backing = index.verify_entry(e)
                if backing is None:
                    # stale: drop the entry, record the event, keep
                    # probing other locations of the same content
                    index.discard(e.digest_hex, e.length, e.path, e.offset)
                    index.note_stale()
                    demoted_here = True
                    with self._lock:
                        self._quarantined.append(QuarantineRecord(
                            c.index, c.offset, c.length, 0,
                            e.digest_hex, "",
                            f"stale index entry {e.path}@{e.offset}: "
                            f"backing bytes failed re-verification",
                        ))
                    continue
                try:
                    if not alias:
                        self.dest.write(c.offset, backing)
                    back = self.dest.read_back(c.offset, c.length)
                except Exception:  # noqa: BLE001 — local copy failed
                    demoted_here = True
                    continue
                if not verify(want, fingerprint_bytes(back)):
                    # the local copy landed corrupt — wire move instead
                    demoted_here = True
                    continue
                satisfied, aliased = True, alias
                break
            now = time.perf_counter()
            if not satisfied:
                if demoted_here:
                    with self._lock:
                        self._dedup_demoted += 1
                    self._m_retry.inc(1, task=self.task, kind="dedup_demote")
                    self.tracer.add("dedup_demote", "dedup", t_p, now,
                                    task=self.task, lane="dedup",
                                    offset=c.offset, index=c.index)
                else:
                    self.tracer.add("dedup_probe", "dedup", t_p, now,
                                    task=self.task, lane="dedup",
                                    offset=c.offset, index=c.index)
                keep.append(c)
                continue
            # custody: the journal record is what makes a deduped chunk
            # indistinguishable from a moved one on restart — kill+restart
            # must never re-move it (same rule as wire custody)
            if self.journal is not None:
                self.journal.append(JournalRecord(
                    c.index, c.offset, c.length, want.hexdigest()))
            if self.dedup_target and not aliased:
                index.put(want.hexdigest(), c.length,
                          self.dedup_target, c.offset)
            self._deduped_parts.append((c.offset, want))
            self._dedup_skip.add(c.index)
            self._deduped_chunks += 1
            self._dedup_bytes_saved += c.length
            self._m_dedup.inc(1, task=self.task)
            self._m_dedup_bytes.inc(c.length, task=self.task)
            self.tracer.add("dedup_hit", "dedup", t_p, now,
                            task=self.task, lane="dedup",
                            offset=c.offset, index=c.index,
                            alias=int(aliased))
        return keep

    # -- intra-chunk striping ----------------------------------------------
    def _expand_work(self, chunks: list[Chunk]) -> list[Chunk]:
        """Split stripe-eligible chunks into stripe work items.

        Caller must hold ``self._lock`` or be single-threaded (run() setup):
        this touches the stripe registries and the stripe index allocator.
        Each stripe becomes an ordinary work item — queued, moved, retried,
        verified, and journaled exactly like a chunk — except its commit is
        routed into the parent's ``_StripeSet`` and the parent only counts
        as landed when every stripe has verified (the journal custody rule).
        """
        if self.stripes <= 1:
            return chunks
        out: list[Chunk] = []
        for c in chunks:
            sp = plan_stripes(c, self.stripes,
                              stripe_min_bytes=self.stripe_min_bytes,
                              alignment=self.alignment)
            if sp.n_stripes <= 1:
                out.append(c)
                continue
            self._striped_chunks += 1
            self._stripe_sets[c.index] = _StripeSet(parent=c, n=sp.n_stripes)
            for s in sp.stripes:
                widx = self._next_stripe_index
                self._next_stripe_index += 1
                item = Chunk(index=widx, offset=s.offset, length=s.length,
                             mover=(c.mover + s.seq) % max(1, self.plan.movers))
                self._stripe_parent[widx] = c
                out.append(item)
        return out

    def _span_extra(self, chunk: Chunk) -> dict:
        """Span kwargs tying a stripe's spans to its parent chunk's chain."""
        p = self._stripe_parent.get(chunk.index)
        return {"parent_offset": p.offset} if p is not None else {}

    def _move_chunk(self, chunk: Chunk, mover: int) -> ChunkOutcome:
        """Move one chunk with per-failure-class recovery budgets.

        * generic I/O error  -> up to ``max_retries`` in-place retries;
        * digest mismatch    -> quarantine + re-fetch from source, up to
          ``max_refetches`` times (chunk-granular corruption healing);
        * endpoint outage    -> wait out the window on its own (larger)
          budget with backoff — outages must not eat the chunk's budget;
        * mover crash        -> NOT retried here: the mover is gone, the
          exception propagates and the worker re-queues the chunk.
        """
        attempts = generic = refetches = outages = 0
        t0 = time.perf_counter()
        signal_s = 0.0    # fault-excluded work time, the autotuner's rate base:
        # generic I/O retries (loss, congestion) COUNT — they are the path
        # slowing down; corruption re-fetches and outage waits do NOT — they
        # are fault recovery and must not masquerade as congestion
        while True:
            attempts += 1
            t_att = time.perf_counter()
            try:
                if self.fault_injector is not None:
                    self.fault_injector(chunk, attempts)
                src_digest, cksum_s = self._copy_chunk(chunk)
                if self.integrity and self.pipeline == "serial":
                    # classic inline verification, kept verbatim
                    t_ck = time.perf_counter()
                    back = self.dest.read_back(chunk.offset, chunk.length)
                    dst_digest = fingerprint_bytes(back)
                    cksum_s += time.perf_counter() - t_ck
                    if not verify(src_digest, dst_digest):
                        raise _ChunkCorruption(src_digest, dst_digest)
                elif self.integrity and self.pipeline == "single_pass":
                    # inline verification through the zero-copy read-back path
                    t_ck = time.perf_counter()
                    dst_digest = read_back_fingerprint(
                        self.dest, chunk.offset, chunk.length,
                        pool=self._pool, granule=self.stream_granule)
                    cksum_s += time.perf_counter() - t_ck
                    if not verify(src_digest, dst_digest):
                        raise _ChunkCorruption(src_digest, dst_digest)
                now = time.perf_counter()
                # retroactive spans: wire = the successful attempt minus its
                # inline checksum share (placed at the attempt's tail — the
                # durations are exact, the sub-placement is synthetic)
                wire_end = max(t_att, now - cksum_s)
                lane = f"mover{mover}"
                extra = self._span_extra(chunk)
                self.tracer.add("move", "wire", t_att, wire_end,
                                task=self.task, lane=lane,
                                offset=chunk.offset, index=chunk.index,
                                attempt=attempts, **extra)
                if cksum_s > 0.0:
                    self.tracer.add("cksum_inline", "cksum", wire_end, now,
                                    task=self.task, lane=lane,
                                    offset=chunk.offset, index=chunk.index,
                                    **extra)
                self._m_wire.observe(signal_s + (now - t_att), task=self.task)
                return ChunkOutcome(
                    chunk, src_digest, attempts, mover, now - t0,
                    attempt_seconds=signal_s + (now - t_att),
                    cksum_seconds=cksum_s,
                    refetches=refetches,
                )
            except MoverCrash:
                raise
            except _ChunkCorruption as c:
                refetches += 1
                self.tracer.add("refetch", "stall", t_att,
                                time.perf_counter(), task=self.task,
                                lane=f"mover{mover}", offset=chunk.offset,
                                index=chunk.index, attempt=attempts)
                self._m_retry.inc(1, task=self.task, kind="refetch")
                with self._lock:
                    self._retries += 1
                    self._refetches += 1
                    self._quarantined.append(QuarantineRecord(
                        chunk.index, chunk.offset, chunk.length, attempts,
                        c.expected.hexdigest(), c.actual.hexdigest(), str(c),
                    ))
                if refetches > self.max_refetches:
                    raise IntegrityError(
                        f"chunk {chunk.index} digest mismatch persisted through "
                        f"{self.max_refetches} re-fetches (offset={chunk.offset}, "
                        f"len={chunk.length}): {c}"
                    ) from None
            except EndpointOutage:
                outages += 1
                with self._lock:
                    self._outage_retries_seen += 1
                self._m_retry.inc(1, task=self.task, kind="outage")
                if outages > self.outage_retries:
                    self.tracer.add("outage_wait", "stall", t_att,
                                    time.perf_counter(), task=self.task,
                                    lane=f"mover{mover}", offset=chunk.offset,
                                    index=chunk.index)
                    raise
                Backoff(self.outage_backoff_s, mode="linear",
                        lane=f"{self.task}:mover{mover}:{chunk.index}",
                        ).sleep(outages)
                # the rejected op plus its backoff is fault recovery, not
                # congestion — same exclusion rule as the tuner's rate signal
                self.tracer.add("outage_wait", "stall", t_att,
                                time.perf_counter(), task=self.task,
                                lane=f"mover{mover}", offset=chunk.offset,
                                index=chunk.index)
            except Exception:
                generic += 1
                now = time.perf_counter()
                signal_s += now - t_att   # congestion-like
                # a generic-I/O retry IS the path slowing down: its time is
                # wire, not stall (mirrors the tuner's congestion signal)
                self.tracer.add("move_retry", "wire", t_att, now,
                                task=self.task, lane=f"mover{mover}",
                                offset=chunk.offset, index=chunk.index,
                                attempt=attempts)
                self._m_retry.inc(1, task=self.task, kind="generic")
                if generic > self.max_retries:
                    raise
                with self._lock:
                    self._retries += 1

    def _enqueue(self, q: "queue.Queue[Chunk]", chunk: Chunk) -> None:
        """Queue a chunk, timestamping it so pickup emits a queue-wait span."""
        self._enq_t[chunk.index] = time.perf_counter()
        q.put(chunk)

    # -- worker loop: pull-from-queue == work stealing ---------------------
    def _worker(self, mover: int, q: "queue.Queue[Chunk]") -> None:
        try:
            while True:
                with self._lock:
                    if self._errors or len(self._outcomes) >= self._target:
                        return
                try:
                    chunk = q.get(timeout=0.02)
                except queue.Empty:
                    continue           # in-flight chunks may still re-queue
                with self._lock:
                    if chunk.index in self._outcomes:   # speculated twin landed
                        continue
                enq = self._enq_t.get(chunk.index)
                if enq is not None:
                    self.tracer.add("queue_wait", "queue", enq,
                                    time.perf_counter(), task=self.task,
                                    lane=f"mover{mover}", offset=chunk.offset,
                                    index=chunk.index,
                                    **self._span_extra(chunk))
                try:
                    out = self._move_chunk(chunk, mover)
                except MoverCrash:
                    # the mover dies; the chunk survives it (re-queued for
                    # whoever is left — or for a respawn if nobody is)
                    with self._lock:
                        self._mover_deaths += 1
                        over = self._mover_deaths > self._death_budget
                        if over:
                            self._errors.append(RuntimeError(
                                f"mover-death budget exhausted "
                                f"({self._mover_deaths} > {self._death_budget})"
                            ))
                    if not over:
                        self._enqueue(q, chunk)
                    return
                except BaseException as e:  # noqa: BLE001 — propagated to caller
                    with self._lock:
                        self._errors.append(e)
                    return
                if self._engine is not None:
                    # pipelined: the move landed; hand verification to the
                    # integrity engine and pull the next chunk NOW. Custody
                    # (outcome + journal) commits in _on_verified only; a
                    # corrupt landing re-queues the chunk in _on_corrupt.
                    self._engine.submit(VerifyJob(
                        key=chunk, offset=chunk.offset, length=chunk.length,
                        expected=out.digest, dest=self.dest,
                        enqueued_s=time.perf_counter(), payload=out,
                        source=self.source if out.digest is None else None,
                    ))
                    continue
                if not self._commit_outcome(chunk, out, q):
                    return
        finally:
            with self._cond:
                self._live_workers -= 1
                self._cond.notify_all()    # wake the supervisor on death/error

    # -- custody commit (serial workers AND integrity-engine callbacks) ----
    def _commit_outcome(self, chunk: Chunk, out: ChunkOutcome,
                        q: "queue.Queue[Chunk]") -> bool:
        """Record one verified chunk: outcome map, journal custody, tuner
        feed. Returns False when a hard error was recorded instead."""
        with self._lock:
            first = chunk.index not in self._outcomes
            if first:
                self._outcomes[chunk.index] = out
                if len(self._outcomes) >= self._target:
                    self._cond.notify_all()
        if first and self.journal is not None:
            t_j = time.perf_counter()
            try:
                self.journal.append(
                    JournalRecord(chunk.index, chunk.offset, chunk.length,
                                  out.digest.hexdigest())
                )
            except Exception as e:  # noqa: BLE001 — dead journal:
                with self._lock:    # fail fast, don't churn movers
                    self._errors.append(RuntimeError(
                        f"journal append failed for chunk {chunk.index}: {e}"
                    ))
                    self._cond.notify_all()
                return False
            # the journal fsync is a real per-chunk control-plane
            # cost: the tuner must see it, or it will shrink chunks
            # into a journal-bound regime on slow filesystems
            j_secs = time.perf_counter() - t_j
            out.seconds += j_secs
            out.attempt_seconds += j_secs
            self.tracer.add("journal_append", "journal", t_j, t_j + j_secs,
                            task=self.task, lane="journal",
                            offset=chunk.offset, index=chunk.index)
        if first:
            self._m_chunks.inc(1, task=self.task, pipeline=self.pipeline)
            self._m_bytes.inc(chunk.length, task=self.task,
                              pipeline=self.pipeline)
            # index population: a verified, journaled chunk is exactly what
            # a future transfer may dedup against (stripes index at the
            # parent level in _finish_stripe — probe keys are chunk-sized)
            if (self.dedup_index is not None and self.dedup_target
                    and chunk.index not in self._stripe_parent):
                try:
                    self.dedup_index.put(out.digest.hexdigest(), chunk.length,
                                         self.dedup_target, chunk.offset)
                except Exception:  # noqa: BLE001 — cache: failed put = miss
                    pass
        if not first:
            return True
        parent = self._stripe_parent.get(chunk.index)
        if parent is not None:
            # a stripe's journal record is its own custody; the parent-level
            # commit (tuner feed, stripe_commit mark) waits for the full set
            return self._finish_stripe(parent, chunk, out, q)
        return self._feed_tuner(out, q, chunk.index)

    def _finish_stripe(self, parent: Chunk, chunk: Chunk, out: ChunkOutcome,
                       q: "queue.Queue[Chunk]") -> bool:
        """Fold one verified stripe into its parent's stripe set; on the last
        stripe, derive the parent chunk digest via the merge law and feed the
        tuner ONE aggregated outcome (per-stripe samples would look like
        tiny chunks and drag the controller toward the floor)."""
        with self._lock:
            st = self._stripe_sets[parent.index]
            st.digests[chunk.offset] = out.digest
            st.attempts += out.attempts
            st.refetches += out.refetches
            st.seconds += out.seconds
            st.attempt_seconds += out.attempt_seconds
            st.cksum_seconds += out.cksum_seconds
            st.cksum_lag_s = max(st.cksum_lag_s, out.cksum_lag_s)
            done = len(st.digests) == st.n
        if not done:
            return True
        # partition refinement: stripe digests in offset order ARE the chunk
        # digest — no extra hashing pass over the parent's bytes
        digest = merge_all(d for _, d in sorted(st.digests.items()))
        self.tracer.mark("stripe_commit", "journal", task=self.task,
                         offset=parent.offset, index=parent.index,
                         stripes=st.n)
        if self.dedup_index is not None and self.dedup_target:
            try:
                self.dedup_index.put(digest.hexdigest(), parent.length,
                                     self.dedup_target, parent.offset)
            except Exception:  # noqa: BLE001 — cache: failed put = miss
                pass
        parent_out = ChunkOutcome(
            parent, digest, st.attempts, -1, st.seconds,
            attempt_seconds=st.attempt_seconds,
            cksum_seconds=st.cksum_seconds,
            cksum_lag_s=st.cksum_lag_s,
            refetches=st.refetches,
        )
        return self._feed_tuner(parent_out, q, parent.index)

    def _feed_tuner(self, out: ChunkOutcome, q: "queue.Queue[Chunk]",
                    idx: int) -> bool:
        """Feed one landed-chunk sample to the controller and act on its
        chunk-size / stripe-count targets. Returns False on controller error."""
        if self.tuner is None:
            return True
        try:
            with self._tune_lock:
                new = self.tuner.observe_outcome(out)
                stripe_changed = False
                ns = getattr(self.tuner, "target_stripes", None)
                if callable(ns):
                    want = int(ns())
                    if want >= 1 and want != self.stripes:
                        with self._lock:
                            self.stripes = want
                            self._stripe_replans += 1
                        self.tracer.mark("stripe_replan", "plan",
                                         task=self.task, stripes=want)
                        stripe_changed = True
                if new is not None and new != self._chunk_bytes_now:
                    self._replan_queued(q, new)
                elif stripe_changed:
                    # a stripe-count change alone must also re-expand the
                    # un-started tail: the new fan-out takes effect now, not
                    # at the next chunk-size replan (which may never come
                    # when the size is pinned at a bound)
                    self._replan_queued(q, self._chunk_bytes_now)
        except Exception as e:  # noqa: BLE001 — controller bug
            with self._lock:    # must fail the transfer, not hang it
                self._errors.append(RuntimeError(
                    f"autotuner failed after chunk {idx}: {e}"
                ))
                self._cond.notify_all()
            return False
        return True

    # -- integrity-engine callbacks (pipelined mode, verifier threads) -----
    def _on_verified(self, job: VerifyJob, lag_s: float, ck_s: float) -> None:
        del ck_s          # verify work is off the mover path; lag carries it
        chunk: Chunk = job.key
        out: ChunkOutcome = job.payload
        out.cksum_lag_s = lag_s
        if out.digest is None:
            out.digest = job.expected      # deferred source fingerprint
        with self._lock:
            out.refetches += self._verify_refetches.get(chunk.index, 0)
        self._commit_outcome(chunk, out, self._queue)

    def _on_corrupt(self, job: VerifyJob, actual: Digest, lag_s: float) -> None:
        """A lagging verifier caught a corrupt landing: quarantine the chunk
        and re-queue it for a source re-fetch (same budget as inline)."""
        del lag_s
        chunk: Chunk = job.key
        out: ChunkOutcome = job.payload
        detail = describe_mismatch(job.expected, actual)
        with self._lock:
            self._retries += 1
            self._refetches += 1
            n = self._verify_refetches.get(chunk.index, 0) + 1
            self._verify_refetches[chunk.index] = n
            self._quarantined.append(QuarantineRecord(
                chunk.index, chunk.offset, chunk.length, out.attempts,
                job.expected.hexdigest(), actual.hexdigest(), detail,
            ))
            over = n > self.max_refetches
            if over:
                self._errors.append(IntegrityError(
                    f"chunk {chunk.index} digest mismatch persisted through "
                    f"{self.max_refetches} re-fetches (offset={chunk.offset}, "
                    f"len={chunk.length}): {detail}"
                ))
                self._cond.notify_all()
        if not over:
            # re-move from source (quarantine heal)
            self._enqueue(self._queue, chunk)

    def _on_verify_error(self, job: VerifyJob, exc: BaseException) -> None:
        chunk: Chunk = job.key
        with self._lock:
            self._errors.append(RuntimeError(
                f"deferred verification read-back failed for chunk "
                f"{chunk.index} (offset={chunk.offset}): {exc}"
            ))
            self._cond.notify_all()

    # -- mid-flight tail re-planning (the autotuner's actuator) ------------
    def _replan_queued(self, q: "queue.Queue[Chunk]", new_bytes: int) -> int:
        """Re-partition the un-started tail at ``new_bytes`` nominal size.

        Only chunks still sitting in the queue — never started, never
        journaled — are re-cut. Journaled custody and in-flight chunks keep
        their exact boundaries, so partition refinement keeps the merge-law
        digest chain composable: the final (offset, digest) parts still tile
        the file exactly. Returns the number of chunks re-planned away.
        """
        drained: list[Chunk] = []
        while True:
            try:
                drained.append(q.get_nowait())
            except queue.Empty:
                break
        # stripe work items keep their boundaries: their parent's _StripeSet
        # is already sized, and a journaled sibling pins the partition — only
        # whole un-started plain chunks are re-cuttable
        kept = [c for c in drained if c.index >= STRIPE_INDEX_BASE]
        plain = [c for c in drained if c.index < STRIPE_INDEX_BASE]
        if not plain:
            for c in kept:
                self._enqueue(q, c)
            return 0
        regions = merge_regions([(c.offset, c.length) for c in plain])
        with self._lock:
            fresh = partition_regions(
                regions, new_bytes, start_index=self._next_index,
                movers=self.plan.movers, alignment=self.alignment,
            )
            self._next_index += len(fresh)
            fresh = self._expand_work(fresh)
            self._target += len(fresh) - len(plain)
            if max(self.alignment, int(new_bytes)) != self._chunk_bytes_now:
                self._replans += 1      # stripe-only re-expansions don't count
            self._chunk_bytes_now = max(self.alignment, int(new_bytes))
        self.tracer.mark("replan", "plan", task=self.task,
                         chunk_bytes=int(new_bytes), recut=len(fresh))
        for c in kept:
            self._enqueue(q, c)
        for c in fresh:
            self._enqueue(q, c)
        return len(plain)

    def run(self) -> TransferReport:
        t0 = time.perf_counter()
        recs: dict[int, JournalRecord] = (
            dict(self.journal.records) if self.journal is not None else {}
        )
        resumed_parts = [(r.offset, r.digest()) for r in recs.values()]
        # Static resume: every journaled record matches its plan chunk
        # byte-for-byte (the untuned engine's invariant — preserved exactly).
        # A journal written by a re-planned incarnation has records at other
        # boundaries; then resume is region-based: journaled custody regions
        # are subtracted from the file and fresh chunks (fresh indices, no id
        # collisions) are carved out of the gaps — a journaled chunk can
        # never be re-moved because its bytes are not in any gap.
        static_resume = all(
            idx < self.plan.n_chunks
            and self.plan.chunks[idx].offset == r.offset
            and self.plan.chunks[idx].length == r.length
            for idx, r in recs.items()
        )
        if static_resume:
            pending = [c for c in self.plan.chunks if c.index not in recs]
        else:
            gaps = subtract_regions(
                self.plan.total_bytes, [(r.offset, r.length) for r in recs.values()]
            )
            # the plain-index allocator must not absorb stripe-band ids: a
            # max() over a journal holding stripe records would catapult it
            # into the stripe band and collide with fresh stripe items
            self._next_index = max(
                max((i for i in recs if i < STRIPE_INDEX_BASE), default=-1) + 1,
                self.plan.n_chunks,
            )
            pending = partition_regions(
                gaps, self._chunk_bytes_now, start_index=self._next_index,
                movers=self.plan.movers, alignment=self.alignment,
            )
            self._next_index += len(pending)
        # stripe ids of a crashed striped incarnation are journal keys too:
        # resume the stripe allocator past them or the journal dict would
        # overwrite old custody records on the next crash
        self._next_stripe_index = max(
            self._next_stripe_index,
            max((i + 1 for i in recs if i >= STRIPE_INDEX_BASE),
                default=STRIPE_INDEX_BASE),
        )
        # content plane: satisfy index hits locally before any mover starts
        # (deduped chunks journal custody and leave pending entirely)
        if self.dedup_index is not None and pending:
            pending = self._negotiate_dedup(pending)
        pending = self._expand_work(pending)
        q: "queue.Queue[Chunk]" = queue.Queue()
        for c in pending:
            self._enqueue(q, c)
        self._target = len(pending)
        self._queue = q
        if self.pipeline == "pipelined" and self.integrity and pending:
            self._engine = IntegrityEngine(
                workers=self.integrity_workers, pool=self._pool,
                on_verified=self._on_verified, on_corrupt=self._on_corrupt,
                on_error=self._on_verify_error,
                tracer=self.tracer, task=self.task,
            )
        # warm start: a SimTuner-seeded controller may already disagree with
        # the static plan — re-cut the whole tail before the first byte moves
        if self.tuner is not None and pending:
            tgt = int(self.tuner.target())
            if tgt > 0 and tgt != self._chunk_bytes_now:
                self._replan_queued(q, tgt)
        n_pending = self._target

        movers = max(1, min(self.plan.movers, n_pending)) if n_pending else 0
        if self.max_mover_deaths is not None:
            self._death_budget = self.max_mover_deaths
        else:
            self._death_budget = 4 * movers + 4
        threads: list[threading.Thread] = []

        def spawn(mover_id: int) -> None:
            with self._lock:
                self._live_workers += 1
            th = threading.Thread(target=self._worker, args=(mover_id, q), daemon=True)
            threads.append(th)
            th.start()

        for m in range(movers):
            spawn(m)
        # Straggler mitigation: when the queue drains, re-enqueue the oldest
        # in-flight chunks so idle movers can duplicate them (first write wins
        # — writes are idempotent on disjoint ranges). Only meaningful for
        # static plans: a region-resumed tail has fresh indices the static
        # plan does not know about (and tuner+speculation is rejected above).
        if self.speculative_factor > 0 and pending and static_resume:
            watcher = threading.Thread(
                target=self._speculate,
                args=(q, movers, set(recs) | self._dedup_skip), daemon=True
            )
            watcher.start()
        # Supervise: the transfer outlives its movers. If every worker died
        # (MoverCrash) with work outstanding, spawn a replacement. Sleeps on
        # the condition workers signal at completion, error, and death — no
        # busy-polling in the fault-free path.
        next_mover = movers
        while n_pending:
            with self._cond:
                if self._errors or len(self._outcomes) >= self._target:
                    break
                if self._live_workers > 0:
                    self._cond.wait(0.1)
                    continue
            spawn(next_mover)
            next_mover += 1
        for th in threads:
            th.join()
        if self._engine is not None:
            # fault-free exits leave an empty digest queue (movers only stop
            # once every outcome landed); on error, let queued jobs get their
            # verdicts — their quarantine records are part of the story
            self._engine.close(abandon=False)
        # the root span carries the makespan (obs.attr's default window) and
        # is emitted on the error path too — post-mortem traces need it most
        self.tracer.add("transfer", "task", t0, time.perf_counter(),
                        task=self.task, lane="", pipeline=self.pipeline,
                        bytes=self.plan.total_bytes)
        if self._errors:
            raise self._errors[0]

        # merge-law combine over whatever boundaries actually landed: chunk
        # sets from re-planned incarnations tile the file just as well as the
        # original plan (partition refinement keeps digests composable)
        parts = [(out.chunk.offset, out.digest) for out in self._outcomes.values()]
        parts += resumed_parts
        parts += self._deduped_parts
        file_digest = combine_at_offsets(parts, self.plan.total_bytes)
        return TransferReport(
            total_bytes=self.plan.total_bytes,
            file_digest=file_digest,
            outcomes=self._outcomes,
            seconds=time.perf_counter() - t0,
            retries=self._retries,
            skipped_chunks=len(recs),
            speculated=self._speculated,
            refetches=self._refetches,
            mover_deaths=self._mover_deaths,
            outage_retries=self._outage_retries_seen,
            quarantined=tuple(self._quarantined),
            replans=self._replans,
            chunk_bytes_final=self._chunk_bytes_now,
            pipeline=self.pipeline,
            cksum_lag_s=sum(o.cksum_lag_s for o in self._outcomes.values()),
            stripes=self.stripes,
            striped_chunks=self._striped_chunks,
            stripe_replans=self._stripe_replans,
            deduped_chunks=self._deduped_chunks,
            dedup_bytes_saved=self._dedup_bytes_saved,
            dedup_demoted=self._dedup_demoted,
        )

    def _speculate(self, q: "queue.Queue[Chunk]", movers: int, skip: set[int]) -> None:
        # NOTE: journaled chunks (``skip``) must never be duplicated — a
        # speculated twin of an already-landed chunk would re-move journaled
        # bytes, the exact thing partial restart exists to avoid.
        target = self._target
        while True:
            time.sleep(0.005)
            with self._lock:
                done = len(self._outcomes)
                if done >= target or self._errors:
                    return
                if q.qsize() <= movers and target - done <= movers:
                    missing = [c for c in self.plan.chunks
                               if c.index not in self._outcomes and c.index not in skip]
                    for c in missing[: movers]:
                        self._enqueue(q, c)
                        self._speculated += 1
                    return


def transfer_verified(
    source: ByteSource,
    dest: ByteDest,
    plan: ChunkPlan,
    expected: Digest | None = None,
    **kw,
) -> TransferReport:
    """One-shot helper: run the transfer; optionally check the end-to-end digest."""
    report = ChunkedTransfer(source, dest, plan, **kw).run()
    if expected is not None and not verify(expected, report.file_digest):
        raise IntegrityError(
            f"end-to-end digest mismatch: expected {expected.hexdigest()}, "
            f"got {report.file_digest.hexdigest()}"
        )
    return report
