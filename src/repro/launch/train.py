"""Training driver: data pipeline -> train_step -> chunked checkpoints.

Fault tolerance story (exercised by tests/test_train_loop.py and
examples/train_e2e.py):

  * checkpoints are chunked + integrity-checked + journaled (repro.ckpt);
    a crash mid-save leaves a resumable journal, a crash between saves
    restarts from the latest verified step;
  * the data pipeline is (seed, step)-keyed, so restore(step) resumes the
    exact sample order;
  * **elastic restart**: checkpoints are mesh-agnostic (host-side arrays +
    PartitionSpecs re-derived per mesh), so a job that lost nodes restarts on
    a smaller --mesh from the same checkpoint — the paper's partial-restart
    behaviour lifted to whole-job scale;
  * stragglers: the checkpoint writer's movers pull chunks from a shared
    queue (work stealing), and slow chunk writes can be speculatively
    duplicated (core.transfer.speculative_factor).

Usage (CPU example — reduced config):
  PYTHONPATH=src python -m repro.launch.train --arch gemma-2b --smoke \
      --mesh 2x2 --steps 40 --ckpt-dir /tmp/ck --ckpt-every 10
"""
from __future__ import annotations

import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.ckpt import CheckpointManager
from repro.configs.registry import SHAPES, ShapeCell, build_model
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.distributed.mesh import make_mesh
from repro.launch.steps import build_train_step
from repro.optim import adamw


def parse_mesh(spec: str):
    dims = [int(x) for x in spec.split("x")]
    if len(dims) == 2:
        names = ("data", "model")
    elif len(dims) == 3:
        names = ("pod", "data", "model")
    else:
        raise ValueError(spec)
    devices = jax.devices()[: int(np.prod(dims))]
    if len(devices) < int(np.prod(dims)):
        raise RuntimeError(f"mesh {spec} needs {np.prod(dims)} devices, have {len(devices)}")
    return make_mesh(tuple(dims), names, devices=devices)


def restore_into(mesh, model, ocfg, mgr: CheckpointManager):
    """Mesh-agnostic restore: host arrays -> shardings of THIS mesh."""
    tree, step = mgr.restore()
    pspecs = model.param_specs(mesh)
    params = jax.tree.map(
        lambda x, s: jax.device_put(x, NamedSharding(mesh, s)), tree["params"], pspecs)
    m = jax.tree.map(
        lambda x, s: jax.device_put(x, NamedSharding(mesh, s)), tree["opt"]["m"], pspecs)
    v = jax.tree.map(
        lambda x, s: jax.device_put(x, NamedSharding(mesh, s)), tree["opt"]["v"], pspecs)
    opt = adamw.OptState(step=jnp.asarray(tree["opt"]["step"]), m=m, v=v)
    return params, opt, step


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--smoke", action="store_true", help="reduced config (CPU)")
    ap.add_argument("--mesh", default="1x1")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--sync-mode", default="auto", choices=["auto", "chunked"])
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=5)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    mesh = parse_mesh(args.mesh)
    model = build_model(args.arch, mesh, smoke=args.smoke)
    cfg = model.cfg
    cell = ShapeCell("custom", args.seq_len, args.global_batch, "train")
    ocfg = adamw.AdamWConfig(lr=args.lr, warmup_steps=10)
    bundle = build_train_step(model, mesh, ocfg, cell=cell,
                              microbatches=args.microbatches,
                              sync_mode=args.sync_mode)
    with mesh:
        step_fn = jax.jit(bundle.fn, in_shardings=bundle.in_shardings,
                          out_shardings=bundle.out_shardings)

        mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
        start = 0
        if mgr is not None and mgr.latest_step() is not None:
            params, opt, start = restore_into(mesh, model, ocfg, mgr)
            print(f"[restore] resumed from step {start} ({mgr.root})")
        else:
            pspecs = model.param_specs(mesh)
            params = jax.jit(
                lambda: model.init_params(args.seed),
                out_shardings=jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs),
            )()
            opt = adamw.init(params, ocfg)

        data = TokenPipeline(
            DataConfig(vocab=cfg.vocab, seq_len=args.seq_len,
                       global_batch=args.global_batch, seed=args.seed),
            mesh, start_step=start)

        losses = []
        t0 = time.perf_counter()
        for step in range(start, args.steps):
            batch = next(data)
            if cfg.family == "encdec":
                batch["audio_embed"] = jnp.zeros(
                    (args.global_batch, cfg.enc_positions, cfg.d_model), cfg.dtype)
            if cfg.family == "vlm":
                batch["vis_embed"] = jnp.zeros(
                    (args.global_batch, cfg.n_vis_tokens, cfg.d_model), cfg.dtype)
            params, opt, stats = step_fn(params, opt, batch)
            loss = float(stats["loss"])
            losses.append(loss)
            if args.log_every and (step + 1) % args.log_every == 0:
                dt = (time.perf_counter() - t0) / max(1, len(losses))
                print(f"step {step+1:5d}  loss {loss:8.4f}  "
                      f"gnorm {float(stats['grad_norm']):8.3f}  {dt*1e3:6.0f} ms/step",
                      flush=True)
            if mgr is not None and args.ckpt_every and (step + 1) % args.ckpt_every == 0:
                rep = mgr.save(step + 1, {"params": params,
                                          "opt": {"step": opt.step, "m": opt.m, "v": opt.v}})
                print(f"[ckpt] step {step+1}: {rep.total_bytes/1e6:.1f} MB "
                      f"in {rep.seconds:.2f}s (resumed_chunks={rep.resumed_chunks})",
                      flush=True)
        data.close()
    return {"losses": losses, "final_loss": losses[-1] if losses else None}


if __name__ == "__main__":
    out = main()
    print(f"final loss: {out['final_loss']:.4f}")
