"""Launchers: mesh construction, step builders, dry-run, train/serve drivers."""
