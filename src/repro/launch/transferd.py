"""transferd — drive the transfer-task service from the command line.

Two modes:

  * testbed (default): run a mixed multi-tenant workload through the
    service scheduling stack in virtual time against the calibrated
    ALCF->NERSC simulator, and report aggregate Gb/s + task-latency
    percentiles per allocation policy. This answers "which mover-allocation
    policy should the service run?" without a testbed:

        PYTHONPATH=src python -m repro.launch.transferd \\
            --policy all --small 1000 --small-mb 100 --large 4 --large-gb 1000

  * --real DIR: spin a *real* TransferService in DIR, generate a small mixed
    batch of local files, submit them across two tenants, and print each
    task's lifecycle — a smoke test of the wall-clock path:

        PYTHONPATH=src python -m repro.launch.transferd --real /tmp/transferd
"""
from __future__ import annotations

import argparse
import os
import time

from repro.core.chunker import MiB
from repro.core.simulator import SITES
from repro.service import (
    BatchConfig,
    ServiceConfig,
    TransferService,
    mixed_workload,
    run_load,
)

POLICIES = ("fair", "file_bound", "marginal")


def _fmt_row(policy: str, rep) -> str:
    return (
        f"{policy:11s} {rep.aggregate_gbps:9.2f} {rep.makespan_s:11.1f} "
        f"{rep.p50_s:9.1f} {rep.p99_s:9.1f} {len(rep.tasks):6d}"
    )


def run_testbed(args) -> dict[str, object]:
    work = mixed_workload(
        n_small=args.small,
        small_bytes=args.small_mb * 1000 * 1000,
        n_large=args.large,
        large_bytes=args.large_gb * 1000 * 1000 * 1000,
        tenants=args.tenants,
    )
    total = sum(sum(s.file_bytes) for s in work)
    print(f"# workload: {args.small} x {args.small_mb} MB + "
          f"{args.large} x {args.large_gb} GB over {args.tenants} tenants "
          f"({total / 1e12:.2f} TB total)")
    print(f"# budget: {args.movers} movers, {args.concurrent} concurrent tasks, "
          f"{args.src}->{args.dst}, chunk {args.chunk_mb} MB")
    print(f"{'policy':11s} {'agg Gb/s':>9s} {'makespan s':>11s} "
          f"{'p50 s':>9s} {'p99 s':>9s} {'tasks':>6s}")
    policies = POLICIES if args.policy == "all" else (args.policy,)
    reports = {}
    for pol in policies:
        t0 = time.perf_counter()
        rep = run_load(
            work,
            policy=pol,
            mover_budget=args.movers,
            max_concurrent=args.concurrent,
            chunk_bytes=args.chunk_mb * 1000 * 1000,
            src=SITES[args.src],
            dst=SITES[args.dst],
            batch=BatchConfig(
                direct_bytes=args.direct_mb * 1000 * 1000,
                batch_files=args.batch_files,
            ),
        )
        reports[pol] = rep
        print(_fmt_row(pol, rep) + f"   ({time.perf_counter() - t0:.1f}s wall)")
    if "marginal" in reports and "file_bound" in reports:
        m, f = reports["marginal"], reports["file_bound"]
        if f.aggregate_gbps > 0:
            print(f"# marginal/file_bound aggregate speedup: "
                  f"{m.aggregate_gbps / f.aggregate_gbps:.2f}x")
    return reports


def run_real(args) -> None:
    import numpy as np

    root = os.path.abspath(args.real)
    datadir = os.path.join(root, "data")
    os.makedirs(datadir, exist_ok=True)
    rng = np.random.default_rng(args.seed)

    budget = max(1, min(args.movers, 16))      # smoke mode: local threads
    svc = TransferService(
        os.path.join(root, "state"),
        ServiceConfig(
            mover_budget=budget,
            max_concurrent_tasks=max(1, min(4, args.concurrent, budget)),
            chunk_bytes=256 * 1024,
            batch=BatchConfig(direct_bytes=4 * MiB, batch_files=8),
        ),
    )
    events = []
    svc.subscribe(lambda e: events.append(e))

    all_ids = []
    for k in range(2):
        tenant = f"tenant{k}"
        items = []
        for i in range(6):
            p = os.path.join(datadir, f"{tenant}-small{i}.bin")
            with open(p, "wb") as fh:
                fh.write(rng.integers(0, 256, 300_000 + i, dtype=np.uint8).tobytes())
            items.append((p, p + ".out"))
        big = os.path.join(datadir, f"{tenant}-big.bin")
        with open(big, "wb") as fh:
            fh.write(rng.integers(0, 256, 8 * MiB, dtype=np.uint8).tobytes())
        items.append((big, big + ".out"))
        all_ids += svc.submit(items, tenant=tenant, label="smoke")

    print(f"submitted {len(all_ids)} tasks")
    for st in svc.wait_all(all_ids, timeout=120):
        print(f"  {st.task_id:24s} {st.state:9s} files={st.n_files:2d} "
              f"chunks={st.chunks_done}/{st.chunks_total} "
              f"retries={st.retries} latency={st.latency_s:.2f}s")
    kinds = {}
    for e in events:
        kinds[e.kind] = kinds.get(e.kind, 0) + 1
    print("events:", dict(sorted(kinds.items())))
    svc.close()


def main(argv=None):
    ap = argparse.ArgumentParser(prog="transferd", description=__doc__)
    ap.add_argument("--policy", default="all", choices=POLICIES + ("all",))
    ap.add_argument("--movers", type=int, default=64)
    ap.add_argument("--concurrent", type=int, default=16)
    ap.add_argument("--small", type=int, default=1000, help="# small files")
    ap.add_argument("--small-mb", type=int, default=100)
    ap.add_argument("--large", type=int, default=4, help="# large files")
    ap.add_argument("--large-gb", type=int, default=1000)
    ap.add_argument("--tenants", type=int, default=4)
    ap.add_argument("--chunk-mb", type=int, default=500)
    ap.add_argument("--direct-mb", type=int, default=500, help="direct-route threshold")
    ap.add_argument("--batch-files", type=int, default=64)
    ap.add_argument("--src", default="ALCF", choices=sorted(SITES))
    ap.add_argument("--dst", default="NERSC", choices=sorted(SITES))
    ap.add_argument("--real", default=None, metavar="DIR",
                    help="run a real local service smoke test in DIR instead")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    if args.concurrent > args.movers:
        ap.error(f"--concurrent ({args.concurrent}) must be <= --movers "
                 f"({args.movers}): every active task needs a mover")

    if args.real:
        run_real(args)
        return None
    return run_testbed(args)


if __name__ == "__main__":
    main()
