"""transferd — drive the transfer-task service from the command line.

Service modes:

  * testbed (default): run a mixed multi-tenant workload through the
    service scheduling stack in virtual time against the calibrated
    ALCF->NERSC simulator, and report aggregate Gb/s + task-latency
    percentiles per allocation policy. This answers "which mover-allocation
    policy should the service run?" without a testbed:

        PYTHONPATH=src python -m repro.launch.transferd \\
            --policy all --small 1000 --small-mb 100 --large 4 --large-gb 1000

  * --real DIR: spin a *real* TransferService in DIR, generate a small mixed
    batch of local files, submit them across two tenants, and print each
    task's lifecycle — a smoke test of the wall-clock path:

        PYTHONPATH=src python -m repro.launch.transferd --real /tmp/transferd

Observability modes (``transferd top`` / ``transferd trace``):

  * ``top``    — live terminal snapshot of a draining service: one row per
    task (state, progress, wire-time quantiles, verify lag, faults) plus a
    registry header (active tasks per tenant, movers, aggregate bytes).
    Drives the same local smoke workload as ``--real``:

        ... transferd top --root /tmp/transferd-top

  * ``trace``  — run a workload with the span tracer attached and export a
    Chrome/Perfetto ``trace_event`` JSON (open at https://ui.perfetto.dev):

        ... transferd trace --export /tmp/testbed.trace.json           # virtual
        ... transferd trace --export /tmp/real.trace.json --real DIR   # real

Content-addressed store (``transferd cas <cmd>``, the dedup chunk index):

  * ``cas stats`` — entry/byte counts and hit/miss/stale counters of an
    endpoint's chunk-index log:

        ... transferd cas stats --index /tmp/transferd/state/cas/index.log

  * ``cas gc``    — compact the index log (drop superseded/discarded records
    and the torn tail, atomically rewrite):

        ... transferd cas gc --index /tmp/transferd/state/cas/index.log

Resilience plane (``transferd scrub``, the landed-data repair daemon):

  * ``scrub`` — one budgeted scrub pass over a service root: re-verify landed
    regions against their journal digests, repair bit-rot from replicas via
    the chunk index, quarantine regions with no surviving donor (the cursor
    resumes where the budget ran out, so cron-style invocations round-robin
    the whole fleet):

        ... transferd scrub --root /tmp/transferd/state --budget-mb 256

Fabric modes (``transferd fabric <cmd>``, the multi-endpoint WAN layer):

  * ``fabric plan``      — k-shortest routes between two endpoints:

        ... transferd fabric plan --topology chain --src src --dst d0 -k 3

  * ``fabric campaign``  — virtual-time 1->N replication campaign vs naive
    per-destination transfers (wire bytes + makespan), optionally under a
    chaos scenario:

        ... transferd fabric campaign --topology chain --fanout 4 --gb 100 \\
                --chaos link_outage_at_50pct+degrade_hop

  * ``fabric replicate`` — REAL fan-out campaign on local directories,
    decomposed into service tasks (one per distribution-tree edge):

        ... transferd fabric replicate --root /tmp/fabric --fanout 4 --kb 512

``--topology`` is a built-in shape (``chain`` / ``star`` / ``fat_tree``) or
a JSON topology file (see ``repro.fabric.topology.Topology.save``).
"""
from __future__ import annotations

import argparse
import os
import sys
import time

from repro.core.chunker import MiB
from repro.core.simulator import SITES
from repro.service import (
    BatchConfig,
    ServiceConfig,
    TransferService,
    mixed_workload,
    run_load,
)

POLICIES = ("fair", "file_bound", "marginal")


def _fmt_row(policy: str, rep) -> str:
    return (
        f"{policy:11s} {rep.aggregate_gbps:9.2f} {rep.makespan_s:11.1f} "
        f"{rep.p50_s:9.1f} {rep.p99_s:9.1f} {len(rep.tasks):6d}"
    )


def run_testbed(args) -> dict[str, object]:
    work = mixed_workload(
        n_small=args.small,
        small_bytes=args.small_mb * 1000 * 1000,
        n_large=args.large,
        large_bytes=args.large_gb * 1000 * 1000 * 1000,
        tenants=args.tenants,
    )
    total = sum(sum(s.file_bytes) for s in work)
    chunk_bytes = args.chunk_mb * 1000 * 1000
    if args.tune:
        # SimTuner warm start: replace the static default with the
        # calibrated simulator's predicted-optimal size for the large files
        from repro.tune import SimTuner

        tuner = SimTuner(SITES[args.src], SITES[args.dst])
        chunk_bytes = tuner.seed_chunk(args.large_gb * 1000 * 1000 * 1000)
        print(f"# sim-tuned chunk size: {chunk_bytes / 1e6:.0f} MB "
              f"(static default was {args.chunk_mb} MB)")
    print(f"# workload: {args.small} x {args.small_mb} MB + "
          f"{args.large} x {args.large_gb} GB over {args.tenants} tenants "
          f"({total / 1e12:.2f} TB total)")
    print(f"# budget: {args.movers} movers, {args.concurrent} concurrent tasks, "
          f"{args.src}->{args.dst}, chunk {chunk_bytes / 1e6:.0f} MB")
    print(f"{'policy':11s} {'agg Gb/s':>9s} {'makespan s':>11s} "
          f"{'p50 s':>9s} {'p99 s':>9s} {'tasks':>6s}")
    policies = POLICIES if args.policy == "all" else (args.policy,)
    reports = {}
    for pol in policies:
        t0 = time.perf_counter()
        rep = run_load(
            work,
            policy=pol,
            mover_budget=args.movers,
            max_concurrent=args.concurrent,
            chunk_bytes=chunk_bytes,
            src=SITES[args.src],
            dst=SITES[args.dst],
            batch=BatchConfig(
                direct_bytes=args.direct_mb * 1000 * 1000,
                batch_files=args.batch_files,
            ),
        )
        reports[pol] = rep
        print(_fmt_row(pol, rep) + f"   ({time.perf_counter() - t0:.1f}s wall)")
    if "marginal" in reports and "file_bound" in reports:
        m, f = reports["marginal"], reports["file_bound"]
        if f.aggregate_gbps > 0:
            print(f"# marginal/file_bound aggregate speedup: "
                  f"{m.aggregate_gbps / f.aggregate_gbps:.2f}x")
    return reports


def run_real(args) -> None:
    import numpy as np

    root = os.path.abspath(args.real)
    datadir = os.path.join(root, "data")
    os.makedirs(datadir, exist_ok=True)
    rng = np.random.default_rng(args.seed)

    budget = max(1, min(args.movers, 16))      # smoke mode: local threads
    svc = TransferService(
        os.path.join(root, "state"),
        ServiceConfig(
            mover_budget=budget,
            max_concurrent_tasks=max(1, min(4, args.concurrent, budget)),
            chunk_bytes=256 * 1024,
            batch=BatchConfig(direct_bytes=4 * MiB, batch_files=8),
            # --tune: close the chunk-size loop over every submitted task
            tuning="auto" if args.tune else "static",
            tune_min_chunk=32 * 1024,
            tune_max_chunk=4 * MiB,
        ),
    )
    events = []
    svc.subscribe(lambda e: events.append(e))

    all_ids = []
    for k in range(2):
        tenant = f"tenant{k}"
        items = []
        for i in range(6):
            p = os.path.join(datadir, f"{tenant}-small{i}.bin")
            with open(p, "wb") as fh:
                fh.write(rng.integers(0, 256, 300_000 + i, dtype=np.uint8).tobytes())
            items.append((p, p + ".out"))
        big = os.path.join(datadir, f"{tenant}-big.bin")
        with open(big, "wb") as fh:
            fh.write(rng.integers(0, 256, 8 * MiB, dtype=np.uint8).tobytes())
        items.append((big, big + ".out"))
        all_ids += svc.submit(items, tenant=tenant, label="smoke")

    print(f"submitted {len(all_ids)} tasks")
    for st in svc.wait_all(all_ids, timeout=120):
        tuned = (f" replans={st.replans} chunk={st.chunk_bytes_current}"
                 if st.tuning == "auto" else "")
        print(f"  {st.task_id:24s} {st.state:9s} files={st.n_files:2d} "
              f"chunks={st.chunks_done}/{st.chunks_total} "
              f"retries={st.retries} latency={st.latency_s:.2f}s{tuned}")
    kinds = {}
    for e in events:
        kinds[e.kind] = kinds.get(e.kind, 0) + 1
    print("events:", dict(sorted(kinds.items())))
    svc.close()


# ---------------------------------------------------------------------------
# observability subcommands
# ---------------------------------------------------------------------------
def _smoke_ids(svc, datadir, seed, *, tenants=2, n_small=4,
               small_kb=200, big_kb=2048):
    """Generate and submit a small mixed local workload; returns task ids."""
    import numpy as np

    rng = np.random.default_rng(seed)
    ids = []
    for k in range(tenants):
        tenant = f"tenant{k}"
        items = []
        for i in range(n_small):
            p = os.path.join(datadir, f"{tenant}-small{i}.bin")
            with open(p, "wb") as fh:
                fh.write(rng.integers(
                    0, 256, small_kb * 1024 + i, dtype=np.uint8).tobytes())
            items.append((p, p + ".out"))
        big = os.path.join(datadir, f"{tenant}-big.bin")
        with open(big, "wb") as fh:
            fh.write(rng.integers(0, 256, big_kb * 1024, dtype=np.uint8).tobytes())
        items.append((big, big + ".out"))
        ids += svc.submit(items, tenant=tenant, label="smoke")
    return ids


def render_top(svc) -> str:
    """One ``transferd top`` frame: registry header + per-task metric rows."""
    from repro.obs.metrics import REGISTRY

    snap = REGISTRY.snapshot()
    active = snap.get("service_active_tasks", {"series": {}})["series"]
    act = ", ".join(
        f"{k or 'default'}={int(v)}" for k, v in sorted(active.items())
    ) or "-"
    rows = [
        f"tenants active: {act}",
        f"{'task':26s} {'state':9s} {'prog':>5s} {'chunks':>9s} "
        f"{'wire p50/p99 ms':>16s} {'vlag p99 ms':>11s} {'faults':>6s} {'retries':>7s}",
    ]
    for st in sorted(svc.tasks(), key=lambda s: s.task_id):
        m = st.metrics or {}
        faults = sum((m.get("faults") or {}).values())
        chunks = f"{st.chunks_done}/{st.chunks_total}"
        rows.append(
            f"{st.task_id:26s} {st.state:9s} {st.progress * 100:4.0f}% "
            f"{chunks:>9s} "
            f"{m.get('wire_p50_s', 0.0) * 1e3:7.2f}/"
            f"{m.get('wire_p99_s', 0.0) * 1e3:<8.2f} "
            f"{m.get('verify_lag_p99_s', 0.0) * 1e3:11.2f} "
            f"{faults:6.0f} {st.retries:7d}"
        )
    return "\n".join(rows)


def top_main(argv) -> None:
    ap = argparse.ArgumentParser(
        prog="transferd top",
        description="live snapshot of a draining local service")
    ap.add_argument("--root", required=True, help="working directory")
    ap.add_argument("--interval", type=float, default=0.25)
    ap.add_argument("--frames", type=int, default=0,
                    help="stop after N frames (0 = until all tasks drain)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    root = os.path.abspath(args.root)
    datadir = os.path.join(root, "data")
    os.makedirs(datadir, exist_ok=True)
    svc = TransferService(os.path.join(root, "state"), ServiceConfig(
        mover_budget=4, max_concurrent_tasks=2, chunk_bytes=128 * 1024,
        batch=BatchConfig(direct_bytes=1 << 30, batch_files=8),
    ))
    try:
        ids = _smoke_ids(svc, datadir, args.seed)
        frames = 0
        while True:
            print(f"--- transferd top · frame {frames} ---")
            print(render_top(svc))
            frames += 1
            if all(svc.status(i).done for i in ids):
                break
            if args.frames and frames >= args.frames:
                break
            time.sleep(args.interval)
    finally:
        svc.close()


def trace_main(argv) -> None:
    from repro.obs.clock import Clock
    from repro.obs.trace import Tracer

    ap = argparse.ArgumentParser(
        prog="transferd trace",
        description="run a workload under the span tracer and export a "
                    "Chrome/Perfetto trace_event JSON")
    ap.add_argument("--export", required=True, metavar="FILE")
    ap.add_argument("--real", default=None, metavar="DIR",
                    help="trace a real local smoke run instead of the "
                         "virtual testbed (which is deterministic per seed)")
    ap.add_argument("--small", type=int, default=40, help="# small testbed files")
    ap.add_argument("--large", type=int, default=2, help="# large testbed files")
    ap.add_argument("--chaos", nargs="?", default=None,
                    const="corrupt_1_per_TiB+kill_2_movers+outage_at_50pct",
                    help="scenario DSL for the testbed (bare --chaos uses "
                         "the standard compound scenario)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    if args.real:
        root = os.path.abspath(args.real)
        datadir = os.path.join(root, "data")
        os.makedirs(datadir, exist_ok=True)
        svc = TransferService(os.path.join(root, "state"), ServiceConfig(
            mover_budget=4, max_concurrent_tasks=2, chunk_bytes=128 * 1024,
            batch=BatchConfig(direct_bytes=1 << 30, batch_files=8),
        ))
        try:
            svc.wait_all(_smoke_ids(svc, datadir, args.seed), timeout=300)
            tracer = svc.tracer
        finally:
            svc.close()
    else:
        from repro.faults import parse_scenario

        tracer = Tracer(clock=Clock(lambda: 0.0, virtual=True))
        run_load(
            mixed_workload(n_small=args.small, n_large=args.large),
            scenario=parse_scenario(args.chaos) if args.chaos else None,
            seed=args.seed, tracer=tracer,
        )
    path = tracer.export(args.export)
    print(f"exported {len(tracer.spans())} spans "
          f"({len(tracer.tasks())} tasks) -> {path}")


# ---------------------------------------------------------------------------
# fabric subcommands
# ---------------------------------------------------------------------------
def _load_topology(spec: str, fanout: int):
    from repro.fabric import BUILTIN_TOPOLOGIES, Topology

    if spec in BUILTIN_TOPOLOGIES:
        return BUILTIN_TOPOLOGIES[spec](fanout)
    if os.path.exists(spec):
        return Topology.load(spec)
    raise SystemExit(
        f"unknown topology {spec!r}: not a builtin "
        f"({sorted(BUILTIN_TOPOLOGIES)}) and no such file")


def fabric_plan(args) -> None:
    from repro.fabric import RoutePlanner

    topo = _load_topology(args.topology, args.fanout)
    planner = RoutePlanner(topo)
    nbytes = int(args.gb * 1e9)
    routes = planner.k_shortest(args.src, args.dst, nbytes, args.k)
    print(f"# {args.src} -> {args.dst}, {args.gb} GB, k={args.k}")
    for i, r in enumerate(routes):
        print(f"{i}: {' -> '.join(r.nodes)}   ({r.seconds:.2f}s est, "
              f"{r.n_hops} hops)")


def fabric_campaign(args) -> None:
    from repro.fabric import (
        RoutePlanner,
        build_distribution_tree,
        naive_wire_hops,
        simulate_campaign,
        simulate_naive,
    )
    from repro.faults import parse_scenario

    topo = _load_topology(args.topology, args.fanout)
    planner = RoutePlanner(topo)
    nbytes = int(args.gb * 1e9)
    dests = args.dests or [f"d{i}" for i in range(args.fanout)]
    tree = build_distribution_tree(planner, args.src, dests, nbytes)
    scenario = parse_scenario(args.chaos) if args.chaos else None
    camp = simulate_campaign(topo, tree, nbytes, scenario=scenario, seed=args.seed)
    naive = simulate_naive(topo, args.src, dests, nbytes,
                           scenario=scenario, seed=args.seed)
    hops = naive_wire_hops(RoutePlanner(topo), args.src, dests, nbytes)
    print(f"# campaign {args.src} -> {dests} ({args.gb} GB each, "
          f"scenario={camp.scenario})")
    print("tree:")
    for u, v in tree.edges:
        print(f"  {u} -> {v}")
    print(f"{'':14s}{'wire GB':>10s}{'makespan s':>12s}{'agg Gb/s':>10s}")
    for name, rep in (("campaign", camp), ("naive", naive)):
        print(f"{name:14s}{rep.wire_bytes / 1e9:10.1f}{rep.makespan_s:12.1f}"
              f"{rep.aggregate_gbps:10.1f}")
    print(f"# wire reduction: {hops * nbytes / tree.wire_bytes(nbytes):.2f}x, "
          f"makespan speedup: "
          f"{naive.makespan_s / camp.makespan_s if camp.makespan_s else 1.0:.2f}x")
    if camp.victims:
        print(f"# fault victims: {camp.victims}")


def fabric_replicate(args) -> None:
    import numpy as np

    from repro.fabric import CampaignRunner

    topo = _load_topology(args.topology, args.fanout)
    root = os.path.abspath(args.root)
    dirs = {}
    for name in topo.endpoints:
        dirs[name] = os.path.join(root, name)
        os.makedirs(dirs[name], exist_ok=True)
    nbytes = args.kb * 1024
    src_file = os.path.join(dirs[args.src], "replica.bin")
    with open(src_file, "wb") as fh:
        fh.write(np.random.default_rng(args.seed)
                 .integers(0, 256, nbytes, dtype=np.uint8).tobytes())
    dests = args.dests or [f"d{i}" for i in range(args.fanout)]
    svc = TransferService(os.path.join(root, "svc"), ServiceConfig(
        mover_budget=4, max_concurrent_tasks=4, chunk_bytes=64 * 1024,
        tick_s=0.002, batch=BatchConfig(direct_bytes=1 << 30, batch_files=64),
    ))
    try:
        rep = CampaignRunner(svc, topo, dirs).replicate(
            "replica.bin", args.src, dests, tenant=args.tenant, timeout=300)
    finally:
        svc.close()
    print(f"campaign {rep.state}: {rep.replicas_verified}/{len(dests)} replicas "
          f"verified, {rep.integrity_escapes} escapes")
    for (u, v), tid in rep.edge_tasks.items():
        print(f"  {u} -> {v}: {tid} {rep.edge_states.get((u, v), '?')}")
    print(f"wire bytes {rep.wire_bytes} vs naive {rep.naive_wire_bytes} "
          f"({rep.wire_reduction:.2f}x), {rep.seconds:.2f}s")
    if rep.state != "SUCCEEDED":
        raise SystemExit(1)


# ---------------------------------------------------------------------------
# content-addressed store subcommands
# ---------------------------------------------------------------------------
def cas_stats(args) -> None:
    from repro.cas import ChunkIndex

    with ChunkIndex(args.index) as idx:
        s = idx.stats()
        print(f"# chunk index {os.path.abspath(args.index)}")
        print(f"digests        {s['digests']}")
        print(f"locations      {s['locations']}")
        print(f"indexed bytes  {s['indexed_bytes']}")
        print(f"log bytes      {s['log_bytes']}")
        print(f"hits / misses  {int(s['hits'])} / {int(s['misses'])}")
        print(f"stale entries  {int(s['stale'])}")


def cas_gc(args) -> None:
    from repro.cas import ChunkIndex

    with ChunkIndex(args.index) as idx:
        rep = idx.compact()
    saved = rep["bytes_before"] - rep["bytes_after"]
    print(f"compacted {os.path.abspath(args.index)}: "
          f"{rep['records']} live records, "
          f"{rep['bytes_before']} -> {rep['bytes_after']} bytes "
          f"({saved} reclaimed)")


def cas_main(argv) -> None:
    ap = argparse.ArgumentParser(prog="transferd cas",
                                 description="content-addressed chunk store")
    sub = ap.add_subparsers(dest="cmd", required=True)

    p = sub.add_parser("stats", help="entry counts + hit/miss/stale counters")
    p.add_argument("--index", required=True, help="chunk-index log path")
    p.set_defaults(fn=cas_stats)

    p = sub.add_parser("gc", help="compact the index log")
    p.add_argument("--index", required=True, help="chunk-index log path")
    p.set_defaults(fn=cas_gc)

    args = ap.parse_args(argv)
    args.fn(args)


def scrub_main(argv) -> None:
    ap = argparse.ArgumentParser(
        prog="transferd scrub",
        description="re-verify landed regions against their journal digests "
                    "and repair bit-rot from replicas via the chunk index")
    ap.add_argument("--root", required=True, help="service state directory")
    ap.add_argument("--task", default=None,
                    help="scrub one task id (default: every SUCCEEDED task)")
    ap.add_argument("--budget-mb", type=float, default=None,
                    help="max MiB re-read this pass (the cursor resumes "
                         "where the budget ran out)")
    ap.add_argument("--no-repair", action="store_true",
                    help="detect and quarantine only, never rewrite")
    args = ap.parse_args(argv)
    from repro.service import TransferService

    svc = TransferService(args.root)
    try:
        budget = (None if args.budget_mb is None
                  else int(args.budget_mb * 1024 * 1024))
        rep = svc.scrub(args.task, budget_bytes=budget,
                        repair=not args.no_repair)
    finally:
        svc.close()
    print(f"scanned    {rep.scanned} regions / {rep.scanned_bytes} bytes "
          f"({rep.clean} clean, {rep.remaining} past budget)")
    print(f"rot        {rep.rot_detected} detected, {rep.repaired} repaired, "
          f"{rep.quarantined} quarantined")
    for t in rep.quarantines:
        print(f"QUARANTINE {t.task_id} item {t.item} chunk {t.chunk} "
              f"@ {t.path}+{t.offset}")
    if rep.quarantined:
        sys.exit(1)


def fabric_main(argv) -> None:
    ap = argparse.ArgumentParser(prog="transferd fabric",
                                 description="multi-endpoint WAN fabric tools")
    sub = ap.add_subparsers(dest="cmd", required=True)

    def common(p, *, real=False):
        p.add_argument("--topology", default="chain",
                       help="chain | star | fat_tree | topology JSON file")
        p.add_argument("--fanout", type=int, default=4)
        p.add_argument("--src", default="src")
        p.add_argument("--dests", nargs="*", default=None)
        p.add_argument("--seed", type=int, default=0)
        if not real:
            p.add_argument("--gb", type=float, default=100.0,
                           help="payload size per replica (GB)")

    p = sub.add_parser("plan", help="k-shortest routes between two endpoints")
    common(p)
    p.add_argument("--dst", default="d0")
    p.add_argument("-k", type=int, default=3)
    p.set_defaults(fn=fabric_plan)

    p = sub.add_parser("campaign", help="virtual 1->N campaign vs naive")
    common(p)
    p.add_argument("--chaos", default=None,
                   help="scenario DSL, e.g. link_outage_at_50pct+degrade_hop")
    p.set_defaults(fn=fabric_campaign)

    p = sub.add_parser("replicate", help="real fan-out campaign on local dirs")
    common(p, real=True)
    p.add_argument("--root", required=True, help="working directory")
    p.add_argument("--kb", type=int, default=512, help="payload size (KiB)")
    p.add_argument("--tenant", default="default")
    p.set_defaults(fn=fabric_replicate)

    args = ap.parse_args(argv)
    args.fn(args)


def main(argv=None):
    if argv is None:
        argv = sys.argv[1:]
    if argv and argv[0] == "fabric":
        fabric_main(argv[1:])
        return None
    if argv and argv[0] == "cas":
        cas_main(argv[1:])
        return None
    if argv and argv[0] == "scrub":
        scrub_main(argv[1:])
        return None
    if argv and argv[0] == "top":
        top_main(argv[1:])
        return None
    if argv and argv[0] == "trace":
        trace_main(argv[1:])
        return None
    ap = argparse.ArgumentParser(prog="transferd", description=__doc__)
    ap.add_argument("--policy", default="all", choices=POLICIES + ("all",))
    ap.add_argument("--movers", type=int, default=64)
    ap.add_argument("--concurrent", type=int, default=16)
    ap.add_argument("--small", type=int, default=1000, help="# small files")
    ap.add_argument("--small-mb", type=int, default=100)
    ap.add_argument("--large", type=int, default=4, help="# large files")
    ap.add_argument("--large-gb", type=int, default=1000)
    ap.add_argument("--tenants", type=int, default=4)
    ap.add_argument("--chunk-mb", type=int, default=500)
    ap.add_argument("--direct-mb", type=int, default=500, help="direct-route threshold")
    ap.add_argument("--batch-files", type=int, default=64)
    ap.add_argument("--src", default="ALCF", choices=sorted(SITES))
    ap.add_argument("--dst", default="NERSC", choices=sorted(SITES))
    ap.add_argument("--real", default=None, metavar="DIR",
                    help="run a real local service smoke test in DIR instead")
    ap.add_argument("--tune", action="store_true",
                    help="autotune chunk sizes: SimTuner-seeded chunks in "
                         "testbed mode, closed-loop tuning in --real mode")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    if args.concurrent > args.movers:
        ap.error(f"--concurrent ({args.concurrent}) must be <= --movers "
                 f"({args.movers}): every active task needs a mover")

    if args.real:
        run_real(args)
        return None
    return run_testbed(args)


if __name__ == "__main__":
    main()
