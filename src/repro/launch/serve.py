"""Batched greedy serving driver (prefill via decode loop + token generation).

Demonstrates the decode path end-to-end on CPU with reduced configs:
  PYTHONPATH=src python -m repro.launch.serve --arch gemma2-2b --smoke \
      --batch 4 --prompt-len 12 --gen 16
"""
from __future__ import annotations

import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.registry import build_model
from repro.launch.train import parse_mesh


def generate(model, params, prompts: jax.Array, gen: int, max_len: int):
    """Greedy decode: feed prompt tokens, then sample `gen` new ones."""
    B, Lp = prompts.shape
    cache = model.init_cache(B, max_len)
    if model.cfg.family == "encdec":
        raise NotImplementedError("use prefill_cross + decode for enc-dec")
    step = jax.jit(model.decode_step)

    tok = prompts[:, :1]
    out = [tok]
    for t in range(Lp + gen - 1):
        logits, cache = step(params, cache, tok, jnp.full((B,), t, jnp.int32))
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        tok = prompts[:, t + 1 : t + 2] if t + 1 < Lp else nxt
        out.append(tok)
    return jnp.concatenate(out, axis=1)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--mesh", default="1x1")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    mesh = parse_mesh(args.mesh)
    model = build_model(args.arch, mesh if mesh.size > 1 else None, smoke=args.smoke)
    with mesh:
        params = model.init_params(args.seed)
        prompts = jax.random.randint(
            jax.random.PRNGKey(args.seed), (args.batch, args.prompt_len),
            0, model.cfg.vocab)
        t0 = time.perf_counter()
        seqs = generate(model, params, prompts, args.gen, args.prompt_len + args.gen)
        dt = time.perf_counter() - t0
        n_new = args.batch * args.gen
        print(f"generated {n_new} tokens in {dt:.2f}s "
              f"({n_new/dt:.1f} tok/s incl. prefill+compile)")
        print("sample:", np.asarray(seqs[0]).tolist())
    return np.asarray(seqs)


if __name__ == "__main__":
    main()
