"""Production mesh construction.

Single pod = a 16x16 TPU v5e pod slice (256 chips); multi-pod adds a leading
"pod" axis over DCN. Defined as functions so importing this module never
touches jax device state (the dry-run must set XLA_FLAGS before first init).
"""
from __future__ import annotations

import math

import jax

from repro.distributed.mesh import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = math.prod(shape)
    devices = jax.devices()[:n]
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devices)} — "
            "the dry-run must set XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            "before any jax import"
        )
    return make_mesh(shape, axes, devices=devices)


def make_host_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh over however many (fake) devices exist — tests/examples."""
    n = math.prod(shape)
    devices = jax.devices()[:n]
    if len(devices) < n:
        raise RuntimeError(f"need {n} devices, have {len(devices)}")
    return make_mesh(shape, axes, devices=devices)
